"""L1 kernel correctness: Pallas (interpret=True) vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; shapes are drawn from a small lattice
of block multiples (the kernels' documented contract — callers pad), which
also keeps the jit cache bounded.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec, block_dot, axpy, fused_project
from compile.kernels import ref

BLOCK = 8  # small block for shape diversity; DEFAULT_BLOCK=128 covered below
SIZES = st.sampled_from([8, 16, 24, 32, 40])
SEEDS = st.integers(min_value=0, max_value=2**32 - 1)


def _arr(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# matvec
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(m=SIZES, n=SIZES, seed=SEEDS)
def test_matvec_matches_ref(m, n, seed):
    rng = np.random.default_rng(seed)
    mat = _arr(rng, (m, n))
    x = _arr(rng, (n, 1))
    got = matvec(mat, x, block=BLOCK)
    want = ref.ref_matvec(mat, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matvec_default_block_128():
    rng = np.random.default_rng(7)
    mat = _arr(rng, (256, 128))
    x = _arr(rng, (128, 1))
    np.testing.assert_allclose(matvec(mat, x), ref.ref_matvec(mat, x), rtol=1e-4, atol=1e-4)


def test_matvec_identity():
    n = 16
    mat = jnp.eye(n, dtype=jnp.float32)
    x = jnp.arange(n, dtype=jnp.float32).reshape(n, 1)
    np.testing.assert_allclose(matvec(mat, x, block=BLOCK), x)


def test_matvec_rejects_unpadded():
    mat = jnp.zeros((12, 16), jnp.float32)
    x = jnp.zeros((16, 1), jnp.float32)
    with pytest.raises(ValueError):
        matvec(mat, x, block=BLOCK)


def test_matvec_rejects_bad_vector_shape():
    mat = jnp.zeros((16, 16), jnp.float32)
    with pytest.raises(ValueError):
        matvec(mat, jnp.zeros((16,), jnp.float32), block=BLOCK)


def test_matvec_bf16():
    rng = np.random.default_rng(3)
    mat = jnp.asarray(rng.standard_normal((16, 16)), jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((16, 1)), jnp.bfloat16)
    got = matvec(mat, x, block=BLOCK).astype(jnp.float32)
    want = (mat.astype(jnp.float32) @ x.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# block_dot
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=SEEDS)
def test_block_dot_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n, 1))
    y = _arr(rng, (n, 1))
    got = block_dot(x, y, block=BLOCK)
    want = ref.ref_block_dot(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_block_dot_orthogonal():
    x = jnp.zeros((16, 1), jnp.float32).at[0, 0].set(1.0)
    y = jnp.zeros((16, 1), jnp.float32).at[1, 0].set(1.0)
    assert float(block_dot(x, y, block=BLOCK)[0, 0]) == 0.0


def test_block_dot_self_is_norm_sq():
    rng = np.random.default_rng(11)
    x = _arr(rng, (32, 1))
    got = float(block_dot(x, x, block=BLOCK)[0, 0])
    assert got == pytest.approx(float(jnp.sum(x * x)), rel=1e-5)


def test_block_dot_shape_mismatch():
    with pytest.raises(ValueError):
        block_dot(jnp.zeros((16, 1), jnp.float32), jnp.zeros((16, 2), jnp.float32), block=BLOCK)


# ---------------------------------------------------------------------------
# axpy
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=SEEDS, a=st.floats(min_value=-10, max_value=10, allow_nan=False))
def test_axpy_matches_ref(n, seed, a):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (n, 1))
    y = _arr(rng, (n, 1))
    aa = jnp.full((1, 1), a, jnp.float32)
    got = axpy(aa, x, y, block=BLOCK)
    want = ref.ref_axpy(aa, x, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_axpy_zero_scalar_is_identity():
    rng = np.random.default_rng(5)
    x = _arr(rng, (24, 1))
    y = _arr(rng, (24, 1))
    zero = jnp.zeros((1, 1), jnp.float32)
    np.testing.assert_allclose(axpy(zero, x, y, block=BLOCK), y)


def test_axpy_scalar_shape_checked():
    x = jnp.zeros((16, 1), jnp.float32)
    with pytest.raises(ValueError):
        axpy(jnp.zeros((2, 1), jnp.float32), x, x, block=BLOCK)


# ---------------------------------------------------------------------------
# fused_project
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=SEEDS)
def test_fused_project_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    b = _arr(rng, (n, n))
    r = _arr(rng, (n, 1))
    k = int(rng.integers(0, n))
    onehot = jnp.zeros((n, 1), jnp.float32).at[k, 0].set(1.0)
    col, num = fused_project(b, onehot, r, block=BLOCK)
    wcol, wnum = ref.ref_fused_project(b, onehot, r)
    np.testing.assert_allclose(col, wcol, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(num, wnum, rtol=1e-3, atol=1e-3)


def test_fused_project_extracts_column():
    rng = np.random.default_rng(13)
    n = 16
    b = _arr(rng, (n, n))
    r = jnp.zeros((n, 1), jnp.float32)
    for k in (0, 7, n - 1):
        onehot = jnp.zeros((n, 1), jnp.float32).at[k, 0].set(1.0)
        col, num = fused_project(b, onehot, r, block=BLOCK)
        np.testing.assert_allclose(col[:, 0], b[:, k], rtol=1e-5)
        assert float(num[0, 0]) == 0.0


def test_fused_project_rectangular():
    rng = np.random.default_rng(17)
    b = _arr(rng, (24, 16))
    r = _arr(rng, (24, 1))
    onehot = jnp.zeros((16, 1), jnp.float32).at[3, 0].set(1.0)
    col, num = fused_project(b, onehot, r, block=BLOCK)
    wcol, wnum = ref.ref_fused_project(b, onehot, r)
    np.testing.assert_allclose(col, wcol, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(num, wnum, rtol=1e-3, atol=1e-3)
