"""AOT pipeline checks: HLO text is produced, is parseable-looking, and the
manifest agrees with what was lowered. The authoritative load-and-execute
check lives on the Rust side (rust/tests/runtime_e2e.rs)."""
import json
import os
import subprocess
import sys

import pytest

from compile import aot


def test_lower_mp_chunk_text():
    text = aot.lower_mp_chunk(128, 8)
    assert "ENTRY" in text
    assert "f32[128,128]" in text  # padded B operand
    assert "s32[8]" in text  # activation sequence


def test_lower_jacobi_chunk_text():
    text = aot.lower_jacobi_chunk(128, 4)
    assert "ENTRY" in text
    assert "f32[128,128]" in text


def test_lower_size_chunk_text():
    text = aot.lower_size_chunk(128, 8)
    assert "ENTRY" in text


def test_lower_residual_norm_text():
    text = aot.lower_residual_norm(128)
    assert "ENTRY" in text
    assert "f32[1,1]" in text  # the norm output


def test_manifest_entry_shapes():
    e = aot.build_manifest_entry("mp_chunk", 128, 16, "x.hlo.txt")
    names = [o["name"] for o in e["operands"]]
    assert names == ["b_pad", "bnorm2", "x", "r", "ks"]
    assert e["operands"][0]["shape"] == [128, 128]
    assert e["operands"][4]["shape"] == [16]
    assert e["operands"][4]["dtype"] == "i32"
    assert [r["name"] for r in e["results"]] == ["x", "r", "trace"]


def test_manifest_entry_rejects_unknown():
    with pytest.raises(ValueError):
        aot.build_manifest_entry("nope", 128, 1, "x")


def test_cli_end_to_end(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--sizes", "128", "--chunk", "4", "--jacobi-chunk", "2"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest["artifacts"]) == 4
    for entry in manifest["artifacts"]:
        path = out / entry["file"]
        assert path.exists()
        assert "ENTRY" in path.read_text()


def test_cli_rejects_unaligned_size(tmp_path):
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--sizes", "100"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True, timeout=120,
    )
    assert res.returncode != 0
