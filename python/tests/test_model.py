"""L2 model correctness: chunked scan graphs vs the oracle, padding
inertness, and the paper's structural invariants (conservation eq. 11,
exponential decay Prop. 2, exact solve Prop. 1)."""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

ALPHA = 0.85


def er_threshold_graph(n, p, seed):
    """The paper §III graph model: iid U[0,1] entries thresholded at p,
    diagonal cleared, dangling columns repaired by linking to all."""
    rng = np.random.default_rng(seed)
    adj = (rng.random((n, n)) > p).astype(np.float32)
    np.fill_diagonal(adj, 0.0)
    dangling = adj.sum(axis=0) == 0
    adj[:, dangling] = 1.0
    adj[np.diag_indices(n)] = 0.0
    return adj


def hyperlink(adj):
    return jnp.asarray(adj / adj.sum(axis=0, keepdims=True))


def setup(n=100, p=128, seed=0):
    a = hyperlink(er_threshold_graph(n, 0.5, seed))
    a_pad = model.pad_hyperlink(a, p)
    b_pad = model.build_b(a_pad, ALPHA)
    bn2 = model.column_norms_sq(b_pad)
    y = model.pad_vector((1 - ALPHA) * jnp.ones(n, jnp.float32), p)
    return a, a_pad, b_pad, bn2, y


# ---------------------------------------------------------------------------
# padding rules
# ---------------------------------------------------------------------------


def test_pad_hyperlink_is_column_stochastic():
    a, a_pad, *_ = setup()[0], *setup()[1:]
    cols = np.asarray(jnp.sum(setup()[1], axis=0))
    np.testing.assert_allclose(cols, np.ones_like(cols), rtol=1e-5)


def test_pad_hyperlink_identity_block():
    _, a_pad, *_ = setup(n=100, p=128)
    blk = np.asarray(a_pad)[100:, 100:]
    np.testing.assert_allclose(blk, np.eye(28, dtype=np.float32))
    assert np.all(np.asarray(a_pad)[100:, :100] == 0)
    assert np.all(np.asarray(a_pad)[:100, 100:] == 0)


def test_pad_vector_zero_tail():
    v = jnp.arange(5, dtype=jnp.float32)
    out = model.pad_vector(v, 8)
    assert out.shape == (8, 1)
    np.testing.assert_allclose(out[:5, 0], v)
    assert np.all(np.asarray(out)[5:] == 0)


def test_pad_size():
    assert model.pad_size(100, 128) == 128
    assert model.pad_size(128, 128) == 128
    assert model.pad_size(129, 128) == 256


def test_pad_rejects_shrink():
    with pytest.raises(ValueError):
        model.pad_hyperlink(jnp.eye(8, dtype=jnp.float32), 4)


def test_padded_b_column_norms():
    # B_pad = blockdiag(B, (1-alpha) I): padded column norms = (1-alpha)^2
    _, _, b_pad, bn2, _ = setup(n=100, p=128)
    tail = np.asarray(bn2)[100:, 0]
    np.testing.assert_allclose(tail, (1 - ALPHA) ** 2 * np.ones(28), rtol=1e-5)


# ---------------------------------------------------------------------------
# mp_chunk
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_mp_chunk_matches_ref(seed):
    n, p = 40, 48
    a = hyperlink(er_threshold_graph(n, 0.5, seed))
    a_pad = model.pad_hyperlink(a, p)
    b_pad = model.build_b(a_pad, ALPHA)
    bn2 = model.column_norms_sq(b_pad)
    y = model.pad_vector((1 - ALPHA) * jnp.ones(n, jnp.float32), p)
    rng = np.random.default_rng(seed + 1)
    ks = jnp.asarray(rng.integers(0, n, size=24), jnp.int32)

    run = jax.jit(functools.partial(model.mp_chunk, block=8))
    x_t, r_t, trace = run(b_pad, bn2, jnp.zeros((p, 1), jnp.float32), y, ks)

    b = np.asarray(b_pad)[:n, :n]
    xr, rr, trr = ref.ref_mp_chunk(
        jnp.asarray(b), jnp.sum(b * b, axis=0), jnp.zeros(n), (1 - ALPHA) * jnp.ones(n),
        np.asarray(ks),
    )
    np.testing.assert_allclose(np.asarray(x_t)[:n, 0], xr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(r_t)[:n, 0], rr, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(trace)[:, 0], trr, rtol=1e-3, atol=1e-4)


def test_mp_chunk_padding_inert():
    _, _, b_pad, bn2, y = setup(n=100, p=128)
    rng = np.random.default_rng(2)
    ks = jnp.asarray(rng.integers(0, 100, size=64), jnp.int32)
    x_t, r_t, _ = jax.jit(model.mp_chunk)(b_pad, bn2, jnp.zeros((128, 1), jnp.float32), y, ks)
    assert np.abs(np.asarray(x_t)[100:]).max() == 0.0
    assert np.abs(np.asarray(r_t)[100:]).max() == 0.0


def test_mp_chunk_conservation():
    # eq. 11: B x_t + r_t = y for all t
    _, _, b_pad, bn2, y = setup(n=100, p=128)
    rng = np.random.default_rng(3)
    ks = jnp.asarray(rng.integers(0, 100, size=128), jnp.int32)
    x_t, r_t, _ = jax.jit(model.mp_chunk)(b_pad, bn2, jnp.zeros((128, 1), jnp.float32), y, ks)
    lhs = np.asarray(b_pad) @ np.asarray(x_t) + np.asarray(r_t)
    np.testing.assert_allclose(lhs, np.asarray(y), atol=2e-5)


def test_mp_chunk_residual_decreases():
    # ||r|| is non-increasing pathwise (each step is an orthogonal projection)
    _, _, b_pad, bn2, y = setup(n=100, p=128)
    rng = np.random.default_rng(4)
    ks = jnp.asarray(rng.integers(0, 100, size=128), jnp.int32)
    _, _, trace = jax.jit(model.mp_chunk)(b_pad, bn2, jnp.zeros((128, 1), jnp.float32), y, ks)
    tr = np.asarray(trace)[:, 0]
    assert np.all(tr[1:] <= tr[:-1] + 1e-6)
    # per-step contraction is 1 - sigma^2(Bhat)/N ~ 0.9998 at N=100, so 128
    # steps shave a few percent — check a strict decrease, not a collapse
    assert tr[-1] < 0.98 * tr[0]


def test_mp_chunk_converges_to_exact():
    # Small N so the contraction 1 - sigma^2(Bhat)/N bites within a few
    # thousand steps (at N=100 one decade of ||r||^2 costs ~10k steps).
    n = p = 16
    a = hyperlink(er_threshold_graph(n, 0.5, 5))
    b_pad = model.build_b(model.pad_hyperlink(a, p), ALPHA)
    bn2 = model.column_norms_sq(b_pad)
    y = model.pad_vector((1 - ALPHA) * jnp.ones(n, jnp.float32), p)
    x_star = ref.ref_pagerank_exact(a.astype(jnp.float64), 0.85)
    rng = np.random.default_rng(5)
    x = jnp.zeros((p, 1), jnp.float32)
    r = y
    run = jax.jit(functools.partial(model.mp_chunk, block=8))
    for _ in range(64):  # 8192 steps
        ks = jnp.asarray(rng.integers(0, n, size=128), jnp.int32)
        x, r, _ = run(b_pad, bn2, x, r, ks)
    err = np.abs(np.asarray(x)[:n, 0] - np.asarray(x_star)).max()
    assert err < 0.02, err


# ---------------------------------------------------------------------------
# jacobi_chunk
# ---------------------------------------------------------------------------


def test_jacobi_chunk_matches_ref():
    _, a_pad, b_pad, _, y = setup(n=100, p=128)
    x = jnp.zeros((128, 1), jnp.float32)
    alpha = jnp.full((1, 1), ALPHA, jnp.float32)
    got = jax.jit(model.jacobi_chunk, static_argnames="t")(a_pad, x, y, alpha, t=16)
    want = ref.ref_jacobi_chunk(a_pad, x, y, ALPHA, 16)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_jacobi_converges_to_exact():
    a, a_pad, _, _, y = setup(n=100, p=128)
    x_star = ref.ref_pagerank_exact(a.astype(jnp.float64), 0.85)
    x = jnp.zeros((128, 1), jnp.float32)
    alpha = jnp.full((1, 1), ALPHA, jnp.float32)
    step = jax.jit(model.jacobi_chunk, static_argnames="t")
    for _ in range(8):
        x = step(a_pad, x, y, alpha, t=16)  # 128 total iters, rate alpha
    err = np.abs(np.asarray(x)[:100, 0] - np.asarray(x_star)).max()
    assert err < 1e-4, err


def test_jacobi_padding_inert():
    _, a_pad, _, _, y = setup(n=100, p=128)
    x = jnp.zeros((128, 1), jnp.float32)
    alpha = jnp.full((1, 1), ALPHA, jnp.float32)
    out = jax.jit(model.jacobi_chunk, static_argnames="t")(a_pad, x, y, alpha, t=16)
    assert np.abs(np.asarray(out)[100:]).max() == 0.0


# ---------------------------------------------------------------------------
# size_chunk (Algorithm 2)
# ---------------------------------------------------------------------------


def _size_setup(n=60, p=64, seed=9):
    a = hyperlink(er_threshold_graph(n, 0.5, seed))
    a_pad = model.pad_hyperlink(a, p)
    ct_pad = jnp.eye(p, dtype=jnp.float32) - a_pad  # C^T = I - A
    cn2 = jnp.sum(ct_pad * ct_pad, axis=0).reshape(p, 1)
    # padded rows of C are zero-norm-free: pad columns of C^T are 0 vectors!
    # C^T pad block = I - I = 0 -> guard: set pad norms to 1 so division is
    # safe; ks never selects them.
    cn2 = cn2.at[n:].set(1.0)
    target = model.pad_vector(jnp.ones(n, jnp.float32) / n, p)
    s0 = model.pad_vector(jnp.zeros(n, jnp.float32).at[0].set(1.0), p)
    return a, ct_pad, cn2, target, s0


def test_size_chunk_matches_ref():
    n, p = 60, 64
    a, ct_pad, cn2, target, s0 = _size_setup(n, p)
    rng = np.random.default_rng(10)
    ks = jnp.asarray(rng.integers(0, n, size=32), jnp.int32)
    s_t, trace = jax.jit(functools.partial(model.size_chunk, block=32))(ct_pad, cn2, s0, target, ks)

    c = np.asarray(ct_pad)[:n, :n].T  # C = (I - A)^T
    sr, err = ref.ref_size_est_chunk(
        jnp.asarray(c), jnp.sum(c * c, axis=1), jnp.zeros(n).at[0].set(1.0), np.asarray(ks)
    )
    np.testing.assert_allclose(np.asarray(s_t)[:n, 0], sr, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(np.asarray(trace)[:, 0], err, rtol=1e-3, atol=1e-6)


def test_size_chunk_sum_conserved():
    # multiplying eq. 14 by 1^T shows sum(s_t) is invariant (=1)
    n, p = 60, 64
    _, ct_pad, cn2, target, s0 = _size_setup(n, p)
    rng = np.random.default_rng(11)
    ks = jnp.asarray(rng.integers(0, n, size=64), jnp.int32)
    s_t, _ = jax.jit(functools.partial(model.size_chunk, block=32))(ct_pad, cn2, s0, target, ks)
    assert float(jnp.sum(s_t)) == pytest.approx(1.0, abs=1e-4)


def test_size_chunk_error_decays():
    n, p = 60, 64
    _, ct_pad, cn2, target, s0 = _size_setup(n, p)
    rng = np.random.default_rng(12)
    s, trace0 = jax.jit(functools.partial(model.size_chunk, block=32))(
        ct_pad, cn2, s0, target, jnp.asarray(rng.integers(0, n, size=128), jnp.int32))
    s, trace1 = jax.jit(functools.partial(model.size_chunk, block=32))(
        ct_pad, cn2, s, target, jnp.asarray(rng.integers(0, n, size=128), jnp.int32))
    assert float(trace1[-1, 0]) < 0.01 * float(trace0[0, 0])


def test_size_estimate_recovers_n():
    n, p = 60, 64
    _, ct_pad, cn2, target, s0 = _size_setup(n, p)
    rng = np.random.default_rng(13)
    s = s0
    run = jax.jit(functools.partial(model.size_chunk, block=32))
    for _ in range(6):
        s, _ = run(ct_pad, cn2, s, target, jnp.asarray(rng.integers(0, n, size=128), jnp.int32))
    est = 1.0 / np.asarray(s)[:n, 0]
    np.testing.assert_allclose(est, n * np.ones(n), rtol=5e-2)


# ---------------------------------------------------------------------------
# residual_norm
# ---------------------------------------------------------------------------


def test_residual_norm_matches_ref():
    _, _, b_pad, _, y = setup(n=100, p=128)
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.standard_normal((128, 1)), jnp.float32)
    r, rn2 = jax.jit(model.residual_norm)(b_pad, x, y)
    want = np.asarray(y) - np.asarray(b_pad) @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(r), want, rtol=1e-4, atol=1e-4)
    assert float(rn2[0, 0]) == pytest.approx(float(np.sum(want**2)), rel=1e-4)


def test_residual_norm_zero_at_solution():
    a, _, b_pad, _, y = setup(n=100, p=128)
    x_star = ref.ref_pagerank_exact(a.astype(jnp.float64), 0.85)
    x = model.pad_vector(jnp.asarray(x_star, jnp.float32), 128)
    _, rn2 = jax.jit(model.residual_norm)(b_pad, x, y)
    assert float(rn2[0, 0]) < 1e-9
