"""L2 — JAX compute graphs for the paper's iterated operators.

Each public ``*_chunk`` function is a scan of T algorithm steps whose inner
ops are the L1 Pallas kernels (``kernels.matvec`` / ``block_dot`` / ``axpy``
/ ``fused_project``). ``python/compile/aot.py`` lowers these once to HLO
text; the Rust runtime loads and executes them via PJRT. Chunking T steps
per executable amortizes the per-call PJRT dispatch overhead.

Padding contract (see DESIGN.md §5): all operands are padded to an artifact
size P ≥ N that is a multiple of the kernel block. The hyperlink matrix A
is padded block-diagonally with the identity, hence

    B_pad = I - alpha * blockdiag(A, I) = blockdiag(B, (1-alpha) I)

so padded columns are scaled unit vectors, padded residual/state entries
start at 0 and provably stay 0 for any activation sequence that only
selects real coordinates (k < N). ``jacobi_chunk`` takes the affine vector
y as an input (0 on padded coordinates) for the same reason.

Everything is float32: the f64 path lives in the Rust implementation; the
PJRT path is cross-validated against it at f32 tolerances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import matvec, block_dot, axpy, fused_project, DEFAULT_BLOCK

F32 = jnp.float32


# ---------------------------------------------------------------------------
# padding helpers (build-time only; the Rust runtime performs the same
# padding natively — rust/src/runtime/pad.rs mirrors these rules and the
# python tests pin them down)
# ---------------------------------------------------------------------------


def pad_size(n: int, block: int) -> int:
    """Smallest multiple of ``block`` that is >= n."""
    return ((n + block - 1) // block) * block


def pad_hyperlink(a_mat: jax.Array, p: int) -> jax.Array:
    """Pad the (N,N) column-stochastic A to (P,P) block-diagonally with I.

    The padded matrix remains column stochastic; padded coordinates form
    self-loops that never interact with real ones.
    """
    n = a_mat.shape[0]
    if p < n:
        raise ValueError(f"pad target {p} < matrix size {n}")
    out = jnp.zeros((p, p), dtype=a_mat.dtype)
    out = out.at[:n, :n].set(a_mat)
    idx = jnp.arange(n, p)
    return out.at[idx, idx].set(1.0)


def pad_vector(v: jax.Array, p: int) -> jax.Array:
    """Zero-pad an (N,) or (N,1) vector to (P, 1)."""
    v = v.reshape(-1, 1)
    n = v.shape[0]
    return jnp.zeros((p, 1), dtype=v.dtype).at[:n].set(v)


def build_b(a_pad: jax.Array, alpha) -> jax.Array:
    """B = I - alpha A on the padded matrix."""
    p = a_pad.shape[0]
    return jnp.eye(p, dtype=a_pad.dtype) - alpha * a_pad


def column_norms_sq(b_pad: jax.Array) -> jax.Array:
    """Per-column ||B(:,k)||^2 as a (P, 1) vector (paper Remark 3)."""
    return jnp.sum(b_pad * b_pad, axis=0).reshape(-1, 1)


def _onehot(k, p: int) -> jax.Array:
    """(P, 1) float indicator of coordinate k (traced int32 scalar)."""
    return (jnp.arange(p, dtype=jnp.int32).reshape(p, 1) == k).astype(F32)


# ---------------------------------------------------------------------------
# Algorithm 1 — Matching-Pursuit PageRank, T steps per call
# ---------------------------------------------------------------------------


def mp_chunk(b_pad, bnorm2, x, r, ks, *, block: int = DEFAULT_BLOCK):
    """Run T = len(ks) MP iterations (paper eqs. 7–8) on dense padded B.

    Args:
      b_pad:  (P, P) padded B = I - alpha A.
      bnorm2: (P, 1) per-column squared norms.
      x:      (P, 1) PageRank estimate.
      r:      (P, 1) residual.
      ks:     (T,) int32 activation sequence, entries in [0, N).

    Returns (x_T, r_T, rnorm2_trace) with rnorm2_trace of shape (T, 1):
    ||r_{t+1}||^2 after each step — the quantity of Proposition 2 / Fig. 1.
    """
    p = b_pad.shape[0]

    def step(carry, k):
        x, r, rn2 = carry
        onehot = _onehot(k, p)
        col, num = fused_project(b_pad, onehot, r, block=block)
        denom = block_dot(onehot, bnorm2, block=block)  # = bnorm2[k], gather-free
        coef = num / denom  # (1, 1)
        x = axpy(coef, onehot, x, block=block)
        r = axpy(-coef, col, r, block=block)
        # Orthogonal projection: ||r'||^2 = ||r||^2 - num^2/||B(:,k)||^2.
        # Tracking it as a scalar recurrence saves a full O(P) reduction
        # kernel per step (see EXPERIMENTS.md §Perf).
        rn2 = rn2 - coef * num
        return (x, r, rn2), rn2[0]

    rn2_0 = block_dot(r, r, block=block)
    (x, r, _), trace = jax.lax.scan(step, (x, r, rn2_0), ks)
    return x, r, trace


# ---------------------------------------------------------------------------
# Centralized baseline — Jacobi / power-like fixed point, T steps per call
# ---------------------------------------------------------------------------


def jacobi_chunk(a_pad, x, y, alpha, t: int, *, block: int = DEFAULT_BLOCK):
    """x <- alpha * A x + y, iterated t times (t is static).

    With y = (1-alpha) 1 on real coordinates this is the centralized
    scaled-PageRank iteration (paper eq. 6 fixed point); linear
    convergence at rate alpha.
    """

    def step(x, _):
        ax = matvec(a_pad, x, block=block)
        return axpy(alpha, ax, y, block=block), None

    x, _ = jax.lax.scan(step, x, None, length=t)
    return x


# ---------------------------------------------------------------------------
# Algorithm 2 — network size estimation, T steps per call
# ---------------------------------------------------------------------------


def size_chunk(ct_pad, cnorm2, s, target, ks, *, block: int = DEFAULT_BLOCK):
    """Run T Kaczmarz steps of Algorithm 2 (paper eq. 14).

    We pass C^T (so row operations become column operations and reuse
    fused_project): with C = (I - A)^T, C^T = I - A and
    C(k,:) = (C^T)(:,k).

    Args:
      ct_pad: (P, P) padded C^T = I - A_pad.
      cnorm2: (P, 1) squared row norms ||C(k,:)||^2.
      s:      (P, 1) current iterate.
      target: (P, 1) the true s = 1/N on real coordinates, 0 on padding.
      ks:     (T,) int32 activation sequence.

    Returns (s_T, err_trace) with err_trace[t] = ||s_{t+1} - target||^2 —
    the quantity plotted in Fig. 2.
    """
    p = ct_pad.shape[0]
    neg_one = -jnp.ones((1, 1), dtype=F32)

    def step(carry, k):
        s, err = carry
        onehot = _onehot(k, p)
        row, num = fused_project(ct_pad, onehot, s, block=block)
        denom = block_dot(onehot, cnorm2, block=block)
        coef = num / denom
        s = axpy(-coef, row, s, block=block)
        # ||s' - target||^2 = ||s - target||^2 - num^2/||C(k,:)||^2, using
        # C(k,:)·target = 0 (rows of C sum to zero against the uniform
        # target) — an exact scalar recurrence replacing two O(P) kernels.
        err = err - coef * num
        return (s, err), err[0]

    diff = axpy(neg_one, target, s, block=block)
    err0 = block_dot(diff, diff, block=block)
    (s, _), trace = jax.lax.scan(step, (s, err0), ks)
    return s, trace


# ---------------------------------------------------------------------------
# Residual evaluation — r = y - B x and its squared norm
# ---------------------------------------------------------------------------


def residual_norm(b_pad, x, y, *, block: int = DEFAULT_BLOCK):
    """Return (r, ||r||^2) for r = y - B x (conservation check, eq. 11)."""
    bx = matvec(b_pad, x, block=block)
    neg_one = -jnp.ones((1, 1), dtype=F32)
    r = axpy(neg_one, bx, y, block=block)
    rn2 = block_dot(r, r, block=block)
    return r, rn2


# ---------------------------------------------------------------------------
# jit entry points (shape-specialized in aot.py)
# ---------------------------------------------------------------------------

mp_chunk_jit = jax.jit(mp_chunk, static_argnames=("block",))
jacobi_chunk_jit = jax.jit(jacobi_chunk, static_argnames=("t", "block"))
size_chunk_jit = jax.jit(size_chunk, static_argnames=("block",))
residual_norm_jit = jax.jit(residual_norm, static_argnames=("block",))
