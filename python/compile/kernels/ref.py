"""Pure-jnp oracle for the L1 kernels and the paper's operators.

Everything in here is straight-line jax.numpy — no Pallas — and serves as
the correctness reference for:

  * the L1 kernels (pytest/hypothesis compare kernel vs ref per-op), and
  * the L2 chunked models (ref_mp_chunk vs model.mp_chunk), and
  * the Rust implementation (the runtime_e2e integration test replays the
    identical activation sequence through artifacts generated from these
    graphs and compares against the sparse Rust trajectory).

Mathematical setting (paper §II): B = I - alpha*A, y = (1-alpha)*1, and
the scaled PageRank vector is the unique solution of B x* = y with
sum(x*) = N (Proposition 1).
"""
from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# primitive oracles
# ---------------------------------------------------------------------------


def ref_matvec(m, x):
    """Oracle for kernels.matvec: (M,N) @ (N,1) -> (M,1)."""
    return m @ x


def ref_block_dot(x, y):
    """Oracle for kernels.block_dot: (N,1)·(N,1) -> (1,1)."""
    return jnp.sum(x * y).reshape(1, 1)


def ref_axpy(a, x, y):
    """Oracle for kernels.axpy: a*x + y with a (1,1)."""
    return a[0, 0] * x + y


def ref_fused_project(b, onehot, r):
    """Oracle for kernels.fused_project: (B@e_k, B(:,k)^T r)."""
    col = b @ onehot
    num = jnp.sum(col * r).reshape(1, 1)
    return col, num


# ---------------------------------------------------------------------------
# paper operators (dense form)
# ---------------------------------------------------------------------------


def build_b(a_mat, alpha):
    """B = I - alpha * A  (paper §II-B)."""
    n = a_mat.shape[0]
    return jnp.eye(n, dtype=a_mat.dtype) - alpha * a_mat


def column_norms_sq(b_mat):
    """Precomputed ||B(:,k)||^2 per column (paper Remark 3)."""
    return jnp.sum(b_mat * b_mat, axis=0)


def ref_mp_step(b_mat, bnorm2, x, r, k):
    """One Algorithm-1 iteration in dense form.

    x' = x + (B(:,k)^T r / ||B(:,k)||^2) e_k        (eq. 7)
    r' = r - (B(:,k)^T r / ||B(:,k)||^2) B(:,k)     (eq. 8)
    """
    col = b_mat[:, k]
    coef = col @ r / bnorm2[k]
    x = x.at[k].add(coef)
    r = r - coef * col
    return x, r


def ref_mp_chunk(b_mat, bnorm2, x, r, ks):
    """T sequential MP steps; returns (x_T, r_T, ||r_t||^2 trace of len T)."""
    norms = []
    for k in ks:
        x, r = ref_mp_step(b_mat, bnorm2, x, r, int(k))
        norms.append(jnp.sum(r * r))
    return x, r, jnp.stack(norms)


def ref_jacobi_step(a_mat, x, y, alpha):
    """x' = alpha*A@x + y — the fixed-point (power-like) iteration for
    B x = y. With y = (1-alpha)*1 this is the scaled-PageRank centralized
    iteration; padded coordinates stay inert when their y entries are 0."""
    return alpha * (a_mat @ x) + y


def ref_jacobi_chunk(a_mat, x, y, alpha, t):
    for _ in range(t):
        x = ref_jacobi_step(a_mat, x, y, alpha)
    return x


def ref_size_est_step(c_mat, cnorm2, s, k):
    """One Algorithm-2 iteration: s' = s - (C(k,:) s / ||C(k,:)||^2) C(k,:)^T
    with C = (I - A)^T (paper eq. 14)."""
    row = c_mat[k, :]
    coef = row @ s / cnorm2[k]
    return s - coef * row


def ref_size_est_chunk(c_mat, cnorm2, s, ks):
    errs = []
    n = c_mat.shape[0]
    target = jnp.ones(n, dtype=c_mat.dtype) / n
    for k in ks:
        s = ref_size_est_step(c_mat, cnorm2, s, int(k))
        errs.append(jnp.sum((s - target) ** 2))
    return s, jnp.stack(errs)


def ref_residual(b_mat, x, y):
    """r = y - B x  (the conserved quantity of eq. 11 is B x_t + r_t = y)."""
    return y - b_mat @ x


def ref_pagerank_exact(a_mat, alpha):
    """Scaled PageRank by direct solve of (I - alpha A) x = (1-alpha) 1
    (Proposition 1). Dense; reference only."""
    n = a_mat.shape[0]
    b = build_b(a_mat, alpha)
    y = (1.0 - alpha) * jnp.ones((n,), dtype=a_mat.dtype)
    return jnp.linalg.solve(b, y)


def ref_hyperlink_from_adj(adj):
    """Column-stochastic hyperlink matrix A from a 0/1 adjacency 'adj'
    where adj[i, j] = 1 iff page j links to page i (paper §I). Requires no
    dangling columns."""
    out_deg = jnp.sum(adj, axis=0)
    return adj / out_deg[None, :]
