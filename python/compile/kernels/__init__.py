# L1 — Pallas kernels (interpret=True on CPU-PJRT; see DESIGN.md
# §Hardware-Adaptation for the TPU tiling rationale).
#
# Three primitive kernels compose into every iterated operator the paper
# needs (MP projection step, Jacobi/power step, Kaczmarz size-estimation
# step):
#
#   matvec    — tiled (BM, BN) dense mat-vec through the MXU
#   block_dot — blocked inner product with sequential-grid accumulation
#   axpy      — fused z = a*x + y over (BM, 1) tiles
#
# A column gather B(:,k) is expressed as matvec(B, onehot(k)): on TPU a
# dense matvec through the 128x128 systolic array beats a scalar gather,
# and it keeps every kernel shape static (no dynamic slices in the HLO).
from .matvec import matvec, block_dot, axpy, fused_project, DEFAULT_BLOCK
from . import ref

__all__ = ["matvec", "block_dot", "axpy", "fused_project", "DEFAULT_BLOCK", "ref"]
