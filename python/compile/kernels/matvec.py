"""L1 Pallas primitive kernels.

All kernels are written for TPU block shapes (VMEM tiles, MXU-aligned
128-lane last dimensions) but are lowered with ``interpret=True`` so the
resulting HLO executes on any PJRT backend, including the Rust CPU client
(real-TPU Mosaic custom-calls cannot run on CPU — see
/opt/xla-example/README.md).

Vectors are carried as ``(N, 1)`` column matrices: TPU vector registers are
(8, 128) tiles, and a rank-2 layout keeps the lowering uniform between the
matrix and vector operands.

Shape contract: callers pad to a multiple of the block size *before*
invoking (``python/compile/model.py`` owns padding). Keeping the kernels
free of tail-masking logic keeps the generated HLO loop bodies dense and
branch-free — the padded coordinates are arranged by the caller to be
exactly inert (identity columns / zero entries), so correctness does not
depend on masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array is 128x128; an (8,128) float32 VMEM tile is the
# minimum vector-register shape. 128 keeps both units fully fed while a
# (128,128) f32 block is only 64 KiB of VMEM — far under the ~16 MiB/core
# budget even with double buffering (see DESIGN.md §Perf).
DEFAULT_BLOCK = 128


def _check(n: int, block: int, what: str) -> None:
    if n % block != 0:
        raise ValueError(f"{what}={n} must be a multiple of block={block}")


# ---------------------------------------------------------------------------
# matvec: y = M @ x
# ---------------------------------------------------------------------------


def _matvec_kernel(m_ref, x_ref, o_ref):
    """One (BM, BN) tile of the mat-vec.

    Grid is (M/BM, N/BN) with the contraction dimension innermost; TPU
    grids execute sequentially, so ``o_ref`` accumulates across the j axis
    of the grid (revisiting the same output block is the canonical Pallas
    reduction idiom).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (BM, BN) @ (BN, 1) through the MXU; accumulate in f32.
    o_ref[...] += jnp.dot(
        m_ref[...], x_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def matvec(m: jax.Array, x: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Tiled dense mat-vec ``m @ x`` with ``m: (M, N)``, ``x: (N, 1)``."""
    mm, nn = m.shape
    _check(mm, block, "M")
    _check(nn, block, "N")
    if x.shape != (nn, 1):
        raise ValueError(f"x must be ({nn}, 1), got {x.shape}")
    grid = (mm // block, nn // block)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((block, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mm, 1), m.dtype),
        interpret=True,
    )(m, x)


# ---------------------------------------------------------------------------
# block_dot: s = x . y (scalar, returned as (1, 1))
# ---------------------------------------------------------------------------


def _dot_kernel(x_ref, y_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...] * y_ref[...], keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def block_dot(x: jax.Array, y: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Blocked inner product of two ``(N, 1)`` vectors; result ``(1, 1)``.

    The sequential TPU grid accumulates partial sums into the single output
    tile — one VMEM-resident scalar, no cross-block tree needed.
    """
    nn = x.shape[0]
    _check(nn, block, "N")
    if x.shape != (nn, 1) or y.shape != (nn, 1):
        raise ValueError(f"x, y must be ({nn}, 1); got {x.shape}, {y.shape}")
    return pl.pallas_call(
        _dot_kernel,
        grid=(nn // block,),
        in_specs=[
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        interpret=True,
    )(x, y)


# ---------------------------------------------------------------------------
# axpy: z = a * x + y  (a is a (1, 1) scalar tile)
# ---------------------------------------------------------------------------


def _axpy_kernel(a_ref, x_ref, y_ref, o_ref):
    o_ref[...] = a_ref[0, 0] * x_ref[...] + y_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def axpy(a: jax.Array, x: jax.Array, y: jax.Array, *, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Fused ``a * x + y`` over ``(block, 1)`` tiles; ``a`` is ``(1, 1)``."""
    nn = x.shape[0]
    _check(nn, block, "N")
    if a.shape != (1, 1):
        raise ValueError(f"a must be (1, 1), got {a.shape}")
    if x.shape != (nn, 1) or y.shape != (nn, 1):
        raise ValueError(f"x, y must be ({nn}, 1); got {x.shape}, {y.shape}")
    return pl.pallas_call(
        _axpy_kernel,
        grid=(nn // block,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nn, 1), x.dtype),
        interpret=True,
    )(a, x, y)


# ---------------------------------------------------------------------------
# fused_project: the MP hot-spot in one kernel
#
#   col  = B @ e_k            (column gather as masked matvec)
#   num  = col . r            (projection numerator)
#
# Fusing the gather-matvec with the dot avoids writing `col` back to HBM
# between the two passes: each (BM, BN) tile of B is read once, multiplied
# into the onehot to produce the tile's column segment, immediately dotted
# with the matching r segment, and both the running numerator and the
# column (needed later for the residual AXPY) stay in VMEM.
# ---------------------------------------------------------------------------


def _fused_project_kernel(b_ref, onehot_ref, r_ref, col_ref, num_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_col():
        col_ref[...] = jnp.zeros_like(col_ref)

    @pl.when((i == 0) & (j == 0))
    def _init_num():
        num_ref[...] = jnp.zeros_like(num_ref)

    seg = jnp.dot(
        b_ref[...], onehot_ref[...], preferred_element_type=jnp.float32
    ).astype(col_ref.dtype)
    col_ref[...] += seg
    num_ref[...] += jnp.sum(seg * r_ref[...], keepdims=True).astype(num_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def fused_project(
    b: jax.Array, onehot: jax.Array, r: jax.Array, *, block: int = DEFAULT_BLOCK
):
    """Return ``(col, num) = (B @ e_k, B(:,k)^T r)`` in one HBM pass over B.

    ``onehot`` is the (N, 1) indicator of column k; ``r`` the (N, 1)
    residual. The numerator accumulates across the whole grid, the column
    accumulates across the contraction axis only.
    """
    mm, nn = b.shape
    _check(mm, block, "M")
    _check(nn, block, "N")
    if onehot.shape != (nn, 1) or r.shape != (mm, 1):
        raise ValueError(
            f"onehot must be ({nn},1), r must be ({mm},1); got {onehot.shape}, {r.shape}"
        )
    grid = (mm // block, nn // block)
    return pl.pallas_call(
        _fused_project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((block, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, 1), b.dtype),
            jax.ShapeDtypeStruct((1, 1), b.dtype),
        ],
        interpret=True,
    )(b, onehot, r)
