"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage (normally driven by `make artifacts`):

    cd python && python -m compile.aot --out-dir ../artifacts \
        [--sizes 128,256] [--chunk 128] [--jacobi-chunk 16]

Emits, per padded size P:

    mp_chunk_p{P}_t{T}.hlo.txt       (B, bnorm2, x, r, ks)    -> (x', r', trace)
    jacobi_chunk_p{P}_t{TJ}.hlo.txt  (A, x, y, alpha)         -> x'
    size_chunk_p{P}_t{T}.hlo.txt     (Ct, cnorm2, s, tgt, ks) -> (s', trace)
    residual_norm_p{P}.hlo.txt       (B, x, y)                -> (r, ||r||^2)

plus `manifest.json` describing every artifact (entry point, operand
shapes/dtypes, chunk length, block size) — the Rust runtime
(rust/src/runtime/artifacts.rs) is driven entirely by the manifest.
"""
from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import DEFAULT_BLOCK

# Per-artifact kernel block: on the CPU PJRT plugin a multi-block Pallas
# grid in interpret mode costs ~20x (measured: P=256 with 128-blocks is
# 11.5 ms/chunk vs 0.54 ms with one 256-block), so each artifact is
# lowered with block = P — one VMEM-resident tile per operand. A real TPU
# lowering would keep 128 (MXU-aligned); see DESIGN.md §Hardware-Adaptation.
MAX_SINGLE_BLOCK = 2048


def block_for(p: int) -> int:
    if p > MAX_SINGLE_BLOCK:
        raise SystemExit(
            f"padded size {p} exceeds the single-block VMEM budget "
            f"({MAX_SINGLE_BLOCK}); extend aot.py with multi-block tiling"
        )
    return p

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True: the
    bundled xla_extension 0.5.1 PJRT client returns the result tuple as a
    single buffer, so the Rust side unwraps with Literal::to_tuple —
    attempted untupled lowering still produced one tuple buffer, see
    EXPERIMENTS.md §Perf iteration log)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_mp_chunk(p: int, t: int) -> str:
    fn = functools.partial(model.mp_chunk, block=block_for(p))
    lowered = jax.jit(fn).lower(
        _spec((p, p)),  # b_pad
        _spec((p, 1)),  # bnorm2
        _spec((p, 1)),  # x
        _spec((p, 1)),  # r
        _spec((t,), jnp.int32),  # ks
    )
    return to_hlo_text(lowered)


def lower_jacobi_chunk(p: int, t: int) -> str:
    fn = functools.partial(model.jacobi_chunk, t=t, block=block_for(p))
    lowered = jax.jit(fn).lower(
        _spec((p, p)),  # a_pad
        _spec((p, 1)),  # x
        _spec((p, 1)),  # y
        _spec((1, 1)),  # alpha
    )
    return to_hlo_text(lowered)


def lower_size_chunk(p: int, t: int) -> str:
    fn = functools.partial(model.size_chunk, block=block_for(p))
    lowered = jax.jit(fn).lower(
        _spec((p, p)),  # ct_pad
        _spec((p, 1)),  # cnorm2
        _spec((p, 1)),  # s
        _spec((p, 1)),  # target
        _spec((t,), jnp.int32),  # ks
    )
    return to_hlo_text(lowered)


def lower_residual_norm(p: int) -> str:
    fn = functools.partial(model.residual_norm, block=block_for(p))
    lowered = jax.jit(fn).lower(
        _spec((p, p)),  # b_pad
        _spec((p, 1)),  # x
        _spec((p, 1)),  # y
    )
    return to_hlo_text(lowered)


def _operands(*ops):
    return [{"name": n, "shape": list(s), "dtype": d} for (n, s, d) in ops]


def build_manifest_entry(kind: str, p: int, t: int | None, fname: str) -> dict:
    if kind == "mp_chunk":
        operands = _operands(
            ("b_pad", (p, p), F32),
            ("bnorm2", (p, 1), F32),
            ("x", (p, 1), F32),
            ("r", (p, 1), F32),
            ("ks", (t,), I32),
        )
        results = _operands(("x", (p, 1), F32), ("r", (p, 1), F32), ("trace", (t, 1), F32))
    elif kind == "jacobi_chunk":
        operands = _operands(
            ("a_pad", (p, p), F32),
            ("x", (p, 1), F32),
            ("y", (p, 1), F32),
            ("alpha", (1, 1), F32),
        )
        results = _operands(("x", (p, 1), F32))
    elif kind == "size_chunk":
        operands = _operands(
            ("ct_pad", (p, p), F32),
            ("cnorm2", (p, 1), F32),
            ("s", (p, 1), F32),
            ("target", (p, 1), F32),
            ("ks", (t,), I32),
        )
        results = _operands(("s", (p, 1), F32), ("trace", (t, 1), F32))
    elif kind == "residual_norm":
        operands = _operands(
            ("b_pad", (p, p), F32),
            ("x", (p, 1), F32),
            ("y", (p, 1), F32),
        )
        results = _operands(("r", (p, 1), F32), ("rnorm2", (1, 1), F32))
    else:
        raise ValueError(kind)
    return {
        "kind": kind,
        "file": fname,
        "padded_size": p,
        "chunk": t,
        "block": block_for(p),
        "operands": operands,
        "results": results,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="128,256", help="padded sizes P (multiples of block)")
    ap.add_argument("--chunk", type=int, default=128, help="MP/size-est steps per call")
    ap.add_argument("--jacobi-chunk", type=int, default=16, help="Jacobi steps per call")
    args = ap.parse_args()

    sizes = [int(s) for s in args.sizes.split(",") if s]
    for p in sizes:
        if p % DEFAULT_BLOCK != 0:
            raise SystemExit(f"size {p} is not a multiple of the kernel block {DEFAULT_BLOCK}")
        block_for(p)  # validate against the single-block budget

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for p in sizes:
        jobs = [
            ("mp_chunk", args.chunk, lambda: lower_mp_chunk(p, args.chunk),
             f"mp_chunk_p{p}_t{args.chunk}.hlo.txt"),
            ("jacobi_chunk", args.jacobi_chunk, lambda: lower_jacobi_chunk(p, args.jacobi_chunk),
             f"jacobi_chunk_p{p}_t{args.jacobi_chunk}.hlo.txt"),
            ("size_chunk", args.chunk, lambda: lower_size_chunk(p, args.chunk),
             f"size_chunk_p{p}_t{args.chunk}.hlo.txt"),
            ("residual_norm", None, lambda: lower_residual_norm(p),
             f"residual_norm_p{p}.hlo.txt"),
        ]
        for kind, t, produce, fname in jobs:
            text = produce()
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            entries.append(build_manifest_entry(kind, p, t, fname))
            print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "version": 1,
        "block": DEFAULT_BLOCK,
        "dtype": "f32",
        "artifacts": entries,
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
