//! Minimal command-line parser (the build environment has no `clap`).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] ...`
//! Values may also be attached as `--key=value`. Unknown flags are
//! collected and reported so typos fail loudly instead of being ignored.

use std::collections::BTreeMap;
use std::fmt;

/// Parsed arguments: one optional subcommand plus `--key [value]` pairs.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` and `--key=value` pairs; bare `--flag` maps to "true".
    options: BTreeMap<String, String>,
    /// Keys the program actually read — used to report unused/unknown keys.
    consumed: std::cell::RefCell<Vec<String>>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

/// Error produced when an option fails to parse as the requested type.
#[derive(Debug)]
pub struct ParseError {
    pub key: String,
    pub value: String,
    pub wanted: &'static str,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "option --{} = {:?} is not a valid {}",
            self.key, self.value, self.wanted
        )
    }
}

impl std::error::Error for ParseError {}

impl Args {
    /// Parse from an iterator of tokens (excluding the binary name).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked");
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.options.insert(stripped.to_string(), "true".into());
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// Raw string lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.options.get(key).map(String::as_str)
    }

    /// String with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed lookup with default; returns an error if present but invalid.
    pub fn get_parse<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
    ) -> Result<T, ParseError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| ParseError {
                key: key.to_string(),
                value: v.to_string(),
                wanted: std::any::type_name::<T>(),
            }),
        }
    }

    /// Bare-flag check (`--verbose`).
    pub fn flag(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }

    /// Keys provided on the command line but never read by the program —
    /// call after all lookups to catch typos.
    pub fn unknown_keys(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.options
            .keys()
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("fig1 --rounds 100 --alpha 0.85 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig1"));
        assert_eq!(a.get_parse("rounds", 0usize).unwrap(), 100);
        assert_eq!(a.get_parse("alpha", 0.0f64).unwrap(), 0.85);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --n=500 --graph=ba");
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 500);
        assert_eq!(a.get("graph"), Some("ba"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_parse("steps", 123usize).unwrap(), 123);
        assert_eq!(a.get_str("out", "report.csv"), "report.csv");
    }

    #[test]
    fn invalid_value_is_error() {
        let a = parse("run --steps banana");
        assert!(a.get_parse("steps", 0usize).is_err());
        let e = a.get_parse("steps", 0usize).unwrap_err();
        assert!(e.to_string().contains("steps"));
    }

    #[test]
    fn positional_arguments() {
        let a = parse("rank graph.txt out.csv --alpha 0.9");
        assert_eq!(a.command.as_deref(), Some("rank"));
        assert_eq!(a.positional, vec!["graph.txt", "out.csv"]);
    }

    #[test]
    fn unknown_keys_reported() {
        let a = parse("run --known 1 --typo 2");
        let _ = a.get("known");
        assert_eq!(a.unknown_keys(), vec!["typo".to_string()]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --verbose --n 5");
        assert!(a.flag("verbose"));
        assert_eq!(a.get_parse("n", 0usize).unwrap(), 5);
    }
}
