//! Deterministic pseudo-random number generation.
//!
//! All randomness in the library flows through [`Rng`] (xoshiro256++,
//! seeded via SplitMix64) so that every experiment is reproducible from a
//! single `u64` seed, and so the Rust sparse path and the PJRT dense path
//! can replay *identical* activation sequences (the cross-validation in
//! `rust/tests/runtime_e2e.rs` depends on this).
//!
//! xoshiro256++ is the same generator family used by `rand_xoshiro`; it
//! passes BigCrush and is far stronger than needed for sampling page
//! activations, while being a handful of ALU ops per draw.

/// SplitMix64 step — used to expand a single seed into the xoshiro state
/// (the construction recommended by the xoshiro authors).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed; any seed (including 0) is valid.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s2n = s2 ^ s0;
        let mut s3n = s3 ^ s1;
        let s1n = s1 ^ s2n;
        let s0n = s0 ^ s3n;
        s2n ^= t;
        s3n = s3n.rotate_left(45);
        self.s = [s0n, s1n, s2n, s3n];
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection
    /// (unbiased). This is the paper's `U[1, N]` sampler (0-indexed).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0, "below(0) is meaningless");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n {
                return (m >> 64) as usize;
            }
            // Rejection zone: recompute threshold only on the cold path.
            let t = n.wrapping_neg() % n;
            if lo >= t {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Exponential variate with the given rate — the 'exponential clocks'
    /// of the paper's Remark 1 / [16] map asynchronous wake-ups to an
    /// i.i.d. uniform activation sequence.
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // Avoid ln(0): uniform() < 1 so 1-uniform() > 0.
        -(1.0 - self.uniform()).ln() / rate
    }

    /// Standard normal via Box–Muller (used by synthetic workloads).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        // For small k relative to n use a hash-free rejection over a sorted
        // vec; for large k shuffle an index vector.
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut out: Vec<usize> = Vec::with_capacity(k);
            while out.len() < k {
                let c = self.below(n);
                if !out.contains(&c) {
                    out.push(c);
                }
            }
            out
        }
    }

    /// Weighted index sample proportional to `weights` (linear scan; the
    /// residual-weighted sampler keeps its own alias/tree structure).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "weights must have positive mass");
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent stream for a sub-experiment (`round` is mixed
    /// into the state via SplitMix64 so streams are decorrelated).
    pub fn fork(&self, round: u64) -> Rng {
        let mut sm = self.s[0] ^ round.wrapping_mul(0xA076_1D64_78BD_642F);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::seeded(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = Rng::seeded(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::seeded(5);
        let n = 7;
        let mut counts = vec![0usize; n];
        let draws = 70_000;
        for _ in 0..draws {
            counts[rng.below(n)] += 1;
        }
        let expect = draws / n;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < 0.1 * expect as f64,
                "bucket {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn range_bounds() {
        let mut rng = Rng::seeded(6);
        for _ in 0..1000 {
            let v = rng.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seeded(7);
        let rate = 3.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seeded(8);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seeded(9);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Rng::seeded(10);
        for &(n, k) in &[(100, 5), (100, 80), (10, 10), (1, 1)] {
            let s = rng.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let mut dedup = s.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), k, "duplicates in sample ({n}, {k})");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_prefers_heavy_indices() {
        let mut rng = Rng::seeded(11);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0] / 2, "{counts:?}");
    }

    #[test]
    fn fork_streams_decorrelated() {
        let base = Rng::seeded(12);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_is_deterministic() {
        let base = Rng::seeded(13);
        let mut a = base.fork(5);
        let mut b = base.fork(5);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
