//! Micro-benchmark harness (the environment has no `criterion`; this
//! provides the same discipline: warm-up, many timed iterations, robust
//! summary statistics, throughput reporting and a stable text format the
//! `cargo bench` binaries use).

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in nanoseconds.
    pub samples_ns: Vec<f64>,
    /// Optional units-per-iteration for throughput (e.g. activations).
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        stats::median(&self.samples_ns)
    }

    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }

    pub fn p95_ns(&self) -> f64 {
        stats::quantile(&self.samples_ns, 0.95)
    }

    /// Units per second at the median iteration time.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter
            .map(|u| u / (self.median_ns() / 1e9))
    }

    /// One human-readable line, criterion-style.
    pub fn report_line(&self) -> String {
        let med = format_ns(self.median_ns());
        let mean = format_ns(self.mean_ns());
        let p95 = format_ns(self.p95_ns());
        match self.throughput() {
            Some(tp) => format!(
                "{:<44} median {:>10}  mean {:>10}  p95 {:>10}  thrpt {:>12}/s",
                self.name,
                med,
                mean,
                p95,
                format_count(tp)
            ),
            None => format!(
                "{:<44} median {:>10}  mean {:>10}  p95 {:>10}",
                self.name, med, mean, p95
            ),
        }
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn format_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Format a large count with an adaptive suffix.
pub fn format_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Benchmark runner with warm-up and a time budget per case.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 100_000,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode runner for CI / smoke runs (shorter budget).
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            budget: Duration::from_millis(400),
            min_iters: 5,
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Run one case. `f` is invoked once per iteration; use
    /// `std::hint::black_box` inside to defeat DCE. `units` is the number
    /// of logical operations per iteration for throughput reporting.
    pub fn bench<F: FnMut()>(&mut self, name: &str, units: Option<f64>, mut f: F) -> &BenchResult {
        // Warm-up phase.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            f();
        }
        // Timed phase.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            samples_ns: samples,
            units_per_iter: units,
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last().expect("just pushed")
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render all collected results as a CSV (name, median_ns, mean_ns,
    /// p95_ns, throughput_per_s).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,median_ns,mean_ns,p95_ns,throughput_per_s\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{:.1},{:.1},{:.1},{}\n",
                r.name,
                r.median_ns(),
                r.mean_ns(),
                r.p95_ns(),
                r.throughput().map(|t| format!("{t:.1}")).unwrap_or_default()
            ));
        }
        out
    }
}

/// Whether `cargo bench` was invoked in quick mode (env PAGERANK_BENCH_QUICK).
pub fn quick_mode() -> bool {
    std::env::var("PAGERANK_BENCH_QUICK").map(|v| v != "0").unwrap_or(false)
}

/// Construct the standard bencher honouring quick mode.
pub fn standard() -> Bencher {
    if quick_mode() {
        Bencher::quick()
    } else {
        Bencher::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut b = Bencher::quick().with_budget(Duration::from_millis(30));
        let mut acc = 0u64;
        let r = b.bench("noop", Some(1.0), || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(r.samples_ns.len() >= 5);
        assert!(r.median_ns() >= 0.0);
        assert!(r.throughput().expect("units set") > 0.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut b = Bencher::quick().with_budget(Duration::from_millis(10));
        b.bench("a", None, || {
            std::hint::black_box(3u64.pow(7));
        });
        let csv = b.to_csv();
        assert!(csv.starts_with("name,median_ns"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn formatters() {
        assert_eq!(format_ns(12.0), "12.0 ns");
        assert_eq!(format_ns(1_500.0), "1.50 µs");
        assert_eq!(format_ns(2_000_000.0), "2.00 ms");
        assert_eq!(format_ns(3e9), "3.00 s");
        assert_eq!(format_count(999.0), "999.0");
        assert_eq!(format_count(1_200.0), "1.20k");
        assert_eq!(format_count(3_400_000.0), "3.40M");
        assert_eq!(format_count(5e9), "5.00G");
    }
}
