//! Shared infrastructure: deterministic RNG, statistics, CLI parsing and
//! the micro-benchmark harness.
//!
//! Everything here is dependency-free by design: the build environment is
//! fully offline, so the substrates a typical project would pull from
//! crates.io (`rand`, `clap`, `criterion`) are implemented in-repo.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
