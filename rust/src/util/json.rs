//! Minimal JSON parser (the environment has no `serde`); sufficient for
//! the artifact manifest written by `python/compile/aot.py` and for the
//! harness' report files. Full JSON grammar minus `\u` surrogate pairs.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

/// Parse error with byte position.
#[derive(Debug, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.to_string() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{lit}`"))
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return self.err("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                            .map_err(|_| JsonError { pos: self.pos, msg: "bad utf8".into() })?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError { pos: self.pos, msg: "bad hex".into() })?;
                        self.pos += 4;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(b) => {
                    // Pass raw UTF-8 bytes through.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        // Collect the multibyte sequence.
                        let start = self.pos - 1;
                        let len = if b >= 0xF0 {
                            4
                        } else if b >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return self.err("truncated utf8");
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| JsonError { pos: start, msg: "bad utf8".into() })?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError { pos: start, msg: "bad utf8 in number".into() })?;
        s.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number {s:?}") })
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            None => self.err("unexpected end of input"),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut arr = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Array(arr));
                }
                loop {
                    arr.push(self.value()?);
                    self.skip_ws();
                    match self.bump() {
                        Some(b',') => continue,
                        Some(b']') => return Ok(Json::Array(arr)),
                        _ => return self.err("expected , or ]"),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let val = self.value()?;
                    map.insert(key, val);
                    self.skip_ws();
                    match self.bump() {
                        Some(b',') => continue,
                        Some(b'}') => return Ok(Json::Object(map)),
                        _ => return self.err("expected , or }"),
                    }
                }
            }
            Some(_) => self.number(),
        }
    }
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return p.err("trailing garbage");
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize (stable key order, compact).
    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".into(),
            Json::Bool(b) => b.to_string(),
            Json::Number(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/inf literal; degrade to null so the
                    // output always re-parses (NaN decay rates etc.).
                    "null".into()
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Json::String(s) => format!("{s:?}"),
            Json::Array(a) => {
                let inner: Vec<String> = a.iter().map(Json::render).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Object(m) => {
                let inner: Vec<String> =
                    m.iter().map(|(k, v)| format!("{k:?}:{}", v.render())).collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").expect("ok"), Json::Null);
        assert_eq!(Json::parse("true").expect("ok"), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").expect("ok"), Json::Number(-250.0));
        assert_eq!(Json::parse(r#""hi""#).expect("ok"), Json::String("hi".into()));
    }

    #[test]
    fn escapes_and_unicode() {
        assert_eq!(
            Json::parse(r#""a\nb\t\"c\"""#).expect("ok"),
            Json::String("a\nb\t\"c\"".into())
        );
        assert_eq!(Json::parse(r#""A""#).expect("ok"), Json::String("A".into()));
        assert_eq!(Json::parse("\"héllo\"").expect("ok"), Json::String("héllo".into()));
    }

    #[test]
    fn arrays_and_objects() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).expect("ok");
        assert_eq!(v.get("c").and_then(Json::as_str), Some("x"));
        let arr = v.get("a").and_then(Json::as_array).expect("array");
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Bool(false)));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").expect("ok"), Json::Array(vec![]));
        assert_eq!(Json::parse("{}").expect("ok"), Json::Object(Default::default()));
    }

    #[test]
    fn errors_reported_with_position() {
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 garbage").is_err());
        let e = Json::parse("nul").unwrap_err();
        assert!(e.to_string().contains("null"));
    }

    #[test]
    fn manifest_shape_parses() {
        // A realistic slice of the aot.py manifest.
        let text = r#"{
          "version": 1, "block": 128, "dtype": "f32",
          "artifacts": [
            {"kind": "mp_chunk", "file": "mp_chunk_p128_t128.hlo.txt",
             "padded_size": 128, "chunk": 128,
             "operands": [{"name": "b_pad", "shape": [128, 128], "dtype": "f32"}],
             "results": [{"name": "x", "shape": [128, 1], "dtype": "f32"}]}
          ]
        }"#;
        let v = Json::parse(text).expect("ok");
        assert_eq!(v.get("block").and_then(Json::as_usize), Some(128));
        let arts = v.get("artifacts").and_then(Json::as_array).expect("arr");
        assert_eq!(arts[0].get("kind").and_then(Json::as_str), Some("mp_chunk"));
        assert_eq!(
            arts[0].get("operands").and_then(Json::as_array).expect("ops")[0]
                .get("shape")
                .and_then(Json::as_array)
                .expect("shape")
                .len(),
            2
        );
    }

    #[test]
    fn round_trip_render() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null}}"#;
        let v = Json::parse(src).expect("ok");
        let rendered = v.render();
        assert_eq!(Json::parse(&rendered).expect("ok"), v);
    }

    #[test]
    fn non_finite_numbers_render_as_null() {
        assert_eq!(Json::Number(f64::NAN).render(), "null");
        assert_eq!(Json::Number(f64::INFINITY).render(), "null");
        assert_eq!(Json::Number(f64::NEG_INFINITY).render(), "null");
        // The rendered document must stay parseable.
        let mut m = BTreeMap::new();
        m.insert("decay_rate".to_string(), Json::Number(f64::NAN));
        let doc = Json::Object(m).render();
        assert!(Json::parse(&doc).is_ok(), "bad doc: {doc}");
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Number(1.5).as_usize(), None);
        assert_eq!(Json::Number(-3.0).as_usize(), None);
        assert_eq!(Json::Number(7.0).as_usize(), Some(7));
    }
}
