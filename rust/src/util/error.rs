//! Minimal `anyhow`-compatible error type (the build environment is fully
//! offline — see the [`crate::util`] module docs). Supports exactly the
//! subset the runtime layer uses: the [`crate::anyhow!`] constructor
//! macro, [`Context::context`] / [`Context::with_context`] wrapping, a
//! defaulted [`Result`] alias, and `{:#}` full-chain rendering.

use std::fmt;

/// A context-chained error. `chain[0]` is the outermost (most recent)
/// context; the last entry is the root cause.
#[derive(Debug, Clone)]
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { chain: vec![msg.into()] }
    }

    /// Wrap with an outer context message.
    pub fn wrap(mut self, outer: impl Into<String>) -> Error {
        self.chain.insert(0, outer.into());
        self
    }

    /// The cause chain, outermost context first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

/// Result alias defaulting the error type, as `anyhow::Result` does.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!`-style construction from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($($arg)*))
    };
}

/// Context-wrapping on fallible values, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "no such file"))
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad value {} at {}", 7, "x");
        assert_eq!(e.to_string(), "bad value 7 at x");
    }

    #[test]
    fn context_chains_and_alternate_renders() {
        let e = io_err().context("loading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: no such file");
        assert_eq!(e.chain().len(), 2);
    }

    #[test]
    fn with_context_is_lazy() {
        use std::cell::Cell;
        let calls = Cell::new(0u32);
        let base: std::result::Result<u32, Error> = Ok(3);
        let ok = base.with_context(|| {
            calls.set(calls.get() + 1);
            "ctx"
        });
        assert_eq!(ok.expect("ok"), 3);
        assert_eq!(calls.get(), 0, "context closure must not run on Ok");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
        assert_eq!(Some(5).context("unused").expect("some"), 5);
    }

    #[test]
    fn nested_contexts_render_outermost_first() {
        let e = io_err()
            .context("inner step")
            .context("outer step")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer step: inner step: no such file");
    }
}
