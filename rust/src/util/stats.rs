//! Small statistics toolkit for the experiment harness: summary moments,
//! quantiles, trajectory averaging and log-linear decay-rate fits (used to
//! compare measured contraction against the paper's `1 - σ²(B̂)/N` bound).

/// Arithmetic mean; 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance; 0 for fewer than two samples.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Quantile with linear interpolation, `q` in `[0, 1]`.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q={q} out of range");
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median (0.5 quantile).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Element-wise mean of equally-long trajectories — the paper averages 100
/// (Fig. 1) / 1000 (Fig. 2) simulation rounds this way.
pub fn average_trajectories(rounds: &[Vec<f64>]) -> Vec<f64> {
    assert!(!rounds.is_empty(), "no trajectories to average");
    let len = rounds[0].len();
    assert!(
        rounds.iter().all(|r| r.len() == len),
        "trajectory lengths differ"
    );
    let mut out = vec![0.0; len];
    for r in rounds {
        for (o, v) in out.iter_mut().zip(r) {
            *o += v;
        }
    }
    let n = rounds.len() as f64;
    out.iter_mut().for_each(|o| *o /= n);
    out
}

/// Element-wise sample variance across trajectories (the paper remarks that
/// [6] has visibly larger trajectory variance than MP / [15]).
pub fn trajectory_variance(rounds: &[Vec<f64>]) -> Vec<f64> {
    assert!(!rounds.is_empty());
    let len = rounds[0].len();
    let avg = average_trajectories(rounds);
    let mut out = vec![0.0; len];
    if rounds.len() < 2 {
        return out;
    }
    for r in rounds {
        for i in 0..len {
            let d = r[i] - avg[i];
            out[i] += d * d;
        }
    }
    let n = (rounds.len() - 1) as f64;
    out.iter_mut().for_each(|o| *o /= n);
    out
}

/// Ordinary least squares fit `y ≈ a + b x`; returns `(a, b)`.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    assert!(sxx > 0.0, "degenerate x values");
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Fit an exponential decay `y_t ≈ C ρ^t` on the positive entries of a
/// trajectory and return the per-step rate `ρ` (log-linear OLS). This is
/// how the harness extracts the measured contraction factor compared with
/// the paper's predicted `1 - σ²(B̂)/N`. NaN-safe: see
/// [`decay_rate_above`] (this is the `floor = 0` case).
pub fn decay_rate(traj: &[f64]) -> f64 {
    decay_rate_above(traj, 0.0)
}

/// Like [`decay_rate`] but fits only the prefix that stays above
/// `floor` — trajectories that reach the floating-point noise floor
/// flatten out and would bias the fit toward 1.
///
/// NaN-safe (the one shared fitter for the harnesses and the engine):
/// non-finite and non-positive samples are *skipped* (`ln` is undefined
/// there), the fit *stops* at the first positive sample at/below
/// `floor`, and `f64::NAN` is returned when fewer than two fittable
/// samples remain — degenerate trajectories (all-zero, diverged) must
/// never panic the fit or masquerade as a rate.
pub fn decay_rate_above(traj: &[f64], floor: f64) -> f64 {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (t, &v) in traj.iter().enumerate() {
        if !v.is_finite() || v <= 0.0 {
            continue; // log-undefined sample: skip, keep scanning
        }
        if v <= floor {
            break; // noise floor reached: flat from here on
        }
        xs.push(t as f64);
        ys.push(v.ln());
    }
    if xs.len() < 2 {
        return f64::NAN;
    }
    let (_, slope) = linear_fit(&xs, &ys);
    slope.exp()
}

/// Kendall-tau-style pairwise ranking agreement between two score vectors:
/// the fraction of ordered pairs on which they agree. 1.0 = identical
/// ranking. Used by the stopping-criterion extension and examples.
pub fn ranking_agreement(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            let da = a[i] - a[j];
            let db = b[i] - b[j];
            total += 1;
            if (da > 0.0 && db > 0.0) || (da < 0.0 && db < 0.0) || (da == 0.0 && db == 0.0) {
                agree += 1;
            }
        }
    }
    agree as f64 / total as f64
}

/// Indices sorted by descending score — the ranking induced by a PageRank
/// vector (ties broken by index for determinism).
pub fn ranking(scores: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&i, &j| {
        scores[j]
            .partial_cmp(&scores[i])
            .expect("NaN score")
            .then(i.cmp(&j))
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[1.0]), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    #[should_panic]
    fn quantile_empty_panics() {
        quantile(&[], 0.5);
    }

    #[test]
    fn trajectory_average_and_variance() {
        let rounds = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(average_trajectories(&rounds), vec![2.0, 3.0]);
        assert_eq!(trajectory_variance(&rounds), vec![2.0, 2.0]);
    }

    #[test]
    #[should_panic]
    fn trajectory_length_mismatch_panics() {
        average_trajectories(&[vec![1.0], vec![1.0, 2.0]]);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 - 0.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-10);
        assert!((b + 0.5).abs() < 1e-10);
    }

    #[test]
    fn decay_rate_recovers_rho() {
        let rho: f64 = 0.98;
        let traj: Vec<f64> = (0..200).map(|t| 5.0 * rho.powi(t)).collect();
        let got = decay_rate(&traj);
        assert!((got - rho).abs() < 1e-9, "got {got}");
    }

    #[test]
    fn decay_rate_skips_nonpositive() {
        let rho: f64 = 0.9;
        let mut traj: Vec<f64> = (0..100).map(|t| rho.powi(t)).collect();
        traj[3] = 0.0; // e.g. an exactly-converged entry
        let got = decay_rate(&traj);
        assert!((got - rho).abs() < 1e-6);
    }

    #[test]
    fn decay_rate_nan_on_degenerate_input() {
        // Fewer than two fittable samples must yield NaN, not a panic.
        assert!(decay_rate(&[]).is_nan());
        assert!(decay_rate(&[1.0]).is_nan());
        assert!(decay_rate(&[0.0, 0.0, 0.0]).is_nan());
        assert!(decay_rate(&[f64::INFINITY, f64::NAN, 1.0]).is_nan());
        // And the floor cuts before fitting flat noise.
        let traj = [1.0, 1e-2, 1e-30, 1e-30, 1e-30];
        let got = decay_rate_above(&traj, 1e-26);
        assert!((got - 1e-2).abs() < 1e-9, "got {got}");
    }

    #[test]
    fn ranking_and_agreement() {
        let a = [0.1, 0.9, 0.5];
        assert_eq!(ranking(&a), vec![1, 2, 0]);
        assert_eq!(ranking_agreement(&a, &a), 1.0);
        let b = [0.9, 0.1, 0.5]; // swap top and bottom
        let agr = ranking_agreement(&a, &b);
        assert!(agr < 0.5, "agr={agr}");
    }

    #[test]
    fn ranking_deterministic_on_ties() {
        let a = [1.0, 1.0, 0.5];
        assert_eq!(ranking(&a), vec![0, 1, 2]);
    }
}
