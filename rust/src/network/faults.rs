//! Fault injection for the virtual-time network: a seeded [`FaultPlan`]
//! composed with [`crate::network::Transport`].
//!
//! The plan describes what the simulated wire does to traffic —
//! per-transmission **drop** probability, **duplication** probability,
//! adversarial **reordering** (extra latency jitter drawn per frame),
//! scheduled **shard crash/restart windows** (any number, overlap is
//! legal), directional **link windows** (one `src → dst` direction cut
//! on `[at, at + down_for)`, so asymmetric failures are expressible)
//! and **partition windows** (every link crossing a shard bipartition
//! cut and later healed) — plus the seed of the dedicated fault stream,
//! so identical plans replay identical fault realizations whatever the
//! run seed or reliability mode. The plan is pure data; the transport
//! owns the stream and makes the per-frame decisions (every frame —
//! data, ack, retransmission — is routed through the window check), and
//! [`crate::coordinator::msgpass::MsgpassRuntime`] interprets the
//! windows (queue discard, checkpoint restore, peer re-sync on restart
//! *and* on heal).
//!
//! [`Reliability`] selects what the transport layers on top of that
//! wire: `raw` is the PR-6 fire-and-forget semantics (drops lose
//! deltas, duplicates double-apply), `rel` adds sequence numbers,
//! receiver-side dedup and ack/retransmit with exponential backoff —
//! the same runtime raced honestly vs robustly under one plan.
//!
//! [`FaultCounters`] is the cross-layer ledger threaded into
//! [`crate::engine::report::SolverReport`] and `BENCH_faults.json`.

use std::fmt;

/// Default seed of the dedicated fault stream: registry-built plans use
/// it so a spec string alone pins the fault realization.
pub const DEFAULT_FAULT_SEED: u64 = 0xFA01_5EED;

/// Delivery semantics of a [`crate::network::Transport`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Reliability {
    /// Fire-and-forget (the PR-6 wire): whatever the fault plan drops
    /// or duplicates is applied as-is.
    #[default]
    Raw,
    /// Sequence-numbered links with receiver dedup, acks and
    /// exponential-backoff retransmission under a retry budget.
    Reliable,
}

impl Reliability {
    /// Registry segment (`raw` | `rel`).
    pub fn key(self) -> &'static str {
        match self {
            Reliability::Raw => "raw",
            Reliability::Reliable => "rel",
        }
    }

    pub fn parse(s: &str) -> Option<Reliability> {
        match s {
            "raw" => Some(Reliability::Raw),
            "rel" | "reliable" => Some(Reliability::Reliable),
            _ => None,
        }
    }
}

/// A scheduled crash/restart window for one shard: the shard is down on
/// `[at, at + down_for)` in virtual time. While down it activates
/// nothing and every frame delivered to it is lost with its queue; at
/// `at` its replica memory of *unowned* pages is lost (the owned
/// `(x_k, r_k)` pairs are the durable two-scalars-per-page checkpoint),
/// and at restart the peers re-sync the lost entries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashWindow {
    pub shard: usize,
    /// Virtual time of the crash.
    pub at: f64,
    /// How long the shard stays down; it restarts at `at + down_for`.
    pub down_for: f64,
}

impl CrashWindow {
    pub fn restart_at(&self) -> f64 {
        self.at + self.down_for
    }

    /// Parse the `<shard>@<at>+<down_for>` segment body (the part after
    /// the `crash` tag), e.g. `1@64+32`.
    pub fn parse(s: &str) -> Result<CrashWindow, String> {
        let grammar = "crash<shard>@<at>+<down-for>, e.g. crash1@64+32";
        let (shard, rest) = s
            .split_once('@')
            .ok_or_else(|| format!("bad crash spec {s:?} ({grammar})"))?;
        let (at, down_for) = rest
            .split_once('+')
            .ok_or_else(|| format!("bad crash spec {s:?} ({grammar})"))?;
        let shard: usize = shard
            .parse()
            .map_err(|_| format!("bad crash shard {shard:?} ({grammar})"))?;
        let at: f64 = at
            .parse()
            .map_err(|_| format!("bad crash time {at:?} ({grammar})"))?;
        let down_for: f64 = down_for
            .parse()
            .map_err(|_| format!("bad crash duration {down_for:?} ({grammar})"))?;
        if !(at.is_finite() && at >= 0.0) {
            return Err(format!("crash time must be finite and >= 0, got {at}"));
        }
        if !(down_for.is_finite() && down_for > 0.0) {
            return Err(format!("crash duration must be finite and > 0, got {down_for}"));
        }
        Ok(CrashWindow { shard, at, down_for })
    }

    /// Canonical segment body (inverse of [`CrashWindow::parse`]).
    pub fn key(&self) -> String {
        format!("{}@{}+{}", self.shard, self.at, self.down_for)
    }
}

impl fmt::Display for CrashWindow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {} down on [{}, {})", self.shard, self.at, self.restart_at())
    }
}

/// A scheduled *directional* link failure: every frame travelling
/// `src → dst` (data, duplicates, retransmissions — and acks for data
/// that flowed `dst → src`) is lost on `[at, at + down_for)` in virtual
/// time. The reverse direction is untouched, so an asymmetric failure
/// (`A → B` up, `B → A` down) is one window, not two.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkWindow {
    pub src: usize,
    pub dst: usize,
    /// Virtual time the link goes down.
    pub at: f64,
    /// How long it stays down; it heals at `at + down_for`.
    pub down_for: f64,
}

impl LinkWindow {
    pub fn heal_at(&self) -> f64 {
        self.at + self.down_for
    }

    /// Parse the `<src>-<dst>@<at>+<down_for>` segment body (the part
    /// after the `link` tag), e.g. `0-1@64+32`. Self-links are rejected
    /// here — a shard's frames to itself never touch the wire.
    pub fn parse(s: &str) -> Result<LinkWindow, String> {
        let grammar = "link<src>-<dst>@<at>+<down-for>, e.g. link0-1@64+32";
        let (pair, rest) = s
            .split_once('@')
            .ok_or_else(|| format!("bad link spec {s:?} ({grammar})"))?;
        let (src, dst) = pair
            .split_once('-')
            .ok_or_else(|| format!("bad link spec {s:?} ({grammar})"))?;
        let (at, down_for) = rest
            .split_once('+')
            .ok_or_else(|| format!("bad link spec {s:?} ({grammar})"))?;
        let src: usize = src
            .parse()
            .map_err(|_| format!("bad link src shard {src:?} ({grammar})"))?;
        let dst: usize = dst
            .parse()
            .map_err(|_| format!("bad link dst shard {dst:?} ({grammar})"))?;
        let at: f64 = at
            .parse()
            .map_err(|_| format!("bad link time {at:?} ({grammar})"))?;
        let down_for: f64 = down_for
            .parse()
            .map_err(|_| format!("bad link duration {down_for:?} ({grammar})"))?;
        if src == dst {
            return Err(format!(
                "link window {s:?} is a self-link (src == dst == {src}); \
                 links connect distinct shards"
            ));
        }
        if !(at.is_finite() && at >= 0.0) {
            return Err(format!("link time must be finite and >= 0, got {at}"));
        }
        if !(down_for.is_finite() && down_for > 0.0) {
            return Err(format!("link duration must be finite and > 0, got {down_for}"));
        }
        Ok(LinkWindow { src, dst, at, down_for })
    }

    /// Canonical segment body (inverse of [`LinkWindow::parse`]).
    pub fn key(&self) -> String {
        format!("{}-{}@{}+{}", self.src, self.dst, self.at, self.down_for)
    }
}

impl fmt::Display for LinkWindow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "link {}->{} down on [{}, {})",
            self.src,
            self.dst,
            self.at,
            self.heal_at()
        )
    }
}

/// A scheduled network partition: every link crossing the bipartition
/// `{left} | {rest}` is cut — both directions — on `[at, at + down_for)`
/// and heals at `at + down_for`. Convenience over 2·|left|·|rest|
/// individual [`LinkWindow`]s; the heal instant is what triggers the
/// runtime's re-sync of the two drifted halves.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionWindow {
    /// One side of the bipartition, sorted and deduplicated; every
    /// shard not listed is on the other side.
    pub left: Vec<usize>,
    /// Virtual time the partition begins.
    pub at: f64,
    /// How long it lasts; it heals at `at + down_for`.
    pub down_for: f64,
}

impl PartitionWindow {
    pub fn new(mut left: Vec<usize>, at: f64, down_for: f64) -> Self {
        left.sort_unstable();
        left.dedup();
        PartitionWindow { left, at, down_for }
    }

    pub fn heal_at(&self) -> f64 {
        self.at + self.down_for
    }

    /// Whether the directed link `src → dst` crosses the bipartition.
    pub fn cuts(&self, src: usize, dst: usize) -> bool {
        self.left.binary_search(&src).is_ok() != self.left.binary_search(&dst).is_ok()
    }

    /// Parse the `<s1>.<s2>…@<at>+<down_for>` segment body (the part
    /// after the `part` tag), e.g. `0.1@64+32` — shards {0, 1} cut off
    /// from everything else on `[64, 96)`.
    pub fn parse(s: &str) -> Result<PartitionWindow, String> {
        let grammar = "part<s1>.<s2>...@<at>+<down-for>, e.g. part0.1@64+32";
        let (members, rest) = s
            .split_once('@')
            .ok_or_else(|| format!("bad partition spec {s:?} ({grammar})"))?;
        let (at, down_for) = rest
            .split_once('+')
            .ok_or_else(|| format!("bad partition spec {s:?} ({grammar})"))?;
        let mut left = Vec::new();
        for m in members.split('.') {
            let shard: usize = m
                .parse()
                .map_err(|_| format!("bad partition shard {m:?} ({grammar})"))?;
            left.push(shard);
        }
        let at: f64 = at
            .parse()
            .map_err(|_| format!("bad partition time {at:?} ({grammar})"))?;
        let down_for: f64 = down_for
            .parse()
            .map_err(|_| format!("bad partition duration {down_for:?} ({grammar})"))?;
        if !(at.is_finite() && at >= 0.0) {
            return Err(format!("partition time must be finite and >= 0, got {at}"));
        }
        if !(down_for.is_finite() && down_for > 0.0) {
            return Err(format!("partition duration must be finite and > 0, got {down_for}"));
        }
        Ok(PartitionWindow::new(left, at, down_for))
    }

    /// Canonical segment body (inverse of [`PartitionWindow::parse`]).
    pub fn key(&self) -> String {
        let members: Vec<String> = self.left.iter().map(|s| s.to_string()).collect();
        format!("{}@{}+{}", members.join("."), self.at, self.down_for)
    }
}

impl fmt::Display for PartitionWindow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "partition {{{}}} | rest on [{}, {})",
            self.left.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(","),
            self.at,
            self.heal_at()
        )
    }
}

/// A seeded fault plan — pure data describing the injected wire faults.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Per-transmission drop probability in `[0, 1)`.
    pub drop: f64,
    /// Per-transmission duplication probability in `[0, 1)` (the
    /// duplicate is its own metered frame with its own latency draw).
    pub duplicate: f64,
    /// Adversarial reordering: extra latency drawn uniformly from
    /// `[0, jitter]` per frame, on top of the latency model.
    pub jitter: f64,
    /// Scheduled crash/restart windows — any number; overlapping
    /// multi-shard crashes are a legal plan.
    pub crashes: Vec<CrashWindow>,
    /// Scheduled directional link failures.
    pub links: Vec<LinkWindow>,
    /// Scheduled bipartition cuts (every crossing link, both ways).
    pub partitions: Vec<PartitionWindow>,
    /// Seed of the dedicated fault stream (drop/duplicate/jitter
    /// decisions) — independent of the run seed, so `raw` and `rel` are
    /// raced under the *identical* plan.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            drop: 0.0,
            duplicate: 0.0,
            jitter: 0.0,
            crashes: Vec::new(),
            links: Vec::new(),
            partitions: Vec::new(),
            seed: DEFAULT_FAULT_SEED,
        }
    }
}

impl FaultPlan {
    /// Whether the plan injects anything at all. An empty plan composed
    /// with a transport is normalized away, keeping the no-fault path
    /// bit-identical to the PR-6 wire.
    pub fn is_empty(&self) -> bool {
        self.drop == 0.0
            && self.duplicate == 0.0
            && self.jitter == 0.0
            && self.crashes.is_empty()
            && self.links.is_empty()
            && self.partitions.is_empty()
    }

    pub fn with_drop(mut self, p: f64) -> Self {
        assert!((0.0..1.0).contains(&p), "drop probability out of [0,1): {p}");
        self.drop = p;
        self
    }

    pub fn with_duplicate(mut self, p: f64) -> Self {
        assert!((0.0..1.0).contains(&p), "duplicate probability out of [0,1): {p}");
        self.duplicate = p;
        self
    }

    pub fn with_jitter(mut self, jitter: f64) -> Self {
        assert!(jitter >= 0.0 && jitter.is_finite(), "jitter must be finite and >= 0");
        self.jitter = jitter;
        self
    }

    pub fn with_crash(mut self, crash: CrashWindow) -> Self {
        self.crashes.push(crash);
        self
    }

    pub fn with_link(mut self, link: LinkWindow) -> Self {
        assert!(link.src != link.dst, "self-link window: src == dst == {}", link.src);
        self.links.push(link);
        self
    }

    pub fn with_partition(mut self, partition: PartitionWindow) -> Self {
        self.partitions.push(partition);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Whether `shard` is inside one of its crash windows at `time`.
    pub fn is_down(&self, shard: usize, time: f64) -> bool {
        self.crashes
            .iter()
            .any(|c| c.shard == shard && time >= c.at && time < c.restart_at())
    }

    /// Whether the directed link `src → dst` is cut at `time` — by a
    /// scheduled [`LinkWindow`] or by a [`PartitionWindow`] whose
    /// bipartition the link crosses. Windows are half-open `[at, heal)`.
    pub fn is_link_down(&self, src: usize, dst: usize, time: f64) -> bool {
        self.links
            .iter()
            .any(|l| l.src == src && l.dst == dst && time >= l.at && time < l.heal_at())
            || self
                .partitions
                .iter()
                .any(|p| p.cuts(src, dst) && time >= p.at && time < p.heal_at())
    }

    /// Check every window against the actual shard count, so a plan
    /// naming an unreachable shard (or a degenerate bipartition) fails
    /// loudly where it is built instead of silently never firing.
    pub fn validate(&self, shards: usize) -> Result<(), String> {
        for (i, c) in self.crashes.iter().enumerate() {
            if c.shard >= shards {
                return Err(format!(
                    "crash window #{i} (crash{}) names shard {} but valid shards are 0..{shards}",
                    c.key(),
                    c.shard
                ));
            }
        }
        for (i, l) in self.links.iter().enumerate() {
            if l.src == l.dst {
                return Err(format!(
                    "link window #{i} (link{}) is a self-link; \
                     src and dst must be distinct shards in 0..{shards}",
                    l.key()
                ));
            }
            for (role, s) in [("src", l.src), ("dst", l.dst)] {
                if s >= shards {
                    return Err(format!(
                        "link window #{i} (link{}) names {role} shard {s} \
                         but valid shards are 0..{shards}",
                        l.key()
                    ));
                }
            }
        }
        for (i, p) in self.partitions.iter().enumerate() {
            for &s in &p.left {
                if s >= shards {
                    return Err(format!(
                        "partition window #{i} (part{}) names shard {s} \
                         but valid shards are 0..{shards}",
                        p.key()
                    ));
                }
            }
            if p.left.is_empty() || p.left.len() >= shards {
                return Err(format!(
                    "partition window #{i} (part{}) is not a proper bipartition \
                     of 0..{shards}: both sides must be non-empty",
                    p.key()
                ));
            }
        }
        Ok(())
    }
}

/// What a [`crate::network::Transport`] composes on the plain wire: an
/// optional fault plan plus the delivery semantics. The default profile
/// (no plan, raw) *is* the PR-6 wire.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetProfile {
    pub faults: Option<FaultPlan>,
    pub reliability: Reliability,
}

impl NetProfile {
    /// A raw wire with `plan` injected.
    pub fn faulty(plan: FaultPlan) -> Self {
        NetProfile { faults: Some(plan), reliability: Reliability::Raw }
    }

    /// Switch to reliable delivery (builder-style).
    pub fn reliable(mut self) -> Self {
        self.reliability = Reliability::Reliable;
        self
    }
}

/// The fault-injection ledger: what the wire did to the traffic and
/// what the recovery machinery had to repair. Transport-level fields
/// (drops, dedup suppressions, retransmissions) and runtime-level
/// fields (recoveries, divergence gauge) merge into one record per
/// solver in [`crate::engine::report::SolverReport`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultCounters {
    /// Frames lost: dropped on the wire by the plan, or delivered into
    /// a crashed shard's discarded queue.
    pub messages_dropped: u64,
    /// Frames the reliable receiver discarded as already-seen sequence
    /// numbers (wire duplicates and spurious retransmissions).
    pub duplicates_suppressed: u64,
    /// Retransmission attempts by the reliable sender.
    pub retransmits: u64,
    /// Shard restarts completed (checkpoint restore + peer re-sync).
    pub recoveries: u64,
    /// Max over crash instants of `(1/N)·Σ_j (r_view_j − (y − Bx)_j)²`
    /// — how far the owner-authoritative residual had diverged from the
    /// true residual when the crash hit (in-flight and lost mass).
    pub residual_divergence_at_crash: f64,
    /// Frames lost to a cut link — a scheduled [`LinkWindow`] or a
    /// [`PartitionWindow`] crossing (data, duplicates, retransmissions
    /// and acks all count).
    pub link_downs: u64,
    /// Partition windows that completed their heal (re-sync fired).
    pub partitions_healed: u64,
    /// Max over links of the reliable sender's EWMA ack-RTT estimate,
    /// in virtual-time units — the base the adaptive retransmission
    /// backoff and abandon budget are expressed in. Zero until the
    /// first ack RTT is observed.
    pub rtt_estimate: f64,
}

impl FaultCounters {
    /// Whether anything at all was recorded.
    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }

    /// Merge another ledger: event counters add, the gauges (divergence,
    /// RTT estimate) take the max — both commute, so cross-round
    /// accumulation is thread-invariant.
    pub fn absorb(&mut self, other: &FaultCounters) {
        self.messages_dropped += other.messages_dropped;
        self.duplicates_suppressed += other.duplicates_suppressed;
        self.retransmits += other.retransmits;
        self.recoveries += other.recoveries;
        self.residual_divergence_at_crash =
            self.residual_divergence_at_crash.max(other.residual_divergence_at_crash);
        self.link_downs += other.link_downs;
        self.partitions_healed += other.partitions_healed;
        self.rtt_estimate = self.rtt_estimate.max(other.rtt_estimate);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_window_parses_and_round_trips() {
        let c = CrashWindow::parse("1@64+32").expect("parses");
        assert_eq!(c, CrashWindow { shard: 1, at: 64.0, down_for: 32.0 });
        assert_eq!(c.key(), "1@64+32");
        assert_eq!(c.restart_at(), 96.0);
        let c = CrashWindow::parse("0@12.5+0.5").expect("parses");
        assert_eq!(c.key(), "0@12.5+0.5");
        assert_eq!(CrashWindow::parse(&c.key()).expect("round-trips"), c);
    }

    #[test]
    fn bad_crash_specs_are_loud() {
        for bad in ["", "1", "1@64", "x@1+2", "1@x+2", "1@1+x", "1@-3+2", "1@3+0", "1@3+-1"] {
            assert!(CrashWindow::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn down_windows_are_half_open() {
        let plan = FaultPlan::default().with_crash(CrashWindow {
            shard: 2,
            at: 10.0,
            down_for: 5.0,
        });
        assert!(!plan.is_down(2, 9.999));
        assert!(plan.is_down(2, 10.0));
        assert!(plan.is_down(2, 14.999));
        assert!(!plan.is_down(2, 15.0), "restart instant is up");
        assert!(!plan.is_down(1, 12.0), "other shards unaffected");
    }

    #[test]
    fn link_window_parses_and_round_trips() {
        let l = LinkWindow::parse("0-1@64+32").expect("parses");
        assert_eq!(l, LinkWindow { src: 0, dst: 1, at: 64.0, down_for: 32.0 });
        assert_eq!(l.key(), "0-1@64+32");
        assert_eq!(l.heal_at(), 96.0);
        let l = LinkWindow::parse("3-0@12.5+0.5").expect("parses");
        assert_eq!(l.key(), "3-0@12.5+0.5");
        assert_eq!(LinkWindow::parse(&l.key()).expect("round-trips"), l);
    }

    #[test]
    fn bad_link_specs_are_loud() {
        for bad in [
            "", "0-1", "0-1@64", "0@64+32", "x-1@1+2", "0-x@1+2", "0-1@x+2", "0-1@1+x",
            "0-1@-3+2", "0-1@3+0", "0-1@3+-1",
        ] {
            assert!(LinkWindow::parse(bad).is_err(), "{bad:?} must not parse");
        }
        let self_link = LinkWindow::parse("2-2@5+5").unwrap_err();
        assert!(self_link.contains("self-link"), "{self_link}");
    }

    #[test]
    fn partition_window_parses_sorts_and_round_trips() {
        let p = PartitionWindow::parse("0.1@64+32").expect("parses");
        assert_eq!(p, PartitionWindow::new(vec![0, 1], 64.0, 32.0));
        assert_eq!(p.key(), "0.1@64+32");
        assert_eq!(p.heal_at(), 96.0);
        // Members are canonicalized: sorted and deduplicated.
        let p = PartitionWindow::parse("2.0.2@8+4").expect("parses");
        assert_eq!(p.left, vec![0, 2]);
        assert_eq!(p.key(), "0.2@8+4");
        assert_eq!(PartitionWindow::parse(&p.key()).expect("round-trips"), p);
    }

    #[test]
    fn bad_partition_specs_are_loud() {
        for bad in ["", "0.1", "0.1@64", "x@1+2", "0.x@1+2", "0@x+2", "0@1+x", "0@-3+2", "0@3+0"] {
            assert!(PartitionWindow::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn partition_cuts_only_crossing_links() {
        let p = PartitionWindow::new(vec![0, 1], 10.0, 5.0);
        assert!(p.cuts(0, 2) && p.cuts(2, 0), "crossing links cut both ways");
        assert!(p.cuts(1, 3) && p.cuts(3, 1));
        assert!(!p.cuts(0, 1) && !p.cuts(1, 0), "intra-left links survive");
        assert!(!p.cuts(2, 3) && !p.cuts(3, 2), "intra-rest links survive");
    }

    #[test]
    fn link_down_windows_are_half_open_and_directional() {
        let plan = FaultPlan::default()
            .with_link(LinkWindow { src: 0, dst: 1, at: 10.0, down_for: 5.0 });
        assert!(!plan.is_link_down(0, 1, 9.999));
        assert!(plan.is_link_down(0, 1, 10.0));
        assert!(plan.is_link_down(0, 1, 14.999));
        assert!(!plan.is_link_down(0, 1, 15.0), "heal instant is up");
        assert!(!plan.is_link_down(1, 0, 12.0), "reverse direction stays up");

        let plan = FaultPlan::default()
            .with_partition(PartitionWindow::new(vec![0], 10.0, 5.0));
        assert!(plan.is_link_down(0, 1, 12.0) && plan.is_link_down(1, 0, 12.0));
        assert!(!plan.is_link_down(1, 2, 12.0), "intra-side link stays up");
        assert!(!plan.is_link_down(0, 1, 15.0), "partition heals");
    }

    #[test]
    fn plan_validation_names_the_offender_and_the_range() {
        let ok = FaultPlan::default()
            .with_crash(CrashWindow { shard: 1, at: 4.0, down_for: 2.0 })
            .with_crash(CrashWindow { shard: 2, at: 5.0, down_for: 2.0 })
            .with_link(LinkWindow { src: 0, dst: 3, at: 1.0, down_for: 1.0 })
            .with_partition(PartitionWindow::new(vec![0, 1], 2.0, 2.0));
        assert!(ok.validate(4).is_ok(), "overlapping crashes are a legal plan");

        let e = FaultPlan::default()
            .with_crash(CrashWindow { shard: 9, at: 1.0, down_for: 1.0 })
            .validate(2)
            .unwrap_err();
        assert!(e.contains("crash window #0") && e.contains("shard 9") && e.contains("0..2"), "{e}");

        let e = FaultPlan::default()
            .with_link(LinkWindow { src: 0, dst: 5, at: 1.0, down_for: 1.0 })
            .validate(4)
            .unwrap_err();
        assert!(e.contains("link window #0") && e.contains("dst shard 5") && e.contains("0..4"), "{e}");

        let mut self_link = FaultPlan::default();
        self_link.links.push(LinkWindow { src: 1, dst: 1, at: 1.0, down_for: 1.0 });
        let e = self_link.validate(4).unwrap_err();
        assert!(e.contains("self-link"), "{e}");

        let e = FaultPlan::default()
            .with_partition(PartitionWindow::new(vec![0, 7], 1.0, 1.0))
            .validate(4)
            .unwrap_err();
        assert!(e.contains("partition window #0") && e.contains("shard 7") && e.contains("0..4"), "{e}");

        let e = FaultPlan::default()
            .with_partition(PartitionWindow::new(vec![0, 1], 1.0, 1.0))
            .validate(2)
            .unwrap_err();
        assert!(e.contains("bipartition"), "{e}");
    }

    #[test]
    fn empty_plan_detection() {
        assert!(FaultPlan::default().is_empty());
        assert!(!FaultPlan::default().with_drop(0.1).is_empty());
        assert!(!FaultPlan::default().with_duplicate(0.1).is_empty());
        assert!(!FaultPlan::default().with_jitter(1.0).is_empty());
        assert!(
            !FaultPlan::default()
                .with_crash(CrashWindow { shard: 0, at: 1.0, down_for: 1.0 })
                .is_empty()
        );
        assert!(
            !FaultPlan::default()
                .with_link(LinkWindow { src: 0, dst: 1, at: 1.0, down_for: 1.0 })
                .is_empty(),
            "a links-only plan must not be normalized away"
        );
        assert!(
            !FaultPlan::default()
                .with_partition(PartitionWindow::new(vec![0], 1.0, 1.0))
                .is_empty(),
            "a partitions-only plan must not be normalized away"
        );
    }

    #[test]
    fn counters_absorb_sums_and_maxes() {
        let mut a = FaultCounters {
            messages_dropped: 3,
            duplicates_suppressed: 1,
            retransmits: 5,
            recoveries: 1,
            residual_divergence_at_crash: 0.25,
            link_downs: 4,
            partitions_healed: 1,
            rtt_estimate: 2.0,
        };
        let b = FaultCounters {
            messages_dropped: 2,
            duplicates_suppressed: 0,
            retransmits: 1,
            recoveries: 0,
            residual_divergence_at_crash: 0.5,
            link_downs: 3,
            partitions_healed: 0,
            rtt_estimate: 1.5,
        };
        a.absorb(&b);
        assert_eq!(a.messages_dropped, 5);
        assert_eq!(a.duplicates_suppressed, 1);
        assert_eq!(a.retransmits, 6);
        assert_eq!(a.recoveries, 1);
        assert_eq!(a.residual_divergence_at_crash, 0.5);
        assert_eq!(a.link_downs, 7);
        assert_eq!(a.partitions_healed, 1);
        assert_eq!(a.rtt_estimate, 2.0, "RTT gauge max-merges");
        assert!(a.any());
        assert!(!FaultCounters::default().any());
        let gauge_only = FaultCounters { rtt_estimate: 3.5, ..FaultCounters::default() };
        assert!(gauge_only.any(), "a nonzero RTT gauge alone counts as activity");
    }

    #[test]
    fn reliability_keys_round_trip() {
        assert_eq!(Reliability::parse("raw"), Some(Reliability::Raw));
        assert_eq!(Reliability::parse("rel"), Some(Reliability::Reliable));
        assert_eq!(Reliability::parse("reliable"), Some(Reliability::Reliable));
        assert_eq!(Reliability::parse("bogus"), None);
        assert_eq!(Reliability::Raw.key(), "raw");
        assert_eq!(Reliability::Reliable.key(), "rel");
        assert_eq!(Reliability::default(), Reliability::Raw);
    }
}
