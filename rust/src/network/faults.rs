//! Fault injection for the virtual-time network: a seeded [`FaultPlan`]
//! composed with [`crate::network::Transport`].
//!
//! The plan describes what the simulated wire does to traffic —
//! per-transmission **drop** probability, **duplication** probability,
//! adversarial **reordering** (extra latency jitter drawn per frame) and
//! scheduled **shard crash/restart windows** — plus the seed of the
//! dedicated fault stream, so identical plans replay identical fault
//! realizations whatever the run seed or reliability mode. The plan is
//! pure data; the transport owns the stream and makes the per-frame
//! decisions, and [`crate::coordinator::msgpass::MsgpassRuntime`]
//! interprets the crash windows (queue discard, checkpoint restore,
//! peer re-sync).
//!
//! [`Reliability`] selects what the transport layers on top of that
//! wire: `raw` is the PR-6 fire-and-forget semantics (drops lose
//! deltas, duplicates double-apply), `rel` adds sequence numbers,
//! receiver-side dedup and ack/retransmit with exponential backoff —
//! the same runtime raced honestly vs robustly under one plan.
//!
//! [`FaultCounters`] is the cross-layer ledger threaded into
//! [`crate::engine::report::SolverReport`] and `BENCH_faults.json`.

use std::fmt;

/// Default seed of the dedicated fault stream: registry-built plans use
/// it so a spec string alone pins the fault realization.
pub const DEFAULT_FAULT_SEED: u64 = 0xFA01_5EED;

/// Delivery semantics of a [`crate::network::Transport`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Reliability {
    /// Fire-and-forget (the PR-6 wire): whatever the fault plan drops
    /// or duplicates is applied as-is.
    #[default]
    Raw,
    /// Sequence-numbered links with receiver dedup, acks and
    /// exponential-backoff retransmission under a retry budget.
    Reliable,
}

impl Reliability {
    /// Registry segment (`raw` | `rel`).
    pub fn key(self) -> &'static str {
        match self {
            Reliability::Raw => "raw",
            Reliability::Reliable => "rel",
        }
    }

    pub fn parse(s: &str) -> Option<Reliability> {
        match s {
            "raw" => Some(Reliability::Raw),
            "rel" | "reliable" => Some(Reliability::Reliable),
            _ => None,
        }
    }
}

/// A scheduled crash/restart window for one shard: the shard is down on
/// `[at, at + down_for)` in virtual time. While down it activates
/// nothing and every frame delivered to it is lost with its queue; at
/// `at` its replica memory of *unowned* pages is lost (the owned
/// `(x_k, r_k)` pairs are the durable two-scalars-per-page checkpoint),
/// and at restart the peers re-sync the lost entries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashWindow {
    pub shard: usize,
    /// Virtual time of the crash.
    pub at: f64,
    /// How long the shard stays down; it restarts at `at + down_for`.
    pub down_for: f64,
}

impl CrashWindow {
    pub fn restart_at(&self) -> f64 {
        self.at + self.down_for
    }

    /// Parse the `<shard>@<at>+<down_for>` segment body (the part after
    /// the `crash` tag), e.g. `1@64+32`.
    pub fn parse(s: &str) -> Result<CrashWindow, String> {
        let grammar = "crash<shard>@<at>+<down-for>, e.g. crash1@64+32";
        let (shard, rest) = s
            .split_once('@')
            .ok_or_else(|| format!("bad crash spec {s:?} ({grammar})"))?;
        let (at, down_for) = rest
            .split_once('+')
            .ok_or_else(|| format!("bad crash spec {s:?} ({grammar})"))?;
        let shard: usize = shard
            .parse()
            .map_err(|_| format!("bad crash shard {shard:?} ({grammar})"))?;
        let at: f64 = at
            .parse()
            .map_err(|_| format!("bad crash time {at:?} ({grammar})"))?;
        let down_for: f64 = down_for
            .parse()
            .map_err(|_| format!("bad crash duration {down_for:?} ({grammar})"))?;
        if !(at.is_finite() && at >= 0.0) {
            return Err(format!("crash time must be finite and >= 0, got {at}"));
        }
        if !(down_for.is_finite() && down_for > 0.0) {
            return Err(format!("crash duration must be finite and > 0, got {down_for}"));
        }
        Ok(CrashWindow { shard, at, down_for })
    }

    /// Canonical segment body (inverse of [`CrashWindow::parse`]).
    pub fn key(&self) -> String {
        format!("{}@{}+{}", self.shard, self.at, self.down_for)
    }
}

impl fmt::Display for CrashWindow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "shard {} down on [{}, {})", self.shard, self.at, self.restart_at())
    }
}

/// A seeded fault plan — pure data describing the injected wire faults.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Per-transmission drop probability in `[0, 1)`.
    pub drop: f64,
    /// Per-transmission duplication probability in `[0, 1)` (the
    /// duplicate is its own metered frame with its own latency draw).
    pub duplicate: f64,
    /// Adversarial reordering: extra latency drawn uniformly from
    /// `[0, jitter]` per frame, on top of the latency model.
    pub jitter: f64,
    /// Scheduled crash/restart windows.
    pub crashes: Vec<CrashWindow>,
    /// Seed of the dedicated fault stream (drop/duplicate/jitter
    /// decisions) — independent of the run seed, so `raw` and `rel` are
    /// raced under the *identical* plan.
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            drop: 0.0,
            duplicate: 0.0,
            jitter: 0.0,
            crashes: Vec::new(),
            seed: DEFAULT_FAULT_SEED,
        }
    }
}

impl FaultPlan {
    /// Whether the plan injects anything at all. An empty plan composed
    /// with a transport is normalized away, keeping the no-fault path
    /// bit-identical to the PR-6 wire.
    pub fn is_empty(&self) -> bool {
        self.drop == 0.0
            && self.duplicate == 0.0
            && self.jitter == 0.0
            && self.crashes.is_empty()
    }

    pub fn with_drop(mut self, p: f64) -> Self {
        assert!((0.0..1.0).contains(&p), "drop probability out of [0,1): {p}");
        self.drop = p;
        self
    }

    pub fn with_duplicate(mut self, p: f64) -> Self {
        assert!((0.0..1.0).contains(&p), "duplicate probability out of [0,1): {p}");
        self.duplicate = p;
        self
    }

    pub fn with_jitter(mut self, jitter: f64) -> Self {
        assert!(jitter >= 0.0 && jitter.is_finite(), "jitter must be finite and >= 0");
        self.jitter = jitter;
        self
    }

    pub fn with_crash(mut self, crash: CrashWindow) -> Self {
        self.crashes.push(crash);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Whether `shard` is inside one of its crash windows at `time`.
    pub fn is_down(&self, shard: usize, time: f64) -> bool {
        self.crashes
            .iter()
            .any(|c| c.shard == shard && time >= c.at && time < c.restart_at())
    }
}

/// What a [`crate::network::Transport`] composes on the plain wire: an
/// optional fault plan plus the delivery semantics. The default profile
/// (no plan, raw) *is* the PR-6 wire.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetProfile {
    pub faults: Option<FaultPlan>,
    pub reliability: Reliability,
}

impl NetProfile {
    /// A raw wire with `plan` injected.
    pub fn faulty(plan: FaultPlan) -> Self {
        NetProfile { faults: Some(plan), reliability: Reliability::Raw }
    }

    /// Switch to reliable delivery (builder-style).
    pub fn reliable(mut self) -> Self {
        self.reliability = Reliability::Reliable;
        self
    }
}

/// The fault-injection ledger: what the wire did to the traffic and
/// what the recovery machinery had to repair. Transport-level fields
/// (drops, dedup suppressions, retransmissions) and runtime-level
/// fields (recoveries, divergence gauge) merge into one record per
/// solver in [`crate::engine::report::SolverReport`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultCounters {
    /// Frames lost: dropped on the wire by the plan, or delivered into
    /// a crashed shard's discarded queue.
    pub messages_dropped: u64,
    /// Frames the reliable receiver discarded as already-seen sequence
    /// numbers (wire duplicates and spurious retransmissions).
    pub duplicates_suppressed: u64,
    /// Retransmission attempts by the reliable sender.
    pub retransmits: u64,
    /// Shard restarts completed (checkpoint restore + peer re-sync).
    pub recoveries: u64,
    /// Max over crash instants of `(1/N)·Σ_j (r_view_j − (y − Bx)_j)²`
    /// — how far the owner-authoritative residual had diverged from the
    /// true residual when the crash hit (in-flight and lost mass).
    pub residual_divergence_at_crash: f64,
}

impl FaultCounters {
    /// Whether anything at all was recorded.
    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }

    /// Merge another ledger: event counters add, the divergence gauge
    /// takes the max — both commute, so cross-round accumulation is
    /// thread-invariant.
    pub fn absorb(&mut self, other: &FaultCounters) {
        self.messages_dropped += other.messages_dropped;
        self.duplicates_suppressed += other.duplicates_suppressed;
        self.retransmits += other.retransmits;
        self.recoveries += other.recoveries;
        self.residual_divergence_at_crash =
            self.residual_divergence_at_crash.max(other.residual_divergence_at_crash);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_window_parses_and_round_trips() {
        let c = CrashWindow::parse("1@64+32").expect("parses");
        assert_eq!(c, CrashWindow { shard: 1, at: 64.0, down_for: 32.0 });
        assert_eq!(c.key(), "1@64+32");
        assert_eq!(c.restart_at(), 96.0);
        let c = CrashWindow::parse("0@12.5+0.5").expect("parses");
        assert_eq!(c.key(), "0@12.5+0.5");
        assert_eq!(CrashWindow::parse(&c.key()).expect("round-trips"), c);
    }

    #[test]
    fn bad_crash_specs_are_loud() {
        for bad in ["", "1", "1@64", "x@1+2", "1@x+2", "1@1+x", "1@-3+2", "1@3+0", "1@3+-1"] {
            assert!(CrashWindow::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn down_windows_are_half_open() {
        let plan = FaultPlan::default().with_crash(CrashWindow {
            shard: 2,
            at: 10.0,
            down_for: 5.0,
        });
        assert!(!plan.is_down(2, 9.999));
        assert!(plan.is_down(2, 10.0));
        assert!(plan.is_down(2, 14.999));
        assert!(!plan.is_down(2, 15.0), "restart instant is up");
        assert!(!plan.is_down(1, 12.0), "other shards unaffected");
    }

    #[test]
    fn empty_plan_detection() {
        assert!(FaultPlan::default().is_empty());
        assert!(!FaultPlan::default().with_drop(0.1).is_empty());
        assert!(!FaultPlan::default().with_duplicate(0.1).is_empty());
        assert!(!FaultPlan::default().with_jitter(1.0).is_empty());
        assert!(
            !FaultPlan::default()
                .with_crash(CrashWindow { shard: 0, at: 1.0, down_for: 1.0 })
                .is_empty()
        );
    }

    #[test]
    fn counters_absorb_sums_and_maxes() {
        let mut a = FaultCounters {
            messages_dropped: 3,
            duplicates_suppressed: 1,
            retransmits: 5,
            recoveries: 1,
            residual_divergence_at_crash: 0.25,
        };
        let b = FaultCounters {
            messages_dropped: 2,
            duplicates_suppressed: 0,
            retransmits: 1,
            recoveries: 0,
            residual_divergence_at_crash: 0.5,
        };
        a.absorb(&b);
        assert_eq!(a.messages_dropped, 5);
        assert_eq!(a.duplicates_suppressed, 1);
        assert_eq!(a.retransmits, 6);
        assert_eq!(a.recoveries, 1);
        assert_eq!(a.residual_divergence_at_crash, 0.5);
        assert!(a.any());
        assert!(!FaultCounters::default().any());
    }

    #[test]
    fn reliability_keys_round_trip() {
        assert_eq!(Reliability::parse("raw"), Some(Reliability::Raw));
        assert_eq!(Reliability::parse("rel"), Some(Reliability::Reliable));
        assert_eq!(Reliability::parse("reliable"), Some(Reliability::Reliable));
        assert_eq!(Reliability::parse("bogus"), None);
        assert_eq!(Reliability::Raw.key(), "raw");
        assert_eq!(Reliability::Reliable.key(), "rel");
        assert_eq!(Reliability::default(), Reliability::Raw);
    }
}
