//! Metered shard-to-shard message transport over the virtual-time
//! [`EventQueue`].
//!
//! The msgpass backend ([`crate::coordinator::msgpass`]) communicates
//! *only* through this layer: every cross-shard payload goes through
//! [`Transport::send`], which samples a link latency from the configured
//! [`LatencyModel`], meters the message through the
//! [`CongestionTracker`] (peak per-shard queue depth, peak total
//! in-flight) and charges its fixed wire encoding size to the
//! bytes-on-the-wire counter. Local shard wake-ups
//! ([`Transport::wake_at`] / [`Transport::wake_in`]) ride the same queue
//! for deterministic interleaving but are free — they model a shard's
//! own event loop timer, not network traffic.
//!
//! Determinism: the queue breaks time ties FIFO and every latency draw
//! comes from the caller-supplied [`Rng`], so a run is a pure function
//! of (graph, seed, latency model) — the same contract the rest of the
//! simulated network keeps.

use crate::network::congestion::CongestionTracker;
use crate::network::events::{EventQueue, Timed};
use crate::network::latency::LatencyModel;
use crate::util::rng::Rng;

/// Fixed wire encoding size of a message, in bytes. Implementations
/// return the size of the message's serialized form under the fixed
/// per-type encoding documented in docs/ENGINE.md (no dynamic parts —
/// the accounting must be replayable from the message counts alone).
pub trait WireSized {
    fn wire_bytes(&self) -> usize;
}

/// What the transport's event loop yields.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportEvent<M> {
    /// A metered shard-to-shard message arriving at `dst`.
    Deliver { src: usize, dst: usize, msg: M },
    /// An unmetered local timer on `shard`'s own event loop.
    Wake { shard: usize },
}

/// The metered transport: event queue + latency model + congestion and
/// byte accounting, indexed by *shard* (the unit of distribution in the
/// msgpass backend — per-page accounting lives in the coordinator's
/// agent runtime).
#[derive(Debug)]
pub struct Transport<M: PartialEq + WireSized> {
    queue: EventQueue<TransportEvent<M>>,
    latency: LatencyModel,
    congestion: CongestionTracker,
    bytes: u64,
}

impl<M: PartialEq + WireSized> Transport<M> {
    pub fn new(shards: usize, latency: LatencyModel) -> Transport<M> {
        assert!(shards >= 1, "a transport needs at least one shard");
        Transport {
            queue: EventQueue::new(),
            latency,
            congestion: CongestionTracker::new(shards),
            bytes: 0,
        }
    }

    /// Number of shards the congestion tracker is indexed by.
    pub fn shards(&self) -> usize {
        self.congestion.peaks().len()
    }

    pub fn latency(&self) -> LatencyModel {
        self.latency
    }

    /// Send `msg` from shard `src` to shard `dst`: draws one latency
    /// sample (zero/constant models consume no rng), meters the message
    /// and schedules its delivery.
    pub fn send(&mut self, src: usize, dst: usize, msg: M, rng: &mut Rng) {
        debug_assert!(src != dst, "a shard does not message itself");
        self.bytes += msg.wire_bytes() as u64;
        self.congestion.on_send(dst);
        let delay = self.latency.sample(rng);
        self.queue.schedule_in(delay, TransportEvent::Deliver { src, dst, msg });
    }

    /// Schedule an unmetered local wake-up for `shard` at absolute
    /// virtual time `at`.
    pub fn wake_at(&mut self, shard: usize, at: f64) {
        self.queue.schedule(at, TransportEvent::Wake { shard });
    }

    /// Schedule an unmetered local wake-up for `shard` after `delay`.
    pub fn wake_in(&mut self, shard: usize, delay: f64) {
        self.queue.schedule_in(delay, TransportEvent::Wake { shard });
    }

    /// Pop the earliest event, advancing virtual time; deliveries are
    /// drained from the congestion tracker here, so peak depths reflect
    /// genuine in-flight overlap under the latency model.
    pub fn pop(&mut self) -> Option<Timed<TransportEvent<M>>> {
        let ev = self.queue.pop();
        if let Some(t) = &ev {
            if let TransportEvent::Deliver { dst, .. } = &t.event {
                self.congestion.on_deliver(*dst);
            }
        }
        ev
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.queue.now()
    }

    /// Total metered messages sent so far.
    pub fn messages_sent(&self) -> u64 {
        self.congestion.total_messages()
    }

    /// Total bytes charged to the wire so far (fixed per-type encoding).
    pub fn bytes_on_wire(&self) -> u64 {
        self.bytes
    }

    /// Peak number of messages simultaneously queued for any single
    /// shard over the run.
    pub fn peak_queue_depth(&self) -> u32 {
        self.congestion.peak_page_load()
    }

    /// Peak number of messages simultaneously in flight network-wide.
    pub fn peak_in_flight(&self) -> u32 {
        self.congestion.peak_total()
    }

    /// Per-shard peak queue depths (hotspot reports).
    pub fn peak_depths(&self) -> &[u32] {
        self.congestion.peaks()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, PartialEq)]
    struct Ping(u32);

    impl WireSized for Ping {
        fn wire_bytes(&self) -> usize {
            12
        }
    }

    #[test]
    fn meters_messages_and_bytes() {
        let mut t: Transport<Ping> = Transport::new(3, LatencyModel::Zero);
        let mut rng = Rng::seeded(1);
        t.send(0, 1, Ping(7), &mut rng);
        t.send(0, 2, Ping(8), &mut rng);
        t.send(1, 2, Ping(9), &mut rng);
        assert_eq!(t.messages_sent(), 3);
        assert_eq!(t.bytes_on_wire(), 36);
        assert_eq!(t.len(), 3);
        // Wakes ride the queue but are free.
        t.wake_in(0, 0.0);
        assert_eq!(t.messages_sent(), 3);
        assert_eq!(t.bytes_on_wire(), 36);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn zero_latency_delivers_fifo_and_draws_no_rng() {
        let mut t: Transport<Ping> = Transport::new(2, LatencyModel::Zero);
        let mut rng = Rng::seeded(2);
        let mut witness = rng.clone();
        for i in 0..5 {
            t.send(0, 1, Ping(i), &mut rng);
        }
        // Zero (and constant) latency must not consume the stream.
        assert_eq!(rng.next_u64(), witness.next_u64());
        for i in 0..5 {
            let ev = t.pop().expect("delivery");
            assert_eq!(ev.time, 0.0);
            match ev.event {
                TransportEvent::Deliver { src, dst, msg } => {
                    assert_eq!((src, dst), (0, 1));
                    assert_eq!(msg, Ping(i), "same-time deliveries must pop FIFO");
                }
                TransportEvent::Wake { .. } => panic!("no wakes scheduled"),
            }
        }
        assert!(t.pop().is_none());
    }

    #[test]
    fn constant_latency_orders_wakes_and_deliveries_by_time() {
        let mut t: Transport<Ping> = Transport::new(2, LatencyModel::Constant(2.0));
        let mut rng = Rng::seeded(3);
        t.wake_at(1, 1.0);
        t.send(0, 1, Ping(0), &mut rng); // delivers at 2.0
        t.wake_at(1, 3.0);
        let order: Vec<f64> = std::iter::from_fn(|| t.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn peak_in_flight_under_exponential_latency() {
        // Burst-send under the exponential model: every message is in
        // flight until popped, so the peaks must reflect the burst, then
        // drain back to a sticky maximum.
        let mut t: Transport<Ping> = Transport::new(4, LatencyModel::Exponential { mean: 1.0 });
        let mut rng = Rng::seeded(4);
        for i in 0..8 {
            t.send(0, 1 + (i % 3) as usize, Ping(i), &mut rng);
        }
        assert_eq!(t.peak_in_flight(), 8, "burst of 8 all in flight");
        assert!(t.peak_queue_depth() >= 3, "8 messages over 3 shards");
        assert!(t.peak_queue_depth() <= 8);
        let mut last = f64::NEG_INFINITY;
        let mut delivered = 0;
        while let Some(ev) = t.pop() {
            assert!(ev.time >= last, "deliveries advance virtual time");
            assert!(ev.time > 0.0, "exponential latency is a.s. positive");
            last = ev.time;
            delivered += 1;
        }
        assert_eq!(delivered, 8);
        // Draining never lowers the sticky peaks.
        assert_eq!(t.peak_in_flight(), 8);
        assert_eq!(t.messages_sent(), 8);
        assert_eq!(t.bytes_on_wire(), 96);
    }

    #[test]
    fn exponential_latency_is_deterministic_per_seed() {
        let times = |seed: u64| -> Vec<f64> {
            let mut t: Transport<Ping> =
                Transport::new(2, LatencyModel::Exponential { mean: 0.5 });
            let mut rng = Rng::seeded(seed);
            for i in 0..6 {
                t.send(0, 1, Ping(i), &mut rng);
            }
            std::iter::from_fn(|| t.pop()).map(|e| e.time).collect()
        };
        assert_eq!(times(7), times(7));
        assert_ne!(times(7), times(8));
    }
}
