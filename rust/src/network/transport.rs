//! Metered shard-to-shard message transport over the virtual-time
//! [`EventQueue`], with optional fault injection and reliable delivery.
//!
//! The msgpass backend ([`crate::coordinator::msgpass`]) communicates
//! *only* through this layer: every cross-shard payload goes through
//! [`Transport::send`], which samples a link latency from the configured
//! [`LatencyModel`], meters the message through the
//! [`CongestionTracker`] (peak per-shard queue depth, peak total
//! in-flight) and charges its fixed wire encoding size to the
//! bytes-on-the-wire counter. Local shard wake-ups
//! ([`Transport::wake_at`] / [`Transport::wake_in`]) ride the same queue
//! for deterministic interleaving but are free — they model a shard's
//! own event loop timer, not network traffic.
//!
//! A [`NetProfile`] composes two optional layers on the PR-6 wire:
//!
//! * a seeded [`FaultPlan`] — per-transmission drop and duplication
//!   probabilities, reorder jitter, crash windows (a frame delivered
//!   inside a receiver's down window is lost with its queue — any
//!   number of windows, overlap legal), directional link windows and
//!   partition windows (every frame — data, duplicate, retransmission
//!   or ack — whose delivery instant falls inside a cut `src → dst`
//!   direction is lost and counted as a `link_down`). Fault decisions
//!   draw from the plan's own stream, so a plan replays the identical
//!   realization whatever the run seed or reliability mode.
//! * [`Reliability::Reliable`] — per-(src,dst) sequence numbers, an ack
//!   per received data frame, receiver-side dedup (a watermark plus the
//!   out-of-order set), and retransmission with exponential backoff.
//!   The backoff base is **RTT-adaptive**: each link keeps an EWMA of
//!   observed ack RTTs (sampled Karn-style against the latest
//!   transmission, never across a retransmission gap) and times out at
//!   [`RTT_BACKOFF_FACTOR`] × the clamped estimate, doubling per
//!   attempt; before the first sample the base is the static
//!   [`RETX_RTO`], so a fault-free run's timer schedule is unchanged. The abandon budget is likewise
//!   expressed in RTT multiples — a message unacked
//!   [`RETX_BUDGET_RTTS`] estimates after its first transmission is
//!   dropped and counted `abandoned` — rather than a fixed vtime
//!   constant. Acks and retransmissions are metered wire traffic and
//!   cross the same faulty links and windows. Protocol state (sequence
//!   counters, unacked buffers, dedup watermarks, RTT estimates)
//!   models stable storage: it survives the owner's crash window,
//!   while a crashed shard's *queue* is discarded — the split that
//!   lets retransmission replay exactly the deltas a crash swallowed.
//!   Cancelled retransmit timers (their seq already acked) are
//!   discarded without advancing virtual time, so the protocol's
//!   timers never inflate the makespan of a healthy run.
//!
//! With the default profile (no plan, `raw`) every code path, byte
//! charge and rng draw is identical to the PR-6 wire — the msgpass
//! bit-identity pins hold unperturbed.
//!
//! Determinism: the queue breaks time ties FIFO and every latency draw
//! comes from the caller-supplied [`Rng`] (protocol frames use a stream
//! derived from the plan seed), so a run is a pure function of (graph,
//! seed, latency model, fault plan) — the same contract the rest of the
//! simulated network keeps.

use crate::network::congestion::CongestionTracker;
use crate::network::events::{EventQueue, Timed};
use crate::network::faults::{FaultCounters, FaultPlan, NetProfile, Reliability};
use crate::network::latency::LatencyModel;
use crate::util::rng::Rng;

/// Fixed wire encoding size of a message, in bytes. Implementations
/// return the size of the message's serialized form under the fixed
/// per-type encoding documented in docs/ENGINE.md (no dynamic parts —
/// the accounting must be replayable from the message counts alone).
pub trait WireSized {
    fn wire_bytes(&self) -> usize;
}

/// Wire bytes of a reliable-mode ack frame: 4-byte type tag + 8-byte
/// sequence number.
pub const ACK_BYTES: usize = 12;

/// Extra header a reliable-mode data frame carries on the wire: its
/// 8-byte sequence number.
pub const SEQ_BYTES: usize = 8;

/// Retransmit timeout base in virtual time before any ack RTT has been
/// observed on a link; doubles per attempt (exponential backoff). Once
/// a link has an RTT estimate the base adapts to it.
pub const RETX_RTO: f64 = 4.0;

/// Floor of the adaptive retransmit base: RTT estimates below this
/// clamp up, so near-zero-latency links do not fire spurious timers.
pub const RETX_RTO_MIN: f64 = 1.0;

/// EWMA gain of the per-link ack-RTT estimator (TCP's classic 1/8).
pub const RTT_EWMA_ALPHA: f64 = 0.125;

/// Margin of the adaptive retransmit base over the RTT estimate
/// (`RTO = 2 × estimate`): a timer scheduled exactly one RTT ahead
/// would tie with its own ack and fire spuriously (the queue breaks
/// ties FIFO, and the timer was scheduled first).
pub const RTT_BACKOFF_FACTOR: f64 = 2.0;

/// Abandon budget of the reliable sender, in multiples of the link's
/// RTT estimate: a message still unacked this many estimates after its
/// *first* transmission is dropped and counted. Before the first RTT
/// sample the estimate is [`RETX_RTO`], so the span is
/// `4096 · RETX_RTO` ≈ 16k virtual time units — the same window the
/// old fixed 12-attempt budget covered, comfortably outlasting any
/// scheduled crash or partition window.
pub const RETX_BUDGET_RTTS: f64 = 4096.0;

/// What the transport's event loop yields.
#[derive(Debug, Clone, PartialEq)]
pub enum TransportEvent<M> {
    /// A metered shard-to-shard message arriving at `dst`.
    Deliver { src: usize, dst: usize, msg: M },
    /// An unmetered local timer on `shard`'s own event loop.
    Wake { shard: usize },
}

/// Internal queue payload: the public events plus the reliability
/// protocol's frames and timers. Data frames carry their sequence
/// number (`None` in raw mode); `Retx` is the sender's local
/// retransmit-check timer, not wire traffic.
#[derive(Debug, Clone, PartialEq)]
enum Wire<M> {
    Deliver { src: usize, dst: usize, msg: M, seq: Option<u64> },
    Ack { src: usize, dst: usize, seq: u64 },
    Retx { src: usize, dst: usize, seq: u64, attempt: u32 },
    Wake { shard: usize },
}

/// Fault-plan runtime state: the plan, its dedicated decision stream
/// and the loss ledgers.
#[derive(Debug)]
struct FaultState {
    plan: FaultPlan,
    rng: Rng,
    dropped: u64,
    /// Frames lost to a cut link or partition crossing.
    link_downs: u64,
}

/// One in-flight reliable frame awaiting its ack.
#[derive(Debug, Clone)]
struct Unacked<M> {
    seq: u64,
    msg: M,
    /// First transmission time — the RTT-multiple abandon budget is
    /// measured from here.
    first_sent: f64,
    /// Latest (re)transmission time — ack RTT samples are measured
    /// from here (Karn-style: never across a retransmission gap).
    last_sent: f64,
}

/// One (src,dst) link's protocol state — sender side (`next_seq`,
/// `unacked`, RTT estimate) and receiver side (`contiguous` watermark
/// + sorted `ahead` set) share the record since both ends live in one
/// process here. Models stable storage: crash windows do not reset it.
#[derive(Debug, Clone, Default)]
struct LinkState<M> {
    next_seq: u64,
    /// In-flight frames awaiting ack — retransmit candidates.
    unacked: Vec<Unacked<M>>,
    /// Receiver: every seq below this has been applied.
    contiguous: u64,
    /// Receiver: applied seqs at/above the watermark, sorted.
    ahead: Vec<u64>,
    /// EWMA of observed ack RTTs; 0 until the first sample lands.
    rtt_ewma: f64,
}

impl<M> LinkState<M> {
    /// Effective RTT estimate in virtual time: the ack EWMA clamped up
    /// to [`RETX_RTO_MIN`] once observed, the static [`RETX_RTO`]
    /// before — the unit the abandon budget is expressed in.
    fn rtt_estimate(&self) -> f64 {
        if self.rtt_ewma > 0.0 {
            self.rtt_ewma.max(RETX_RTO_MIN)
        } else {
            RETX_RTO
        }
    }

    /// Backoff base of the retransmit timers: the RTT estimate with a
    /// [`RTT_BACKOFF_FACTOR`] safety margin once observed, the static
    /// [`RETX_RTO`] before — so a link that never acked behaves
    /// exactly like the fixed-timeout protocol.
    fn rto_base(&self) -> f64 {
        if self.rtt_ewma > 0.0 {
            (RTT_BACKOFF_FACTOR * self.rtt_ewma).max(RETX_RTO_MIN)
        } else {
            RETX_RTO
        }
    }
}

/// Reliable-delivery state across all links.
#[derive(Debug)]
struct ReliableState<M> {
    /// Indexed `src * shards + dst`.
    links: Vec<LinkState<M>>,
    /// Latency draws for protocol frames (acks, retransmissions) — a
    /// stream derived from the plan seed, so enabling reliability never
    /// perturbs the caller's latency stream.
    rng: Rng,
    retransmits: u64,
    duplicates_suppressed: u64,
    /// Messages abandoned after the retry budget.
    abandoned: u64,
}

/// The metered transport: event queue + latency model + congestion and
/// byte accounting, indexed by *shard* (the unit of distribution in the
/// msgpass backend — per-page accounting lives in the coordinator's
/// agent runtime).
#[derive(Debug)]
pub struct Transport<M: Clone + PartialEq + WireSized> {
    queue: EventQueue<Wire<M>>,
    latency: LatencyModel,
    congestion: CongestionTracker,
    bytes: u64,
    shards: usize,
    faults: Option<FaultState>,
    reliable: Option<ReliableState<M>>,
}

impl<M: Clone + PartialEq + WireSized> Transport<M> {
    /// The PR-6 wire: no fault plan, fire-and-forget delivery.
    pub fn new(shards: usize, latency: LatencyModel) -> Transport<M> {
        Transport::with_profile(shards, latency, NetProfile::default())
    }

    /// A wire with an optional fault plan and a reliability mode. An
    /// empty plan is normalized away, so composing `FaultPlan::default()`
    /// in raw mode *is* [`Transport::new`] — same paths, same draws.
    pub fn with_profile(
        shards: usize,
        latency: LatencyModel,
        profile: NetProfile,
    ) -> Transport<M> {
        assert!(shards >= 1, "a transport needs at least one shard");
        let seed = profile
            .faults
            .as_ref()
            .map_or(crate::network::faults::DEFAULT_FAULT_SEED, |p| p.seed);
        let faults = profile.faults.filter(|p| !p.is_empty()).map(|plan| FaultState {
            rng: Rng::seeded(plan.seed),
            plan,
            dropped: 0,
            link_downs: 0,
        });
        let reliable = match profile.reliability {
            Reliability::Raw => None,
            Reliability::Reliable => Some(ReliableState {
                links: vec![LinkState::default(); shards * shards],
                rng: Rng::seeded(seed ^ 0x70_726F_746F), // "proto"
                retransmits: 0,
                duplicates_suppressed: 0,
                abandoned: 0,
            }),
        };
        Transport {
            queue: EventQueue::new(),
            latency,
            congestion: CongestionTracker::new(shards),
            bytes: 0,
            shards,
            faults,
            reliable,
        }
    }

    /// Number of shards the congestion tracker is indexed by.
    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn latency(&self) -> LatencyModel {
        self.latency
    }

    /// The composed fault plan, if any.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_ref().map(|f| &f.plan)
    }

    /// Whether delivery is sequence-numbered/acked/retransmitted.
    pub fn is_reliable(&self) -> bool {
        self.reliable.is_some()
    }

    /// Whether `shard` sits inside a scheduled crash window at `time`.
    pub fn is_down(&self, shard: usize, time: f64) -> bool {
        self.faults.as_ref().is_some_and(|f| f.plan.is_down(shard, time))
    }

    /// Whether the directed link `src → dst` is cut at `time` by a
    /// scheduled link or partition window.
    pub fn is_link_down(&self, src: usize, dst: usize, time: f64) -> bool {
        self.faults.as_ref().is_some_and(|f| f.plan.is_link_down(src, dst, time))
    }

    /// Send `msg` from shard `src` to shard `dst`: draws one latency
    /// sample (zero/constant models consume no rng), meters the message
    /// and schedules its delivery. In reliable mode the frame carries a
    /// sequence number, is buffered for retransmission and gets a
    /// retransmit-check timer one RTT estimate ahead ([`RETX_RTO`]
    /// before the link's first ack RTT sample).
    pub fn send(&mut self, src: usize, dst: usize, msg: M, rng: &mut Rng) {
        debug_assert!(src != dst, "a shard does not message itself");
        let now = self.queue.now();
        let (seq, rto) = match &mut self.reliable {
            Some(rel) => {
                let link = &mut rel.links[src * self.shards + dst];
                let s = link.next_seq;
                link.next_seq += 1;
                link.unacked.push(Unacked {
                    seq: s,
                    msg: msg.clone(),
                    first_sent: now,
                    last_sent: now,
                });
                (Some(s), backoff(link.rto_base(), 1))
            }
            None => (None, 0.0),
        };
        self.transmit(src, dst, msg, seq, rng);
        if let Some(s) = seq {
            self.queue.schedule_in(rto, Wire::Retx { src, dst, seq: s, attempt: 1 });
        }
    }

    /// One physical transmission attempt: charge bytes, meter
    /// congestion, apply the fault plan (drop / duplicate / jitter) and
    /// schedule whatever survives.
    fn transmit(&mut self, src: usize, dst: usize, msg: M, seq: Option<u64>, rng: &mut Rng) {
        let head = if seq.is_some() { SEQ_BYTES } else { 0 };
        self.bytes += (msg.wire_bytes() + head) as u64;
        self.congestion.on_send(dst);
        let Some(f) = &mut self.faults else {
            let delay = self.latency.sample(rng);
            self.queue.schedule_in(delay, Wire::Deliver { src, dst, msg, seq });
            return;
        };
        if f.plan.drop > 0.0 && f.rng.bernoulli(f.plan.drop) {
            f.dropped += 1;
            // Lost on the wire: balance the congestion ledger now — the
            // frame never occupies the receiver's queue.
            self.congestion.on_deliver(dst);
            return;
        }
        let dup = f.plan.duplicate > 0.0 && f.rng.bernoulli(f.plan.duplicate);
        let jit = if f.plan.jitter > 0.0 { f.rng.uniform() * f.plan.jitter } else { 0.0 };
        let jit2 = if dup && f.plan.jitter > 0.0 { f.rng.uniform() * f.plan.jitter } else { 0.0 };
        let delay = self.latency.sample(rng) + jit;
        if dup {
            self.queue
                .schedule_in(delay, Wire::Deliver { src, dst, msg: msg.clone(), seq });
            // The duplicate is its own metered frame with its own delay.
            self.bytes += (msg.wire_bytes() + head) as u64;
            self.congestion.on_send(dst);
            let delay2 = self.latency.sample(rng) + jit2;
            self.queue.schedule_in(delay2, Wire::Deliver { src, dst, msg, seq });
        } else {
            self.queue.schedule_in(delay, Wire::Deliver { src, dst, msg, seq });
        }
    }

    /// Ack `seq` of the (data_src → data_dst) link, travelling back
    /// dst → src. Metered, and subject to the plan's drop/jitter like
    /// any frame (a lost ack provokes a retransmission, which the
    /// receiver dedups).
    fn send_ack(&mut self, data_src: usize, data_dst: usize, seq: u64) {
        self.bytes += ACK_BYTES as u64;
        self.congestion.on_send(data_src);
        let mut extra = 0.0;
        if let Some(f) = &mut self.faults {
            if f.plan.drop > 0.0 && f.rng.bernoulli(f.plan.drop) {
                f.dropped += 1;
                self.congestion.on_deliver(data_src);
                return;
            }
            if f.plan.jitter > 0.0 {
                extra = f.rng.uniform() * f.plan.jitter;
            }
        }
        let delay = {
            let rel = self.reliable.as_mut().expect("acks exist only in reliable mode");
            self.latency.sample(&mut rel.rng) + extra
        };
        self.queue
            .schedule_in(delay, Wire::Ack { src: data_src, dst: data_dst, seq });
    }

    /// Whether a retransmit timer still guards an unacked message.
    fn retx_live(&self, src: usize, dst: usize, seq: u64) -> bool {
        match &self.reliable {
            Some(rel) => rel.links[src * self.shards + dst]
                .unacked
                .iter()
                .any(|u| u.seq == seq),
            None => false,
        }
    }

    /// Discard retransmit timers whose message was acked meanwhile —
    /// without advancing virtual time, so cancelled timers never
    /// inflate the makespan.
    fn discard_dead_timers(&mut self) {
        while let Some(Wire::Retx { src, dst, seq, .. }) = self.queue.peek_event() {
            let (src, dst, seq) = (*src, *dst, *seq);
            if self.retx_live(src, dst, seq) {
                break;
            }
            self.queue.discard_head();
        }
    }

    /// Receiver-side dedup: record `seq` on the (src,dst) link; `true`
    /// if it was fresh (apply it), `false` if already seen (suppress).
    fn mark_seen(&mut self, src: usize, dst: usize, seq: u64) -> bool {
        let shards = self.shards;
        let rel = self.reliable.as_mut().expect("dedup exists only in reliable mode");
        let link = &mut rel.links[src * shards + dst];
        if seq < link.contiguous {
            return false;
        }
        match link.ahead.binary_search(&seq) {
            Ok(_) => false,
            Err(i) => {
                link.ahead.insert(i, seq);
                while link.ahead.first() == Some(&link.contiguous) {
                    link.ahead.remove(0);
                    link.contiguous += 1;
                }
                true
            }
        }
    }

    /// Schedule an unmetered local wake-up for `shard` at absolute
    /// virtual time `at`.
    pub fn wake_at(&mut self, shard: usize, at: f64) {
        self.queue.schedule(at, Wire::Wake { shard });
    }

    /// Schedule an unmetered local wake-up for `shard` after `delay`.
    pub fn wake_in(&mut self, shard: usize, delay: f64) {
        self.queue.schedule_in(delay, Wire::Wake { shard });
    }

    /// Pop the earliest surfaced event, advancing virtual time;
    /// deliveries are drained from the congestion tracker here, so peak
    /// depths reflect genuine in-flight overlap under the latency model.
    /// Protocol frames (acks, retransmit timers) and suppressed frames
    /// (duplicates, deliveries into a crashed shard's discarded queue)
    /// are consumed internally — the caller only ever sees `Deliver`
    /// and `Wake`.
    pub fn pop(&mut self) -> Option<Timed<TransportEvent<M>>> {
        loop {
            self.discard_dead_timers();
            let ev = self.queue.pop()?;
            let time = ev.time;
            match ev.event {
                Wire::Wake { shard } => {
                    return Some(Timed::at(time, TransportEvent::Wake { shard }));
                }
                Wire::Deliver { src, dst, msg, seq } => {
                    self.congestion.on_deliver(dst);
                    if self.is_down(dst, time) {
                        // The crashed shard's queue is discarded — the
                        // frame is lost (reliable senders retransmit it
                        // past the window).
                        if let Some(f) = &mut self.faults {
                            f.dropped += 1;
                        }
                        continue;
                    }
                    if self.is_link_down(src, dst, time) {
                        // Cut link: lost before the receiver sees it —
                        // ahead of ack/dedup, so reliable senders keep
                        // retransmitting until the window heals.
                        if let Some(f) = &mut self.faults {
                            f.link_downs += 1;
                        }
                        continue;
                    }
                    if let Some(s) = seq {
                        // Re-ack every arrival (covers a lost first
                        // ack), then apply at most once.
                        self.send_ack(src, dst, s);
                        if !self.mark_seen(src, dst, s) {
                            let rel =
                                self.reliable.as_mut().expect("seq frames are reliable-mode");
                            rel.duplicates_suppressed += 1;
                            continue;
                        }
                    }
                    return Some(Timed::at(time, TransportEvent::Deliver { src, dst, msg }));
                }
                Wire::Ack { src, dst, seq } => {
                    self.congestion.on_deliver(src);
                    if self.is_down(src, time) {
                        // Acks into a down window are lost like any
                        // frame; the paused sender re-acks on resume.
                        if let Some(f) = &mut self.faults {
                            f.dropped += 1;
                        }
                        continue;
                    }
                    if self.is_link_down(dst, src, time) {
                        // The ack crosses the physical dst → src link
                        // — the reverse of its data frame's direction.
                        if let Some(f) = &mut self.faults {
                            f.link_downs += 1;
                        }
                        continue;
                    }
                    let shards = self.shards;
                    if let Some(rel) = &mut self.reliable {
                        let link = &mut rel.links[src * shards + dst];
                        if let Some(i) = link.unacked.iter().position(|u| u.seq == seq) {
                            // Karn-style RTT sample against the latest
                            // transmission, folded into the link EWMA
                            // that seeds the adaptive backoff.
                            let sample = (time - link.unacked[i].last_sent).max(0.0);
                            link.unacked.remove(i);
                            link.rtt_ewma = if link.rtt_ewma > 0.0 {
                                (1.0 - RTT_EWMA_ALPHA) * link.rtt_ewma + RTT_EWMA_ALPHA * sample
                            } else {
                                sample
                            };
                        }
                    }
                    continue;
                }
                Wire::Retx { src, dst, seq, attempt } => {
                    if !self.retx_live(src, dst, seq) {
                        continue;
                    }
                    let idx = src * self.shards + dst;
                    let (base, est) = {
                        let link = &self.reliable.as_ref().expect("retx is reliable-mode").links[idx];
                        (link.rto_base(), link.rtt_estimate())
                    };
                    if self.is_down(src, time) {
                        // A crashed sender's retransmit daemon is
                        // paused: re-check one timeout later without
                        // consuming budget, resuming after restart.
                        self.queue
                            .schedule_in(backoff(base, attempt), Wire::Retx { src, dst, seq, attempt });
                        continue;
                    }
                    // Adaptive abandon budget: unacked for more than
                    // RETX_BUDGET_RTTS RTT estimates since the *first*
                    // transmission means even `rel` mode gives up.
                    let expired = {
                        let rel = self.reliable.as_ref().expect("retx is reliable-mode");
                        let u = rel.links[idx]
                            .unacked
                            .iter()
                            .find(|u| u.seq == seq)
                            .expect("live retx has a payload");
                        time - u.first_sent >= RETX_BUDGET_RTTS * est
                    };
                    if expired {
                        let rel = self.reliable.as_mut().expect("retx is reliable-mode");
                        let link = &mut rel.links[idx];
                        if let Some(i) = link.unacked.iter().position(|u| u.seq == seq) {
                            link.unacked.remove(i);
                        }
                        rel.abandoned += 1;
                        continue;
                    }
                    let (msg, mut proto_rng) = {
                        let rel = self.reliable.as_mut().expect("retx is reliable-mode");
                        rel.retransmits += 1;
                        let u = rel.links[idx]
                            .unacked
                            .iter_mut()
                            .find(|u| u.seq == seq)
                            .expect("live retx has a payload");
                        u.last_sent = time;
                        (u.msg.clone(), std::mem::replace(&mut rel.rng, Rng::seeded(0)))
                    };
                    self.transmit(src, dst, msg, Some(seq), &mut proto_rng);
                    self.reliable.as_mut().expect("retx is reliable-mode").rng = proto_rng;
                    self.queue.schedule_in(
                        backoff(base, attempt + 1),
                        Wire::Retx { src, dst, seq, attempt: attempt + 1 },
                    );
                    continue;
                }
            }
        }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.queue.now()
    }

    /// Total metered frames sent so far (data, duplicates and acks).
    pub fn messages_sent(&self) -> u64 {
        self.congestion.total_messages()
    }

    /// Total bytes charged to the wire so far (fixed per-type encoding,
    /// plus seq/ack overhead in reliable mode).
    pub fn bytes_on_wire(&self) -> u64 {
        self.bytes
    }

    /// The transport's slice of the fault ledger: drops, link-cut
    /// losses, dedup suppressions, retransmissions and the RTT gauge
    /// (the runtime adds recoveries, heals and the divergence gauges).
    /// The RTT gauge is reported only when a fault plan is composed —
    /// a fault-free reliable run keeps its all-zero ledger, so
    /// historical summary shapes stay unchanged.
    pub fn fault_counters(&self) -> FaultCounters {
        FaultCounters {
            messages_dropped: self.faults.as_ref().map_or(0, |f| f.dropped),
            duplicates_suppressed: self
                .reliable
                .as_ref()
                .map_or(0, |r| r.duplicates_suppressed),
            retransmits: self.reliable.as_ref().map_or(0, |r| r.retransmits),
            recoveries: 0,
            residual_divergence_at_crash: 0.0,
            link_downs: self.faults.as_ref().map_or(0, |f| f.link_downs),
            partitions_healed: 0,
            rtt_estimate: if self.faults.is_some() { self.rtt_estimate() } else { 0.0 },
        }
    }

    /// Max over links of the reliable sender's ack-RTT EWMA, in
    /// virtual-time units; 0 in raw mode or before any ack RTT landed.
    pub fn rtt_estimate(&self) -> f64 {
        self.reliable
            .as_ref()
            .map_or(0.0, |r| r.links.iter().map(|l| l.rtt_ewma).fold(0.0, f64::max))
    }

    /// Messages the reliable sender abandoned after the retry budget —
    /// nonzero means even `rel` mode lost data (the conservation tests
    /// gate on this).
    pub fn abandoned(&self) -> u64 {
        self.reliable.as_ref().map_or(0, |r| r.abandoned)
    }

    /// Peak number of messages simultaneously queued for any single
    /// shard over the run.
    pub fn peak_queue_depth(&self) -> u32 {
        self.congestion.peak_page_load()
    }

    /// Peak number of messages simultaneously in flight network-wide.
    pub fn peak_in_flight(&self) -> u32 {
        self.congestion.peak_total()
    }

    /// Per-shard peak queue depths (hotspot reports).
    pub fn peak_depths(&self) -> &[u32] {
        self.congestion.peaks()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

/// Backoff schedule: the check for attempt `a` fires `base · 2^(a-1)`
/// after the previous transmission, where `base` is the link's RTT
/// estimate ([`RETX_RTO`] before the first sample).
fn backoff(base: f64, attempt: u32) -> f64 {
    base * f64::powi(2.0, (attempt.saturating_sub(1)).min(20) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::faults::{CrashWindow, LinkWindow, PartitionWindow};

    #[derive(Debug, Clone, PartialEq)]
    struct Ping(u32);

    impl WireSized for Ping {
        fn wire_bytes(&self) -> usize {
            12
        }
    }

    fn drain(t: &mut Transport<Ping>) -> Vec<(f64, usize, usize, Ping)> {
        let mut out = Vec::new();
        while let Some(ev) = t.pop() {
            if let TransportEvent::Deliver { src, dst, msg } = ev.event {
                out.push((ev.time, src, dst, msg));
            }
        }
        out
    }

    #[test]
    fn meters_messages_and_bytes() {
        let mut t: Transport<Ping> = Transport::new(3, LatencyModel::Zero);
        let mut rng = Rng::seeded(1);
        t.send(0, 1, Ping(7), &mut rng);
        t.send(0, 2, Ping(8), &mut rng);
        t.send(1, 2, Ping(9), &mut rng);
        assert_eq!(t.messages_sent(), 3);
        assert_eq!(t.bytes_on_wire(), 36);
        assert_eq!(t.len(), 3);
        // Wakes ride the queue but are free.
        t.wake_in(0, 0.0);
        assert_eq!(t.messages_sent(), 3);
        assert_eq!(t.bytes_on_wire(), 36);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn zero_latency_delivers_fifo_and_draws_no_rng() {
        let mut t: Transport<Ping> = Transport::new(2, LatencyModel::Zero);
        let mut rng = Rng::seeded(2);
        let mut witness = rng.clone();
        for i in 0..5 {
            t.send(0, 1, Ping(i), &mut rng);
        }
        // Zero (and constant) latency must not consume the stream.
        assert_eq!(rng.next_u64(), witness.next_u64());
        for i in 0..5 {
            let ev = t.pop().expect("delivery");
            assert_eq!(ev.time, 0.0);
            match ev.event {
                TransportEvent::Deliver { src, dst, msg } => {
                    assert_eq!((src, dst), (0, 1));
                    assert_eq!(msg, Ping(i), "same-time deliveries must pop FIFO");
                }
                TransportEvent::Wake { .. } => panic!("no wakes scheduled"),
            }
        }
        assert!(t.pop().is_none());
    }

    #[test]
    fn constant_latency_orders_wakes_and_deliveries_by_time() {
        let mut t: Transport<Ping> = Transport::new(2, LatencyModel::Constant(2.0));
        let mut rng = Rng::seeded(3);
        t.wake_at(1, 1.0);
        t.send(0, 1, Ping(0), &mut rng); // delivers at 2.0
        t.wake_at(1, 3.0);
        let order: Vec<f64> = std::iter::from_fn(|| t.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn peak_in_flight_under_exponential_latency() {
        // Burst-send under the exponential model: every message is in
        // flight until popped, so the peaks must reflect the burst, then
        // drain back to a sticky maximum.
        let mut t: Transport<Ping> = Transport::new(4, LatencyModel::Exponential { mean: 1.0 });
        let mut rng = Rng::seeded(4);
        for i in 0..8 {
            t.send(0, 1 + (i % 3) as usize, Ping(i), &mut rng);
        }
        assert_eq!(t.peak_in_flight(), 8, "burst of 8 all in flight");
        assert!(t.peak_queue_depth() >= 3, "8 messages over 3 shards");
        assert!(t.peak_queue_depth() <= 8);
        let mut last = f64::NEG_INFINITY;
        let mut delivered = 0;
        while let Some(ev) = t.pop() {
            assert!(ev.time >= last, "deliveries advance virtual time");
            assert!(ev.time > 0.0, "exponential latency is a.s. positive");
            last = ev.time;
            delivered += 1;
        }
        assert_eq!(delivered, 8);
        // Draining never lowers the sticky peaks.
        assert_eq!(t.peak_in_flight(), 8);
        assert_eq!(t.messages_sent(), 8);
        assert_eq!(t.bytes_on_wire(), 96);
    }

    #[test]
    fn exponential_latency_is_deterministic_per_seed() {
        let times = |seed: u64| -> Vec<f64> {
            let mut t: Transport<Ping> =
                Transport::new(2, LatencyModel::Exponential { mean: 0.5 });
            let mut rng = Rng::seeded(seed);
            for i in 0..6 {
                t.send(0, 1, Ping(i), &mut rng);
            }
            std::iter::from_fn(|| t.pop()).map(|e| e.time).collect()
        };
        assert_eq!(times(7), times(7));
        assert_ne!(times(7), times(8));
    }

    #[test]
    fn empty_plan_raw_profile_is_the_plain_wire() {
        // Composing an all-zero plan in raw mode must be normalized away:
        // identical deliveries, bytes and rng consumption as Transport::new.
        let run = |profile: NetProfile| {
            let mut t: Transport<Ping> =
                Transport::with_profile(3, LatencyModel::Exponential { mean: 0.7 }, profile);
            let mut rng = Rng::seeded(11);
            for i in 0..10 {
                t.send(i as usize % 2, 2, Ping(i), &mut rng);
            }
            let seen = drain(&mut t);
            (seen, t.bytes_on_wire(), rng.next_u64())
        };
        let plain = run(NetProfile::default());
        let composed = run(NetProfile { faults: Some(FaultPlan::default()), ..Default::default() });
        assert_eq!(plain, composed);
    }

    #[test]
    fn drops_are_counted_and_balance_the_congestion_ledger() {
        let plan = FaultPlan::default().with_drop(0.5).with_seed(77);
        let mut t: Transport<Ping> =
            Transport::with_profile(2, LatencyModel::Zero, NetProfile::faulty(plan));
        let mut rng = Rng::seeded(5);
        for i in 0..200 {
            t.send(0, 1, Ping(i), &mut rng);
        }
        let seen = drain(&mut t);
        let dropped = t.fault_counters().messages_dropped;
        assert!(dropped > 50 && dropped < 150, "~half drop, got {dropped}");
        assert_eq!(seen.len() as u64 + dropped, 200, "every frame lands or is counted lost");
        // All 200 sends were metered even though some never arrived.
        assert_eq!(t.messages_sent(), 200);
        assert_eq!(t.bytes_on_wire(), 200 * 12);
    }

    #[test]
    fn raw_duplication_double_delivers_and_reliable_suppresses_it() {
        let plan = || FaultPlan::default().with_duplicate(0.4).with_seed(9);
        let mut raw: Transport<Ping> =
            Transport::with_profile(2, LatencyModel::Zero, NetProfile::faulty(plan()));
        let mut rng = Rng::seeded(6);
        for i in 0..100 {
            raw.send(0, 1, Ping(i), &mut rng);
        }
        let raw_seen = drain(&mut raw);
        assert!(raw_seen.len() > 100, "raw mode must double-apply duplicates");

        let mut rel: Transport<Ping> = Transport::with_profile(
            2,
            LatencyModel::Zero,
            NetProfile::faulty(plan()).reliable(),
        );
        let mut rng = Rng::seeded(6);
        for i in 0..100 {
            rel.send(0, 1, Ping(i), &mut rng);
        }
        let rel_seen = drain(&mut rel);
        assert_eq!(rel_seen.len(), 100, "dedup applies each seq exactly once");
        let c = rel.fault_counters();
        assert_eq!(c.duplicates_suppressed, raw_seen.len() as u64 - 100);
        assert_eq!(rel.abandoned(), 0);
    }

    #[test]
    fn reliable_mode_retransmits_through_drops_to_exactly_once() {
        let plan = FaultPlan::default().with_drop(0.3).with_seed(123);
        let mut t: Transport<Ping> = Transport::with_profile(
            3,
            LatencyModel::Exponential { mean: 0.4 },
            NetProfile::faulty(plan).reliable(),
        );
        let mut rng = Rng::seeded(8);
        for i in 0..120 {
            t.send(i as usize % 3, (i as usize + 1) % 3, Ping(i), &mut rng);
        }
        let seen = drain(&mut t);
        let c = t.fault_counters();
        assert!(c.messages_dropped > 0, "the plan must actually drop");
        assert!(c.retransmits > 0, "drops must provoke retransmissions");
        assert_eq!(t.abandoned(), 0, "budget must cover a 30% drop rate");
        // Exactly-once: every payload delivered, none twice.
        assert_eq!(seen.len(), 120);
        let mut ids: Vec<u32> = seen.iter().map(|(_, _, _, p)| p.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 120);
    }

    #[test]
    fn reliable_overhead_is_metered_and_timers_do_not_inflate_time() {
        // Zero latency, no faults: one data frame (+seq header) and one
        // ack; the retransmit timer dies unfired, so virtual time stays
        // at the delivery instant instead of jumping to the RTO.
        let mut t: Transport<Ping> = Transport::with_profile(
            2,
            LatencyModel::Zero,
            NetProfile::default().reliable(),
        );
        let mut rng = Rng::seeded(10);
        t.send(0, 1, Ping(1), &mut rng);
        let seen = drain(&mut t);
        assert_eq!(seen.len(), 1);
        assert_eq!(t.messages_sent(), 2, "data + ack");
        assert_eq!(t.bytes_on_wire(), (12 + SEQ_BYTES + ACK_BYTES) as u64);
        assert_eq!(t.now(), 0.0, "a cancelled retransmit timer must not advance time");
        assert_eq!(t.fault_counters().retransmits, 0);
    }

    #[test]
    fn frames_into_a_crash_window_are_lost_and_retransmitted_after_restart() {
        let plan = FaultPlan::default().with_crash(CrashWindow {
            shard: 1,
            at: 0.0,
            down_for: 10.0,
        });
        let mut t: Transport<Ping> = Transport::with_profile(
            2,
            LatencyModel::Constant(0.5),
            NetProfile::faulty(plan.clone()).reliable(),
        );
        let mut rng = Rng::seeded(12);
        t.send(0, 1, Ping(42), &mut rng);
        let seen = drain(&mut t);
        assert_eq!(seen.len(), 1, "the retransmission lands after restart");
        assert!(seen[0].0 >= 10.0, "delivery only after the window, got t={}", seen[0].0);
        let c = t.fault_counters();
        assert!(c.messages_dropped >= 1, "the in-window frame is lost with the queue");
        assert!(c.retransmits >= 1);
        assert_eq!(t.abandoned(), 0);

        // Raw mode under the same plan loses the frame for good.
        let mut raw: Transport<Ping> =
            Transport::with_profile(2, LatencyModel::Constant(0.5), NetProfile::faulty(plan));
        let mut rng = Rng::seeded(12);
        raw.send(0, 1, Ping(42), &mut rng);
        assert!(drain(&mut raw).is_empty(), "raw mode: lost is lost");
        assert_eq!(raw.fault_counters().messages_dropped, 1);
    }

    #[test]
    fn link_window_cuts_one_direction_and_reliable_retransmits_past_heal() {
        // 0 → 1 is cut on [0, 10); 1 → 0 stays up the whole time.
        let plan = FaultPlan::default().with_link(LinkWindow {
            src: 0,
            dst: 1,
            at: 0.0,
            down_for: 10.0,
        });
        let mut t: Transport<Ping> = Transport::with_profile(
            2,
            LatencyModel::Constant(0.5),
            NetProfile::faulty(plan.clone()).reliable(),
        );
        let mut rng = Rng::seeded(21);
        t.send(0, 1, Ping(1), &mut rng);
        t.send(1, 0, Ping(2), &mut rng);
        let seen = drain(&mut t);
        assert_eq!(seen.len(), 2, "both payloads land exactly once");
        let up = seen.iter().find(|(_, src, _, _)| *src == 1).expect("reverse direction");
        assert!(up.0 < 10.0, "the asymmetric reverse direction delivers immediately");
        let healed = seen.iter().find(|(_, src, _, _)| *src == 0).expect("cut direction");
        assert!(healed.0 >= 10.0, "cut direction only lands after heal, got t={}", healed.0);
        let c = t.fault_counters();
        assert!(c.link_downs >= 1, "in-window frames are counted as link losses");
        assert!(c.retransmits >= 1);
        assert_eq!(t.abandoned(), 0);

        // Raw mode under the same plan loses the cut-direction frame.
        let mut raw: Transport<Ping> =
            Transport::with_profile(2, LatencyModel::Constant(0.5), NetProfile::faulty(plan));
        let mut rng = Rng::seeded(21);
        raw.send(0, 1, Ping(1), &mut rng);
        raw.send(1, 0, Ping(2), &mut rng);
        let seen = drain(&mut raw);
        assert_eq!(seen.len(), 1, "raw mode: the cut direction is lost for good");
        assert_eq!(seen[0].1, 1, "only the reverse direction lands");
        assert_eq!(raw.fault_counters().link_downs, 1);
    }

    #[test]
    fn acks_crossing_a_cut_link_are_lost_and_counted() {
        // Data flows 0 → 1 on an open link; the ack's physical path
        // 1 → 0 is cut, so the sender keeps retransmitting and the
        // receiver keeps suppressing until the window heals.
        let plan = FaultPlan::default().with_link(LinkWindow {
            src: 1,
            dst: 0,
            at: 0.0,
            down_for: 10.0,
        });
        let mut t: Transport<Ping> = Transport::with_profile(
            2,
            LatencyModel::Constant(0.5),
            NetProfile::faulty(plan).reliable(),
        );
        let mut rng = Rng::seeded(22);
        t.send(0, 1, Ping(7), &mut rng);
        let seen = drain(&mut t);
        assert_eq!(seen.len(), 1, "the data frame applies exactly once");
        assert!(seen[0].0 < 10.0, "data landed inside the window — only acks were cut");
        let c = t.fault_counters();
        assert!(c.link_downs >= 1, "lost acks are counted as link losses");
        assert!(c.retransmits >= 1, "unacked data provokes retransmission");
        assert!(c.duplicates_suppressed >= 1, "the receiver dedups the retransmissions");
        assert_eq!(t.abandoned(), 0, "the budget outlasts the window");
    }

    #[test]
    fn partition_window_cuts_both_directions_and_heals() {
        let plan = FaultPlan::default()
            .with_partition(PartitionWindow::new(vec![0], 0.0, 10.0));
        let mut t: Transport<Ping> = Transport::with_profile(
            3,
            LatencyModel::Constant(0.5),
            NetProfile::faulty(plan.clone()).reliable(),
        );
        let mut rng = Rng::seeded(23);
        t.send(0, 1, Ping(1), &mut rng);
        t.send(1, 0, Ping(2), &mut rng);
        t.send(1, 2, Ping(3), &mut rng);
        let seen = drain(&mut t);
        assert_eq!(seen.len(), 3, "everything lands exactly once after heal");
        for (time, src, dst, _) in &seen {
            if *src == 0 || *dst == 0 {
                assert!(*time >= 10.0, "crossing link {src}->{dst} delivered at {time}");
            } else {
                assert!(*time < 10.0, "intra-side link {src}->{dst} must not wait for heal");
            }
        }
        assert!(t.fault_counters().link_downs >= 2, "both crossing directions were cut");
        assert_eq!(t.abandoned(), 0);

        // Raw mode loses exactly the crossing frames.
        let mut raw: Transport<Ping> =
            Transport::with_profile(3, LatencyModel::Constant(0.5), NetProfile::faulty(plan));
        let mut rng = Rng::seeded(23);
        raw.send(0, 1, Ping(1), &mut rng);
        raw.send(1, 0, Ping(2), &mut rng);
        raw.send(1, 2, Ping(3), &mut rng);
        let seen = drain(&mut raw);
        assert_eq!(seen.len(), 1);
        assert_eq!((seen[0].1, seen[0].2), (1, 2), "only the intra-side frame survives");
    }

    #[test]
    fn rtt_estimate_tracks_acks_and_adapts_the_backoff() {
        // Constant latency 1.0: every ack RTT sample is exactly 2.0, so
        // the EWMA must converge there. The plan is non-empty (a window
        // far in the future) so the gauge is surfaced in the ledger.
        let plan = FaultPlan::default().with_link(LinkWindow {
            src: 0,
            dst: 1,
            at: 1e9,
            down_for: 1.0,
        });
        let mut t: Transport<Ping> = Transport::with_profile(
            2,
            LatencyModel::Constant(1.0),
            NetProfile::faulty(plan).reliable(),
        );
        let mut rng = Rng::seeded(24);
        for i in 0..20 {
            t.send(0, 1, Ping(i), &mut rng);
            let _ = drain(&mut t);
        }
        let est = t.rtt_estimate();
        assert!((est - 2.0).abs() < 1e-9, "EWMA of constant 2.0 samples is 2.0, got {est}");
        assert!((t.fault_counters().rtt_estimate - est).abs() < 1e-12);
        assert_eq!(t.fault_counters().retransmits, 0, "adapted timers still die unfired");
        assert_eq!(t.abandoned(), 0);
    }

    #[test]
    fn fault_free_reliable_ledger_stays_all_zero() {
        // No plan composed: the RTT EWMA still drives the protocol
        // internally, but the reported ledger must stay default so
        // ideal-network summaries keep their historical shape.
        let mut t: Transport<Ping> = Transport::with_profile(
            2,
            LatencyModel::Constant(1.0),
            NetProfile::default().reliable(),
        );
        let mut rng = Rng::seeded(25);
        for i in 0..10 {
            t.send(0, 1, Ping(i), &mut rng);
        }
        let seen = drain(&mut t);
        assert_eq!(seen.len(), 10);
        assert!(t.rtt_estimate() > 0.0, "the estimator itself runs");
        assert!(!t.fault_counters().any(), "but the ledger stays silent without a plan");
    }

    #[test]
    fn fault_realization_is_a_function_of_the_plan_seed() {
        let run = |plan_seed: u64| {
            let plan = FaultPlan::default().with_drop(0.4).with_seed(plan_seed);
            let mut t: Transport<Ping> =
                Transport::with_profile(2, LatencyModel::Zero, NetProfile::faulty(plan));
            let mut rng = Rng::seeded(999);
            for i in 0..50 {
                t.send(0, 1, Ping(i), &mut rng);
            }
            drain(&mut t).iter().map(|(_, _, _, p)| p.0).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(1), "same plan, same realization");
        assert_ne!(run(1), run(2), "the seed picks the realization");
    }
}
