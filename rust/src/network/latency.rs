//! Link-latency models for the simulated network.

use crate::util::rng::Rng;

/// Distribution of one-way message latency (virtual time units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyModel {
    /// Idealized zero-latency network (pure algorithmic time).
    Zero,
    /// Fixed latency per message.
    Constant(f64),
    /// Uniform in `[lo, hi]`.
    Uniform { lo: f64, hi: f64 },
    /// Exponential with the given mean (heavy-ish WAN-style tail).
    Exponential { mean: f64 },
}

impl LatencyModel {
    /// Draw one latency sample.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            LatencyModel::Zero => 0.0,
            LatencyModel::Constant(l) => {
                debug_assert!(l >= 0.0);
                l
            }
            LatencyModel::Uniform { lo, hi } => {
                debug_assert!(0.0 <= lo && lo <= hi);
                lo + (hi - lo) * rng.uniform()
            }
            LatencyModel::Exponential { mean } => {
                debug_assert!(mean > 0.0);
                rng.exponential(1.0 / mean)
            }
        }
    }

    /// Expected latency (used by reports).
    pub fn mean(&self) -> f64 {
        match *self {
            LatencyModel::Zero => 0.0,
            LatencyModel::Constant(l) => l,
            LatencyModel::Uniform { lo, hi } => 0.5 * (lo + hi),
            LatencyModel::Exponential { mean } => mean,
        }
    }

    /// Parse from CLI syntax: `zero`, `const:0.5`, `uniform:0.1:0.9`,
    /// `exp:1.0`.
    pub fn parse(s: &str) -> Option<LatencyModel> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["zero"] => Some(LatencyModel::Zero),
            ["const", l] => l.parse().ok().map(LatencyModel::Constant),
            ["uniform", lo, hi] => {
                let lo = lo.parse().ok()?;
                let hi = hi.parse().ok()?;
                Some(LatencyModel::Uniform { lo, hi })
            }
            ["exp", m] => m.parse().ok().map(|mean| LatencyModel::Exponential { mean }),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_and_constant() {
        let mut rng = Rng::seeded(1);
        assert_eq!(LatencyModel::Zero.sample(&mut rng), 0.0);
        assert_eq!(LatencyModel::Constant(0.25).sample(&mut rng), 0.25);
    }

    #[test]
    fn uniform_within_bounds_and_mean() {
        let mut rng = Rng::seeded(2);
        let m = LatencyModel::Uniform { lo: 0.5, hi: 1.5 };
        let mut acc = 0.0;
        let n = 50_000;
        for _ in 0..n {
            let s = m.sample(&mut rng);
            assert!((0.5..=1.5).contains(&s));
            acc += s;
        }
        assert!((acc / n as f64 - m.mean()).abs() < 0.01);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seeded(3);
        let m = LatencyModel::Exponential { mean: 2.0 };
        let n = 100_000;
        let acc: f64 = (0..n).map(|_| m.sample(&mut rng)).sum();
        assert!((acc / n as f64 - 2.0).abs() < 0.05);
    }

    #[test]
    fn parse_syntax() {
        assert_eq!(LatencyModel::parse("zero"), Some(LatencyModel::Zero));
        assert_eq!(LatencyModel::parse("const:0.5"), Some(LatencyModel::Constant(0.5)));
        assert_eq!(
            LatencyModel::parse("uniform:0.1:0.9"),
            Some(LatencyModel::Uniform { lo: 0.1, hi: 0.9 })
        );
        assert_eq!(
            LatencyModel::parse("exp:1.5"),
            Some(LatencyModel::Exponential { mean: 1.5 })
        );
        assert_eq!(LatencyModel::parse("bogus:1"), None);
    }
}
