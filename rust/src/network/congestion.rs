//! Per-page congestion accounting.
//!
//! Tracks, per destination page, how many messages are in flight /
//! queued, and the peaks over the run. The paper's §I argues the
//! Monte-Carlo approach \[9\] "may lead to the problem of congestion in
//! the network"; the coordinator feeds both MP's and the walk baseline's
//! traffic through this tracker so the claim is measured, not asserted.

/// Running congestion statistics.
#[derive(Debug, Clone, Default)]
pub struct CongestionTracker {
    in_flight: Vec<u32>,
    peak_per_page: Vec<u32>,
    /// Global peak of (messages in flight anywhere).
    peak_total: u32,
    total_in_flight: u32,
    /// Total messages ever enqueued.
    messages: u64,
}

impl CongestionTracker {
    pub fn new(n: usize) -> Self {
        CongestionTracker {
            in_flight: vec![0; n],
            peak_per_page: vec![0; n],
            peak_total: 0,
            total_in_flight: 0,
            messages: 0,
        }
    }

    /// A message addressed to `dst` entered the network.
    pub fn on_send(&mut self, dst: usize) {
        self.in_flight[dst] += 1;
        self.total_in_flight += 1;
        self.messages += 1;
        if self.in_flight[dst] > self.peak_per_page[dst] {
            self.peak_per_page[dst] = self.in_flight[dst];
        }
        if self.total_in_flight > self.peak_total {
            self.peak_total = self.total_in_flight;
        }
    }

    /// The message addressed to `dst` was delivered/processed.
    pub fn on_deliver(&mut self, dst: usize) {
        assert!(self.in_flight[dst] > 0, "deliver without send at {dst}");
        self.in_flight[dst] -= 1;
        self.total_in_flight -= 1;
    }

    /// Peak queued messages at any single page.
    pub fn peak_page_load(&self) -> u32 {
        self.peak_per_page.iter().copied().max().unwrap_or(0)
    }

    /// Peak number of messages simultaneously in flight network-wide.
    pub fn peak_total(&self) -> u32 {
        self.peak_total
    }

    pub fn total_messages(&self) -> u64 {
        self.messages
    }

    /// Per-page peak loads (for hotspot reports).
    pub fn peaks(&self) -> &[u32] {
        &self.peak_per_page
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peaks() {
        let mut c = CongestionTracker::new(3);
        c.on_send(1);
        c.on_send(1);
        c.on_send(2);
        assert_eq!(c.peak_page_load(), 2);
        assert_eq!(c.peak_total(), 3);
        c.on_deliver(1);
        c.on_send(1); // back to 2 at page 1, total 3 again
        assert_eq!(c.peak_page_load(), 2);
        assert_eq!(c.peak_total(), 3);
        assert_eq!(c.total_messages(), 4);
    }

    #[test]
    fn peaks_are_sticky() {
        let mut c = CongestionTracker::new(2);
        for _ in 0..5 {
            c.on_send(0);
        }
        for _ in 0..5 {
            c.on_deliver(0);
        }
        assert_eq!(c.peak_page_load(), 5);
        assert_eq!(c.peaks()[0], 5);
        assert_eq!(c.peaks()[1], 0);
    }

    #[test]
    #[should_panic]
    fn deliver_without_send_panics() {
        let mut c = CongestionTracker::new(1);
        c.on_deliver(0);
    }
}
