//! Deterministic discrete-event message network.
//!
//! The paper's system is a fleet of per-page processes exchanging
//! residual reads/writes with out-neighbours; its experiments (like ours)
//! run in simulation. This module provides the substrate the
//! [`crate::coordinator`] runs on:
//!
//! * [`events`] — a virtual-time event queue with deterministic FIFO
//!   tie-breaking (same seed ⇒ bit-identical runs);
//! * [`latency`] — pluggable link-latency models (zero / constant /
//!   uniform / exponential);
//! * [`congestion`] — per-page queueing accounting (peak in-flight load,
//!   used to contrast MP's O(N_k) traffic against the Monte-Carlo
//!   baseline's walk congestion).
//!
//! See DESIGN.md §6: the paper used no physical testbed; this simulated
//! network preserves the communication pattern (which pages talk to which
//! and how often) — the property the paper's claims are about.

pub mod congestion;
pub mod events;
pub mod latency;

pub use events::{EventQueue, Timed};
pub use latency::LatencyModel;
