//! Deterministic discrete-event message network.
//!
//! The paper's system is a fleet of per-page processes exchanging
//! residual reads/writes with out-neighbours; its experiments (like ours)
//! run in simulation. This module provides the substrate the
//! [`crate::coordinator`] runs on:
//!
//! * [`events`] — a virtual-time event queue with deterministic FIFO
//!   tie-breaking (same seed ⇒ bit-identical runs);
//! * [`latency`] — pluggable link-latency models (zero / constant /
//!   uniform / exponential);
//! * [`congestion`] — per-destination queueing accounting (peak in-flight
//!   load, used to contrast MP's O(N_k) traffic against the Monte-Carlo
//!   baseline's walk congestion);
//! * [`transport`] — the metered shard-to-shard message layer: latency
//!   draws, congestion tracking and bytes-on-the-wire accounting behind a
//!   single `send`/`pop` interface;
//! * [`faults`] — seeded fault plans (drop / duplicate / reorder jitter /
//!   crash windows / directional link windows / partition windows)
//!   composed with the transport, the `raw`/`rel` reliability modes,
//!   and the fault ledger threaded into reports.
//!
//! As of the msgpass backend ([`crate::coordinator::msgpass`]) this
//! substrate is load-bearing, not decorative: every cross-shard residual
//! update and weight-summary gossip message rides [`transport`], so the
//! reported message counts, byte totals, queue depths and virtual
//! time-to-ε are produced by this module's accounting. (The paper used no
//! physical testbed either; the simulation preserves the communication
//! pattern — which pages talk to which and how often — the property the
//! paper's claims are about.)

pub mod congestion;
pub mod events;
pub mod faults;
pub mod latency;
pub mod transport;

pub use events::{EventQueue, Timed};
pub use faults::{
    CrashWindow, FaultCounters, FaultPlan, LinkWindow, NetProfile, PartitionWindow, Reliability,
};
pub use latency::LatencyModel;
pub use transport::{Transport, TransportEvent, WireSized};
