//! Virtual-time event queue.
//!
//! A thin wrapper over a binary heap keyed by `(time, sequence)`: events
//! scheduled at equal times pop in insertion order, making simulations
//! bit-reproducible regardless of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event with its virtual firing time.
#[derive(Debug, Clone, PartialEq)]
pub struct Timed<E> {
    pub time: f64,
    seq: u64,
    pub event: E,
}

impl<E> Timed<E> {
    fn key(&self) -> (f64, u64) {
        (self.time, self.seq)
    }

    /// A surfaced event outside any queue — the transport re-wraps the
    /// popped timestamp around the public payload. The tie-break
    /// sequence is meaningless off-queue and zeroed.
    pub(crate) fn at(time: f64, event: E) -> Timed<E> {
        Timed { time, seq: 0, event }
    }
}

impl<E: PartialEq> Eq for Timed<E> {}

impl<E: PartialEq> Ord for Timed<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: reverse of natural order; NaN times are rejected at
        // insertion so partial_cmp is total here.
        other
            .key()
            .partial_cmp(&self.key())
            .expect("event times are never NaN")
    }
}

impl<E: PartialEq> PartialOrd for Timed<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-time event queue.
#[derive(Debug)]
pub struct EventQueue<E: PartialEq> {
    heap: BinaryHeap<Timed<E>>,
    next_seq: u64,
    now: f64,
}

impl<E: PartialEq> Default for EventQueue<E> {
    fn default() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now: 0.0,
        }
    }
}

impl<E: PartialEq> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `event` at absolute virtual time `at` (must be ≥ now and
    /// finite).
    pub fn schedule(&mut self, at: f64, event: E) {
        assert!(at.is_finite(), "event time must be finite");
        assert!(
            at >= self.now - 1e-12,
            "cannot schedule in the past: {at} < {}",
            self.now
        );
        self.heap.push(Timed {
            time: at,
            seq: self.next_seq,
            event,
        });
        self.next_seq += 1;
    }

    /// Schedule at `now + delay`.
    pub fn schedule_in(&mut self, delay: f64, event: E) {
        assert!(delay >= 0.0);
        let at = self.now + delay;
        self.schedule(at, event);
    }

    /// Pop the earliest event, advancing virtual time.
    pub fn pop(&mut self) -> Option<Timed<E>> {
        let ev = self.heap.pop();
        if let Some(t) = &ev {
            self.now = t.time;
        }
        ev
    }

    /// Earliest scheduled time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|t| t.time)
    }

    /// Earliest scheduled event without popping (and without advancing
    /// virtual time).
    pub fn peek_event(&self) -> Option<&E> {
        self.heap.peek().map(|t| &t.event)
    }

    /// Drop the earliest event **without advancing virtual time** — for
    /// cancelled timers (an acked message's pending retransmit check)
    /// whose firing would otherwise inflate the clock. Returns whether
    /// anything was discarded.
    pub fn discard_head(&mut self) -> bool {
        self.heap.pop().is_some()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().expect("a").event, "a");
        assert_eq!(q.now(), 1.0);
        assert_eq!(q.pop().expect("b").event, "b");
        assert_eq!(q.pop().expect("c").event, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_times_fifo() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule(5.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop().expect("event").event, i);
        }
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule(2.0, "first");
        q.pop();
        q.schedule_in(3.0, "second");
        let t = q.pop().expect("second");
        assert_eq!(t.time, 5.0);
    }

    #[test]
    #[should_panic]
    fn rejects_past() {
        let mut q = EventQueue::new();
        q.schedule(2.0, 1);
        q.pop();
        q.schedule(1.0, 2);
    }

    #[test]
    #[should_panic]
    fn rejects_nan() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.schedule(f64::NAN, 1);
    }

    #[test]
    fn discard_head_does_not_advance_time() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "keep");
        q.pop();
        q.schedule(7.0, "dead-timer");
        q.schedule(9.0, "live");
        assert_eq!(q.peek_event(), Some(&"dead-timer"));
        assert!(q.discard_head());
        assert_eq!(q.now(), 1.0, "discarding must not move the clock");
        let live = q.pop().expect("live event");
        assert_eq!((live.time, live.event), (9.0, "live"));
        assert!(!q.discard_head(), "empty queue discards nothing");
    }

    #[test]
    fn peek_and_len() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(4.0, 1);
        q.schedule(2.0, 2);
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.len(), 2);
    }
}
