//! The message-passing distributed backend: per-shard event loops over
//! the virtual-time network, communicating **only by messages**.
//!
//! Where [`super::sharded`] is a real multi-threaded deployment over
//! *shared memory* (every worker reads the one residual array, and the
//! PR-5 residual samplers consult idealized global/per-shard weight
//! trees), [`MsgpassRuntime`] models what the same algorithm costs on a
//! wire. Each shard owns a page partition (a [`ShardMap`] — closed-form
//! `mod`/`block` or the table-backed topology-aware `cluster`/`scc`
//! maps, resolved once at construction), keeps a full-length *replica*
//! of the residual vector, and runs an event loop over the shared
//! [`Transport`]:
//!
//! * **Activation** (a `Wake` event): the shard draws one owned page `k`
//!   uniformly from its own stream, computes the eq. 7/8 projection
//!   against its replica (stale for unowned pages under latency), applies
//!   it locally, and pushes one [`Msg::ResidualUpdate`] per touched page
//!   `j ∈ {k} ∪ out(k)` to every *subscriber* shard of `j` — the owners
//!   of `{j} ∪ in(j)`, i.e. exactly the shards that will ever read or
//!   own `r_j`. This is the paper's §II-D write fan-out aggregated to
//!   shard granularity.
//! * **Gossip**: every `gossip` activations a shard broadcasts a
//!   [`Msg::WeightSummary`] carrying its residual-weight tree total.
//!   The allocator splits each super-step's `batch` activation slots
//!   across shards proportionally to the *most recently delivered*
//!   summaries, decayed toward the floor with a half-life of one gossip
//!   interval — so cross-shard load follows residual mass using only
//!   gossiped (stale, metered) information, never a global view.
//!
//! Locality is metered alongside the wire: every cross-shard
//! `ResidualUpdate` is counted (messages and bytes), each activation
//! records how many *distinct* remote shards its updates fanned out to,
//! and the resolved map's static cross-edge fraction is reported — the
//! [`LocalityCounters`] the `locality` bench races across maps. A
//! cluster map keeps most of `{j} ∪ in(j)` on one shard, so subscriber
//! sets shrink and the same activation costs fewer wire bytes.
//!
//! Within a shard, page selection stays **uniform** over owned pages:
//! that is what makes `msgpass:1:1:mod` with zero latency replay
//! [`crate::algo::mp::MatchingPursuit`] *bit for bit* under the scenario
//! rng protocol (worker 0 clones the caller's stream verbatim, exactly
//! like the sharded runtime — pinned in `tests/engine.rs`). The weight
//! trees and gossip only steer *how many* slots each shard gets when
//! `shards > 1`.
//!
//! Every activation takes one unit of virtual time on its shard's event
//! loop (shards proceed in parallel), so `virtual_time()` measures the
//! parallel makespan: more shards ⇒ fewer serial slots per shard ⇒ less
//! virtual time per super-step, while the transport meters what that
//! parallelism costs in messages and bytes.
//!
//! ## Faults, crashes and recovery
//!
//! A [`MsgpassConfig`] composes a seeded
//! [`FaultPlan`](crate::network::FaultPlan) and a reliability mode onto
//! the wire (see [`crate::network::faults`]). Drop/duplicate/jitter are
//! entirely the transport's business; the runtime interprets **crash
//! windows**:
//!
//! * **down** (`[at, at+down_for)`): the shard's `Wake` events are
//!   discarded (it activates nothing) and every frame delivered to it
//!   is lost with its queue — the transport enforces both.
//! * **crash instant**: the shard's replica memory of *unowned* pages
//!   is lost (zeroed). Its owned `(x_k, r_k)` pairs survive — they are
//!   the durable two-scalars-per-page checkpoint the paper's protocol
//!   needs anyway — as do the protocol's sequence/dedup tables (modeled
//!   as stable storage). The `residual_divergence_at_crash` gauge
//!   records `(1/N)·Σ_j (r_owner_j − (y−Bx)_j)²` at that instant.
//! * **restart**: peers re-sync — each page's owner pushes one
//!   [`Msg::ResidualSync`] (absolute value, not a delta) to the
//!   restarted shard for every page it subscribes to. Syncs are
//!   ordinary metered traffic: sequence-numbered in `rel` mode,
//!   droppable in `raw`.
//!
//! **Link and partition windows** generalize the recovery path to
//! *heal* events. While a directional link (or a bipartition's crossing
//! links) is cut, the transport loses every frame across it; the
//! runtime's fault-schedule state machine watches the same windows and
//! fires at two extra instants:
//!
//! * **partition onset / heal**: the divergence gauge
//!   `(1/N)·Σ_j (r_owner_j − (y−Bx)_j)²` is sampled at both instants
//!   ([`MsgpassRuntime::partition_divergence`]), so
//!   `BENCH_partitions.json` can chart how far the halves drifted and
//!   how fast conservation recovers.
//! * **heal** (link restored or partition merged): a *targeted* re-sync
//!   — for each healed `src → dst` direction, `src` pushes one
//!   [`Msg::ResidualSync`] to `dst` for every page `src` owns and `dst`
//!   subscribes to. The stale side catches up without waiting for the
//!   next organic update; in `rel` mode retransmission already replays
//!   the lost deltas, so the sync is pure staleness repair and the
//!   conservation invariant holds exactly after drain.
//!
//! Correctness under faults is owner-authoritative: conservation
//! `Bx + r = (1−α)𝟙` needs every `ResidualUpdate` applied to its
//! *owner* exactly once. `rel` mode guarantees that (retransmission
//! past drops and crash windows, dedup past duplicates) as long as no
//! retry budget is exhausted — pinned by the conservation tests — while
//! `raw` mode loses owner deltas and degrades honestly. Replica entries
//! for *unowned* pages may double-apply a re-synced in-flight delta;
//! that only staleness-perturbs future projections (convergence rate),
//! never the invariant.

use crate::coordinator::sharded::{LocalityCounters, ResolvedMap, ShardMap};
use crate::graph::Graph;
use crate::linalg::select::{DEFAULT_WEIGHT_FLOOR, WeightTree};
use crate::linalg::sparse::BColumns;
use crate::network::faults::{
    CrashWindow, FaultCounters, FaultPlan, LinkWindow, NetProfile, PartitionWindow, Reliability,
};
use crate::network::latency::LatencyModel;
use crate::network::transport::{Transport, TransportEvent, WireSized};
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;

/// Default gossip period (activations per shard between
/// `WeightSummary` broadcasts) — the `msgpass:<shards>:<batch>:<map>`
/// registry forms without an explicit period use this.
pub const DEFAULT_GOSSIP_PERIOD: usize = 8;

/// Fixed wire size of a [`Msg::ResidualUpdate`]: 4-byte type tag +
/// 4-byte page id + 8-byte delta.
pub const RESIDUAL_UPDATE_BYTES: usize = 16;

/// Fixed wire size of a [`Msg::WeightSummary`]: 4-byte type tag +
/// 4-byte shard id + 8-byte total + 8-byte timestamp.
pub const WEIGHT_SUMMARY_BYTES: usize = 24;

/// Fixed wire size of a [`Msg::ResidualSync`]: 4-byte type tag +
/// 4-byte page id + 8-byte value.
pub const RESIDUAL_SYNC_BYTES: usize = 16;

/// Virtual time one activation occupies on its shard's event loop.
const ACTIVATION_TIME: f64 = 1.0;

/// The msgpass wire protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// `r[page] += delta` at the receiver's replica (§II-D write
    /// fan-out, aggregated to the subscriber shards of `page`).
    ResidualUpdate { page: u32, delta: f64 },
    /// Periodic broadcast of the sender's residual-weight tree total;
    /// drives cross-shard slot allocation.
    WeightSummary { total: f64 },
    /// Post-restart re-sync: `r[page] = value` at the receiver's
    /// replica — the owner's authoritative value, sent to a recovering
    /// subscriber (never to the page's own owner).
    ResidualSync { page: u32, value: f64 },
}

impl WireSized for Msg {
    fn wire_bytes(&self) -> usize {
        match self {
            Msg::ResidualUpdate { .. } => RESIDUAL_UPDATE_BYTES,
            Msg::WeightSummary { .. } => WEIGHT_SUMMARY_BYTES,
            Msg::ResidualSync { .. } => RESIDUAL_SYNC_BYTES,
        }
    }
}

/// Construction parameters of a [`MsgpassRuntime`] beyond the graph and
/// α: topology (shards/map), scheduling (batch/gossip), the latency
/// model, and the fault/reliability profile.
#[derive(Debug, Clone, PartialEq)]
pub struct MsgpassConfig {
    pub shards: usize,
    pub batch: usize,
    pub map: ShardMap,
    pub gossip: usize,
    pub latency: LatencyModel,
    /// Injected wire faults; `None` (or an empty plan — normalized at
    /// construction) is the exact PR-6 wire.
    pub faults: Option<FaultPlan>,
    pub reliability: Reliability,
}

impl MsgpassConfig {
    pub fn new(
        shards: usize,
        batch: usize,
        map: ShardMap,
        gossip: usize,
        latency: LatencyModel,
    ) -> MsgpassConfig {
        MsgpassConfig {
            shards,
            batch,
            map,
            gossip,
            latency,
            faults: None,
            reliability: Reliability::Raw,
        }
    }

    /// Compose a fault plan (an empty plan is normalized to `None`).
    pub fn with_faults(mut self, plan: FaultPlan) -> MsgpassConfig {
        self.faults = if plan.is_empty() { None } else { Some(plan) };
        self
    }

    /// Switch on the reliable-delivery protocol.
    pub fn reliable(mut self) -> MsgpassConfig {
        self.reliability = Reliability::Reliable;
        self
    }
}

/// The message-passing runtime (see the module docs).
#[derive(Debug)]
pub struct MsgpassRuntime {
    graph: Graph,
    cols: BColumns,
    alpha: f64,
    shards: usize,
    batch: usize,
    map: ShardMap,
    /// The map resolved against this graph (owner table for the
    /// topology-aware maps) — what every ownership lookup consults.
    rmap: ResolvedMap,
    gossip: usize,
    transport: Transport<Msg>,
    /// Dedicated stream for latency draws, forked from the seed stream —
    /// keeps the shard candidate streams identical whatever the latency
    /// model.
    net_rng: Rng,
    /// Per-shard candidate streams; seeded on the first super-step from
    /// the caller's rng (shard 0 clones it verbatim, the rest fork —
    /// the same protocol as the sharded runtime's worker packing).
    streams: Vec<Rng>,
    streams_seeded: bool,
    /// Per-shard full-length residual replicas; `views[w][j]` is shard
    /// `w`'s (possibly stale) knowledge of `r_j`.
    views: Vec<Vec<f64>>,
    /// Per-shard residual-weight tree over *owned* pages (local indices)
    /// — maintained only when `shards > 1` (it only drives allocation).
    trees: Vec<WeightTree>,
    /// Pages owned per shard.
    owned: Vec<usize>,
    /// Per-shard activation counters (gossip cadence).
    act_counts: Vec<u64>,
    /// Most recently *delivered* `WeightSummary` per source shard:
    /// `(total, receive_time)`.
    summaries: Vec<(f64, f64)>,
    /// PageRank estimate; `x[k]` is written only by `k`'s owner.
    x: Vec<f64>,
    /// Subscriber shards per page: owners of `{j} ∪ in(j)`, sorted.
    subs: Vec<Vec<u32>>,
    activations: u64,
    logical_reads: u64,
    logical_writes: u64,
    /// Scratch: touched pages of the current activation, sorted.
    touched: Vec<u32>,
    /// Scratch: pre-update replica values of the touched pages.
    old_vals: Vec<f64>,
    /// Crash windows from the fault plan (construction order) with
    /// onset/recovery progress flags, ticked against event times.
    /// Overlapping windows are legal — each advances independently.
    crashes: Vec<CrashWindow>,
    crash_started: Vec<bool>,
    crash_recovered: Vec<bool>,
    /// Directional link windows from the plan; the transport loses the
    /// frames, this schedule fires the heal-triggered re-sync.
    links: Vec<LinkWindow>,
    link_started: Vec<bool>,
    link_healed: Vec<bool>,
    /// Partition windows from the plan; onset and heal both sample the
    /// divergence gauge, heal re-syncs every crossing direction.
    partitions: Vec<PartitionWindow>,
    part_started: Vec<bool>,
    part_healed: Vec<bool>,
    /// Completed restarts (checkpoint restore + peer re-sync issued).
    recoveries: u64,
    /// Partition windows that have healed (merged + re-synced).
    partitions_healed: u64,
    /// Max over crash instants of the owner-residual's squared
    /// divergence from the true residual, scaled by 1/N.
    fault_divergence: f64,
    /// Max of the divergence gauge sampled at partition *onset*
    /// instants — how far the halves had already drifted when the wall
    /// came down.
    partition_divergence_onset: f64,
    /// Max of the divergence gauge sampled at partition *heal* instants
    /// — the drift accumulated across the window, the quantity
    /// `BENCH_partitions.json` charts recovering.
    partition_divergence_heal: f64,
    /// Largest `|{k} ∪ out(k)|` over pages — sizes the per-super-step
    /// event budget.
    max_fanout: usize,
    /// Test hook: forces the event budget ([`Self::set_event_budget`]).
    budget_override: Option<u64>,
    /// Locality ledger: cross-shard residual-update messages/bytes,
    /// per-activation distinct-peer fan-out, and the resolved map's
    /// static cross-edge fraction (set at construction).
    locality: LocalityCounters,
    /// Scratch: per-shard stamp of the last activation that counted the
    /// shard as a remote subscriber (dedups the fan-out count without
    /// allocating per activation).
    peer_mark: Vec<u64>,
}

impl MsgpassRuntime {
    /// The fault-free PR-6 constructor (raw wire, no plan) — delegates
    /// to [`MsgpassRuntime::with_config`].
    pub fn new(
        graph: Graph,
        alpha: f64,
        shards: usize,
        batch: usize,
        map: ShardMap,
        gossip: usize,
        latency: LatencyModel,
    ) -> MsgpassRuntime {
        MsgpassRuntime::with_config(
            graph,
            alpha,
            MsgpassConfig::new(shards, batch, map, gossip, latency),
        )
    }

    pub fn with_config(graph: Graph, alpha: f64, cfg: MsgpassConfig) -> MsgpassRuntime {
        let MsgpassConfig { shards, batch, map, gossip, latency, faults, reliability } = cfg;
        assert!(shards >= 1, "need at least one shard");
        assert!(batch >= 1, "need at least one activation per super-step");
        assert!(gossip >= 1, "gossip period must be >= 1");
        let faults = faults.filter(|p| !p.is_empty());
        if let Some(p) = faults.as_ref() {
            if let Err(e) = p.validate(shards) {
                panic!("invalid fault plan: {e}");
            }
        }
        let crashes: Vec<CrashWindow> =
            faults.as_ref().map(|p| p.crashes.clone()).unwrap_or_default();
        let links: Vec<LinkWindow> =
            faults.as_ref().map(|p| p.links.clone()).unwrap_or_default();
        let partitions: Vec<PartitionWindow> =
            faults.as_ref().map(|p| p.partitions.clone()).unwrap_or_default();
        let n = graph.n();
        let cols = BColumns::new(&graph, alpha);
        let y = 1.0 - alpha;
        let w0 = (y * y).max(DEFAULT_WEIGHT_FLOOR);
        // Resolve the map once (table-backed maps run their partition
        // algorithm here — same fixed internal seed as the sharded
        // runtime, so both backends place pages identically).
        let rmap = map.resolve(&graph, shards);
        let locality = LocalityCounters {
            cross_edge_fraction: rmap.cross_edge_fraction(&graph),
            ..LocalityCounters::default()
        };
        let owned: Vec<usize> = (0..shards).map(|w| rmap.owned_count(w)).collect();
        let trees: Vec<WeightTree> =
            owned.iter().map(|&cnt| WeightTree::new(&vec![w0; cnt])).collect();
        let summaries: Vec<(f64, f64)> =
            owned.iter().map(|&cnt| (cnt as f64 * w0, 0.0)).collect();
        let mut subs = Vec::with_capacity(n);
        for j in 0..n {
            let mut s: Vec<u32> = Vec::with_capacity(1 + graph.inc(j).len());
            s.push(rmap.owner(j) as u32);
            for &p in graph.inc(j) {
                s.push(rmap.owner(p as usize) as u32);
            }
            s.sort_unstable();
            s.dedup();
            subs.push(s);
        }
        let max_fanout =
            (0..n).map(|k| 1 + graph.out(k).len()).max().unwrap_or(1);
        let crash_count = crashes.len();
        let link_count = links.len();
        let part_count = partitions.len();
        MsgpassRuntime {
            cols,
            alpha,
            shards,
            batch,
            map,
            gossip,
            transport: Transport::with_profile(
                shards,
                latency,
                NetProfile { faults, reliability },
            ),
            net_rng: Rng::seeded(0),
            streams: Vec::new(),
            streams_seeded: false,
            views: vec![vec![y; n]; shards],
            trees,
            owned,
            act_counts: vec![0; shards],
            summaries,
            x: vec![0.0; n],
            subs,
            activations: 0,
            logical_reads: 0,
            logical_writes: 0,
            touched: Vec::new(),
            old_vals: Vec::new(),
            crashes,
            crash_started: vec![false; crash_count],
            crash_recovered: vec![false; crash_count],
            links,
            link_started: vec![false; link_count],
            link_healed: vec![false; link_count],
            partitions,
            part_started: vec![false; part_count],
            part_healed: vec![false; part_count],
            recoveries: 0,
            partitions_healed: 0,
            fault_divergence: 0.0,
            partition_divergence_onset: 0.0,
            partition_divergence_heal: 0.0,
            max_fanout,
            budget_override: None,
            locality,
            peer_mark: vec![0; shards],
            rmap,
            graph,
        }
    }

    /// Run one super-step, panicking if it cannot drain — the
    /// infallible wrapper over [`MsgpassRuntime::try_run_super_step`]
    /// for fault-free callers.
    pub fn run_super_step(&mut self, rng: &mut Rng) {
        self.try_run_super_step(rng).expect("msgpass super-step failed to drain");
    }

    /// Run one super-step: allocate `batch` activation slots across the
    /// shards from the gossiped weight summaries, schedule each shard's
    /// slots on its event loop, and drain the transport (activations,
    /// deliveries, gossip, fault-schedule ticks and the reliability
    /// protocol interleave in virtual-time order).
    ///
    /// Fails loudly — a named error instead of a spin — if the drain
    /// surfaces more events than the structural budget allows, which
    /// can only mean the queue will never drain (a pathological fault
    /// plan or a protocol bug).
    ///
    /// `rng` seeds the per-shard candidate streams on the first call
    /// (shard 0 clones it verbatim — the msgpass ≡ mp anchor) and is
    /// untouched afterwards.
    pub fn try_run_super_step(&mut self, rng: &mut Rng) -> Result<()> {
        if !self.streams_seeded {
            for w in 0..self.shards {
                self.streams.push(if w == 0 { rng.clone() } else { rng.fork(w as u64) });
            }
            self.net_rng = rng.fork(0x6E65_745F_7374); // "net_st"
            self.streams_seeded = true;
        }
        let budget = self.budget_override.unwrap_or_else(|| self.event_budget());
        let slots = self.allocate();
        let t0 = self.transport.now();
        for (w, &count) in slots.iter().enumerate() {
            for slot in 0..count {
                self.transport.wake_at(w, t0 + (slot + 1) as f64 * ACTIVATION_TIME);
            }
        }
        let mut surfaced: u64 = 0;
        while let Some(ev) = self.transport.pop() {
            surfaced += 1;
            if surfaced > budget {
                return Err(crate::anyhow!(
                    "msgpass super-step event budget exhausted: {surfaced} events surfaced \
                     (budget {budget}, {} still queued at vtime {}) — the event queue cannot \
                     drain; the fault plan or reliability protocol is generating unbounded \
                     traffic",
                    self.transport.len(),
                    self.transport.now(),
                ));
            }
            self.tick_faults(ev.time);
            match ev.event {
                TransportEvent::Wake { shard } => {
                    // A crashed shard's event loop is dead: its slots
                    // are simply lost capacity.
                    if !self.transport.is_down(shard, ev.time) {
                        self.activate_one(shard);
                    }
                }
                TransportEvent::Deliver { src, dst, msg } => self.deliver(src, dst, msg, ev.time),
            }
        }
        Ok(())
    }

    /// Structural upper bound on the events one super-step can surface:
    /// the transport consumes protocol frames and suppressed deliveries
    /// internally, so what reaches the runtime is at most the wakes,
    /// each send's deliveries (×2 for duplication), re-sync fan-in
    /// after recoveries and heals (a partition heal re-syncs up to
    /// `shards` crossing directions), and whatever was carried over in
    /// the queue.
    /// Exceeding it is impossible for a draining queue by construction.
    fn event_budget(&self) -> u64 {
        let n = self.graph.n() as u64;
        let per_act = (self.max_fanout as u64 + 2) * self.shards as u64 * 4;
        let carry = self.transport.len() as u64;
        let windows = (self.crashes.len() + self.links.len()) as u64
            + self.partitions.len() as u64 * self.shards as u64;
        (self.batch as u64 + carry) * per_act + (windows + 1) * 4 * n + 1024
    }

    /// Test hook: force the super-step event budget to exercise the
    /// named cannot-drain error.
    #[cfg(test)]
    fn set_event_budget(&mut self, budget: u64) {
        self.budget_override = Some(budget);
    }

    /// Drive super-steps until the scaled residual `(1/N)‖r‖²` reaches
    /// `eps` or `max_super_steps` elapse; returns the super-steps taken
    /// (the cap itself if `eps` was not reached), or the named
    /// cannot-drain error from [`MsgpassRuntime::try_run_super_step`].
    pub fn run_to_residual(
        &mut self,
        eps: f64,
        max_super_steps: usize,
        rng: &mut Rng,
    ) -> Result<usize> {
        for step in 0..max_super_steps {
            if self.residual_norm_sq() / self.graph.n() as f64 <= eps {
                return Ok(step);
            }
            self.try_run_super_step(rng)
                .with_context(|| format!("msgpass run_to_residual at super-step {step}"))?;
        }
        Ok(max_super_steps)
    }

    /// Advance the fault-schedule state machine to `now`: fire every
    /// crash onset (divergence gauge + replica wipe), recovery (counter
    /// + peer re-sync), partition onset (gauge sample), and heal (link
    /// restored / partition merged — targeted re-sync) whose instant
    /// has passed. Windows fire in event-time order because this is
    /// called per popped event; overlapping windows of any kind advance
    /// independently.
    fn tick_faults(&mut self, now: f64) {
        for i in 0..self.crashes.len() {
            let c = self.crashes[i];
            if !self.crash_started[i] && now >= c.at {
                self.crash_started[i] = true;
                self.on_crash(c.shard);
            }
            if self.crash_started[i] && !self.crash_recovered[i] && now >= c.restart_at() {
                self.crash_recovered[i] = true;
                self.on_recover(c.shard);
            }
        }
        for i in 0..self.links.len() {
            let l = self.links[i];
            if !self.link_started[i] && now >= l.at {
                self.link_started[i] = true;
            }
            if self.link_started[i] && !self.link_healed[i] && now >= l.heal_at() {
                self.link_healed[i] = true;
                self.sync_direction(l.src, l.dst);
            }
        }
        for i in 0..self.partitions.len() {
            let (at, heal_at) = (self.partitions[i].at, self.partitions[i].heal_at());
            if !self.part_started[i] && now >= at {
                self.part_started[i] = true;
                let g = self.divergence_gauge();
                self.partition_divergence_onset = self.partition_divergence_onset.max(g);
            }
            if self.part_started[i] && !self.part_healed[i] && now >= heal_at {
                self.part_healed[i] = true;
                self.on_partition_heal(i);
            }
        }
    }

    /// The divergence gauge: `(1/N)·Σ_j (r_owner_j − (y − Bx)_j)²` —
    /// how far the owner-authoritative residuals have drifted from the
    /// true residual (in-flight and lost mass). Sampled at crash
    /// instants and at partition onset/heal.
    fn divergence_gauge(&self) -> f64 {
        let n = self.graph.n();
        let y = 1.0 - self.alpha;
        let mut truth = vec![y; n];
        for k in 0..n {
            if self.x[k] != 0.0 {
                self.cols.sub_scaled_col(&self.graph, k, self.x[k], &mut truth);
            }
        }
        let mut div = 0.0;
        for (j, t) in truth.iter().enumerate() {
            let d = self.views[self.rmap.owner(j)][j] - t;
            div += d * d;
        }
        div / n as f64
    }

    /// Crash instant: gauge how far the owner-authoritative residual
    /// had diverged from the true `y − Bx` (in-flight and lost mass),
    /// then drop the shard's replica memory of unowned pages. The owned
    /// `(x_k, r_k)` pairs are the durable two-scalars-per-page
    /// checkpoint and survive.
    fn on_crash(&mut self, w: usize) {
        let g = self.divergence_gauge();
        self.fault_divergence = self.fault_divergence.max(g);
        for j in 0..self.graph.n() {
            if self.rmap.owner(j) != w {
                self.views[w][j] = 0.0;
            }
        }
    }

    /// A healed `src → dst` direction: `src` pushes its authoritative
    /// value to `dst` for every page it owns and `dst` subscribes to —
    /// the targeted analogue of the post-restart re-sync (same metered,
    /// faultable [`Msg::ResidualSync`] traffic). Pages `dst` owns need
    /// no sync: `dst`'s own entries are authoritative, and in `rel`
    /// mode the lost owner deltas are replayed by retransmission.
    fn sync_direction(&mut self, src: usize, dst: usize) {
        for j in 0..self.graph.n() {
            if self.rmap.owner(j) != src || self.subs[j].binary_search(&(dst as u32)).is_err() {
                continue;
            }
            let value = self.views[src][j];
            self.transport.send(
                src,
                dst,
                Msg::ResidualSync { page: j as u32, value },
                &mut self.net_rng,
            );
        }
    }

    /// Partition heal: sample the divergence gauge (the drift the
    /// window accumulated), then re-sync every crossing direction of
    /// the bipartition.
    fn on_partition_heal(&mut self, idx: usize) {
        self.partitions_healed += 1;
        let g = self.divergence_gauge();
        self.partition_divergence_heal = self.partition_divergence_heal.max(g);
        let p = self.partitions[idx].clone();
        for a in 0..self.shards {
            for b in 0..self.shards {
                if a != b && p.cuts(a, b) {
                    self.sync_direction(a, b);
                }
            }
        }
    }

    /// Restart: peers re-sync the wiped replica — each page's owner
    /// pushes its authoritative value to the restarted shard for every
    /// page it subscribes to (metered, faultable traffic like any
    /// other).
    fn on_recover(&mut self, w: usize) {
        self.recoveries += 1;
        let n = self.graph.n();
        for j in 0..n {
            let o = self.rmap.owner(j);
            if o == w || self.subs[j].binary_search(&(w as u32)).is_err() {
                continue;
            }
            let value = self.views[o][j];
            self.transport.send(
                o,
                w,
                Msg::ResidualSync { page: j as u32, value },
                &mut self.net_rng,
            );
        }
    }

    /// Split `batch` slots across shards proportionally to the decayed
    /// gossiped weight totals (largest-remainder rounding, ties to the
    /// lower shard id). Single-shard runs take the whole batch; shards
    /// owning no pages get no slots.
    fn allocate(&self) -> Vec<usize> {
        if self.shards == 1 {
            return vec![self.batch];
        }
        let now = self.transport.now();
        let half_life = self.gossip as f64 * ACTIVATION_TIME;
        let mut weights = vec![0.0; self.shards];
        for w in 0..self.shards {
            if self.owned[w] == 0 {
                continue;
            }
            let (total, t_recv) = self.summaries[w];
            let age = (now - t_recv).max(0.0);
            let decayed = total * 0.5f64.powf(age / half_life);
            weights[w] = decayed.max(self.owned[w] as f64 * DEFAULT_WEIGHT_FLOOR);
        }
        let wsum: f64 = weights.iter().sum();
        let mut slots = vec![0usize; self.shards];
        if !(wsum > 0.0) || !wsum.is_finite() {
            // Degenerate summaries: fall back to a static split over the
            // shards that own pages.
            let eligible: Vec<usize> =
                (0..self.shards).filter(|&w| self.owned[w] > 0).collect();
            let per = self.batch / eligible.len();
            let extra = self.batch % eligible.len();
            for (i, &w) in eligible.iter().enumerate() {
                slots[w] = per + usize::from(i < extra);
            }
            return slots;
        }
        let mut assigned = 0usize;
        let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(self.shards);
        for w in 0..self.shards {
            let exact = self.batch as f64 * weights[w] / wsum;
            let fl = exact.floor() as usize;
            slots[w] = fl;
            assigned += fl;
            fracs.push((exact - fl as f64, w));
        }
        fracs.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).expect("weights are finite").then(a.1.cmp(&b.1))
        });
        let remainder = self.batch.saturating_sub(assigned);
        for i in 0..remainder {
            slots[fracs[i % fracs.len()].1] += 1;
        }
        slots
    }

    /// One activation on shard `w`'s event loop: uniform owned-page
    /// draw, eq. 7/8 projection against the local replica, residual
    /// messages to the subscriber shards, gossip on cadence.
    fn activate_one(&mut self, w: usize) {
        let owned = self.owned[w];
        if owned == 0 {
            return;
        }
        let pick = self.streams[w].below(owned);
        let k = self.rmap.owned_page(w, pick);
        let deg = self.graph.out_degree(k);
        let num = self.cols.col_dot(&self.graph, k, &self.views[w]);
        let coef = num / self.cols.norm_sq(k);
        self.x[k] += coef;
        // Residual support of the projection: {k} ∪ out(k), sorted so
        // message order (and the Fenwick update order downstream) is a
        // pure function of the activation sequence.
        self.touched.clear();
        self.touched.push(k as u32);
        self.touched.extend_from_slice(self.graph.out(k));
        self.touched.sort_unstable();
        self.touched.dedup();
        self.old_vals.clear();
        for i in 0..self.touched.len() {
            self.old_vals.push(self.views[w][self.touched[i] as usize]);
        }
        self.cols.sub_scaled_col(&self.graph, k, coef, &mut self.views[w]);
        // Locality ledger stamp: `activations` increments below, so
        // `activations + 1` is unique per activation — peer_mark dedups
        // the distinct-remote-shard count without a per-call allocation.
        let stamp = self.activations + 1;
        for i in 0..self.touched.len() {
            let j = self.touched[i] as usize;
            let new = self.views[w][j];
            // Exact replica delta: a receiver holding the same old value
            // lands on the bit-identical new value.
            let delta = new - self.old_vals[i];
            if self.shards > 1 {
                for si in 0..self.subs[j].len() {
                    let s = self.subs[j][si] as usize;
                    if s != w {
                        self.transport.send(
                            w,
                            s,
                            Msg::ResidualUpdate { page: j as u32, delta },
                            &mut self.net_rng,
                        );
                        self.locality.cross_messages += 1;
                        self.locality.cross_bytes += RESIDUAL_UPDATE_BYTES as u64;
                        if self.peer_mark[s] != stamp {
                            self.peer_mark[s] = stamp;
                            self.locality.subscriber_shard_sum += 1;
                        }
                    }
                }
                if self.rmap.owner(j) == w {
                    let li = self.rmap.local_index(j);
                    self.trees[w].update(li, (new * new).max(DEFAULT_WEIGHT_FLOOR));
                }
            }
        }
        self.activations += 1;
        self.logical_reads += deg as u64;
        self.logical_writes += deg as u64;
        if self.shards > 1 {
            self.act_counts[w] += 1;
            if self.act_counts[w] % self.gossip as u64 == 0 {
                let total = self.trees[w].total();
                for s in 0..self.shards {
                    if s != w {
                        self.transport.send(
                            w,
                            s,
                            Msg::WeightSummary { total },
                            &mut self.net_rng,
                        );
                    }
                }
            }
        }
    }

    /// Apply a delivered message at shard `dst`.
    fn deliver(&mut self, src: usize, dst: usize, msg: Msg, time: f64) {
        match msg {
            Msg::ResidualUpdate { page, delta } => {
                let j = page as usize;
                self.views[dst][j] += delta;
                if self.shards > 1 && self.rmap.owner(j) == dst {
                    let v = self.views[dst][j];
                    let li = self.rmap.local_index(j);
                    self.trees[dst].update(li, (v * v).max(DEFAULT_WEIGHT_FLOOR));
                }
            }
            Msg::WeightSummary { total } => {
                self.summaries[src] = (total, time);
            }
            Msg::ResidualSync { page, value } => {
                // Absolute owner value for a recovering replica; never
                // targets the page's owner, so no tree update.
                self.views[dst][page as usize] = value;
            }
        }
    }

    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn gossip_period(&self) -> usize {
        self.gossip
    }

    pub fn map(&self) -> ShardMap {
        self.map
    }

    pub fn latency(&self) -> LatencyModel {
        self.transport.latency()
    }

    /// The composed fault plan, if any.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.transport.fault_plan()
    }

    /// Whether the reliable-delivery protocol is on.
    pub fn is_reliable(&self) -> bool {
        self.transport.is_reliable()
    }

    /// The merged fault ledger: the transport's wire counters plus the
    /// runtime's recovery/heal counts and crash-divergence gauge.
    pub fn fault_counters(&self) -> FaultCounters {
        let mut c = self.transport.fault_counters();
        c.recoveries = self.recoveries;
        c.partitions_healed = self.partitions_healed;
        c.residual_divergence_at_crash = self.fault_divergence;
        c
    }

    /// The divergence gauge sampled at partition `(onset, heal)`
    /// instants — max over windows of
    /// `(1/N)·Σ_j (r_owner_j − (y − Bx)_j)²`. Both zero when no
    /// partition window has fired.
    pub fn partition_divergence(&self) -> (f64, f64) {
        (self.partition_divergence_onset, self.partition_divergence_heal)
    }

    /// The locality ledger: cross-shard residual-update messages and
    /// bytes, the distinct-remote-subscriber sum (divide by
    /// [`Self::activations`] for the mean fan-out per activation), and
    /// the resolved map's static cross-edge fraction. All zeros on
    /// single-shard runs.
    pub fn locality(&self) -> LocalityCounters {
        self.locality
    }

    /// The shard map resolved against this graph (owner table for the
    /// `cluster`/`scc` maps).
    pub fn resolved_map(&self) -> &ResolvedMap {
        &self.rmap
    }

    /// Messages the reliable sender gave up on after the retry budget —
    /// nonzero means even `rel` mode lost deltas and conservation may
    /// not hold exactly.
    pub fn abandoned_messages(&self) -> u64 {
        self.transport.abandoned()
    }

    /// Current PageRank estimate (owner-written, globally consistent).
    pub fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    pub fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    /// Owner-authoritative residual: each entry from its owner's
    /// replica. Exact once the transport is drained at zero latency;
    /// lags only in-flight foreign deltas otherwise.
    pub fn residual(&self) -> Vec<f64> {
        let n = self.graph.n();
        (0..n).map(|j| self.views[self.rmap.owner(j)][j]).collect()
    }

    pub fn residual_norm_sq(&self) -> f64 {
        let n = self.graph.n();
        (0..n)
            .map(|j| {
                let r = self.views[self.rmap.owner(j)][j];
                r * r
            })
            .sum()
    }

    pub fn activations(&self) -> u64 {
        self.activations
    }

    pub fn logical_reads(&self) -> u64 {
        self.logical_reads
    }

    pub fn logical_writes(&self) -> u64 {
        self.logical_writes
    }

    /// Metered messages sent so far (residual updates + gossip).
    pub fn messages_sent(&self) -> u64 {
        self.transport.messages_sent()
    }

    /// Bytes charged to the wire so far (fixed per-type encodings).
    pub fn bytes_on_wire(&self) -> u64 {
        self.transport.bytes_on_wire()
    }

    /// Peak messages simultaneously queued for any single shard.
    pub fn peak_queue_depth(&self) -> u32 {
        self.transport.peak_queue_depth()
    }

    /// Peak messages simultaneously in flight network-wide.
    pub fn peak_in_flight(&self) -> u32 {
        self.transport.peak_in_flight()
    }

    /// Virtual time consumed: the parallel makespan of all event loops.
    pub fn virtual_time(&self) -> f64 {
        self.transport.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::common::PageRankSolver;
    use crate::algo::mp::MatchingPursuit;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn single_shard_batch_one_matches_matrix_mp_bit_for_bit() {
        // The equivalence anchor: one shard, one slot per super-step,
        // zero latency — shard 0 clones the caller's stream, samples
        // below(n) and applies the same BColumns arithmetic, so the
        // estimate must be bit-identical to matrix-form Algorithm 1.
        let g = generators::er_threshold(40, 0.5, 2);
        let mut rt = MsgpassRuntime::new(
            g.clone(),
            0.85,
            1,
            1,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(13);
        for _ in 0..500 {
            rt.run_super_step(&mut rng);
        }
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng2 = Rng::seeded(13);
        for _ in 0..500 {
            let k = rng2.below(40);
            mp.step_at(k);
        }
        assert_eq!(rt.estimate(), PageRankSolver::estimate(&mp), "not bit-identical");
        assert_eq!(rt.residual(), mp.residual().to_vec());
        assert_eq!(rt.activations(), 500);
        assert_eq!(rt.messages_sent(), 0, "one shard never messages");
        assert_eq!(rt.bytes_on_wire(), 0);
    }

    #[test]
    fn one_super_step_meters_every_wire_byte() {
        // ring(2), mod map: shard 0 owns page 0, shard 1 owns page 1,
        // and both shards subscribe to both pages. One activation
        // touches {k, out(k)} = both pages -> 2 residual updates to the
        // peer; gossip period 1 adds one summary. Fixed encodings make
        // the byte count exact.
        let g = generators::ring(2);
        let mut rt =
            MsgpassRuntime::new(g, 0.85, 2, 1, ShardMap::Modulo, 1, LatencyModel::Zero);
        let mut rng = Rng::seeded(5);
        rt.run_super_step(&mut rng);
        assert_eq!(rt.activations(), 1);
        assert_eq!(rt.messages_sent(), 3);
        assert_eq!(
            rt.bytes_on_wire(),
            (2 * RESIDUAL_UPDATE_BYTES + WEIGHT_SUMMARY_BYTES) as u64
        );
        assert!(rt.peak_queue_depth() >= 1);
        // The locality ledger sees only the residual-update fan-out
        // (gossip is allocator business, not data locality): 2 cross
        // messages to 1 distinct remote shard.
        let loc = rt.locality();
        assert_eq!(loc.cross_messages, 2);
        assert_eq!(loc.cross_bytes, (2 * RESIDUAL_UPDATE_BYTES) as u64);
        assert_eq!(loc.subscriber_shard_sum, 1, "one distinct remote peer");
        assert!(loc.cross_edge_fraction > 0.0, "ring(2) has only cross edges under mod");
    }

    #[test]
    fn multi_shard_zero_latency_converges_to_exact_pagerank() {
        let g = generators::er_threshold(20, 0.5, 7);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            4,
            8,
            ShardMap::Modulo,
            4,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(9);
        for _ in 0..8_000 {
            rt.run_super_step(&mut rng);
        }
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-7, "err={err}");
        assert!(rt.messages_sent() > 0, "multi-shard runs must message");
        assert!(rt.bytes_on_wire() > rt.messages_sent(), "every message has bytes");
        assert!(rt.virtual_time() > 0.0);
    }

    #[test]
    fn conservation_b_x_plus_r_is_y_at_zero_latency() {
        // eq. 11 survives sharding: activations apply exact additive
        // column updates, so after a full drain the owner-gathered
        // residual satisfies B x + r = (1-α)1.
        let g = generators::er_threshold(30, 0.5, 11);
        let alpha = 0.85;
        let mut rt = MsgpassRuntime::new(
            g.clone(),
            alpha,
            3,
            8,
            ShardMap::Block,
            4,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(12);
        for _ in 0..200 {
            rt.run_super_step(&mut rng);
        }
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&rt.estimate());
        let r = rt.residual();
        for (i, v) in bx.iter().enumerate() {
            let lhs = v + r[i];
            assert!((lhs - (1.0 - alpha)).abs() < 1e-9, "page {i}: {lhs}");
        }
    }

    #[test]
    fn converges_and_meters_under_exponential_latency() {
        // Stale replicas under a heavy-tailed latency model: the error
        // must still contract (asynchronous additive updates), and the
        // congestion tracker must observe genuine in-flight overlap.
        let g = generators::er_threshold(20, 0.5, 13);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            2,
            4,
            ShardMap::Modulo,
            4,
            LatencyModel::Exponential { mean: 0.3 },
        );
        let mut rng = Rng::seeded(14);
        let before = rt.error_sq_vs(&x_star);
        for _ in 0..4_000 {
            rt.run_super_step(&mut rng);
        }
        let after = rt.error_sq_vs(&x_star);
        assert!(after.is_finite());
        assert!(after < before / 100.0, "no contraction: {before} -> {after}");
        assert!(rt.peak_in_flight() >= 2, "latency must create overlap");
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let build = || {
            MsgpassRuntime::new(
                generators::er_threshold(15, 0.5, 3),
                0.85,
                3,
                6,
                ShardMap::Modulo,
                2,
                LatencyModel::Exponential { mean: 0.5 },
            )
        };
        let (mut a, mut b) = (build(), build());
        let (mut ra, mut rb) = (Rng::seeded(21), Rng::seeded(21));
        for _ in 0..300 {
            a.run_super_step(&mut ra);
            b.run_super_step(&mut rb);
        }
        assert_eq!(a.estimate(), b.estimate());
        assert_eq!(a.messages_sent(), b.messages_sent());
        assert_eq!(a.bytes_on_wire(), b.bytes_on_wire());
        assert_eq!(a.virtual_time(), b.virtual_time());
    }

    #[test]
    fn dangling_chain_converges_via_the_shared_guard() {
        // chain(20) ends in a genuine sink; the BColumns implicit
        // self-loop keeps every replica finite and the fixed point
        // matches the dense reference.
        let g = generators::chain(20);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            2,
            4,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(17);
        for _ in 0..15_000 {
            rt.run_super_step(&mut rng);
        }
        assert!(rt.estimate().iter().all(|v| v.is_finite()));
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn shards_without_pages_get_no_slots() {
        // More shards than pages: the empty shards must be skipped by
        // the allocator, not sampled (below(0) is UB in release).
        let g = generators::ring(3);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            8,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(19);
        for _ in 0..50 {
            rt.run_super_step(&mut rng);
        }
        assert_eq!(rt.activations(), 50 * 8, "every slot lands on a live shard");
        assert!(rt.estimate().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn run_to_residual_stops_at_epsilon() {
        let g = generators::er_threshold(15, 0.5, 23);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            2,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(24);
        let steps =
            rt.run_to_residual(1e-10, 100_000, &mut rng).expect("fault-free runs drain");
        assert!(steps < 100_000, "must reach epsilon before the cap");
        assert!(rt.residual_norm_sq() / rt.n() as f64 <= 1e-10);
    }

    fn faulted(
        graph: crate::graph::Graph,
        shards: usize,
        latency: LatencyModel,
        plan: FaultPlan,
        reliable: bool,
    ) -> MsgpassRuntime {
        let mut cfg = MsgpassConfig::new(shards, batch_for(shards), ShardMap::Modulo, 4, latency)
            .with_faults(plan);
        if reliable {
            cfg = cfg.reliable();
        }
        MsgpassRuntime::with_config(graph, 0.85, cfg)
    }

    fn batch_for(shards: usize) -> usize {
        2 * shards
    }

    fn max_conservation_violation(rt: &MsgpassRuntime, g: &crate::graph::Graph) -> f64 {
        let b = DenseMatrix::b_matrix(g, 0.85);
        let bx = b.matvec(&rt.estimate());
        let r = rt.residual();
        bx.iter()
            .zip(&r)
            .map(|(v, rj)| (v + rj - 0.15).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn empty_fault_plan_raw_mode_is_bit_identical_to_the_plain_backend() {
        // The PR-6 compatibility pin: composing an all-zero plan in raw
        // mode must change nothing — same estimate, bytes, messages and
        // virtual time, event for event.
        let g = generators::er_threshold(25, 0.5, 31);
        let mut plain = MsgpassRuntime::new(
            g.clone(),
            0.85,
            3,
            6,
            ShardMap::Modulo,
            4,
            LatencyModel::Exponential { mean: 0.4 },
        );
        let cfg = MsgpassConfig::new(
            3,
            6,
            ShardMap::Modulo,
            4,
            LatencyModel::Exponential { mean: 0.4 },
        )
        .with_faults(FaultPlan::default());
        let mut composed = MsgpassRuntime::with_config(g, 0.85, cfg);
        let (mut ra, mut rb) = (Rng::seeded(42), Rng::seeded(42));
        for _ in 0..300 {
            plain.run_super_step(&mut ra);
            composed.run_super_step(&mut rb);
        }
        assert_eq!(plain.estimate(), composed.estimate());
        assert_eq!(plain.messages_sent(), composed.messages_sent());
        assert_eq!(plain.bytes_on_wire(), composed.bytes_on_wire());
        assert_eq!(plain.virtual_time(), composed.virtual_time());
        assert!(!composed.fault_counters().any());
    }

    #[test]
    fn reliable_mode_without_faults_converges_and_meters_its_overhead() {
        let g = generators::er_threshold(20, 0.5, 7);
        let x_star = exact_pagerank(&g, 0.85);
        let build = |reliable: bool| {
            let mut cfg =
                MsgpassConfig::new(2, 4, ShardMap::Modulo, 4, LatencyModel::Zero);
            if reliable {
                cfg = cfg.reliable();
            }
            MsgpassRuntime::with_config(g.clone(), 0.85, cfg)
        };
        let (mut raw, mut rel) = (build(false), build(true));
        let (mut ra, mut rb) = (Rng::seeded(33), Rng::seeded(33));
        for _ in 0..4_000 {
            raw.run_super_step(&mut ra);
            rel.run_super_step(&mut rb);
        }
        assert!(vector::dist_inf(&rel.estimate(), &x_star) < 1e-7);
        assert_eq!(rel.abandoned_messages(), 0);
        assert_eq!(rel.fault_counters().retransmits, 0, "no faults, no retransmits");
        assert!(
            rel.bytes_on_wire() > raw.bytes_on_wire(),
            "seq headers and acks must cost bytes: rel={} raw={}",
            rel.bytes_on_wire(),
            raw.bytes_on_wire()
        );
        assert!(max_conservation_violation(&rel, &g) < 1e-9);
    }

    #[test]
    fn conservation_holds_after_drain_under_every_fault_plan_in_reliable_mode() {
        // The tentpole invariant: drop, duplicate, reorder jitter and a
        // crash window each (and combined) leave Bx + r = (1-α)1 exact
        // after the queue drains, because the reliable protocol applies
        // every owner delta exactly once and retransmits across the
        // crash window. Gated on a clean retry ledger.
        let plans: Vec<(&str, FaultPlan)> = vec![
            ("drop", FaultPlan::default().with_drop(0.2)),
            ("dup", FaultPlan::default().with_duplicate(0.3)),
            ("reorder", FaultPlan::default().with_jitter(3.0)),
            (
                "crash",
                FaultPlan::default().with_crash(CrashWindow {
                    shard: 1,
                    at: 40.0,
                    down_for: 20.0,
                }),
            ),
            (
                "combined",
                FaultPlan::default()
                    .with_drop(0.1)
                    .with_duplicate(0.1)
                    .with_jitter(1.5)
                    .with_crash(CrashWindow { shard: 2, at: 30.0, down_for: 15.0 }),
            ),
            (
                "link",
                FaultPlan::default().with_link(LinkWindow {
                    src: 0,
                    dst: 1,
                    at: 40.0,
                    down_for: 20.0,
                }),
            ),
            (
                "partition",
                FaultPlan::default().with_partition(PartitionWindow::new(
                    vec![0],
                    40.0,
                    20.0,
                )),
            ),
            (
                "overlapping-crashes",
                FaultPlan::default()
                    .with_crash(CrashWindow { shard: 1, at: 40.0, down_for: 30.0 })
                    .with_crash(CrashWindow { shard: 2, at: 50.0, down_for: 30.0 }),
            ),
            (
                "partition+crash+drop",
                FaultPlan::default()
                    .with_drop(0.05)
                    .with_link(LinkWindow { src: 2, dst: 0, at: 25.0, down_for: 10.0 })
                    .with_partition(PartitionWindow::new(vec![1], 60.0, 15.0))
                    .with_crash(CrashWindow { shard: 0, at: 65.0, down_for: 20.0 }),
            ),
        ];
        for (name, plan) in plans {
            let g = generators::er_threshold(24, 0.5, 11);
            let mut rt = faulted(g.clone(), 3, LatencyModel::Zero, plan, true);
            let mut rng = Rng::seeded(55);
            for _ in 0..400 {
                rt.run_super_step(&mut rng);
            }
            assert_eq!(
                rt.abandoned_messages(),
                0,
                "{name}: retry budget must cover the plan"
            );
            let viol = max_conservation_violation(&rt, &g);
            assert!(viol < 1e-9, "{name}: conservation violated by {viol}");
        }
    }

    #[test]
    fn pinned_drop_plus_crash_reliable_run_reaches_the_fault_free_epsilon() {
        // The acceptance pin: a seeded plan with 5% drop and one
        // mid-run shard crash must not stop `rel` mode from reaching
        // the same (1/N)·‖r‖² ≤ ε as the fault-free run.
        let eps = 1e-8;
        let cap = 60_000;
        let g = generators::er_threshold(30, 0.5, 2);
        let mut clean = MsgpassRuntime::new(
            g.clone(),
            0.85,
            4,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(77);
        let clean_steps = clean.run_to_residual(eps, cap, &mut rng).expect("drains");
        assert!(clean_steps < cap, "fault-free run must converge");

        let plan = FaultPlan::default()
            .with_drop(0.05)
            .with_crash(CrashWindow { shard: 1, at: 50.0, down_for: 25.0 });
        let cfg = MsgpassConfig::new(
            4,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        )
        .with_faults(plan)
        .reliable();
        let mut rt = MsgpassRuntime::with_config(g, 0.85, cfg);
        let mut rng = Rng::seeded(77);
        let steps = rt.run_to_residual(eps, cap, &mut rng).expect("drains");
        assert!(steps < cap, "rel mode under 5% drop + crash must still converge");
        assert!(rt.residual_norm_sq() / rt.n() as f64 <= eps);
        let c = rt.fault_counters();
        assert!(c.messages_dropped > 0, "the plan must have actually dropped frames");
        assert!(c.retransmits > 0, "recovery must have gone through retransmission");
        assert_eq!(c.recoveries, 1, "exactly one scheduled restart");
        assert!(c.residual_divergence_at_crash.is_finite());
        assert_eq!(rt.abandoned_messages(), 0);
    }

    #[test]
    fn raw_mode_under_drops_degrades_honestly() {
        // Fire-and-forget under 30% drop: lost owner deltas must break
        // conservation (that is the point of measuring it), and the
        // ledger must say how much was lost.
        let g = generators::er_threshold(24, 0.5, 11);
        let mut rt =
            faulted(g.clone(), 3, LatencyModel::Zero, FaultPlan::default().with_drop(0.3), false);
        let mut rng = Rng::seeded(55);
        for _ in 0..400 {
            rt.run_super_step(&mut rng);
        }
        let c = rt.fault_counters();
        assert!(c.messages_dropped > 100, "expected heavy loss, got {}", c.messages_dropped);
        assert_eq!(c.retransmits, 0, "raw mode never retransmits");
        let viol = max_conservation_violation(&rt, &g);
        assert!(viol > 1e-9, "dropped deltas must show up as a conservation gap");
    }

    #[test]
    fn crash_recovery_restores_the_replica_and_is_deterministic() {
        let run = || {
            let g = generators::er_threshold(20, 0.5, 13);
            let plan = FaultPlan::default().with_crash(CrashWindow {
                shard: 0,
                at: 25.0,
                down_for: 10.0,
            });
            let mut rt = faulted(g, 2, LatencyModel::Exponential { mean: 0.3 }, plan, true);
            let mut rng = Rng::seeded(88);
            for _ in 0..600 {
                rt.run_super_step(&mut rng);
            }
            rt
        };
        let (a, b) = (run(), run());
        assert_eq!(a.estimate(), b.estimate(), "faulted runs are deterministic per seed");
        assert_eq!(a.bytes_on_wire(), b.bytes_on_wire());
        let c = a.fault_counters();
        assert_eq!(c.recoveries, 1);
        assert!(c.residual_divergence_at_crash >= 0.0);
        assert!(a.estimate().iter().all(|v| v.is_finite()));
        // The wiped replica was re-synced: the restarted shard's view of
        // unowned pages matches the owners' (both drained, zero in-flight).
        let n = a.n();
        for j in 0..n {
            let owner = a.map().owner(j, n, 2);
            if owner != 0 && a.subs[j].binary_search(&0).is_ok() {
                assert!(
                    a.views[0][j].is_finite(),
                    "page {j}: replica must be restored, not poisoned"
                );
            }
        }
    }

    #[test]
    fn asymmetric_link_window_reliable_conserves_and_raw_degrades() {
        // One direction of one link down mid-run: `rel` retransmits
        // across the window and conserves exactly; `raw` loses the
        // owner deltas that crossed the cut and the gap shows.
        let window = LinkWindow { src: 0, dst: 1, at: 30.0, down_for: 25.0 };
        let run = |reliable: bool| {
            let g = generators::er_threshold(24, 0.5, 11);
            let plan = FaultPlan::default().with_link(window);
            let mut rt = faulted(g.clone(), 3, LatencyModel::Zero, plan, reliable);
            let mut rng = Rng::seeded(55);
            for _ in 0..400 {
                rt.run_super_step(&mut rng);
            }
            (rt, g)
        };
        let (rel, g) = run(true);
        let c = rel.fault_counters();
        assert!(c.link_downs > 0, "the window must have cut frames, got {}", c.link_downs);
        assert!(c.retransmits > 0, "recovery must ride retransmission");
        assert_eq!(rel.abandoned_messages(), 0);
        let viol = max_conservation_violation(&rel, &g);
        assert!(viol < 1e-9, "rel: conservation violated by {viol}");

        let (raw, g) = run(false);
        let c = raw.fault_counters();
        assert!(c.link_downs > 0);
        assert_eq!(c.retransmits, 0, "raw mode never retransmits");
        let viol = max_conservation_violation(&raw, &g);
        assert!(viol > 1e-9, "raw: deltas lost to the cut must show as a gap");
    }

    #[test]
    fn partition_heal_gauges_divergence_and_resyncs() {
        // A healing bipartition: both crossing directions cut for the
        // window, the divergence gauge sampled at onset and heal, one
        // `partitions_healed` tick, and (rel) exact conservation after
        // the retransmitted deltas land.
        let g = generators::er_threshold(24, 0.5, 11);
        let plan =
            FaultPlan::default().with_partition(PartitionWindow::new(vec![0], 30.0, 20.0));
        let mut rt = faulted(g.clone(), 3, LatencyModel::Zero, plan, true);
        let mut rng = Rng::seeded(55);
        for _ in 0..400 {
            rt.run_super_step(&mut rng);
        }
        let c = rt.fault_counters();
        assert_eq!(c.partitions_healed, 1, "exactly one partition window healed");
        assert!(c.link_downs > 0, "crossing frames must have been cut");
        let (onset, heal) = rt.partition_divergence();
        assert!(onset >= 0.0 && onset.is_finite());
        assert!(
            heal > 0.0,
            "the window must accumulate owner-visible drift, gauge was {heal}"
        );
        assert_eq!(rt.abandoned_messages(), 0);
        let viol = max_conservation_violation(&rt, &g);
        assert!(viol < 1e-9, "conservation violated by {viol}");
    }

    #[test]
    fn overlapping_crashes_both_recover_and_are_deterministic() {
        // Two crash windows overlapping in time (legal since the
        // multi-window schedule): both shards restart, both re-sync,
        // and the run stays deterministic and conservative.
        let run = || {
            let g = generators::er_threshold(24, 0.5, 11);
            let plan = FaultPlan::default()
                .with_crash(CrashWindow { shard: 1, at: 30.0, down_for: 25.0 })
                .with_crash(CrashWindow { shard: 2, at: 40.0, down_for: 25.0 });
            let mut rt = faulted(g.clone(), 3, LatencyModel::Zero, plan, true);
            let mut rng = Rng::seeded(55);
            for _ in 0..400 {
                rt.run_super_step(&mut rng);
            }
            (rt, g)
        };
        let (a, g) = run();
        let (b, _) = run();
        assert_eq!(a.estimate(), b.estimate(), "overlapping-crash runs are deterministic");
        let c = a.fault_counters();
        assert_eq!(c.recoveries, 2, "both crashed shards must restart");
        assert_eq!(a.abandoned_messages(), 0);
        let viol = max_conservation_violation(&a, &g);
        assert!(viol < 1e-9, "conservation violated by {viol}");
    }

    #[test]
    #[should_panic(expected = "invalid fault plan")]
    fn out_of_range_fault_plan_panics_at_construction() {
        let g = generators::er_threshold(10, 0.5, 1);
        let plan = FaultPlan::default().with_link(LinkWindow {
            src: 0,
            dst: 7,
            at: 1.0,
            down_for: 1.0,
        });
        let _ = faulted(g, 2, LatencyModel::Zero, plan, true);
    }

    #[test]
    fn exhausted_event_budget_is_a_named_error_not_a_spin() {
        let g = generators::er_threshold(20, 0.5, 7);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            2,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        rt.set_event_budget(3);
        let mut rng = Rng::seeded(91);
        let err = rt.try_run_super_step(&mut rng).expect_err("budget of 3 must trip");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("event budget exhausted"),
            "error must name the failure: {msg}"
        );
        // And run_to_residual propagates it instead of spinning.
        let mut rt2 = MsgpassRuntime::new(
            generators::er_threshold(20, 0.5, 7),
            0.85,
            2,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        rt2.set_event_budget(3);
        let mut rng = Rng::seeded(91);
        assert!(rt2.run_to_residual(1e-12, 100, &mut rng).is_err());
    }

    #[test]
    fn cluster_map_cuts_cross_traffic_and_still_converges() {
        // The tentpole claim on the wire: on a two-block SBM the cluster
        // map aligns shards with blocks, so subscriber sets collapse to
        // (mostly) singletons and the same activation count costs fewer
        // cross-shard residual updates than the mod interleave — while
        // both reach the exact fixed point.
        let g = generators::sbm_two_block(60, 0.3, 0.02, 91);
        let x_star = exact_pagerank(&g, 0.85);
        let run = |map: ShardMap| {
            let mut rt = MsgpassRuntime::new(
                g.clone(),
                0.85,
                2,
                8,
                map,
                DEFAULT_GOSSIP_PERIOD,
                LatencyModel::Zero,
            );
            let mut rng = Rng::seeded(37);
            for _ in 0..6_000 {
                rt.run_super_step(&mut rng);
            }
            rt
        };
        let (modulo, cluster) = (run(ShardMap::Modulo), run(ShardMap::Cluster));
        assert_eq!(modulo.activations(), cluster.activations(), "same activation budget");
        let (lm, lc) = (modulo.locality(), cluster.locality());
        assert!(
            lc.cross_messages < lm.cross_messages,
            "cluster must cut cross traffic: cluster={} mod={}",
            lc.cross_messages,
            lm.cross_messages
        );
        assert!(lc.cross_edge_fraction < lm.cross_edge_fraction);
        assert!(lc.subscriber_shard_sum < lm.subscriber_shard_sum);
        assert!(cluster.bytes_on_wire() < modulo.bytes_on_wire());
        for rt in [&modulo, &cluster] {
            let err = vector::dist_inf(&rt.estimate(), &x_star);
            assert!(err < 1e-6, "err={err}");
        }
    }

    #[test]
    fn scc_map_converges_on_a_multi_component_graph() {
        // chain(20) condenses to 20 singleton SCCs; the scc map packs
        // them largest-first but must still satisfy the ownership
        // contract and reach the dense fixed point.
        let g = generators::chain(20);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            3,
            6,
            ShardMap::Scc,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(29);
        for _ in 0..15_000 {
            rt.run_super_step(&mut rng);
        }
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
        assert!(rt.locality().any(), "multi-shard runs record locality");
    }

    #[test]
    fn single_shard_table_maps_record_no_locality() {
        // One shard: the table map is the identity, nothing crosses a
        // boundary, and the ledger must stay all-zero so downstream JSON
        // shapes are unchanged.
        let g = generators::er_threshold(20, 0.5, 3);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            1,
            4,
            ShardMap::Cluster,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(41);
        for _ in 0..200 {
            rt.run_super_step(&mut rng);
        }
        assert!(!rt.locality().any(), "single-shard runs have no locality story");
        assert_eq!(rt.messages_sent(), 0);
    }
}
