//! The message-passing distributed backend: per-shard event loops over
//! the virtual-time network, communicating **only by messages**.
//!
//! Where [`super::sharded`] is a real multi-threaded deployment over
//! *shared memory* (every worker reads the one residual array, and the
//! PR-5 residual samplers consult idealized global/per-shard weight
//! trees), [`MsgpassRuntime`] models what the same algorithm costs on a
//! wire. Each shard owns a page partition ([`ShardMap`]), keeps a
//! full-length *replica* of the residual vector, and runs an event loop
//! over the shared [`Transport`]:
//!
//! * **Activation** (a `Wake` event): the shard draws one owned page `k`
//!   uniformly from its own stream, computes the eq. 7/8 projection
//!   against its replica (stale for unowned pages under latency), applies
//!   it locally, and pushes one [`Msg::ResidualUpdate`] per touched page
//!   `j ∈ {k} ∪ out(k)` to every *subscriber* shard of `j` — the owners
//!   of `{j} ∪ in(j)`, i.e. exactly the shards that will ever read or
//!   own `r_j`. This is the paper's §II-D write fan-out aggregated to
//!   shard granularity.
//! * **Gossip**: every `gossip` activations a shard broadcasts a
//!   [`Msg::WeightSummary`] carrying its residual-weight tree total.
//!   The allocator splits each super-step's `batch` activation slots
//!   across shards proportionally to the *most recently delivered*
//!   summaries, decayed toward the floor with a half-life of one gossip
//!   interval — so cross-shard load follows residual mass using only
//!   gossiped (stale, metered) information, never a global view.
//!
//! Within a shard, page selection stays **uniform** over owned pages:
//! that is what makes `msgpass:1:1:mod` with zero latency replay
//! [`crate::algo::mp::MatchingPursuit`] *bit for bit* under the scenario
//! rng protocol (worker 0 clones the caller's stream verbatim, exactly
//! like the sharded runtime — pinned in `tests/engine.rs`). The weight
//! trees and gossip only steer *how many* slots each shard gets when
//! `shards > 1`.
//!
//! Every activation takes one unit of virtual time on its shard's event
//! loop (shards proceed in parallel), so `virtual_time()` measures the
//! parallel makespan: more shards ⇒ fewer serial slots per shard ⇒ less
//! virtual time per super-step, while the transport meters what that
//! parallelism costs in messages and bytes.

use crate::coordinator::sharded::ShardMap;
use crate::graph::Graph;
use crate::linalg::select::{DEFAULT_WEIGHT_FLOOR, WeightTree};
use crate::linalg::sparse::BColumns;
use crate::network::latency::LatencyModel;
use crate::network::transport::{Transport, TransportEvent, WireSized};
use crate::util::rng::Rng;

/// Default gossip period (activations per shard between
/// `WeightSummary` broadcasts) — the `msgpass:<shards>:<batch>:<map>`
/// registry forms without an explicit period use this.
pub const DEFAULT_GOSSIP_PERIOD: usize = 8;

/// Fixed wire size of a [`Msg::ResidualUpdate`]: 4-byte type tag +
/// 4-byte page id + 8-byte delta.
pub const RESIDUAL_UPDATE_BYTES: usize = 16;

/// Fixed wire size of a [`Msg::WeightSummary`]: 4-byte type tag +
/// 4-byte shard id + 8-byte total + 8-byte timestamp.
pub const WEIGHT_SUMMARY_BYTES: usize = 24;

/// Virtual time one activation occupies on its shard's event loop.
const ACTIVATION_TIME: f64 = 1.0;

/// The msgpass wire protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// `r[page] += delta` at the receiver's replica (§II-D write
    /// fan-out, aggregated to the subscriber shards of `page`).
    ResidualUpdate { page: u32, delta: f64 },
    /// Periodic broadcast of the sender's residual-weight tree total;
    /// drives cross-shard slot allocation.
    WeightSummary { total: f64 },
}

impl WireSized for Msg {
    fn wire_bytes(&self) -> usize {
        match self {
            Msg::ResidualUpdate { .. } => RESIDUAL_UPDATE_BYTES,
            Msg::WeightSummary { .. } => WEIGHT_SUMMARY_BYTES,
        }
    }
}

/// The message-passing runtime (see the module docs).
#[derive(Debug)]
pub struct MsgpassRuntime {
    graph: Graph,
    cols: BColumns,
    shards: usize,
    batch: usize,
    map: ShardMap,
    gossip: usize,
    transport: Transport<Msg>,
    /// Dedicated stream for latency draws, forked from the seed stream —
    /// keeps the shard candidate streams identical whatever the latency
    /// model.
    net_rng: Rng,
    /// Per-shard candidate streams; seeded on the first super-step from
    /// the caller's rng (shard 0 clones it verbatim, the rest fork —
    /// the same protocol as the sharded runtime's worker packing).
    streams: Vec<Rng>,
    streams_seeded: bool,
    /// Per-shard full-length residual replicas; `views[w][j]` is shard
    /// `w`'s (possibly stale) knowledge of `r_j`.
    views: Vec<Vec<f64>>,
    /// Per-shard residual-weight tree over *owned* pages (local indices)
    /// — maintained only when `shards > 1` (it only drives allocation).
    trees: Vec<WeightTree>,
    /// Pages owned per shard.
    owned: Vec<usize>,
    /// Per-shard activation counters (gossip cadence).
    act_counts: Vec<u64>,
    /// Most recently *delivered* `WeightSummary` per source shard:
    /// `(total, receive_time)`.
    summaries: Vec<(f64, f64)>,
    /// PageRank estimate; `x[k]` is written only by `k`'s owner.
    x: Vec<f64>,
    /// Subscriber shards per page: owners of `{j} ∪ in(j)`, sorted.
    subs: Vec<Vec<u32>>,
    activations: u64,
    logical_reads: u64,
    logical_writes: u64,
    /// Scratch: touched pages of the current activation, sorted.
    touched: Vec<u32>,
    /// Scratch: pre-update replica values of the touched pages.
    old_vals: Vec<f64>,
}

impl MsgpassRuntime {
    pub fn new(
        graph: Graph,
        alpha: f64,
        shards: usize,
        batch: usize,
        map: ShardMap,
        gossip: usize,
        latency: LatencyModel,
    ) -> MsgpassRuntime {
        assert!(shards >= 1, "need at least one shard");
        assert!(batch >= 1, "need at least one activation per super-step");
        assert!(gossip >= 1, "gossip period must be >= 1");
        let n = graph.n();
        let cols = BColumns::new(&graph, alpha);
        let y = 1.0 - alpha;
        let w0 = (y * y).max(DEFAULT_WEIGHT_FLOOR);
        let owned: Vec<usize> = (0..shards).map(|w| map.owned_count(w, n, shards)).collect();
        let trees: Vec<WeightTree> =
            owned.iter().map(|&cnt| WeightTree::new(&vec![w0; cnt])).collect();
        let summaries: Vec<(f64, f64)> =
            owned.iter().map(|&cnt| (cnt as f64 * w0, 0.0)).collect();
        let mut subs = Vec::with_capacity(n);
        for j in 0..n {
            let mut s: Vec<u32> = Vec::with_capacity(1 + graph.inc(j).len());
            s.push(map.owner(j, n, shards) as u32);
            for &p in graph.inc(j) {
                s.push(map.owner(p as usize, n, shards) as u32);
            }
            s.sort_unstable();
            s.dedup();
            subs.push(s);
        }
        MsgpassRuntime {
            cols,
            shards,
            batch,
            map,
            gossip,
            transport: Transport::new(shards, latency),
            net_rng: Rng::seeded(0),
            streams: Vec::new(),
            streams_seeded: false,
            views: vec![vec![y; n]; shards],
            trees,
            owned,
            act_counts: vec![0; shards],
            summaries,
            x: vec![0.0; n],
            subs,
            activations: 0,
            logical_reads: 0,
            logical_writes: 0,
            touched: Vec::new(),
            old_vals: Vec::new(),
            graph,
        }
    }

    /// Run one super-step: allocate `batch` activation slots across the
    /// shards from the gossiped weight summaries, schedule each shard's
    /// slots on its event loop, and drain the transport (activations,
    /// deliveries and gossip interleave in virtual-time order).
    ///
    /// `rng` seeds the per-shard candidate streams on the first call
    /// (shard 0 clones it verbatim — the msgpass ≡ mp anchor) and is
    /// untouched afterwards.
    pub fn run_super_step(&mut self, rng: &mut Rng) {
        if !self.streams_seeded {
            for w in 0..self.shards {
                self.streams.push(if w == 0 { rng.clone() } else { rng.fork(w as u64) });
            }
            self.net_rng = rng.fork(0x6E65_745F_7374); // "net_st"
            self.streams_seeded = true;
        }
        let slots = self.allocate();
        let t0 = self.transport.now();
        for (w, &count) in slots.iter().enumerate() {
            for slot in 0..count {
                self.transport.wake_at(w, t0 + (slot + 1) as f64 * ACTIVATION_TIME);
            }
        }
        while let Some(ev) = self.transport.pop() {
            match ev.event {
                TransportEvent::Wake { shard } => self.activate_one(shard),
                TransportEvent::Deliver { src, dst, msg } => self.deliver(src, dst, msg, ev.time),
            }
        }
    }

    /// Drive super-steps until the scaled residual `(1/N)‖r‖²` reaches
    /// `eps` or `max_super_steps` elapse; returns the super-steps taken.
    pub fn run_to_residual(&mut self, eps: f64, max_super_steps: usize, rng: &mut Rng) -> usize {
        for step in 0..max_super_steps {
            if self.residual_norm_sq() / self.graph.n() as f64 <= eps {
                return step;
            }
            self.run_super_step(rng);
        }
        max_super_steps
    }

    /// Split `batch` slots across shards proportionally to the decayed
    /// gossiped weight totals (largest-remainder rounding, ties to the
    /// lower shard id). Single-shard runs take the whole batch; shards
    /// owning no pages get no slots.
    fn allocate(&self) -> Vec<usize> {
        if self.shards == 1 {
            return vec![self.batch];
        }
        let now = self.transport.now();
        let half_life = self.gossip as f64 * ACTIVATION_TIME;
        let mut weights = vec![0.0; self.shards];
        for w in 0..self.shards {
            if self.owned[w] == 0 {
                continue;
            }
            let (total, t_recv) = self.summaries[w];
            let age = (now - t_recv).max(0.0);
            let decayed = total * 0.5f64.powf(age / half_life);
            weights[w] = decayed.max(self.owned[w] as f64 * DEFAULT_WEIGHT_FLOOR);
        }
        let wsum: f64 = weights.iter().sum();
        let mut slots = vec![0usize; self.shards];
        if !(wsum > 0.0) || !wsum.is_finite() {
            // Degenerate summaries: fall back to a static split over the
            // shards that own pages.
            let eligible: Vec<usize> =
                (0..self.shards).filter(|&w| self.owned[w] > 0).collect();
            let per = self.batch / eligible.len();
            let extra = self.batch % eligible.len();
            for (i, &w) in eligible.iter().enumerate() {
                slots[w] = per + usize::from(i < extra);
            }
            return slots;
        }
        let mut assigned = 0usize;
        let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(self.shards);
        for w in 0..self.shards {
            let exact = self.batch as f64 * weights[w] / wsum;
            let fl = exact.floor() as usize;
            slots[w] = fl;
            assigned += fl;
            fracs.push((exact - fl as f64, w));
        }
        fracs.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).expect("weights are finite").then(a.1.cmp(&b.1))
        });
        let remainder = self.batch.saturating_sub(assigned);
        for i in 0..remainder {
            slots[fracs[i % fracs.len()].1] += 1;
        }
        slots
    }

    /// One activation on shard `w`'s event loop: uniform owned-page
    /// draw, eq. 7/8 projection against the local replica, residual
    /// messages to the subscriber shards, gossip on cadence.
    fn activate_one(&mut self, w: usize) {
        let n = self.graph.n();
        let owned = self.owned[w];
        if owned == 0 {
            return;
        }
        let pick = self.streams[w].below(owned);
        let k = self.map.owned_page(w, pick, n, self.shards);
        let deg = self.graph.out_degree(k);
        let num = self.cols.col_dot(&self.graph, k, &self.views[w]);
        let coef = num / self.cols.norm_sq(k);
        self.x[k] += coef;
        // Residual support of the projection: {k} ∪ out(k), sorted so
        // message order (and the Fenwick update order downstream) is a
        // pure function of the activation sequence.
        self.touched.clear();
        self.touched.push(k as u32);
        self.touched.extend_from_slice(self.graph.out(k));
        self.touched.sort_unstable();
        self.touched.dedup();
        self.old_vals.clear();
        for i in 0..self.touched.len() {
            self.old_vals.push(self.views[w][self.touched[i] as usize]);
        }
        self.cols.sub_scaled_col(&self.graph, k, coef, &mut self.views[w]);
        for i in 0..self.touched.len() {
            let j = self.touched[i] as usize;
            let new = self.views[w][j];
            // Exact replica delta: a receiver holding the same old value
            // lands on the bit-identical new value.
            let delta = new - self.old_vals[i];
            if self.shards > 1 {
                for si in 0..self.subs[j].len() {
                    let s = self.subs[j][si] as usize;
                    if s != w {
                        self.transport.send(
                            w,
                            s,
                            Msg::ResidualUpdate { page: j as u32, delta },
                            &mut self.net_rng,
                        );
                    }
                }
                if self.map.owner(j, n, self.shards) == w {
                    let li = self.map.local_index(j, n, self.shards);
                    self.trees[w].update(li, (new * new).max(DEFAULT_WEIGHT_FLOOR));
                }
            }
        }
        self.activations += 1;
        self.logical_reads += deg as u64;
        self.logical_writes += deg as u64;
        if self.shards > 1 {
            self.act_counts[w] += 1;
            if self.act_counts[w] % self.gossip as u64 == 0 {
                let total = self.trees[w].total();
                for s in 0..self.shards {
                    if s != w {
                        self.transport.send(
                            w,
                            s,
                            Msg::WeightSummary { total },
                            &mut self.net_rng,
                        );
                    }
                }
            }
        }
    }

    /// Apply a delivered message at shard `dst`.
    fn deliver(&mut self, src: usize, dst: usize, msg: Msg, time: f64) {
        match msg {
            Msg::ResidualUpdate { page, delta } => {
                let j = page as usize;
                self.views[dst][j] += delta;
                if self.shards > 1 && self.map.owner(j, self.graph.n(), self.shards) == dst {
                    let v = self.views[dst][j];
                    let li = self.map.local_index(j, self.graph.n(), self.shards);
                    self.trees[dst].update(li, (v * v).max(DEFAULT_WEIGHT_FLOOR));
                }
            }
            Msg::WeightSummary { total } => {
                self.summaries[src] = (total, time);
            }
        }
    }

    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn gossip_period(&self) -> usize {
        self.gossip
    }

    pub fn map(&self) -> ShardMap {
        self.map
    }

    pub fn latency(&self) -> LatencyModel {
        self.transport.latency()
    }

    /// Current PageRank estimate (owner-written, globally consistent).
    pub fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    pub fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    /// Owner-authoritative residual: each entry from its owner's
    /// replica. Exact once the transport is drained at zero latency;
    /// lags only in-flight foreign deltas otherwise.
    pub fn residual(&self) -> Vec<f64> {
        let n = self.graph.n();
        (0..n).map(|j| self.views[self.map.owner(j, n, self.shards)][j]).collect()
    }

    pub fn residual_norm_sq(&self) -> f64 {
        let n = self.graph.n();
        (0..n)
            .map(|j| {
                let r = self.views[self.map.owner(j, n, self.shards)][j];
                r * r
            })
            .sum()
    }

    pub fn activations(&self) -> u64 {
        self.activations
    }

    pub fn logical_reads(&self) -> u64 {
        self.logical_reads
    }

    pub fn logical_writes(&self) -> u64 {
        self.logical_writes
    }

    /// Metered messages sent so far (residual updates + gossip).
    pub fn messages_sent(&self) -> u64 {
        self.transport.messages_sent()
    }

    /// Bytes charged to the wire so far (fixed per-type encodings).
    pub fn bytes_on_wire(&self) -> u64 {
        self.transport.bytes_on_wire()
    }

    /// Peak messages simultaneously queued for any single shard.
    pub fn peak_queue_depth(&self) -> u32 {
        self.transport.peak_queue_depth()
    }

    /// Peak messages simultaneously in flight network-wide.
    pub fn peak_in_flight(&self) -> u32 {
        self.transport.peak_in_flight()
    }

    /// Virtual time consumed: the parallel makespan of all event loops.
    pub fn virtual_time(&self) -> f64 {
        self.transport.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::common::PageRankSolver;
    use crate::algo::mp::MatchingPursuit;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn single_shard_batch_one_matches_matrix_mp_bit_for_bit() {
        // The equivalence anchor: one shard, one slot per super-step,
        // zero latency — shard 0 clones the caller's stream, samples
        // below(n) and applies the same BColumns arithmetic, so the
        // estimate must be bit-identical to matrix-form Algorithm 1.
        let g = generators::er_threshold(40, 0.5, 2);
        let mut rt = MsgpassRuntime::new(
            g.clone(),
            0.85,
            1,
            1,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(13);
        for _ in 0..500 {
            rt.run_super_step(&mut rng);
        }
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng2 = Rng::seeded(13);
        for _ in 0..500 {
            let k = rng2.below(40);
            mp.step_at(k);
        }
        assert_eq!(rt.estimate(), PageRankSolver::estimate(&mp), "not bit-identical");
        assert_eq!(rt.residual(), mp.residual().to_vec());
        assert_eq!(rt.activations(), 500);
        assert_eq!(rt.messages_sent(), 0, "one shard never messages");
        assert_eq!(rt.bytes_on_wire(), 0);
    }

    #[test]
    fn one_super_step_meters_every_wire_byte() {
        // ring(2), mod map: shard 0 owns page 0, shard 1 owns page 1,
        // and both shards subscribe to both pages. One activation
        // touches {k, out(k)} = both pages -> 2 residual updates to the
        // peer; gossip period 1 adds one summary. Fixed encodings make
        // the byte count exact.
        let g = generators::ring(2);
        let mut rt =
            MsgpassRuntime::new(g, 0.85, 2, 1, ShardMap::Modulo, 1, LatencyModel::Zero);
        let mut rng = Rng::seeded(5);
        rt.run_super_step(&mut rng);
        assert_eq!(rt.activations(), 1);
        assert_eq!(rt.messages_sent(), 3);
        assert_eq!(
            rt.bytes_on_wire(),
            (2 * RESIDUAL_UPDATE_BYTES + WEIGHT_SUMMARY_BYTES) as u64
        );
        assert!(rt.peak_queue_depth() >= 1);
    }

    #[test]
    fn multi_shard_zero_latency_converges_to_exact_pagerank() {
        let g = generators::er_threshold(20, 0.5, 7);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            4,
            8,
            ShardMap::Modulo,
            4,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(9);
        for _ in 0..8_000 {
            rt.run_super_step(&mut rng);
        }
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-7, "err={err}");
        assert!(rt.messages_sent() > 0, "multi-shard runs must message");
        assert!(rt.bytes_on_wire() > rt.messages_sent(), "every message has bytes");
        assert!(rt.virtual_time() > 0.0);
    }

    #[test]
    fn conservation_b_x_plus_r_is_y_at_zero_latency() {
        // eq. 11 survives sharding: activations apply exact additive
        // column updates, so after a full drain the owner-gathered
        // residual satisfies B x + r = (1-α)1.
        let g = generators::er_threshold(30, 0.5, 11);
        let alpha = 0.85;
        let mut rt = MsgpassRuntime::new(
            g.clone(),
            alpha,
            3,
            8,
            ShardMap::Block,
            4,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(12);
        for _ in 0..200 {
            rt.run_super_step(&mut rng);
        }
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&rt.estimate());
        let r = rt.residual();
        for (i, v) in bx.iter().enumerate() {
            let lhs = v + r[i];
            assert!((lhs - (1.0 - alpha)).abs() < 1e-9, "page {i}: {lhs}");
        }
    }

    #[test]
    fn converges_and_meters_under_exponential_latency() {
        // Stale replicas under a heavy-tailed latency model: the error
        // must still contract (asynchronous additive updates), and the
        // congestion tracker must observe genuine in-flight overlap.
        let g = generators::er_threshold(20, 0.5, 13);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            2,
            4,
            ShardMap::Modulo,
            4,
            LatencyModel::Exponential { mean: 0.3 },
        );
        let mut rng = Rng::seeded(14);
        let before = rt.error_sq_vs(&x_star);
        for _ in 0..4_000 {
            rt.run_super_step(&mut rng);
        }
        let after = rt.error_sq_vs(&x_star);
        assert!(after.is_finite());
        assert!(after < before / 100.0, "no contraction: {before} -> {after}");
        assert!(rt.peak_in_flight() >= 2, "latency must create overlap");
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let build = || {
            MsgpassRuntime::new(
                generators::er_threshold(15, 0.5, 3),
                0.85,
                3,
                6,
                ShardMap::Modulo,
                2,
                LatencyModel::Exponential { mean: 0.5 },
            )
        };
        let (mut a, mut b) = (build(), build());
        let (mut ra, mut rb) = (Rng::seeded(21), Rng::seeded(21));
        for _ in 0..300 {
            a.run_super_step(&mut ra);
            b.run_super_step(&mut rb);
        }
        assert_eq!(a.estimate(), b.estimate());
        assert_eq!(a.messages_sent(), b.messages_sent());
        assert_eq!(a.bytes_on_wire(), b.bytes_on_wire());
        assert_eq!(a.virtual_time(), b.virtual_time());
    }

    #[test]
    fn dangling_chain_converges_via_the_shared_guard() {
        // chain(20) ends in a genuine sink; the BColumns implicit
        // self-loop keeps every replica finite and the fixed point
        // matches the dense reference.
        let g = generators::chain(20);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            2,
            4,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(17);
        for _ in 0..15_000 {
            rt.run_super_step(&mut rng);
        }
        assert!(rt.estimate().iter().all(|v| v.is_finite()));
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn shards_without_pages_get_no_slots() {
        // More shards than pages: the empty shards must be skipped by
        // the allocator, not sampled (below(0) is UB in release).
        let g = generators::ring(3);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            8,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(19);
        for _ in 0..50 {
            rt.run_super_step(&mut rng);
        }
        assert_eq!(rt.activations(), 50 * 8, "every slot lands on a live shard");
        assert!(rt.estimate().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn run_to_residual_stops_at_epsilon() {
        let g = generators::er_threshold(15, 0.5, 23);
        let mut rt = MsgpassRuntime::new(
            g,
            0.85,
            2,
            8,
            ShardMap::Modulo,
            DEFAULT_GOSSIP_PERIOD,
            LatencyModel::Zero,
        );
        let mut rng = Rng::seeded(24);
        let steps = rt.run_to_residual(1e-10, 100_000, &mut rng);
        assert!(steps < 100_000, "must reach epsilon before the cap");
        assert!(rt.residual_norm_sq() / rt.n() as f64 <= 1e-10);
    }
}
