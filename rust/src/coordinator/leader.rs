//! The coordinator event loop.
//!
//! A discrete-event execution of the §II-D protocol over the simulated
//! network: the leader owns the virtual clock, the activation sampler,
//! the page agents and the lock table; messages travel with sampled
//! latencies and are counted by [`super::metrics::Metrics`] and the
//! congestion tracker.
//!
//! ## Exactness under concurrency
//!
//! An activation locks the support of its column, `{k} ∪ out(k)`, from
//! fire to the delivery of its last write. Two concurrent activations can
//! therefore only interleave when their supports are disjoint — in which
//! case their projections commute (see [`crate::algo::parallel_mp`]) and
//! the distributed execution equals *some* sequential execution of the
//! same multiset of activations. Conflicting fires are deferred with
//! backoff and retried; the paper's sequential semantics is the
//! [`Mode::Sequential`] special case and is bit-compared against the
//! matrix form in the tests.

use crate::graph::Graph;
use crate::network::congestion::CongestionTracker;
use crate::network::events::EventQueue;
use crate::util::rng::Rng;

use super::agents::PageAgent;
use super::config::{CoordinatorConfig, Mode};
use super::messages::{Envelope, Payload};
use super::metrics::Metrics;
use super::sampler::Sampler;

/// Simulation events.
#[derive(Debug, Clone, PartialEq)]
enum Event {
    /// An activation attempt. `from_sampler` distinguishes fresh clock
    /// fires from deferred retries.
    Fire { page: usize, from_sampler: bool },
    /// Message delivery.
    Deliver(Envelope),
    /// All effects of `page`'s activation have landed; unlock.
    Complete { page: usize, started: f64 },
}

/// Summary of a [`Coordinator::run`] call.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub metrics: Metrics,
    pub peak_page_load: u32,
    pub peak_inflight_messages: u32,
}

/// The distributed MP-PageRank runtime.
pub struct Coordinator<'g> {
    graph: &'g Graph,
    cfg: CoordinatorConfig,
    agents: Vec<PageAgent>,
    queue: EventQueue<Event>,
    sampler: Sampler,
    sampler_rng: Rng,
    latency_rng: Rng,
    metrics: Metrics,
    congestion: CongestionTracker,
    locked: Vec<bool>,
    next_activation: u64,
    in_flight: u32,
    completed: u64,
    /// Fire times of in-progress activations (for duration accounting).
    started_at: Vec<f64>,
}

impl<'g> Coordinator<'g> {
    pub fn new(graph: &'g Graph, cfg: CoordinatorConfig) -> Self {
        // The §II-D message protocol counts one reply per out-neighbour;
        // a zero-out-degree activation would never complete. The sharded
        // and matrix-form backends repair dangling pages on the fly
        // (implicit self-loop in BColumns); the simulated coordinator
        // still requires an explicitly repaired graph.
        assert!(
            graph.dangling().is_empty(),
            "coordinator requires a repaired graph (no dangling pages)"
        );
        let base = Rng::seeded(cfg.seed);
        let mut sampler_rng = base.fork(1);
        let latency_rng = base.fork(2);
        let sampler = Sampler::new(cfg.sampler, graph.n(), &mut sampler_rng);
        let agents = PageAgent::fleet(graph, cfg.alpha);
        Coordinator {
            graph,
            agents,
            queue: EventQueue::new(),
            sampler,
            sampler_rng,
            latency_rng,
            metrics: Metrics::default(),
            congestion: CongestionTracker::new(graph.n()),
            locked: vec![false; graph.n()],
            next_activation: 0,
            in_flight: 0,
            completed: 0,
            started_at: vec![0.0; graph.n()],
            cfg,
        }
    }

    /// Number of pages.
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    /// Current PageRank estimates (x_k per page).
    pub fn estimate(&self) -> Vec<f64> {
        self.agents.iter().map(|a| a.x).collect()
    }

    /// `‖x - x*‖²` against a reference without materializing the
    /// estimate (same summation order as `vector::dist_sq`, so results
    /// are bit-identical to the allocating path).
    pub fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        debug_assert_eq!(x_star.len(), self.agents.len());
        self.agents
            .iter()
            .zip(x_star)
            .map(|(a, &s)| {
                let d = a.x - s;
                d * d
            })
            .sum()
    }

    /// Current residuals (r_k per page).
    pub fn residual(&self) -> Vec<f64> {
        self.agents.iter().map(|a| a.r).collect()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn virtual_time(&self) -> f64 {
        self.queue.now()
    }

    fn conflict(&self, k: usize) -> bool {
        if self.locked[k] {
            return true;
        }
        self.graph.out(k).iter().any(|&j| self.locked[j as usize])
    }

    fn set_locks(&mut self, k: usize, v: bool) {
        self.locked[k] = v;
        for &j in self.graph.out(k) {
            self.locked[j as usize] = v;
        }
    }

    fn send(&mut self, src: usize, dst: usize, payload: Payload) {
        let latency = if src == dst {
            0.0 // local short-circuit (self-loop reads/writes)
        } else {
            self.cfg.latency.sample(&mut self.latency_rng)
        };
        self.metrics.on_send(&payload);
        self.congestion.on_send(dst);
        self.queue.schedule_in(
            latency,
            Event::Deliver(Envelope {
                src: src as u32,
                dst: dst as u32,
                payload,
            }),
        );
    }

    fn schedule_next_sampler_fire(&mut self) {
        let now = self.queue.now();
        let (t, page) = self.sampler.next(now, &mut self.sampler_rng);
        self.queue.schedule(t.max(now), Event::Fire { page, from_sampler: true });
    }

    fn begin_activation(&mut self, k: usize) {
        let id = self.next_activation;
        self.next_activation += 1;
        self.set_locks(k, true);
        self.in_flight += 1;
        self.metrics.peak_overlap = self.metrics.peak_overlap.max(self.in_flight);
        self.started_at[k] = self.queue.now();
        let deg = self.graph.out_degree(k);
        self.agents[k].begin_activation(id, deg);
        // issue reads (self-loop read short-circuits with zero latency);
        // `self.graph` is a shared reference — copying it out decouples
        // the adjacency iteration from the &mut self sends (no per-
        // activation allocation on the hot path).
        let g = self.graph;
        for &j in g.out(k) {
            self.send(k, j as usize, Payload::ReadRequest { activation: id });
        }
    }

    fn handle_deliver(&mut self, env: Envelope) {
        let dst = env.dst as usize;
        self.congestion.on_deliver(dst);
        match env.payload {
            Payload::ReadRequest { activation } => {
                let r = self.agents[dst].r;
                self.send(dst, env.src as usize, Payload::ReadReply { activation, r_value: r });
            }
            Payload::ReadReply { activation, r_value } => {
                let alpha = self.cfg.alpha;
                if let Some(coef) = self.agents[dst].on_read_reply(activation, r_value, alpha) {
                    // dst == activated page k: apply local update, push writes
                    let delta = self.agents[dst].finish_activation(coef, alpha);
                    let r_new = self.agents[dst].r;
                    self.sampler.on_residual(dst, r_new);
                    let now = self.queue.now();
                    let mut t_done = now;
                    let g = self.graph;
                    for &j in g.out(dst) {
                        if j as usize == dst {
                            continue; // self-loop applied in finish_activation
                        }
                        // Track the delivery time to schedule Complete after
                        // the last write lands.
                        let latency = self.cfg.latency.sample(&mut self.latency_rng);
                        let payload = Payload::WriteDelta { activation, delta };
                        self.metrics.on_send(&payload);
                        self.congestion.on_send(j as usize);
                        self.queue.schedule_in(
                            latency,
                            Event::Deliver(Envelope { src: dst as u32, dst: j, payload }),
                        );
                        t_done = t_done.max(now + latency);
                    }
                    self.queue
                        .schedule(t_done, Event::Complete { page: dst, started: self.started_at[dst] });
                }
            }
            Payload::WriteDelta { delta, .. } => {
                self.agents[dst].on_write_delta(delta);
                let r_new = self.agents[dst].r;
                self.sampler.on_residual(dst, r_new);
            }
        }
    }

    /// Run until `target` further activations complete; callable
    /// repeatedly (state persists across calls). Returns the cumulative
    /// report. On return the system is *quiescent* — no activation is in
    /// flight — so `estimate()`/`residual()` form a consistent snapshot
    /// (eq. 11 holds exactly; the async test checks this).
    pub fn run(&mut self, target: u64) -> RunReport {
        let goal = self.completed + target;
        while self.completed < goal {
            // Lazy arming keeps sampler draws aligned across run() calls:
            // a draw is consumed only when a fire is actually needed.
            if self.queue.is_empty() {
                self.schedule_next_sampler_fire();
            }
            let ev = self.queue.pop().expect("queue starvation: no events pending");
            match ev.event {
                Event::Fire { page, from_sampler } => {
                    if self.conflict(page) {
                        // Drop the fire. A page whose neighbourhood is busy
                        // skips this clock tick — queueing conflicting fires
                        // would grow without bound whenever the clock rate
                        // exceeds the conflict-limited service rate (dense
                        // graphs serialize almost everything). The thinned
                        // activation process still visits every page
                        // infinitely often, which is all Algorithm 1 needs.
                        self.metrics.deferred += 1;
                    } else {
                        self.begin_activation(page);
                    }
                    // Async mode: clocks keep ticking regardless; in
                    // sequential mode the next fire is chained on Complete.
                    if from_sampler && self.cfg.mode == Mode::Async {
                        self.schedule_next_sampler_fire();
                    }
                }
                Event::Deliver(env) => self.handle_deliver(env),
                Event::Complete { page, started } => {
                    self.set_locks(page, false);
                    self.in_flight -= 1;
                    self.completed += 1;
                    self.metrics.activations += 1;
                    self.metrics.total_activation_time += self.queue.now() - started;
                    // Sequential mode re-arms lazily at the loop top, so a
                    // run() boundary never consumes an unused draw.
                }
            }
        }
        self.drain();
        self.metrics.makespan = self.queue.now();
        RunReport {
            metrics: self.metrics.clone(),
            peak_page_load: self.congestion.peak_page_load(),
            peak_inflight_messages: self.congestion.peak_total(),
        }
    }

    /// Let in-flight activations finish without admitting new ones, so the
    /// post-run snapshot is consistent. Pending fires (parked or queued)
    /// are dropped; congestion accounting is settled for them.
    fn drain(&mut self) {
        while self.in_flight > 0 {
            let ev = self.queue.pop().expect("in-flight activation lost its events");
            match ev.event {
                Event::Fire { .. } => {} // dropped: no new work during drain
                Event::Deliver(env) => self.handle_deliver(env),
                Event::Complete { page, started } => {
                    self.set_locks(page, false);
                    self.in_flight -= 1;
                    self.completed += 1;
                    self.metrics.activations += 1;
                    self.metrics.total_activation_time += self.queue.now() - started;
                }
            }
        }
        // Drop any residual fire events; deliveries are all settled.
        while let Some(t) = self.queue.peek_time() {
            let _ = t;
            match self.queue.pop().expect("peeked").event {
                Event::Fire { .. } => {}
                other => unreachable!("drain left a non-fire event: {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::common::PageRankSolver;
    use crate::algo::mp::MatchingPursuit;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;
    use crate::network::LatencyModel;
    use crate::coordinator::sampler::SamplerKind;

    #[test]
    fn sequential_zero_latency_equals_matrix_form() {
        let g = generators::er_threshold(40, 0.5, 161);
        let cfg = CoordinatorConfig::default().with_seed(7);
        let mut coord = Coordinator::new(&g, cfg);
        coord.run(500);
        // Matrix form driven by the identical sampler stream: fork(1) of
        // the same base seed.
        let mut mp = MatchingPursuit::new(&g, crate::DEFAULT_ALPHA);
        let mut srng = Rng::seeded(7).fork(1);
        for _ in 0..500 {
            let k = srng.below(40);
            mp.step_at(k);
        }
        assert!(
            vector::dist_inf(&coord.estimate(), &mp.estimate()) < 1e-13,
            "distributed and matrix forms diverged"
        );
        assert!(vector::dist_inf(&coord.residual(), mp.residual()) < 1e-13);
    }

    #[test]
    fn reads_and_writes_equal_out_degree_sum() {
        // The paper's §II-D claim, verified end-to-end: logical reads ==
        // logical writes == Σ N_k over the activation sequence... writes
        // exclude the self-loop short-circuit only in transit, so we count
        // via metrics which include it.
        let g = generators::er_threshold(30, 0.5, 162);
        let cfg = CoordinatorConfig::default().with_seed(8);
        let mut coord = Coordinator::new(&g, cfg);
        let rep = coord.run(300);
        // Reconstruct Σ N_k from the same sampler stream.
        let mut srng = Rng::seeded(8).fork(1);
        let sum_nk: u64 = (0..300).map(|_| g.out_degree(srng.below(30)) as u64).sum();
        assert_eq!(rep.metrics.logical_reads(), sum_nk);
        // Writes: every out-neighbour receives one delta; self-loops are
        // applied locally without a wire message.
        let mut srng = Rng::seeded(8).fork(1);
        let wire_writes: u64 = (0..300)
            .map(|_| {
                let k = srng.below(30);
                let d = g.out_degree(k) as u64;
                if g.has_self_loop(k) { d - 1 } else { d }
            })
            .sum();
        assert_eq!(rep.metrics.logical_writes(), wire_writes);
    }

    #[test]
    fn converges_under_latency() {
        let g = generators::er_threshold(25, 0.5, 163);
        let cfg = CoordinatorConfig::default()
            .with_seed(9)
            .with_latency(LatencyModel::Uniform { lo: 0.01, hi: 0.2 });
        let mut coord = Coordinator::new(&g, cfg);
        coord.run(30_000);
        let x_star = exact_pagerank(&g, crate::DEFAULT_ALPHA);
        let err = vector::dist_inf(&coord.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
        assert!(coord.virtual_time() > 0.0);
    }

    #[test]
    fn async_mode_overlaps_on_sparse_graphs() {
        let g = generators::erdos_renyi(300, 0.005, 164);
        let cfg = CoordinatorConfig::default()
            .with_seed(10)
            .with_mode(Mode::Async)
            .with_sampler(SamplerKind::ExponentialClocks)
            .with_latency(LatencyModel::Constant(0.5));
        let mut coord = Coordinator::new(&g, cfg);
        let rep = coord.run(2000);
        assert!(
            rep.metrics.peak_overlap > 1,
            "async on a sparse graph must overlap: {:?}",
            rep.metrics.peak_overlap
        );
        // Still exact: residual matches r = y - Bx.
        let b = crate::linalg::dense::DenseMatrix::b_matrix(&g, crate::DEFAULT_ALPHA);
        let bx = b.matvec(&coord.estimate());
        let y = 1.0 - crate::DEFAULT_ALPHA;
        for (i, (bxi, ri)) in bx.iter().zip(coord.residual()).enumerate() {
            assert!((bxi + ri - y).abs() < 1e-10, "conservation broken at {i}");
        }
    }

    #[test]
    fn async_dense_graph_defers_conflicts() {
        let g = generators::er_threshold(50, 0.5, 165);
        let cfg = CoordinatorConfig::default()
            .with_seed(11)
            .with_mode(Mode::Async)
            .with_sampler(SamplerKind::ExponentialClocks)
            .with_latency(LatencyModel::Constant(0.3));
        let mut coord = Coordinator::new(&g, cfg);
        let rep = coord.run(500);
        assert!(rep.metrics.deferred > 0, "dense graph must defer");
    }

    #[test]
    fn residual_weighted_sampler_converges_faster() {
        let g = generators::er_threshold(40, 0.5, 166);
        let x_star = exact_pagerank(&g, crate::DEFAULT_ALPHA);
        let steps = 4000;
        let run = |kind| {
            let cfg = CoordinatorConfig::default().with_seed(12).with_sampler(kind);
            let mut coord = Coordinator::new(&g, cfg);
            coord.run(steps);
            vector::dist_sq(&coord.estimate(), &x_star) / 40.0
        };
        let uniform = run(SamplerKind::Uniform);
        let weighted = run(SamplerKind::ResidualWeighted { floor: 1e-12 });
        assert!(
            weighted < uniform,
            "importance sampling should win: weighted {weighted} vs uniform {uniform}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let g = generators::er_threshold(20, 0.5, 167);
        let run = || {
            let cfg = CoordinatorConfig::default()
                .with_seed(13)
                .with_latency(LatencyModel::Exponential { mean: 0.1 });
            let mut c = Coordinator::new(&g, cfg);
            c.run(200);
            (c.estimate(), c.metrics().clone())
        };
        let (x1, m1) = run();
        let (x2, m2) = run();
        assert_eq!(x1, x2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn run_is_resumable() {
        let g = generators::er_threshold(20, 0.5, 168);
        let cfg = CoordinatorConfig::default().with_seed(14);
        let mut a = Coordinator::new(&g, cfg.clone());
        a.run(100);
        a.run(100);
        let mut b = Coordinator::new(&g, cfg);
        b.run(200);
        assert_eq!(a.estimate(), b.estimate());
        assert_eq!(a.metrics().activations, 200);
    }

    #[test]
    fn congestion_reported() {
        let g = generators::star(30);
        let cfg = CoordinatorConfig::default()
            .with_seed(15)
            .with_latency(LatencyModel::Constant(0.1));
        let mut coord = Coordinator::new(&g, cfg);
        let rep = coord.run(100);
        assert!(rep.peak_page_load >= 1);
        assert!(rep.peak_inflight_messages >= rep.peak_page_load);
    }
}
