//! Sharded multi-threaded runtime — a *real* parallel deployment of the
//! paper's algorithm (the §IV-1 extension executed on OS threads), as
//! opposed to the virtual-time simulator in [`super::leader`].
//!
//! Correctness argument (same as [`crate::algo::parallel_mp`]): an
//! activation of page `k` reads and writes only `supp B(:,k) = {k} ∪
//! out(k)`. The leader packs batches whose closed neighbourhoods are
//! pairwise disjoint, so the activations of one batch touch disjoint
//! memory and can run on worker threads with **no ordering between
//! them** — the result equals any sequential execution of the same
//! multiset. Residuals and estimates live in shared `AtomicU64` cells
//! (f64 bit-cast, relaxed ordering): within a batch every cell is touched
//! by at most one worker, and the per-batch channel round-trip provides
//! the inter-batch happens-before edge.
//!
//! Topology: one leader (sampling + packing + dispatch) and `W` persistent
//! workers connected by mpsc channels; each activation is routed to the
//! worker owning page `k` via a pluggable [`ShardMap`] (modulo or block
//! ownership). Routing never changes results — batch supports are
//! disjoint — only load balance: modulo spreads consecutive ids,
//! block keeps cache-friendly contiguous ranges but concentrates the
//! hub-heavy low-id prefix of generator graphs on shard 0.
//!
//! Dangling pages are repaired on the fly by the shared implicit
//! self-loop guard of [`BColumns`] (no `α/0` poisoning — see that
//! module's docs); [`activate`] consults the column constants instead of
//! dividing by the raw out-degree.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::graph::Graph;
use crate::linalg::sparse::BColumns;
use crate::util::rng::Rng;

/// Shared per-page state: f64 stored as bits in atomics. Disjointness of
/// batch supports means `Relaxed` suffices within a batch; the channel
/// synchronization between batches publishes all writes.
struct SharedState {
    x: Vec<AtomicU64>,
    r: Vec<AtomicU64>,
}

impl SharedState {
    fn new(n: usize, y: f64) -> SharedState {
        SharedState {
            x: (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
            r: (0..n).map(|_| AtomicU64::new(y.to_bits())).collect(),
        }
    }

    #[inline]
    fn load_r(&self, i: usize) -> f64 {
        f64::from_bits(self.r[i].load(Ordering::Relaxed))
    }

    #[inline]
    fn store_r(&self, i: usize, v: f64) {
        self.r[i].store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    fn load_x(&self, i: usize) -> f64 {
        f64::from_bits(self.x[i].load(Ordering::Relaxed))
    }

    #[inline]
    fn store_x(&self, i: usize, v: f64) {
        self.x[i].store(v.to_bits(), Ordering::Relaxed);
    }
}

/// One §II-D activation against the shared state. Only touches
/// `{k} ∪ out(k)` — the packing invariant makes this race-free.
///
/// Degree geometry comes from [`BColumns`] (never a raw `α/N_k`
/// division): a dangling `k` has `inv_out_degree = 1` and an implicit
/// self-loop, so sink pages update finitely instead of poisoning the
/// residuals with NaN/inf. The arithmetic and evaluation order mirror
/// [`BColumns::col_dot`]/[`BColumns::sub_scaled_col`] exactly, which is
/// what makes a 1-shard batch-1 run bit-identical to the matrix form.
fn activate(graph: &Graph, cols: &BColumns, state: &SharedState, k: usize, alpha: f64) {
    // numerator: r_k - (α/N_k) Σ_{j∈out(k)} r_j
    let mut acc = 0.0;
    for &j in graph.out(k) {
        acc += state.load_r(j as usize);
    }
    if cols.is_dangling(k) {
        // implicit self-loop: the only "out-neighbour" is k itself
        acc += state.load_r(k);
    }
    let inv_deg = cols.inv_out_degree(k);
    let num = state.load_r(k) - alpha * inv_deg * acc;
    let coef = num / cols.norm_sq(k);
    state.store_x(k, state.load_x(k) + coef);
    // residual update: out-neighbours += coef·α/N_k, diagonal -= coef
    let w = coef * alpha * inv_deg;
    for &j in graph.out(k) {
        let j = j as usize;
        state.store_r(j, state.load_r(j) + w);
    }
    if cols.is_dangling(k) {
        state.store_r(k, state.load_r(k) + w);
    }
    state.store_r(k, state.load_r(k) - coef);
}

/// Page → shard ownership policy.
///
/// `Modulo` (`k % W`) interleaves consecutive ids across shards — the
/// right default for generator graphs whose hub-heavy pages cluster in a
/// low-id range (BA preferential attachment, the star family), where
/// block ownership would hand one shard all the expensive activations.
/// `Block` assigns contiguous ranges of `⌈n/W⌉` pages — cache-friendly
/// contiguous state per worker when degrees are uniform. Ownership only
/// routes work (batch supports are disjoint), so both maps produce
/// identical estimates; only the per-shard load differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMap {
    /// `owner(k) = k % W`.
    Modulo,
    /// `owner(k) = k / ⌈n/W⌉` (contiguous ranges).
    Block,
}

impl ShardMap {
    /// Registry string used by `SolverSpec` (`"mod"` / `"block"`).
    pub fn key(&self) -> &'static str {
        match self {
            ShardMap::Modulo => "mod",
            ShardMap::Block => "block",
        }
    }

    /// Parse the registry string.
    pub fn parse(s: &str) -> Option<ShardMap> {
        match s {
            "mod" | "modulo" => Some(ShardMap::Modulo),
            "block" => Some(ShardMap::Block),
            _ => None,
        }
    }

    /// Which of `shards` workers owns page `k` of an `n`-page graph.
    #[inline]
    pub fn owner(&self, k: usize, n: usize, shards: usize) -> usize {
        match self {
            ShardMap::Modulo => k % shards,
            ShardMap::Block => k / n.div_ceil(shards),
        }
    }
}

enum Job {
    /// Pages to activate (all owned by this worker, supports disjoint from
    /// every other in-flight job).
    Batch(Vec<u32>),
    Shutdown,
}

/// The sharded runtime handle.
pub struct ShardedRuntime {
    graph: Arc<Graph>,
    state: Arc<SharedState>,
    workers: Vec<std::thread::JoinHandle<()>>,
    to_workers: Vec<Sender<Job>>,
    done_rx: Receiver<usize>,
    shards: usize,
    map: ShardMap,
    /// Scratch: generation-tagged marks for conflict-free packing.
    mark: Vec<u64>,
    generation: u64,
    /// Total activations applied.
    activations: u64,
    /// Candidates dropped due to conflicts (batch packing).
    conflicts: u64,
    /// Residual reads issued by applied activations (§II-D accounting:
    /// one per out-neighbour — a dangling page's implicit self-read is
    /// local and free, matching the matrix-form counters).
    logical_reads: u64,
    /// Residual writes issued by applied activations (same count).
    logical_writes: u64,
}

impl ShardedRuntime {
    /// Spin up `shards` worker threads with the default modulo shard map.
    pub fn new(graph: Graph, alpha: f64, shards: usize) -> ShardedRuntime {
        ShardedRuntime::new_with_map(graph, alpha, shards, ShardMap::Modulo)
    }

    /// Spin up `shards` worker threads with an explicit [`ShardMap`].
    pub fn new_with_map(
        graph: Graph,
        alpha: f64,
        shards: usize,
        map: ShardMap,
    ) -> ShardedRuntime {
        assert!(shards >= 1);
        let n = graph.n();
        let graph = Arc::new(graph);
        let cols = Arc::new(BColumns::new(&graph, alpha));
        let state = Arc::new(SharedState::new(n, 1.0 - alpha));
        let (done_tx, done_rx) = channel::<usize>();
        let mut workers = Vec::with_capacity(shards);
        let mut to_workers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = channel::<Job>();
            to_workers.push(tx);
            let graph = Arc::clone(&graph);
            let cols = Arc::clone(&cols);
            let state = Arc::clone(&state);
            let done = done_tx.clone();
            workers.push(std::thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Batch(pages) => {
                            let count = pages.len();
                            for k in pages {
                                activate(&graph, &cols, &state, k as usize, alpha);
                            }
                            if done.send(count).is_err() {
                                return;
                            }
                        }
                        Job::Shutdown => return,
                    }
                }
            }));
        }
        ShardedRuntime {
            mark: vec![0; n],
            generation: 0,
            graph,
            state,
            workers,
            to_workers,
            done_rx,
            shards,
            map,
            activations: 0,
            conflicts: 0,
            logical_reads: 0,
            logical_writes: 0,
        }
    }

    /// Pack a conflict-free batch of up to `budget` uniform candidates
    /// (first-come-first-kept; rejected candidates are counted, preserving
    /// the thinned-uniform activation law of the async coordinator).
    fn pack(&mut self, budget: usize, rng: &mut Rng) -> Vec<u32> {
        self.generation += 1;
        let gen = self.generation;
        let mut accepted = Vec::with_capacity(budget);
        'cand: for _ in 0..budget {
            let k = rng.below(self.graph.n());
            if self.mark[k] == gen {
                self.conflicts += 1;
                continue;
            }
            for &j in self.graph.out(k) {
                if self.mark[j as usize] == gen {
                    self.conflicts += 1;
                    continue 'cand;
                }
            }
            self.mark[k] = gen;
            for &j in self.graph.out(k) {
                self.mark[j as usize] = gen;
            }
            accepted.push(k as u32);
        }
        accepted
    }

    /// Run `batches` super-steps of up to `batch_budget` candidate
    /// activations each. Returns activations applied.
    pub fn run(&mut self, batches: usize, batch_budget: usize, rng: &mut Rng) -> u64 {
        let n = self.graph.n();
        let mut applied = 0u64;
        for _ in 0..batches {
            let batch = self.pack(batch_budget, rng);
            if batch.is_empty() {
                continue;
            }
            // Route each activation to the owner shard.
            let mut per_shard: Vec<Vec<u32>> = vec![Vec::new(); self.shards];
            for k in batch {
                let deg = self.graph.out_degree(k as usize) as u64;
                self.logical_reads += deg;
                self.logical_writes += deg;
                per_shard[self.map.owner(k as usize, n, self.shards)].push(k);
            }
            let mut outstanding = 0usize;
            for (w, pages) in per_shard.into_iter().enumerate() {
                if pages.is_empty() {
                    continue;
                }
                applied += pages.len() as u64;
                self.to_workers[w].send(Job::Batch(pages)).expect("worker alive");
                outstanding += 1;
            }
            // Barrier: wait for all shards of this super-step (provides the
            // inter-batch happens-before edge).
            for _ in 0..outstanding {
                self.done_rx.recv().expect("worker alive");
            }
        }
        self.activations += applied;
        applied
    }

    /// Number of pages.
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn estimate(&self) -> Vec<f64> {
        (0..self.graph.n()).map(|i| self.state.load_x(i)).collect()
    }

    pub fn residual(&self) -> Vec<f64> {
        (0..self.graph.n()).map(|i| self.state.load_r(i)).collect()
    }

    /// Allocation-free `‖x̂ - x*‖²` against a reference (quiescent
    /// between `run` calls — the barrier publishes every write).
    pub fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        assert_eq!(x_star.len(), self.graph.n());
        let mut s = 0.0;
        for (i, &xs) in x_star.iter().enumerate() {
            let d = self.state.load_x(i) - xs;
            s += d * d;
        }
        s
    }

    pub fn activations(&self) -> u64 {
        self.activations
    }

    pub fn conflicts(&self) -> u64 {
        self.conflicts
    }

    /// §II-D residual reads issued by applied activations so far.
    pub fn logical_reads(&self) -> u64 {
        self.logical_reads
    }

    /// §II-D residual writes issued by applied activations so far.
    pub fn logical_writes(&self) -> u64 {
        self.logical_writes
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn shard_map(&self) -> ShardMap {
        self.map
    }
}

impl Drop for ShardedRuntime {
    fn drop(&mut self) {
        for tx in &self.to_workers {
            let _ = tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn conservation_holds_under_parallel_execution() {
        let g = generators::erdos_renyi(300, 0.01, 2001);
        let alpha = 0.85;
        let mut rt = ShardedRuntime::new(g.clone(), alpha, 4);
        let mut rng = Rng::seeded(1);
        rt.run(200, 16, &mut rng);
        assert!(rt.activations() > 0);
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&rt.estimate());
        for (i, (v, r)) in bx.iter().zip(rt.residual()).enumerate() {
            assert!(
                (v + r - (1.0 - alpha)).abs() < 1e-10,
                "conservation broken at page {i}"
            );
        }
    }

    #[test]
    fn matches_sequential_application_of_same_batches() {
        // With 1 shard and the same RNG, the packed batches are identical;
        // multi-shard execution of disjoint supports must give the same
        // state as single-shard (commutativity).
        let g = generators::erdos_renyi(200, 0.01, 2002);
        let run = |shards: usize| {
            let mut rt = ShardedRuntime::new(g.clone(), 0.85, shards);
            let mut rng = Rng::seeded(7);
            rt.run(100, 8, &mut rng);
            (rt.estimate(), rt.residual())
        };
        let (x1, r1) = run(1);
        let (x4, r4) = run(4);
        assert!(vector::dist_inf(&x1, &x4) < 1e-13, "estimates diverged");
        assert!(vector::dist_inf(&r1, &r4) < 1e-13, "residuals diverged");
    }

    #[test]
    fn converges_to_exact_pagerank() {
        let g = generators::erdos_renyi(150, 0.03, 2003);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = ShardedRuntime::new(g, 0.85, 4);
        let mut rng = Rng::seeded(9);
        rt.run(60_000, 8, &mut rng);
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn conflicts_counted_on_dense_graphs() {
        let g = generators::er_threshold(60, 0.5, 2004);
        let mut rt = ShardedRuntime::new(g, 0.85, 2);
        let mut rng = Rng::seeded(11);
        rt.run(50, 16, &mut rng);
        assert!(rt.conflicts() > 0, "dense graphs must produce packing conflicts");
    }

    #[test]
    fn single_shard_single_candidate_equals_matrix_form() {
        use crate::algo::mp::MatchingPursuit;
        let g = generators::er_threshold(40, 0.5, 2005);
        let mut rt = ShardedRuntime::new(g.clone(), 0.85, 1);
        let mut rng1 = Rng::seeded(13);
        rt.run(500, 1, &mut rng1);
        // Matrix form replaying the same sampler stream (batch=1 packing
        // draws exactly one page per super-step and never conflicts).
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng2 = Rng::seeded(13);
        for _ in 0..500 {
            let k = rng2.below(40);
            mp.step_at(k);
        }
        assert!(vector::dist_inf(&rt.estimate(), &crate::algo::common::PageRankSolver::estimate(&mp)) < 1e-13);
    }

    #[test]
    fn block_and_modulo_maps_give_identical_results() {
        // Ownership only routes; disjoint supports make the math
        // placement-invariant.
        let g = generators::erdos_renyi(300, 0.01, 2006);
        let run = |map: ShardMap| {
            let mut rt = ShardedRuntime::new_with_map(g.clone(), 0.85, 4, map);
            let mut rng = Rng::seeded(21);
            rt.run(150, 8, &mut rng);
            (rt.estimate(), rt.residual(), rt.activations())
        };
        let (xm, rm, am) = run(ShardMap::Modulo);
        let (xb, rb, ab) = run(ShardMap::Block);
        assert_eq!(am, ab, "same rng stream must pack the same batches");
        assert!(vector::dist_inf(&xm, &xb) < 1e-13);
        assert!(vector::dist_inf(&rm, &rb) < 1e-13);
    }

    #[test]
    fn shard_map_owners_in_range_and_round_trip() {
        for (n, shards) in [(5usize, 8usize), (100, 4), (101, 4), (1, 1)] {
            for map in [ShardMap::Modulo, ShardMap::Block] {
                for k in 0..n {
                    let w = map.owner(k, n, shards);
                    assert!(w < shards, "{map:?} owner({k}, {n}, {shards}) = {w}");
                }
                assert_eq!(ShardMap::parse(map.key()), Some(map));
            }
        }
        assert_eq!(ShardMap::parse("diagonal"), None);
    }

    #[test]
    fn dangling_node_runs_to_convergence_with_finite_residuals() {
        // Regression: activate() used to compute α/out_degree with no
        // guard, so any sink page produced NaN/inf residuals.
        let g = generators::chain(30); // page 29 is a genuine sink
        assert_eq!(g.dangling(), vec![29]);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = ShardedRuntime::new(g, 0.85, 3);
        let mut rng = Rng::seeded(23);
        rt.run(40_000, 4, &mut rng);
        for (i, r) in rt.residual().into_iter().enumerate() {
            assert!(r.is_finite(), "residual at page {i} poisoned: {r}");
        }
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn read_write_counters_match_matrix_form_accounting() {
        let g = generators::er_threshold(50, 0.5, 2007);
        let mut rt = ShardedRuntime::new(g.clone(), 0.85, 2);
        let mut rng = Rng::seeded(25);
        rt.run(100, 4, &mut rng);
        assert!(rt.activations() > 0);
        // §II-D: exactly N_k reads and N_k writes per activation; the
        // sums must agree and be plausible for the dense paper graph.
        assert_eq!(rt.logical_reads(), rt.logical_writes());
        assert!(rt.logical_reads() >= rt.activations(), "dense pages read >= 1 each");
    }

    #[test]
    fn shards_survive_empty_batches() {
        // star graph: hub conflicts with everything; batch budget 4 packs
        // at most 1 activation, sometimes 0 after dedup.
        let g = generators::star(20);
        let mut rt = ShardedRuntime::new(g, 0.85, 3);
        let mut rng = Rng::seeded(17);
        let applied = rt.run(200, 4, &mut rng);
        assert!(applied > 0);
        assert_eq!(rt.activations(), applied);
    }
}
