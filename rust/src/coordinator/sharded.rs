//! Sharded multi-threaded runtime — a *real* parallel deployment of the
//! paper's algorithm (the §IV-1 extension executed on OS threads), as
//! opposed to the virtual-time simulator in [`super::leader`].
//!
//! Correctness argument (same as [`crate::algo::parallel_mp`]): an
//! activation of page `k` reads and writes only `supp B(:,k) = {k} ∪
//! out(k)`. Every super-step executes a set of activations whose closed
//! neighbourhoods are pairwise disjoint, so they touch disjoint memory
//! and can run on worker threads with **no ordering between them** — the
//! result equals any sequential execution of the same multiset.
//! Residuals and estimates live in shared `AtomicU64` cells (f64
//! bit-cast, relaxed ordering): within a super-step every cell is touched
//! by at most one worker, and the per-step synchronization with the
//! leader provides the inter-step happens-before edge.
//!
//! Two [`Packer`] policies decide *who* finds that disjoint set:
//!
//! * [`Packer::Leader`] — the leader samples uniform candidates and
//!   resolves conflicts serially against a generation-stamped `mark`
//!   array, then routes accepted pages to their owner shard. One thread
//!   does all sampling, conflict detection and routing: simple, exactly
//!   the paper's thinned-uniform law, but a serial bottleneck that caps
//!   batch throughput once the per-candidate `out(k)` scans outweigh the
//!   workers' activation cost (measured in `benches/throughput.rs`).
//! * [`Packer::Worker`] — each worker samples candidates *from its own
//!   shard* and claims the closed neighbourhood `{k} ∪ out(k)` in a
//!   shared generation-stamped atomic claim array (`fetch_max` of a
//!   priority word). After a barrier, a candidate survives iff it holds
//!   *every* page of its neighbourhood; survivors are activated by the
//!   worker that sampled them — no routing, no per-batch allocation, and
//!   the leader degenerates to a barrier + counter aggregator. The claim
//!   word is `(generation << CLAIM_SLOT_BITS) | (mask - claim_id)`, so a
//!   fresh generation always outranks stale stamps (the array is never
//!   cleared) and, within a generation, the survivors are exactly the
//!   candidates whose priority wins every page they claimed — a
//!   deterministic, timing-independent subset of the serial greedy pack
//!   (a loser's stamps still stand, so candidates overlapping only a
//!   loser are rejected too; every rejection is counted), which keeps
//!   seeded runs reproducible.
//!
//! Rejected candidates are **counted as conflicts under both packers**,
//! preserving the thinned activation law of the async coordinator. Under
//! worker packing the candidate law is uniform *per shard* (each worker
//! draws uniformly from the pages it owns); with one shard that is the
//! global uniform law, and worker 0 inherits the caller's exact rng
//! stream, so `sharded:1:1:*:worker` replays the matrix form bit for bit
//! (tested below and in `tests/engine.rs`).
//!
//! An orthogonal [`Sampling`] policy decides *how* candidates are drawn
//! (§IV future-work 3): `uniform` (the above, the default) or
//! `residual` — candidates weighted by `max(r_k², floor)` over the
//! shared Fenwick [`WeightTree`]. Under leader packing one global tree
//! lives on the leader and is refreshed serially from the accepted
//! activations' neighbourhoods after every super-step. Under worker
//! packing each worker keeps a tree over its *owned* pages; because an
//! activation can write residuals owned by other shards, survivors
//! publish their page id to a shared [`Winners`] list and a second
//! barrier separates execution from a weight-refresh phase in which
//! every worker updates the owned pages inside any winner's
//! neighbourhood (winners are pairwise disjoint, so each page refreshes
//! at most once; updates apply in ascending page order, so the Fenwick
//! arithmetic — and with it every future draw — is independent of
//! thread timing). The refresh costs O(Σ winner degrees) index scans
//! per worker per super-step, proportional to the activation work
//! itself. With one shard, both packers' residual paths replay the
//! matrix-form `mp:residual` bit for bit (tested in `tests/engine.rs`).
//!
//! Topology: one leader and `W` persistent workers connected by mpsc
//! channels plus (for worker packing) a `std::sync::Barrier` separating
//! the claim and verify/execute phases of a super-step. Page → shard
//! ownership is a pluggable [`ShardMap`]: closed-form (`mod`/`block`)
//! or table-backed topology-aware (`cluster`/`scc`, resolved once per
//! `(graph, shards)` into a [`ResolvedMap`] by
//! [`crate::graph::partition`]). Under leader packing, ownership only
//! routes work (batch supports are disjoint), so all maps produce
//! identical estimates; under worker packing the map also shapes the
//! candidate law, so different maps are different (but individually
//! deterministic) sampling policies.
//!
//! Locality is measured, not asserted: the worker packer splits its
//! conflict count into intra- vs cross-shard claim rejections (the
//! blocking claim word's id encodes the winning shard), and every
//! multi-shard runtime reports the static cross-edge fraction of its
//! resolved map through [`LocalityCounters`] — the quantities the
//! `locality` bench section races across maps.
//!
//! Dangling pages are repaired on the fly by the shared implicit
//! self-loop guard of [`BColumns`] (no `α/0` poisoning — see that
//! module's docs); [`activate`] consults the column constants instead of
//! dividing by the raw out-degree.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

use crate::graph::partition::{self, OwnerTable};
use crate::graph::Graph;
use crate::linalg::select::{DEFAULT_WEIGHT_FLOOR, WeightTree};
use crate::linalg::sparse::BColumns;
use crate::util::rng::Rng;

/// Shared per-page state: f64 stored as bits in atomics. Disjointness of
/// batch supports means `Relaxed` suffices within a batch; the channel
/// synchronization between batches publishes all writes.
struct SharedState {
    x: Vec<AtomicU64>,
    r: Vec<AtomicU64>,
}

impl SharedState {
    fn new(n: usize, y: f64) -> SharedState {
        SharedState {
            x: (0..n).map(|_| AtomicU64::new(0f64.to_bits())).collect(),
            r: (0..n).map(|_| AtomicU64::new(y.to_bits())).collect(),
        }
    }

    #[inline]
    fn load_r(&self, i: usize) -> f64 {
        f64::from_bits(self.r[i].load(Ordering::Relaxed))
    }

    #[inline]
    fn store_r(&self, i: usize, v: f64) {
        self.r[i].store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    fn load_x(&self, i: usize) -> f64 {
        f64::from_bits(self.x[i].load(Ordering::Relaxed))
    }

    #[inline]
    fn store_x(&self, i: usize, v: f64) {
        self.x[i].store(v.to_bits(), Ordering::Relaxed);
    }
}

/// One §II-D activation against the shared state. Only touches
/// `{k} ∪ out(k)` — the packing invariant makes this race-free.
///
/// Degree geometry comes from [`BColumns`] (never a raw `α/N_k`
/// division): a dangling `k` has `inv_out_degree = 1` and an implicit
/// self-loop, so sink pages update finitely instead of poisoning the
/// residuals with NaN/inf. The arithmetic and evaluation order mirror
/// [`BColumns::col_dot`]/[`BColumns::sub_scaled_col`] exactly, which is
/// what makes a 1-shard batch-1 run bit-identical to the matrix form.
fn activate(graph: &Graph, cols: &BColumns, state: &SharedState, k: usize, alpha: f64) {
    // numerator: r_k - (α/N_k) Σ_{j∈out(k)} r_j
    let mut acc = 0.0;
    for &j in graph.out(k) {
        acc += state.load_r(j as usize);
    }
    if cols.is_dangling(k) {
        // implicit self-loop: the only "out-neighbour" is k itself
        acc += state.load_r(k);
    }
    let inv_deg = cols.inv_out_degree(k);
    let num = state.load_r(k) - alpha * inv_deg * acc;
    let coef = num / cols.norm_sq(k);
    state.store_x(k, state.load_x(k) + coef);
    // residual update: out-neighbours += coef·α/N_k, diagonal -= coef
    let w = coef * alpha * inv_deg;
    for &j in graph.out(k) {
        let j = j as usize;
        state.store_r(j, state.load_r(j) + w);
    }
    if cols.is_dangling(k) {
        state.store_r(k, state.load_r(k) + w);
    }
    state.store_r(k, state.load_r(k) - coef);
}

/// Page → shard ownership policy.
///
/// `Modulo` (`k % W`) interleaves consecutive ids across shards — the
/// right default for generator graphs whose hub-heavy pages cluster in a
/// low-id range (BA preferential attachment, the star family), where
/// block ownership would hand one shard all the expensive activations.
/// `Block` assigns contiguous ranges of `⌈n/W⌉` pages — cache-friendly
/// contiguous state per worker when degrees are uniform. `Cluster` and
/// `Scc` are *table-backed* topology-aware maps (ROADMAP "topology-aware
/// sharding"): seeded label-propagation clusters or Tarjan condensation
/// components, bin-packed onto shards by a balance-bounded largest-first
/// greedy — resolved once per `(graph, shards)` into a [`ResolvedMap`]
/// by [`ShardMap::resolve`] (see [`crate::graph::partition`]).
///
/// Under [`Packer::Leader`] ownership only routes work (batch supports
/// are disjoint), so all maps produce identical estimates; under
/// [`Packer::Worker`] the map additionally defines each worker's local
/// candidate pool, so different maps are different (but individually
/// deterministic) sampling policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMap {
    /// `owner(k) = k % W`.
    Modulo,
    /// `owner(k) = k / ⌈n/W⌉` (contiguous ranges).
    Block,
    /// Seeded label-propagation clusters, balance-packed (table-backed).
    Cluster,
    /// Tarjan SCC condensation components, balance-packed (table-backed).
    Scc,
}

impl ShardMap {
    /// Registry string used by `SolverSpec`
    /// (`"mod"` / `"block"` / `"cluster"` / `"scc"`).
    pub fn key(&self) -> &'static str {
        match self {
            ShardMap::Modulo => "mod",
            ShardMap::Block => "block",
            ShardMap::Cluster => "cluster",
            ShardMap::Scc => "scc",
        }
    }

    /// Parse the registry string. Unknown names are an error naming the
    /// valid set, so the spec grammar can position it instead of
    /// bubbling a silent `None`.
    pub fn parse(s: &str) -> Result<ShardMap, String> {
        match s {
            "mod" | "modulo" => Ok(ShardMap::Modulo),
            "block" => Ok(ShardMap::Block),
            "cluster" => Ok(ShardMap::Cluster),
            "scc" => Ok(ShardMap::Scc),
            other => Err(format!("bad shard map {other:?} (mod|block|cluster|scc)")),
        }
    }

    /// Whether this map is table-backed (needs [`ShardMap::resolve`]
    /// against a concrete graph; the closed-form accessors below panic).
    pub fn table_backed(&self) -> bool {
        matches!(self, ShardMap::Cluster | ShardMap::Scc)
    }

    /// Resolve against a concrete graph into the form the runtimes
    /// consume. Closed-form maps stay arithmetic; the topology-aware
    /// maps build their owner table here — out-CSR only (so in-link-free
    /// graphs resolve too) and with a *fixed* internal seed, so both
    /// runtimes resolve the identical partition for the same
    /// `(graph, shards)` whatever the run seed.
    pub fn resolve(&self, graph: &Graph, shards: usize) -> ResolvedMap {
        match self {
            ShardMap::Modulo | ShardMap::Block => {
                ResolvedMap::Closed { map: *self, n: graph.n(), shards }
            }
            ShardMap::Cluster => {
                ResolvedMap::Table(partition::cluster_partition(graph, shards))
            }
            ShardMap::Scc => ResolvedMap::Table(partition::scc_partition(graph, shards)),
        }
    }

    #[inline]
    fn no_closed_form(&self) -> ! {
        panic!("{self:?} is table-backed and has no closed form; use ShardMap::resolve")
    }

    /// Which of `shards` workers owns page `k` of an `n`-page graph
    /// (closed-form maps only — table-backed maps answer through their
    /// [`ResolvedMap`]).
    #[inline]
    pub fn owner(&self, k: usize, n: usize, shards: usize) -> usize {
        match self {
            ShardMap::Modulo => k % shards,
            ShardMap::Block => k / n.div_ceil(shards),
            ShardMap::Cluster | ShardMap::Scc => self.no_closed_form(),
        }
    }

    /// How many pages of an `n`-page graph shard `w` owns (closed-form
    /// maps only).
    #[inline]
    pub fn owned_count(&self, w: usize, n: usize, shards: usize) -> usize {
        match self {
            ShardMap::Modulo => n.saturating_sub(w).div_ceil(shards),
            ShardMap::Block => {
                let chunk = n.div_ceil(shards);
                n.saturating_sub(w * chunk).min(chunk)
            }
            ShardMap::Cluster | ShardMap::Scc => self.no_closed_form(),
        }
    }

    /// The `i`-th page owned by shard `w` (`i < owned_count`; closed-form
    /// maps only).
    #[inline]
    pub fn owned_page(&self, w: usize, i: usize, n: usize, shards: usize) -> usize {
        match self {
            ShardMap::Modulo => w + i * shards,
            ShardMap::Block => w * n.div_ceil(shards) + i,
            ShardMap::Cluster | ShardMap::Scc => self.no_closed_form(),
        }
    }

    /// Inverse of [`ShardMap::owned_page`]: page `k`'s index within its
    /// owner's page list (closed-form maps only). Monotone in `k`, so
    /// sorting global ids sorts local indices too (the residual samplers
    /// rely on this for deterministic weight-update order).
    #[inline]
    pub fn local_index(&self, k: usize, n: usize, shards: usize) -> usize {
        match self {
            ShardMap::Modulo => k / shards,
            ShardMap::Block => k % n.div_ceil(shards),
            ShardMap::Cluster | ShardMap::Scc => self.no_closed_form(),
        }
    }
}

/// A [`ShardMap`] resolved against a concrete graph — the form every
/// runtime hot path consumes. Closed-form maps compute ownership
/// arithmetically; table-backed maps index the shared [`OwnerTable`].
/// Cheap to clone (the table is all Arcs), so each worker thread holds
/// its own handle. The partition contract is identical across forms:
/// every page owned exactly once, `owned_page` ascending in `i`,
/// `local_index` inverting it.
#[derive(Debug, Clone)]
pub enum ResolvedMap {
    /// `mod`/`block`: ownership from arithmetic on `(n, shards)`.
    Closed { map: ShardMap, n: usize, shards: usize },
    /// `cluster`/`scc`: ownership from the resolved owner table.
    Table(OwnerTable),
}

impl ResolvedMap {
    /// Shard that owns page `k`.
    #[inline]
    pub fn owner(&self, k: usize) -> usize {
        match self {
            ResolvedMap::Closed { map, n, shards } => map.owner(k, *n, *shards),
            ResolvedMap::Table(t) => t.owner(k),
        }
    }

    /// Number of pages shard `w` owns.
    #[inline]
    pub fn owned_count(&self, w: usize) -> usize {
        match self {
            ResolvedMap::Closed { map, n, shards } => map.owned_count(w, *n, *shards),
            ResolvedMap::Table(t) => t.owned_count(w),
        }
    }

    /// The `i`-th page owned by shard `w` (ascending in `i`).
    #[inline]
    pub fn owned_page(&self, w: usize, i: usize) -> usize {
        match self {
            ResolvedMap::Closed { map, n, shards } => map.owned_page(w, i, *n, *shards),
            ResolvedMap::Table(t) => t.owned_page(w, i),
        }
    }

    /// Index of page `k` within its owner's page list.
    #[inline]
    pub fn local_index(&self, k: usize) -> usize {
        match self {
            ResolvedMap::Closed { map, n, shards } => map.local_index(k, *n, *shards),
            ResolvedMap::Table(t) => t.local_index(k),
        }
    }

    /// Number of shards the map partitions onto.
    pub fn shards(&self) -> usize {
        match self {
            ResolvedMap::Closed { shards, .. } => *shards,
            ResolvedMap::Table(t) => t.shards(),
        }
    }

    /// Fraction of out-edges whose endpoints live on different shards —
    /// the static locality gauge both runtimes surface.
    pub fn cross_edge_fraction(&self, graph: &Graph) -> f64 {
        if self.shards() <= 1 {
            return 0.0;
        }
        partition::cross_edge_fraction(graph, |k| self.owner(k))
    }
}

/// Placement/locality ledger surfaced through `SolverReport` — how much
/// of a run's coordination crossed a shard boundary. The sharded worker
/// packer fills the conflict split (leader-packed conflicts are a serial
/// mark scan with no claiming shard to attribute), the msgpass backend
/// fills the wire counters, and both report the static cross-edge
/// fraction of their resolved map. All zero for every other solver.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LocalityCounters {
    /// Worker-packed claim rejections whose winning claim came from the
    /// same shard.
    pub intra_conflicts: u64,
    /// Worker-packed claim rejections lost to another shard's claim.
    pub cross_conflicts: u64,
    /// Fraction of out-edges `(k → j)` with `owner(k) != owner(j)` — a
    /// static gauge of the resolved map (max over absorbed runs).
    pub cross_edge_fraction: f64,
    /// msgpass `ResidualUpdate` messages sent to another shard.
    pub cross_messages: u64,
    /// Wire bytes of those cross-shard residual updates.
    pub cross_bytes: u64,
    /// Sum over activations of the number of *distinct* remote shards
    /// the activation's residual updates fanned out to (the subscriber
    /// fan-out the cluster maps shrink).
    pub subscriber_shard_sum: u64,
}

impl LocalityCounters {
    /// Whether anything was recorded — gates the report fields so
    /// single-shard and non-sharded runs keep their historical JSON
    /// shape (same contract as `FaultCounters::any`).
    pub fn any(&self) -> bool {
        self.intra_conflicts > 0
            || self.cross_conflicts > 0
            || self.cross_edge_fraction > 0.0
            || self.cross_messages > 0
            || self.cross_bytes > 0
            || self.subscriber_shard_sum > 0
    }

    /// Fold another ledger in (counts add; the static gauge maxes).
    pub fn absorb(&mut self, other: &LocalityCounters) {
        self.intra_conflicts += other.intra_conflicts;
        self.cross_conflicts += other.cross_conflicts;
        self.cross_edge_fraction = self.cross_edge_fraction.max(other.cross_edge_fraction);
        self.cross_messages += other.cross_messages;
        self.cross_bytes += other.cross_bytes;
        self.subscriber_shard_sum += other.subscriber_shard_sum;
    }
}

/// Who packs conflict-free super-steps: the serial leader (`mark`-array
/// scan + routing) or the workers themselves (shared atomic claim array,
/// no routing). See the module docs for the trade-off.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Packer {
    /// Leader samples, conflict-checks and routes serially.
    Leader,
    /// Workers sample their own shard and claim neighbourhoods via the
    /// shared atomic claim array; the leader only aggregates counters.
    Worker,
}

impl Packer {
    /// Registry string used by `SolverSpec` (`"leader"` / `"worker"`).
    pub fn key(&self) -> &'static str {
        match self {
            Packer::Leader => "leader",
            Packer::Worker => "worker",
        }
    }

    /// Parse the registry string.
    pub fn parse(s: &str) -> Option<Packer> {
        match s {
            "leader" => Some(Packer::Leader),
            "worker" => Some(Packer::Worker),
            _ => None,
        }
    }
}

/// How candidates are drawn (§IV future-work 3): uniform (the paper's
/// law, the default) or residual-weighted — `k ∝ max(r_k², floor)` over
/// a Fenwick [`WeightTree`]. Under [`Packer::Leader`] one global tree
/// lives on the leader; under [`Packer::Worker`] every worker keeps a
/// local tree over the pages it owns, refreshed from the published
/// winner set after each super-step (see the module docs of
/// [`crate::linalg::select`] for the floor/irreducibility argument).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    /// Uniform candidates (global under leader packing, per-shard under
    /// worker packing) — PR-3 behaviour, bit-for-bit.
    Uniform,
    /// Residual-weighted candidates with the shared default floor.
    Residual,
}

impl Sampling {
    /// Registry string used by `SolverSpec` (`"uniform"` / `"residual"`).
    pub fn key(&self) -> &'static str {
        match self {
            Sampling::Uniform => "uniform",
            Sampling::Residual => "residual",
        }
    }

    /// Parse the registry string.
    pub fn parse(s: &str) -> Option<Sampling> {
        match s {
            "uniform" => Some(Sampling::Uniform),
            "residual" => Some(Sampling::Residual),
            _ => None,
        }
    }
}

/// Winner exchange for worker-packed residual sampling: survivors of the
/// claim phase publish their page id here so every worker can refresh
/// the weights of its owned pages in the winners' neighbourhoods.
/// Winners hold pairwise-disjoint neighbourhoods, so at most `n` entries
/// are ever live; the leader resets `count` between super-steps.
struct Winners {
    count: AtomicUsize,
    pages: Vec<AtomicU64>,
}

/// Low bits of a claim word hold the inverted candidate priority; high
/// bits hold the super-step generation, so `fetch_max` lets fresh claims
/// always outrank stale stamps and the claim array never needs clearing.
const CLAIM_SLOT_BITS: u32 = 20;
const CLAIM_SLOT_MASK: u64 = (1 << CLAIM_SLOT_BITS) - 1;

/// Largest per-super-step batch budget the claim-word priority field can
/// encode for a given shard count (claim ids run up to
/// `budget + shards - 1`). `SolverSpec::parse` refuses bigger budgets up
/// front; [`ShardedRuntime::run`] asserts it as a backstop.
pub fn max_batch_budget(shards: usize) -> usize {
    (CLAIM_SLOT_MASK as usize).saturating_sub(shards)
}

#[inline]
fn claim_word(gen: u64, claim_id: u64) -> u64 {
    debug_assert!(claim_id < CLAIM_SLOT_MASK);
    // Invert the id so that *smaller* claim ids produce *larger* words:
    // fetch_max then implements "earlier candidate wins" per page. Note
    // this thins slightly *more* than the leader's serial scan at the
    // same priority order: a losing candidate's stamps still stand, so
    // a later candidate overlapping only the loser is rejected too
    // (counted as a conflict), where serial greedy would accept it.
    (gen << CLAIM_SLOT_BITS) | (CLAIM_SLOT_MASK - claim_id)
}

enum Job {
    /// Pages to activate, routed by the leader packer (all owned by this
    /// worker, supports disjoint from every other in-flight job).
    Batch(Vec<u32>),
    /// Seed the worker's local candidate stream (sent once, before the
    /// first worker-packed super-step).
    Seed(Rng),
    /// One worker-packed super-step: sample `share` candidates from the
    /// own shard, claim, cross the barrier, then activate the winners.
    Pack { gen: u64, share: usize },
    Shutdown,
}

/// Per-super-step outcome a worker reports back to the leader. In leader
/// mode only `applied`/`buf` are meaningful (the leader tallies
/// conflicts and logical traffic while packing); in worker mode the
/// worker owns all four counters and there is no buffer to return.
#[derive(Default)]
struct Done {
    applied: u64,
    conflicts: u64,
    /// Of `conflicts`, how many were lost to another shard's claim
    /// (worker packing only — the claim word names the winning shard).
    cross_conflicts: u64,
    reads: u64,
    writes: u64,
    /// Leader-mode batch buffer, returned for reuse (the allocation-free
    /// steady state: buffers cycle leader → worker → leader forever).
    buf: Option<Vec<u32>>,
}

/// Everything a worker thread needs; kept in a struct so the spawn loop
/// below stays readable.
struct WorkerCtx {
    w: usize,
    shards: usize,
    alpha: f64,
    map: ResolvedMap,
    sampling: Sampling,
    graph: Arc<Graph>,
    cols: Arc<BColumns>,
    state: Arc<SharedState>,
    claims: Arc<Vec<AtomicU64>>,
    winners: Arc<Winners>,
    barrier: Arc<Barrier>,
    done: Sender<Done>,
}

fn worker_loop(ctx: WorkerCtx, rx: Receiver<Job>) {
    let owned = ctx.map.owned_count(ctx.w);
    let residual = ctx.sampling == Sampling::Residual;
    // Worker-packing locals, allocated once per thread: the candidate
    // stream, the (page, claim word) queue of the current super-step,
    // the per-shard residual weight tree and its update scratch.
    let mut rng: Option<Rng> = None;
    let mut cands: Vec<(u32, u64)> = Vec::new();
    let mut wtree: Option<WeightTree> = None;
    let mut wscratch: Vec<u32> = Vec::new();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Batch(mut pages) => {
                let count = pages.len() as u64;
                for &k in &pages {
                    activate(&ctx.graph, &ctx.cols, &ctx.state, k as usize, ctx.alpha);
                }
                pages.clear();
                let done = Done { applied: count, buf: Some(pages), ..Done::default() };
                if ctx.done.send(done).is_err() {
                    return;
                }
            }
            Job::Seed(stream) => {
                rng = Some(stream);
                // Residual sampling: the local tree over the owned pages
                // starts at the uniform initial residual (1-α)² — built
                // here (not lazily at first draw) so weight refreshes
                // never miss updates from super-steps this worker only
                // observed.
                if residual && owned > 0 {
                    let y = 1.0 - ctx.alpha;
                    let w0 = (y * y).max(DEFAULT_WEIGHT_FLOOR);
                    wtree = Some(WeightTree::new(&vec![w0; owned]));
                }
            }
            Job::Pack { gen, share } => {
                // Claim phase: sample locally, stamp every page of the
                // closed neighbourhood with this candidate's priority
                // word. fetch_max is commutative, so the post-barrier
                // claim state is independent of thread timing.
                cands.clear();
                if owned > 0 && share > 0 {
                    let rng = rng.as_mut().expect("worker stream seeded before packing");
                    cands.reserve(share);
                    for slot in 0..share {
                        // Uniform or residual-weighted local draw — both
                        // O(log owned) at worst, both one stream value.
                        let li = match wtree.as_ref() {
                            Some(tree) => tree.sample(rng),
                            None => rng.below(owned),
                        };
                        let k = ctx.map.owned_page(ctx.w, li);
                        // Interleave priorities across workers (slot-major)
                        // so no shard's whole batch outranks another's.
                        let word = claim_word(gen, (slot * ctx.shards + ctx.w) as u64);
                        ctx.claims[k].fetch_max(word, Ordering::Relaxed);
                        for &j in ctx.graph.out(k) {
                            ctx.claims[j as usize].fetch_max(word, Ordering::Relaxed);
                        }
                        cands.push((k as u32, word));
                    }
                }
                // All claims visible to all workers from here on.
                ctx.barrier.wait();
                // Verify + execute phase: a candidate survives iff its
                // word won every page of its neighbourhood. Survivors
                // are pairwise disjoint (each page names one winner) and
                // the set is deterministic. The leader's recv loop keeps
                // super-steps from overlapping, so no later generation
                // can overwrite a claim before it is verified.
                let mut d = Done::default();
                for &(k, word) in &cands {
                    let k = k as usize;
                    // On a loss, capture the blocking word: fetch_max
                    // means the stored word is ≥ ours, and the leader's
                    // recv loop keeps generations from overlapping, so
                    // the blocker is this generation's winner of that
                    // page — its claim id encodes the winning shard
                    // (ids interleave slot-major across workers).
                    let mut blocker = ctx.claims[k].load(Ordering::Relaxed);
                    let mut wins = blocker == word;
                    if wins {
                        for &j in ctx.graph.out(k) {
                            let stamp = ctx.claims[j as usize].load(Ordering::Relaxed);
                            if stamp != word {
                                blocker = stamp;
                                wins = false;
                                break;
                            }
                        }
                    }
                    if wins {
                        activate(&ctx.graph, &ctx.cols, &ctx.state, k, ctx.alpha);
                        let deg = ctx.graph.out_degree(k) as u64;
                        d.applied += 1;
                        d.reads += deg;
                        d.writes += deg;
                        if residual {
                            // Publish for the weight-refresh phase below.
                            let slot = ctx.winners.count.fetch_add(1, Ordering::Relaxed);
                            ctx.winners.pages[slot].store(k as u64, Ordering::Relaxed);
                        }
                    } else {
                        d.conflicts += 1;
                        let winner_claim = CLAIM_SLOT_MASK - (blocker & CLAIM_SLOT_MASK);
                        if winner_claim as usize % ctx.shards != ctx.w {
                            d.cross_conflicts += 1;
                        }
                    }
                }
                if residual {
                    // Weight-refresh phase: wait until every worker has
                    // activated and published its winners (the barrier
                    // provides the happens-before edge for both the
                    // residual stores and the winner list), then refresh
                    // the weights of owned pages inside any winner's
                    // neighbourhood. Winners are pairwise disjoint, so
                    // each page is refreshed at most once; updates are
                    // applied in ascending page order, making the
                    // Fenwick arithmetic independent of publication
                    // order (and of thread timing).
                    ctx.barrier.wait();
                    if let Some(tree) = wtree.as_mut() {
                        let wins_n = ctx.winners.count.load(Ordering::Relaxed);
                        wscratch.clear();
                        for slot in 0..wins_n {
                            let k = ctx.winners.pages[slot].load(Ordering::Relaxed) as usize;
                            if ctx.map.owner(k) == ctx.w {
                                wscratch.push(k as u32);
                            }
                            for &j in ctx.graph.out(k) {
                                if ctx.map.owner(j as usize) == ctx.w {
                                    wscratch.push(j);
                                }
                            }
                        }
                        wscratch.sort_unstable();
                        wscratch.dedup();
                        for &j in &wscratch {
                            let j = j as usize;
                            let r = ctx.state.load_r(j);
                            tree.update(
                                ctx.map.local_index(j),
                                (r * r).max(DEFAULT_WEIGHT_FLOOR),
                            );
                        }
                    }
                }
                if ctx.done.send(d).is_err() {
                    return;
                }
            }
            Job::Shutdown => return,
        }
    }
}

/// The sharded runtime handle.
pub struct ShardedRuntime {
    graph: Arc<Graph>,
    state: Arc<SharedState>,
    workers: Vec<std::thread::JoinHandle<()>>,
    to_workers: Vec<Sender<Job>>,
    done_rx: Receiver<Done>,
    shards: usize,
    map: ShardMap,
    /// The map resolved against this graph (owner table for the
    /// topology-aware maps) — what the leader's routing consults.
    rmap: ResolvedMap,
    packer: Packer,
    sampling: Sampling,
    /// Scratch: generation-tagged marks for leader-side packing.
    mark: Vec<u64>,
    /// Leader-side global residual weight tree (residual sampling under
    /// leader packing only).
    ltree: Option<WeightTree>,
    /// Scratch: pages accepted this super-step (leader residual
    /// sampling — drives the post-step weight refresh).
    packed: Vec<u32>,
    /// Scratch: sorted touched-page buffer for weight refreshes.
    wscratch: Vec<u32>,
    /// Winner exchange for worker-packed residual sampling (empty
    /// otherwise).
    winners: Arc<Winners>,
    generation: u64,
    /// Whether the workers' candidate streams have been seeded (worker
    /// packing; derived from the first `run` call's rng).
    streams_seeded: bool,
    /// Leader-mode routing buffers, one per shard, refilled in place
    /// every super-step (never reallocated in steady state).
    route: Vec<Vec<u32>>,
    /// Recycled batch buffers returned by the workers.
    spare: Vec<Vec<u32>>,
    /// Accepted count of the previous super-step — pre-sizes replacement
    /// buffers so even the warm-up batches allocate right-sized.
    prev_yield: usize,
    /// Total activations applied.
    activations: u64,
    /// Candidates dropped due to conflicts (both packers count them).
    conflicts: u64,
    /// Of `conflicts`, how many were lost to another shard's claim
    /// (worker packing only; the leader's serial scan has no claiming
    /// shard to attribute).
    cross_conflicts: u64,
    /// Static fraction of out-edges crossing shard boundaries under the
    /// resolved map (0 for a single shard).
    cross_edge_fraction: f64,
    /// Residual reads issued by applied activations (§II-D accounting:
    /// one per out-neighbour — a dangling page's implicit self-read is
    /// local and free, matching the matrix-form counters).
    logical_reads: u64,
    /// Residual writes issued by applied activations (same count).
    logical_writes: u64,
}

impl ShardedRuntime {
    /// Spin up `shards` worker threads with the default modulo shard map
    /// and leader-side packing.
    pub fn new(graph: Graph, alpha: f64, shards: usize) -> ShardedRuntime {
        ShardedRuntime::new_with_map(graph, alpha, shards, ShardMap::Modulo)
    }

    /// Spin up `shards` worker threads with an explicit [`ShardMap`] and
    /// leader-side packing.
    pub fn new_with_map(
        graph: Graph,
        alpha: f64,
        shards: usize,
        map: ShardMap,
    ) -> ShardedRuntime {
        ShardedRuntime::new_with_packer(graph, alpha, shards, map, Packer::Leader)
    }

    /// Spin up `shards` worker threads with an explicit [`ShardMap`] and
    /// [`Packer`] policy (uniform candidate sampling).
    pub fn new_with_packer(
        graph: Graph,
        alpha: f64,
        shards: usize,
        map: ShardMap,
        packer: Packer,
    ) -> ShardedRuntime {
        ShardedRuntime::new_with_sampling(graph, alpha, shards, map, packer, Sampling::Uniform)
    }

    /// Spin up `shards` worker threads with explicit [`ShardMap`],
    /// [`Packer`] and [`Sampling`] policies.
    pub fn new_with_sampling(
        graph: Graph,
        alpha: f64,
        shards: usize,
        map: ShardMap,
        packer: Packer,
        sampling: Sampling,
    ) -> ShardedRuntime {
        assert!(shards >= 1);
        let n = graph.n();
        let graph = Arc::new(graph);
        let cols = Arc::new(BColumns::new(&graph, alpha));
        let state = Arc::new(SharedState::new(n, 1.0 - alpha));
        // Resolve the map once (table-backed maps run their partition
        // algorithm here) and measure its static locality gauge.
        let rmap = map.resolve(&graph, shards);
        let cross_edge_fraction = rmap.cross_edge_fraction(&graph);
        // Each packer's scratch is O(n); only materialize the one in use
        // (claims for worker packing, the mark array for leader packing,
        // the winner exchange for worker-packed residual sampling).
        let claims: Arc<Vec<AtomicU64>> = Arc::new(match packer {
            Packer::Worker => (0..n).map(|_| AtomicU64::new(0)).collect(),
            Packer::Leader => Vec::new(),
        });
        let winners = Arc::new(Winners {
            count: AtomicUsize::new(0),
            pages: match (packer, sampling) {
                // Winners hold pairwise-disjoint neighbourhoods, so at
                // most n can survive one super-step.
                (Packer::Worker, Sampling::Residual) => {
                    (0..n).map(|_| AtomicU64::new(0)).collect()
                }
                _ => Vec::new(),
            },
        });
        let barrier = Arc::new(Barrier::new(shards));
        let (done_tx, done_rx) = channel::<Done>();
        let mut workers = Vec::with_capacity(shards);
        let mut to_workers = Vec::with_capacity(shards);
        for w in 0..shards {
            let (tx, rx) = channel::<Job>();
            to_workers.push(tx);
            let ctx = WorkerCtx {
                w,
                shards,
                alpha,
                map: rmap.clone(),
                sampling,
                graph: Arc::clone(&graph),
                cols: Arc::clone(&cols),
                state: Arc::clone(&state),
                claims: Arc::clone(&claims),
                winners: Arc::clone(&winners),
                barrier: Arc::clone(&barrier),
                done: done_tx.clone(),
            };
            workers.push(std::thread::spawn(move || worker_loop(ctx, rx)));
        }
        ShardedRuntime {
            mark: match packer {
                Packer::Leader => vec![0; n],
                Packer::Worker => Vec::new(),
            },
            ltree: match (packer, sampling) {
                (Packer::Leader, Sampling::Residual) => {
                    let y = 1.0 - alpha;
                    Some(WeightTree::new(&vec![(y * y).max(DEFAULT_WEIGHT_FLOOR); n]))
                }
                _ => None,
            },
            packed: Vec::new(),
            wscratch: Vec::new(),
            winners,
            generation: 0,
            streams_seeded: false,
            route: (0..shards).map(|_| Vec::new()).collect(),
            spare: Vec::new(),
            prev_yield: 0,
            graph,
            state,
            workers,
            to_workers,
            done_rx,
            shards,
            map,
            rmap,
            packer,
            sampling,
            activations: 0,
            conflicts: 0,
            cross_conflicts: 0,
            cross_edge_fraction,
            logical_reads: 0,
            logical_writes: 0,
        }
    }

    /// Run `batches` super-steps of up to `batch_budget` candidate
    /// activations each. Returns activations applied.
    ///
    /// Under [`Packer::Leader`] the rng drives the leader's global
    /// uniform candidate stream. Under [`Packer::Worker`] it seeds the
    /// per-worker streams on the first call (worker 0 *clones* it, so a
    /// 1-shard run replays the caller's stream exactly; workers `w > 0`
    /// fork decorrelated streams) and is left untouched afterwards —
    /// sampling has moved into the workers.
    pub fn run(&mut self, batches: usize, batch_budget: usize, rng: &mut Rng) -> u64 {
        match self.packer {
            Packer::Leader => self.run_leader_packed(batches, batch_budget, rng),
            Packer::Worker => self.run_worker_packed(batches, batch_budget, rng),
        }
    }

    /// Leader-side packing: serial sample + `mark`-scan + routing, with
    /// activations fanned out to the owner shards. Buffers cycle between
    /// leader and workers, so the steady state allocates nothing.
    fn run_leader_packed(&mut self, batches: usize, budget: usize, rng: &mut Rng) -> u64 {
        let n = self.graph.n();
        let mut applied = 0u64;
        for _ in 0..batches {
            self.generation += 1;
            let gen = self.generation;
            // Pack straight into the per-shard route buffers
            // (first-come-first-kept; rejected candidates are counted,
            // preserving the thinned-uniform activation law of the async
            // coordinator).
            let mut accepted = 0usize;
            'cand: for _ in 0..budget {
                // Uniform or residual-weighted global draw — one stream
                // value either way, so the sampling policy never skews
                // the candidate count.
                let k = match self.ltree.as_ref() {
                    Some(tree) => tree.sample(rng),
                    None => rng.below(n),
                };
                if self.mark[k] == gen {
                    self.conflicts += 1;
                    continue;
                }
                for &j in self.graph.out(k) {
                    if self.mark[j as usize] == gen {
                        self.conflicts += 1;
                        continue 'cand;
                    }
                }
                self.mark[k] = gen;
                for &j in self.graph.out(k) {
                    self.mark[j as usize] = gen;
                }
                let deg = self.graph.out_degree(k) as u64;
                self.logical_reads += deg;
                self.logical_writes += deg;
                let owner = self.rmap.owner(k);
                self.route[owner].push(k as u32);
                if self.ltree.is_some() {
                    self.packed.push(k as u32);
                }
                accepted += 1;
            }
            if accepted == 0 {
                continue;
            }
            let mut outstanding = 0usize;
            for w in 0..self.shards {
                if self.route[w].is_empty() {
                    continue;
                }
                // Hand the filled buffer to the worker; replace it from
                // the recycle pool (or, while the pool warms up, a fresh
                // vec pre-sized from the previous super-step's yield).
                let replacement = self.spare.pop().unwrap_or_else(|| {
                    Vec::with_capacity(self.prev_yield.div_ceil(self.shards).max(1))
                });
                let buf = std::mem::replace(&mut self.route[w], replacement);
                applied += buf.len() as u64;
                self.to_workers[w].send(Job::Batch(buf)).expect("worker alive");
                outstanding += 1;
            }
            self.prev_yield = accepted;
            // Barrier: wait for all shards of this super-step (provides
            // the inter-batch happens-before edge) and recover their
            // buffers.
            for _ in 0..outstanding {
                let done = self.done_rx.recv().expect("worker alive");
                if let Some(buf) = done.buf {
                    self.spare.push(buf);
                }
            }
            // Residual sampling: refresh the weights of every page the
            // accepted activations touched ({k} ∪ out(k) per winner —
            // disjoint across winners). The recv loop above published
            // the workers' residual writes; updates apply in ascending
            // page order, the same deterministic walk the matrix-form
            // `mp:residual` and the worker packer use.
            if let Some(tree) = self.ltree.as_mut() {
                self.wscratch.clear();
                for &k in &self.packed {
                    self.wscratch.push(k);
                    self.wscratch.extend_from_slice(self.graph.out(k as usize));
                }
                self.wscratch.sort_unstable();
                self.wscratch.dedup();
                for &j in &self.wscratch {
                    let j = j as usize;
                    let r = self.state.load_r(j);
                    tree.update(j, (r * r).max(DEFAULT_WEIGHT_FLOOR));
                }
                self.packed.clear();
            }
        }
        self.activations += applied;
        applied
    }

    /// Worker-side packing: the leader only hands out the generation
    /// number and per-shard budget shares, then aggregates counters —
    /// sampling, conflict detection and activation all run shard-local.
    fn run_worker_packed(&mut self, batches: usize, budget: usize, rng: &mut Rng) -> u64 {
        assert!(
            budget <= max_batch_budget(self.shards),
            "batch budget {budget} too large for the claim-word priority field \
             (max {} at {} shards)",
            max_batch_budget(self.shards),
            self.shards
        );
        if !self.streams_seeded {
            for (w, tx) in self.to_workers.iter().enumerate() {
                // Worker 0 inherits the caller's stream verbatim (this is
                // what pins `sharded:1:1:*:worker` bit-identical to the
                // matrix form); the rest fork decorrelated streams.
                let stream = if w == 0 { rng.clone() } else { rng.fork(w as u64) };
                tx.send(Job::Seed(stream)).expect("worker alive");
            }
            self.streams_seeded = true;
        }
        let per = budget / self.shards;
        let extra = budget % self.shards;
        let mut applied = 0u64;
        for _ in 0..batches {
            self.generation += 1;
            let gen = self.generation;
            for (w, tx) in self.to_workers.iter().enumerate() {
                let share = per + usize::from(w < extra);
                tx.send(Job::Pack { gen, share }).expect("worker alive");
            }
            // Leader-as-aggregator: every worker reports exactly once
            // per super-step (even with an empty share — it still has to
            // cross the claim barrier), and the recv loop keeps
            // generations from overlapping.
            for _ in 0..self.shards {
                let d = self.done_rx.recv().expect("worker alive");
                applied += d.applied;
                self.conflicts += d.conflicts;
                self.cross_conflicts += d.cross_conflicts;
                self.logical_reads += d.reads;
                self.logical_writes += d.writes;
            }
            // Reset the winner exchange for the next super-step; the
            // Pack sends below publish the store to the workers.
            if self.sampling == Sampling::Residual {
                self.winners.count.store(0, Ordering::Relaxed);
            }
        }
        self.activations += applied;
        applied
    }

    /// Number of pages.
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    pub fn estimate(&self) -> Vec<f64> {
        (0..self.graph.n()).map(|i| self.state.load_x(i)).collect()
    }

    pub fn residual(&self) -> Vec<f64> {
        (0..self.graph.n()).map(|i| self.state.load_r(i)).collect()
    }

    /// Allocation-free `‖x̂ - x*‖²` against a reference (quiescent
    /// between `run` calls — the barrier publishes every write).
    pub fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        assert_eq!(x_star.len(), self.graph.n());
        let mut s = 0.0;
        for (i, &xs) in x_star.iter().enumerate() {
            let d = self.state.load_x(i) - xs;
            s += d * d;
        }
        s
    }

    pub fn activations(&self) -> u64 {
        self.activations
    }

    pub fn conflicts(&self) -> u64 {
        self.conflicts
    }

    /// Of [`ShardedRuntime::conflicts`], how many were lost to another
    /// shard's claim (worker packing only — always 0 under leader
    /// packing, whose serial scan has no claiming shard to attribute).
    pub fn cross_conflicts(&self) -> u64 {
        self.cross_conflicts
    }

    /// Static fraction of out-edges crossing shard boundaries under the
    /// resolved map (0 for a single shard).
    pub fn cross_edge_fraction(&self) -> f64 {
        self.cross_edge_fraction
    }

    /// Locality ledger for `SolverReport` (see [`LocalityCounters`]).
    pub fn locality(&self) -> LocalityCounters {
        let (intra, cross) = match self.packer {
            Packer::Worker => (self.conflicts - self.cross_conflicts, self.cross_conflicts),
            Packer::Leader => (0, 0),
        };
        LocalityCounters {
            intra_conflicts: intra,
            cross_conflicts: cross,
            cross_edge_fraction: self.cross_edge_fraction,
            ..LocalityCounters::default()
        }
    }

    /// §II-D residual reads issued by applied activations so far.
    pub fn logical_reads(&self) -> u64 {
        self.logical_reads
    }

    /// §II-D residual writes issued by applied activations so far.
    pub fn logical_writes(&self) -> u64 {
        self.logical_writes
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn shard_map(&self) -> ShardMap {
        self.map
    }

    /// The map resolved against this runtime's graph (the owner table
    /// for the topology-aware maps).
    pub fn resolved_map(&self) -> &ResolvedMap {
        &self.rmap
    }

    pub fn packer(&self) -> Packer {
        self.packer
    }

    pub fn sampling(&self) -> Sampling {
        self.sampling
    }
}

impl Drop for ShardedRuntime {
    fn drop(&mut self) {
        for tx in &self.to_workers {
            let _ = tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn conservation_holds_under_parallel_execution() {
        let g = generators::erdos_renyi(300, 0.01, 2001);
        let alpha = 0.85;
        let mut rt = ShardedRuntime::new(g.clone(), alpha, 4);
        let mut rng = Rng::seeded(1);
        rt.run(200, 16, &mut rng);
        assert!(rt.activations() > 0);
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&rt.estimate());
        for (i, (v, r)) in bx.iter().zip(rt.residual()).enumerate() {
            assert!(
                (v + r - (1.0 - alpha)).abs() < 1e-10,
                "conservation broken at page {i}"
            );
        }
    }

    #[test]
    fn conservation_holds_under_worker_packing() {
        // Same invariant when the workers pack for themselves: survivors
        // of the claim phase are disjoint, so B·x + r stays an exact
        // telescoping of (1-α)·1.
        let g = generators::erdos_renyi(300, 0.01, 2101);
        let alpha = 0.85;
        let mut rt =
            ShardedRuntime::new_with_packer(g.clone(), alpha, 4, ShardMap::Modulo, Packer::Worker);
        let mut rng = Rng::seeded(2);
        rt.run(200, 16, &mut rng);
        assert!(rt.activations() > 0);
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&rt.estimate());
        for (i, (v, r)) in bx.iter().zip(rt.residual()).enumerate() {
            assert!(
                (v + r - (1.0 - alpha)).abs() < 1e-10,
                "conservation broken at page {i}"
            );
        }
    }

    #[test]
    fn matches_sequential_application_of_same_batches() {
        // With 1 shard and the same RNG, the packed batches are identical;
        // multi-shard execution of disjoint supports must give the same
        // state as single-shard (commutativity).
        let g = generators::erdos_renyi(200, 0.01, 2002);
        let run = |shards: usize| {
            let mut rt = ShardedRuntime::new(g.clone(), 0.85, shards);
            let mut rng = Rng::seeded(7);
            rt.run(100, 8, &mut rng);
            (rt.estimate(), rt.residual())
        };
        let (x1, r1) = run(1);
        let (x4, r4) = run(4);
        assert!(vector::dist_inf(&x1, &x4) < 1e-13, "estimates diverged");
        assert!(vector::dist_inf(&r1, &r4) < 1e-13, "residuals diverged");
    }

    #[test]
    fn converges_to_exact_pagerank() {
        let g = generators::erdos_renyi(150, 0.03, 2003);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt = ShardedRuntime::new(g, 0.85, 4);
        let mut rng = Rng::seeded(9);
        rt.run(60_000, 8, &mut rng);
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn worker_packing_converges_to_exact_pagerank() {
        // Per-shard uniform sampling still activates every page
        // infinitely often, so the residual telescopes to the same fixed
        // point the leader packer reaches.
        let g = generators::erdos_renyi(150, 0.03, 2103);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt =
            ShardedRuntime::new_with_packer(g, 0.85, 4, ShardMap::Modulo, Packer::Worker);
        let mut rng = Rng::seeded(10);
        rt.run(60_000, 8, &mut rng);
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn conflicts_counted_on_dense_graphs() {
        let g = generators::er_threshold(60, 0.5, 2004);
        let mut rt = ShardedRuntime::new(g, 0.85, 2);
        let mut rng = Rng::seeded(11);
        rt.run(50, 16, &mut rng);
        assert!(rt.conflicts() > 0, "dense graphs must produce packing conflicts");
    }

    #[test]
    fn worker_packing_counts_conflicts_on_dense_graphs() {
        // The thinned law survives the move into the workers: losing
        // claimants are counted, not silently dropped.
        let g = generators::er_threshold(60, 0.5, 2104);
        let mut rt =
            ShardedRuntime::new_with_packer(g, 0.85, 2, ShardMap::Modulo, Packer::Worker);
        let mut rng = Rng::seeded(12);
        rt.run(50, 16, &mut rng);
        assert!(rt.conflicts() > 0, "dense graphs must produce claim conflicts");
    }

    #[test]
    fn single_shard_single_candidate_equals_matrix_form() {
        use crate::algo::mp::MatchingPursuit;
        let g = generators::er_threshold(40, 0.5, 2005);
        // Both packers: 1 shard × batch 1 draws exactly one page per
        // super-step from the caller's stream (worker 0 clones it) and
        // never conflicts — bit-identical to the matrix form.
        for packer in [Packer::Leader, Packer::Worker] {
            let mut rt = ShardedRuntime::new_with_packer(
                g.clone(),
                0.85,
                1,
                ShardMap::Modulo,
                packer,
            );
            let mut rng1 = Rng::seeded(13);
            rt.run(500, 1, &mut rng1);
            // Matrix form replaying the same sampler stream.
            let mut mp = MatchingPursuit::new(&g, 0.85);
            let mut rng2 = Rng::seeded(13);
            for _ in 0..500 {
                let k = rng2.below(40);
                mp.step_at(k);
            }
            assert!(
                vector::dist_inf(
                    &rt.estimate(),
                    &crate::algo::common::PageRankSolver::estimate(&mp)
                ) < 1e-13,
                "{packer:?} packer diverged from the matrix form"
            );
            assert_eq!(rt.activations(), 500, "{packer:?}: one activation per super-step");
            assert_eq!(rt.conflicts(), 0, "{packer:?}: a single candidate can never conflict");
        }
    }

    #[test]
    fn all_leader_packed_maps_give_identical_results() {
        // Ownership only routes under leader packing; disjoint supports
        // make the math placement-invariant — for the table-backed maps
        // exactly as for the closed forms.
        let g = generators::erdos_renyi(300, 0.01, 2006);
        let run = |map: ShardMap| {
            let mut rt = ShardedRuntime::new_with_map(g.clone(), 0.85, 4, map);
            let mut rng = Rng::seeded(21);
            rt.run(150, 8, &mut rng);
            (rt.estimate(), rt.residual(), rt.activations())
        };
        let (xm, rm, am) = run(ShardMap::Modulo);
        for map in [ShardMap::Block, ShardMap::Cluster, ShardMap::Scc] {
            let (xb, rb, ab) = run(map);
            assert_eq!(am, ab, "{map:?}: same rng stream must pack the same batches");
            assert!(vector::dist_inf(&xm, &xb) < 1e-13, "{map:?} estimates diverged");
            assert!(vector::dist_inf(&rm, &rb) < 1e-13, "{map:?} residuals diverged");
        }
    }

    #[test]
    fn worker_packing_is_deterministic_across_runs() {
        // The priority claim resolution is commutative, so the survivor
        // set — and with it every counter and the estimate — is a pure
        // function of the seed, independent of thread scheduling.
        let g = generators::er_threshold(80, 0.3, 2007);
        let run = || {
            let mut rt = ShardedRuntime::new_with_packer(
                g.clone(),
                0.85,
                4,
                ShardMap::Modulo,
                Packer::Worker,
            );
            let mut rng = Rng::seeded(31);
            rt.run(200, 16, &mut rng);
            (
                rt.estimate(),
                rt.activations(),
                rt.conflicts(),
                rt.logical_reads(),
                rt.logical_writes(),
            )
        };
        let (xa, aa, ca, ra, wa) = run();
        let (xb, ab, cb, rb, wb) = run();
        assert_eq!(xa, xb, "estimates must be bit-identical across runs");
        assert_eq!(aa, ab);
        assert_eq!(ca, cb);
        assert_eq!(ra, rb);
        assert_eq!(wa, wb);
        assert_eq!(ra, wa, "§II-D: every read pairs with a write");
        assert!(ca > 0, "a dense-ish graph at budget 16 must see claim conflicts");
    }

    #[test]
    fn shard_map_owners_in_range_and_round_trip() {
        for (n, shards) in [(5usize, 8usize), (100, 4), (101, 4), (1, 1)] {
            for map in [ShardMap::Modulo, ShardMap::Block] {
                for k in 0..n {
                    let w = map.owner(k, n, shards);
                    assert!(w < shards, "{map:?} owner({k}, {n}, {shards}) = {w}");
                }
                assert_eq!(ShardMap::parse(map.key()), Ok(map));
            }
        }
        assert_eq!(ShardMap::parse("cluster"), Ok(ShardMap::Cluster));
        assert_eq!(ShardMap::parse("scc"), Ok(ShardMap::Scc));
        let err = ShardMap::parse("diagonal").unwrap_err();
        assert!(
            err.contains("mod|block|cluster|scc") && err.contains("diagonal"),
            "unknown maps must name the valid set: {err}"
        );
        assert_eq!(Packer::parse("leader"), Some(Packer::Leader));
        assert_eq!(Packer::parse("worker"), Some(Packer::Worker));
        assert_eq!(Packer::parse("boss"), None);
    }

    #[test]
    fn owned_pages_partition_the_graph() {
        // owner / owned_count / owned_page must agree: the owned pages of
        // all shards tile [0, n) exactly, under both maps, including the
        // shards > n and non-divisible cases. local_index must invert
        // owned_page.
        for (n, shards) in [(5usize, 8usize), (100, 4), (101, 4), (1, 1), (30, 7)] {
            for map in [ShardMap::Modulo, ShardMap::Block] {
                let mut seen = vec![false; n];
                for w in 0..shards {
                    let count = map.owned_count(w, n, shards);
                    for i in 0..count {
                        let k = map.owned_page(w, i, n, shards);
                        assert!(k < n, "{map:?} owned_page({w},{i},{n},{shards}) = {k}");
                        assert_eq!(map.owner(k, n, shards), w, "{map:?} owner mismatch");
                        assert_eq!(
                            map.local_index(k, n, shards),
                            i,
                            "{map:?} local_index must invert owned_page"
                        );
                        assert!(!seen[k], "{map:?} page {k} owned twice");
                        seen[k] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "{map:?} ({n},{shards}) pages unowned");
            }
        }
    }

    #[test]
    fn resolved_table_maps_satisfy_the_partition_contract() {
        // The table-backed maps must honour the exact contract the
        // closed forms do: every page owned exactly once, owned_page
        // ascending, local_index inverting it.
        let g = generators::sbm_two_block(40, 0.3, 0.05, 9);
        for map in [ShardMap::Cluster, ShardMap::Scc] {
            assert!(map.table_backed());
            for shards in [1usize, 3] {
                let rm = map.resolve(&g, shards);
                assert_eq!(rm.shards(), shards);
                let mut seen = vec![false; 40];
                for w in 0..shards {
                    let mut prev: Option<usize> = None;
                    for i in 0..rm.owned_count(w) {
                        let k = rm.owned_page(w, i);
                        assert_eq!(rm.owner(k), w, "{map:?} owner mismatch");
                        assert_eq!(rm.local_index(k), i, "{map:?} local_index mismatch");
                        assert!(!seen[k], "{map:?} page {k} owned twice");
                        seen[k] = true;
                        if let Some(p) = prev {
                            assert!(k > p, "{map:?} pages not ascending in shard {w}");
                        }
                        prev = Some(k);
                    }
                }
                assert!(seen.iter().all(|&s| s), "{map:?} ({shards}) pages unowned");
            }
        }
    }

    #[test]
    #[should_panic(expected = "table-backed")]
    fn table_backed_maps_have_no_closed_form() {
        ShardMap::Cluster.owner(0, 10, 2);
    }

    #[test]
    fn table_maps_converge_and_replay_under_worker_packing() {
        // A table-backed candidate pool is still a per-shard uniform law
        // over owned pages: the runtime must reach the exact fixed point
        // and stay bit-deterministic across runs.
        let g = generators::sbm_two_block(60, 0.3, 0.05, 2301);
        let x_star = exact_pagerank(&g, 0.85);
        let run = |map: ShardMap| {
            let mut rt =
                ShardedRuntime::new_with_packer(g.clone(), 0.85, 4, map, Packer::Worker);
            let mut rng = Rng::seeded(33);
            rt.run(30_000, 8, &mut rng);
            (rt.estimate(), rt.activations(), rt.conflicts(), rt.cross_conflicts())
        };
        for map in [ShardMap::Cluster, ShardMap::Scc] {
            let (xa, aa, ca, xca) = run(map);
            let (xb, ab, cb, xcb) = run(map);
            assert_eq!(xa, xb, "{map:?} must replay bit-identically");
            assert_eq!((aa, ca, xca), (ab, cb, xcb), "{map:?} counters must replay");
            assert!(xca <= ca, "{map:?}: cross conflicts are a subset");
            let err = vector::dist_inf(&xa, &x_star);
            assert!(err < 1e-6, "{map:?}: err={err}");
        }
    }

    #[test]
    fn worker_packing_splits_conflicts_by_claiming_shard() {
        // Modulo on a dense graph interleaves neighbourhoods across
        // shards, so some rejections must be lost to remote claims; the
        // split partitions the total and the ledger mirrors it.
        let g = generators::er_threshold(60, 0.5, 2404);
        let mut rt =
            ShardedRuntime::new_with_packer(g, 0.85, 4, ShardMap::Modulo, Packer::Worker);
        let mut rng = Rng::seeded(34);
        rt.run(100, 16, &mut rng);
        assert!(rt.conflicts() > 0);
        assert!(rt.cross_conflicts() > 0, "dense modulo runs must lose claims remotely");
        assert!(rt.cross_conflicts() <= rt.conflicts());
        let loc = rt.locality();
        assert_eq!(loc.intra_conflicts + loc.cross_conflicts, rt.conflicts());
        assert!(loc.cross_edge_fraction > 0.0);
        assert!(loc.any());
    }

    #[test]
    fn leader_packing_reports_the_gauge_but_no_split() {
        // The serial mark scan cannot attribute a rejection to a shard:
        // the split stays zero while the static gauge is still reported.
        let g = generators::er_threshold(40, 0.5, 2405);
        let mut rt = ShardedRuntime::new(g, 0.85, 2);
        let mut rng = Rng::seeded(35);
        rt.run(50, 8, &mut rng);
        assert!(rt.conflicts() > 0);
        let loc = rt.locality();
        assert_eq!(loc.intra_conflicts, 0);
        assert_eq!(loc.cross_conflicts, 0);
        assert!(loc.cross_edge_fraction > 0.0);
    }

    #[test]
    fn single_shard_runs_record_no_locality() {
        // Gates the report fields: one shard means no boundary to cross,
        // so the historical JSON shape must not change.
        let g = generators::er_threshold(30, 0.5, 2406);
        let mut rt =
            ShardedRuntime::new_with_packer(g, 0.85, 1, ShardMap::Cluster, Packer::Worker);
        let mut rng = Rng::seeded(36);
        rt.run(50, 4, &mut rng);
        assert!(!rt.locality().any());
        assert_eq!(rt.cross_edge_fraction(), 0.0);
    }

    #[test]
    fn locality_counters_absorb_sums_counts_and_maxes_the_gauge() {
        let mut a = LocalityCounters {
            intra_conflicts: 1,
            cross_conflicts: 2,
            cross_edge_fraction: 0.5,
            cross_messages: 3,
            cross_bytes: 48,
            subscriber_shard_sum: 4,
        };
        let b = LocalityCounters {
            intra_conflicts: 10,
            cross_conflicts: 20,
            cross_edge_fraction: 0.25,
            cross_messages: 30,
            cross_bytes: 480,
            subscriber_shard_sum: 40,
        };
        a.absorb(&b);
        assert_eq!(a.intra_conflicts, 11);
        assert_eq!(a.cross_conflicts, 22);
        assert_eq!(a.cross_edge_fraction, 0.5, "gauge maxes, not sums");
        assert_eq!(a.cross_messages, 33);
        assert_eq!(a.cross_bytes, 528);
        assert_eq!(a.subscriber_shard_sum, 44);
        assert!(a.any());
        assert!(!LocalityCounters::default().any());
    }

    #[test]
    fn residual_sampling_converges_under_both_packers() {
        // The floor keeps every page's candidate probability positive,
        // so residual-weighted packing reaches the same fixed point —
        // including across shard boundaries (cross-shard residual writes
        // must reach the owners' weight trees).
        let g = generators::erdos_renyi(150, 0.03, 2203);
        let x_star = exact_pagerank(&g, 0.85);
        for packer in [Packer::Leader, Packer::Worker] {
            let mut rt = ShardedRuntime::new_with_sampling(
                g.clone(),
                0.85,
                4,
                ShardMap::Modulo,
                packer,
                Sampling::Residual,
            );
            let mut rng = Rng::seeded(24);
            rt.run(60_000, 8, &mut rng);
            let err = vector::dist_inf(&rt.estimate(), &x_star);
            assert!(err < 1e-6, "{packer:?}: err={err}");
            assert_eq!(rt.sampling(), Sampling::Residual);
        }
    }

    #[test]
    fn residual_sampling_conserves_eq_11() {
        // B·x + r = (1-α)·1 must survive weighted candidate selection:
        // the weights only choose *who* activates, never the arithmetic.
        let g = generators::erdos_renyi(300, 0.01, 2204);
        let alpha = 0.85;
        for packer in [Packer::Leader, Packer::Worker] {
            let mut rt = ShardedRuntime::new_with_sampling(
                g.clone(),
                alpha,
                4,
                ShardMap::Modulo,
                packer,
                Sampling::Residual,
            );
            let mut rng = Rng::seeded(25);
            rt.run(200, 16, &mut rng);
            assert!(rt.activations() > 0, "{packer:?}");
            let b = DenseMatrix::b_matrix(&g, alpha);
            let bx = b.matvec(&rt.estimate());
            for (i, (v, r)) in bx.iter().zip(rt.residual()).enumerate() {
                assert!(
                    (v + r - (1.0 - alpha)).abs() < 1e-10,
                    "{packer:?}: conservation broken at page {i}"
                );
            }
        }
    }

    #[test]
    fn worker_residual_sampling_is_deterministic_across_runs() {
        // The weight-refresh phase applies updates in ascending page
        // order, so the per-shard Fenwick trees — and every draw they
        // produce — are a pure function of the seed, independent of
        // winner-publication timing.
        let g = generators::er_threshold(80, 0.3, 2207);
        let run = || {
            let mut rt = ShardedRuntime::new_with_sampling(
                g.clone(),
                0.85,
                4,
                ShardMap::Modulo,
                Packer::Worker,
                Sampling::Residual,
            );
            let mut rng = Rng::seeded(32);
            rt.run(200, 16, &mut rng);
            (
                rt.estimate(),
                rt.activations(),
                rt.conflicts(),
                rt.logical_reads(),
                rt.logical_writes(),
            )
        };
        let (xa, aa, ca, ra, wa) = run();
        let (xb, ab, cb, rb, wb) = run();
        assert_eq!(xa, xb, "estimates must be bit-identical across runs");
        assert_eq!((aa, ca, ra, wa), (ab, cb, rb, wb), "counters must replay");
        assert!(ca > 0, "a dense-ish graph at budget 16 must see claim conflicts");
    }

    #[test]
    fn residual_sampling_handles_dangling_pages() {
        // A sink's residual support is itself (implicit self-loop); its
        // weight must still refresh and the run stay finite.
        for packer in [Packer::Leader, Packer::Worker] {
            let g = generators::chain(30);
            let x_star = exact_pagerank(&g, 0.85);
            let mut rt = ShardedRuntime::new_with_sampling(
                g,
                0.85,
                3,
                ShardMap::Modulo,
                packer,
                Sampling::Residual,
            );
            let mut rng = Rng::seeded(26);
            rt.run(40_000, 4, &mut rng);
            for (i, r) in rt.residual().into_iter().enumerate() {
                assert!(r.is_finite(), "{packer:?}: residual at page {i} poisoned: {r}");
            }
            let err = vector::dist_inf(&rt.estimate(), &x_star);
            assert!(err < 1e-6, "{packer:?}: err={err}");
        }
    }

    #[test]
    fn sampling_registry_round_trips() {
        assert_eq!(Sampling::parse("uniform"), Some(Sampling::Uniform));
        assert_eq!(Sampling::parse("residual"), Some(Sampling::Residual));
        assert_eq!(Sampling::parse("importance"), None);
        for s in [Sampling::Uniform, Sampling::Residual] {
            assert_eq!(Sampling::parse(s.key()), Some(s));
        }
    }

    #[test]
    fn dangling_node_runs_to_convergence_with_finite_residuals() {
        // Regression: activate() used to compute α/out_degree with no
        // guard, so any sink page produced NaN/inf residuals. Both
        // packers must route through the shared BColumns guard.
        for packer in [Packer::Leader, Packer::Worker] {
            let g = generators::chain(30); // page 29 is a genuine sink
            assert_eq!(g.dangling(), vec![29]);
            let x_star = exact_pagerank(&g, 0.85);
            let mut rt = ShardedRuntime::new_with_packer(g, 0.85, 3, ShardMap::Modulo, packer);
            let mut rng = Rng::seeded(23);
            rt.run(40_000, 4, &mut rng);
            for (i, r) in rt.residual().into_iter().enumerate() {
                assert!(r.is_finite(), "{packer:?}: residual at page {i} poisoned: {r}");
            }
            let err = vector::dist_inf(&rt.estimate(), &x_star);
            assert!(err < 1e-6, "{packer:?}: err={err}");
        }
    }

    #[test]
    fn read_write_counters_match_matrix_form_accounting() {
        let g = generators::er_threshold(50, 0.5, 2007);
        let mut rt = ShardedRuntime::new(g.clone(), 0.85, 2);
        let mut rng = Rng::seeded(25);
        rt.run(100, 4, &mut rng);
        assert!(rt.activations() > 0);
        // §II-D: exactly N_k reads and N_k writes per activation; the
        // sums must agree and be plausible for the dense paper graph.
        assert_eq!(rt.logical_reads(), rt.logical_writes());
        assert!(rt.logical_reads() >= rt.activations(), "dense pages read >= 1 each");
    }

    #[test]
    fn shards_survive_empty_batches() {
        // star graph: hub conflicts with everything; batch budget 4 packs
        // at most 1 activation, sometimes 0 after dedup. Both packers
        // must keep cycling through (near-)empty super-steps.
        for packer in [Packer::Leader, Packer::Worker] {
            let g = generators::star(20);
            let mut rt = ShardedRuntime::new_with_packer(g, 0.85, 3, ShardMap::Modulo, packer);
            let mut rng = Rng::seeded(17);
            let applied = rt.run(200, 4, &mut rng);
            assert!(applied > 0, "{packer:?}");
            assert_eq!(rt.activations(), applied, "{packer:?}");
        }
    }

    #[test]
    fn worker_packing_with_more_shards_than_pages() {
        // Degenerate split: some workers own zero pages and zero-share
        // super-steps; the barrier must still cycle and the runtime
        // still converge on the pages that exist.
        let g = generators::er_threshold(5, 0.5, 2009);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rt =
            ShardedRuntime::new_with_packer(g, 0.85, 8, ShardMap::Block, Packer::Worker);
        let mut rng = Rng::seeded(19);
        rt.run(20_000, 8, &mut rng);
        let err = vector::dist_inf(&rt.estimate(), &x_star);
        assert!(err < 1e-6, "err={err}");
    }

    #[test]
    fn claim_words_rank_generation_over_priority() {
        // A fresh generation must outrank any stale stamp, and within a
        // generation a smaller claim id must win fetch_max.
        let newer = claim_word(7, 0);
        let older_best = claim_word(6, 0);
        assert!(newer > older_best, "new generations must beat stale claims");
        assert!(
            claim_word(7, 3) > claim_word(7, 12),
            "earlier candidates (smaller ids) must win within a generation"
        );
    }
}
