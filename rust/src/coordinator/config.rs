//! Coordinator configuration.

use crate::network::LatencyModel;

use super::sampler::SamplerKind;

/// Execution mode of the distributed runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Activations strictly serialized — Algorithm 1's sequential
    /// semantics; equivalent to the matrix form.
    Sequential,
    /// Independent exponential clocks (paper Remark 1); conflict-free
    /// overlap allowed, conflicting activations deferred.
    Async,
}

/// Full configuration of a coordinator run.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub alpha: f64,
    pub mode: Mode,
    pub sampler: SamplerKind,
    pub latency: LatencyModel,
    /// RNG seed (sampler and latency streams are forked from it).
    pub seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            alpha: crate::DEFAULT_ALPHA,
            mode: Mode::Sequential,
            sampler: SamplerKind::Uniform,
            latency: LatencyModel::Zero,
            seed: 0,
        }
    }
}

impl CoordinatorConfig {
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_sampler(mut self, sampler: SamplerKind) -> Self {
        self.sampler = sampler;
        self
    }

    pub fn with_latency(mut self, latency: LatencyModel) -> Self {
        self.latency = latency;
        self
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha in (0,1)");
        self.alpha = alpha;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chain() {
        let c = CoordinatorConfig::default()
            .with_seed(9)
            .with_mode(Mode::Async)
            .with_alpha(0.7)
            .with_latency(LatencyModel::Constant(0.5));
        assert_eq!(c.seed, 9);
        assert_eq!(c.mode, Mode::Async);
        assert_eq!(c.alpha, 0.7);
        assert_eq!(c.latency, LatencyModel::Constant(0.5));
    }

    #[test]
    #[should_panic]
    fn alpha_validated() {
        CoordinatorConfig::default().with_alpha(1.0);
    }
}
