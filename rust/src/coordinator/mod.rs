//! The distributed runtime: page agents + activation sampling + the
//! message protocol of the paper's §II-D, executed over the simulated
//! network of [`crate::network`].
//!
//! One **activation** of page `k` is the full §II-D exchange:
//!
//! ```text
//!   leader clock fires k            (sampler: uniform / exp-clocks / weighted)
//!   k -> out(k):  ReadRequest        (N_k messages)
//!   out(k) -> k:  ReadReply(r_j)     (N_k messages)
//!   k computes    coef = B(:,k)ᵀr / ‖B(:,k)‖²   (local constants only)
//!   k updates     x_k += coef, r_k -= coef
//!   k -> out(k):  WriteDelta(+coef·α/N_k)        (N_k messages)
//! ```
//!
//! exactly `N_k` reads and `N_k` writes, which [`metrics`] verifies at
//! run time. Two execution modes:
//!
//! * **Sequential** — activations are serialized (the paper's Algorithm 1
//!   semantics); with zero latency this is bit-equivalent to the
//!   matrix-form [`crate::algo::mp::MatchingPursuit`] (tested).
//! * **Async** — pages fire on independent exponential clocks (Remark 1 /
//!   \[16\]); overlapping activations with disjoint column supports
//!   proceed concurrently (they commute — see
//!   [`crate::algo::parallel_mp`]), conflicting ones are deferred and
//!   retried, and the achieved overlap is reported.

//!
//! A third execution model lives in [`msgpass`]: shards (not pages) as
//! the unit of distribution, communicating *only* by metered messages
//! over [`crate::network::transport`] — residual-update fan-out plus
//! weight-summary gossip — so the wire cost of the algorithm (messages,
//! bytes, queue depths, virtual time) is measured rather than idealized.

pub mod agents;
pub mod config;
pub mod leader;
pub mod messages;
pub mod metrics;
pub mod msgpass;
pub mod sampler;
pub mod sharded;

pub use config::{CoordinatorConfig, Mode};
pub use leader::{Coordinator, RunReport};
pub use msgpass::{MsgpassConfig, MsgpassRuntime};
pub use sampler::SamplerKind;
pub use sharded::{LocalityCounters, Packer, ResolvedMap, Sampling, ShardMap, ShardedRuntime};
