//! Page agents: the two-scalar-per-page state of the paper plus the
//! local constants of Remark 3.
//!
//! Agents are deliberately *dumb*: they hold state and answer the three
//! §II-D message types; the leader owns scheduling. This mirrors the
//! paper's storage claim — "it only requires storing two scalar values
//! per webpage" (`x_k`, `r_k`); `‖B(:,k)‖²` and `1/N_k` are the
//! preprocessing constants of Remark 3.

use crate::graph::Graph;

/// In-progress activation bookkeeping at the activated page.
#[derive(Debug, Clone, PartialEq)]
pub struct PendingActivation {
    pub activation: u64,
    /// Sum of out-neighbour residuals received so far.
    pub acc: f64,
    pub replies_left: usize,
}

/// One page's local state.
#[derive(Debug, Clone)]
pub struct PageAgent {
    pub id: u32,
    /// PageRank estimate x_k (paper scalar #1).
    pub x: f64,
    /// Residual r_k (paper scalar #2).
    pub r: f64,
    /// Remark-3 constant ‖B(:,k)‖².
    pub norm_sq: f64,
    /// 1/N_k.
    pub inv_deg: f64,
    /// Whether the page links to itself (A_kk = 1/N_k).
    pub self_loop: bool,
    /// Outstanding activation, if this page is currently activated.
    pub pending: Option<PendingActivation>,
}

impl PageAgent {
    /// Build the agent fleet for a graph (the preprocessing step).
    pub fn fleet(graph: &Graph, alpha: f64) -> Vec<PageAgent> {
        let cols = crate::linalg::sparse::BColumns::new(graph, alpha);
        (0..graph.n())
            .map(|k| PageAgent {
                id: k as u32,
                x: 0.0,
                r: 1.0 - alpha, // r_0 = y = (1-α)𝟙
                norm_sq: cols.norm_sq(k),
                inv_deg: 1.0 / graph.out_degree(k) as f64,
                self_loop: graph.has_self_loop(k),
                pending: None,
            })
            .collect()
    }

    /// Begin an activation: returns the number of read requests to issue.
    pub fn begin_activation(&mut self, activation: u64, out_degree: usize) {
        assert!(self.pending.is_none(), "page {} already active", self.id);
        self.pending = Some(PendingActivation {
            activation,
            acc: 0.0,
            replies_left: out_degree,
        });
    }

    /// Record one read reply; returns `Some(coef)` when all replies are in
    /// and the projection coefficient is determined (paper eq. 13).
    pub fn on_read_reply(&mut self, activation: u64, r_value: f64, alpha: f64) -> Option<f64> {
        let p = self.pending.as_mut().expect("reply without activation");
        debug_assert_eq!(p.activation, activation, "cross-activation reply");
        p.acc += r_value;
        p.replies_left -= 1;
        if p.replies_left > 0 {
            return None;
        }
        // B(:,k)ᵀ r = r_k - (α/N_k) Σ_{j∈out(k)} r_j  (§II-D numerator)
        let num = self.r - alpha * self.inv_deg * p.acc;
        let coef = num / self.norm_sq;
        Some(coef)
    }

    /// Apply the local part of the update (eq. 7 for x_k; the diagonal
    /// component of eq. 8 for r_k) and clear the pending state. The
    /// out-neighbour deltas are returned for the leader to route; the
    /// self-loop component is applied locally here.
    pub fn finish_activation(&mut self, coef: f64, alpha: f64) -> f64 {
        debug_assert!(self.pending.is_some());
        self.x += coef;
        self.r -= coef;
        if self.self_loop {
            // page k ∈ out(k): its own WriteDelta short-circuits locally
            self.r += coef * alpha * self.inv_deg;
        }
        self.pending = None;
        // delta each out-neighbour must apply (j != k handled via messages)
        coef * alpha * self.inv_deg
    }

    /// Handle an incoming residual write.
    pub fn on_write_delta(&mut self, delta: f64) {
        self.r += delta;
    }

    pub fn is_active(&self) -> bool {
        self.pending.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn fleet_initial_state() {
        let g = generators::er_threshold(20, 0.5, 141);
        let agents = PageAgent::fleet(&g, 0.85);
        assert_eq!(agents.len(), 20);
        for (k, a) in agents.iter().enumerate() {
            assert_eq!(a.id as usize, k);
            assert_eq!(a.x, 0.0);
            assert!((a.r - 0.15).abs() < 1e-15);
            assert!((a.inv_deg - 1.0 / g.out_degree(k) as f64).abs() < 1e-15);
            assert!(!a.is_active());
        }
    }

    #[test]
    fn activation_protocol_matches_matrix_form() {
        // Drive the agent protocol by hand for one activation and compare
        // against BColumns arithmetic.
        let g = generators::er_threshold(15, 0.5, 142);
        let alpha = 0.85;
        let mut agents = PageAgent::fleet(&g, alpha);
        let cols = crate::linalg::sparse::BColumns::new(&g, alpha);
        let r0: Vec<f64> = agents.iter().map(|a| a.r).collect();
        let k = 3usize;
        let deg = g.out_degree(k);
        agents[k].begin_activation(0, deg);
        assert!(agents[k].is_active());
        // feed replies
        let mut coef = None;
        for &j in g.out(k) {
            let rv = agents[j as usize].r;
            coef = agents[k].on_read_reply(0, rv, alpha);
        }
        let coef = coef.expect("all replies in");
        let want_coef = cols.coefficient(&g, k, &r0);
        assert!((coef - want_coef).abs() < 1e-14);
        // apply local + remote updates
        let delta = agents[k].finish_activation(coef, alpha);
        for &j in g.out(k) {
            if j as usize != k {
                agents[j as usize].on_write_delta(delta);
            }
        }
        // compare against the matrix-form residual update
        let mut want_r = r0.clone();
        cols.sub_scaled_col(&g, k, want_coef, &mut want_r);
        for i in 0..g.n() {
            assert!(
                (agents[i].r - want_r[i]).abs() < 1e-14,
                "residual mismatch at page {i}"
            );
        }
        assert!((agents[k].x - want_coef).abs() < 1e-15);
        assert!(!agents[k].is_active());
    }

    #[test]
    fn self_loop_short_circuit() {
        let mut b = crate::graph::GraphBuilder::new(3)
            .dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 0).add_edge(0, 1).add_edge(1, 0).add_edge(2, 0);
        let g = b.build().expect("builds");
        assert!(g.has_self_loop(0));
        let alpha = 0.85;
        let mut agents = PageAgent::fleet(&g, alpha);
        let cols = crate::linalg::sparse::BColumns::new(&g, alpha);
        let r0: Vec<f64> = agents.iter().map(|a| a.r).collect();
        let k = 0usize;
        agents[k].begin_activation(7, g.out_degree(k));
        let mut coef = None;
        for &j in g.out(k) {
            let rv = agents[j as usize].r;
            coef = agents[k].on_read_reply(7, rv, alpha);
        }
        let coef = coef.expect("done");
        let delta = agents[k].finish_activation(coef, alpha);
        for &j in g.out(k) {
            if j as usize != k {
                agents[j as usize].on_write_delta(delta);
            }
        }
        let mut want_r = r0;
        cols.sub_scaled_col(&g, k, cols.coefficient(&g, k, &want_r.clone()), &mut want_r);
        for i in 0..3 {
            assert!((agents[i].r - want_r[i]).abs() < 1e-14, "page {i}");
        }
    }

    #[test]
    #[should_panic]
    fn double_activation_panics_in_debug() {
        let g = generators::ring(3);
        let mut agents = PageAgent::fleet(&g, 0.85);
        agents[0].begin_activation(0, 1);
        agents[0].begin_activation(1, 1);
    }
}
