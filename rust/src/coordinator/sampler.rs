//! Activation samplers.
//!
//! * [`SamplerKind::Uniform`] — the paper's `U[1, N]` draw.
//! * [`SamplerKind::ExponentialClocks`] — Remark 1 / \[16\]: every page
//!   carries an independent rate-1 exponential clock; the sequence of
//!   firing pages is i.i.d. uniform (tested), but firing *times* are
//!   physical, enabling the async overlap analysis.
//! * [`SamplerKind::ResidualWeighted`] — §IV future-work 3: sample page
//!   `k` proportionally to `r_k²` (an idealized importance sampler; a
//!   real deployment would gossip weight summaries). Implemented with
//!   the shared Fenwick [`WeightTree`] for O(log N) updates/draws.
//!
//! The [`WeightTree`] itself lives in [`crate::linalg::select`] (the
//! indexed selection engine) so the matrix-form `mp:residual` solver and
//! the sharded runtime's per-shard samplers share one implementation —
//! re-exported here for the existing `coordinator::sampler` path.

use crate::network::events::EventQueue;
use crate::util::rng::Rng;

pub use crate::linalg::select::WeightTree;

/// Which sampling strategy the coordinator uses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SamplerKind {
    Uniform,
    ExponentialClocks,
    /// Weight each page by `max(r_k², floor)`; `floor > 0` keeps the
    /// chain irreducible (every page retains positive probability).
    ResidualWeighted { floor: f64 },
}

/// A sampler instance: produces `(fire_time, page)` pairs.
#[derive(Debug)]
pub enum Sampler {
    Uniform {
        n: usize,
    },
    ExponentialClocks {
        clocks: EventQueue<usize>,
    },
    ResidualWeighted {
        tree: WeightTree,
        floor: f64,
    },
}

impl Sampler {
    /// Build; `initial_weights` seeds the residual-weighted tree (use
    /// `|r_0|² = (1-α)²` per page).
    pub fn new(kind: SamplerKind, n: usize, rng: &mut Rng) -> Sampler {
        match kind {
            SamplerKind::Uniform => Sampler::Uniform { n },
            SamplerKind::ExponentialClocks => {
                let mut clocks = EventQueue::new();
                for k in 0..n {
                    let t = rng.exponential(1.0);
                    clocks.schedule(t, k);
                }
                Sampler::ExponentialClocks { clocks }
            }
            SamplerKind::ResidualWeighted { floor } => Sampler::ResidualWeighted {
                tree: WeightTree::new(&vec![1.0; n]),
                floor,
            },
        }
    }

    /// Next activation: `(earliest allowed fire time, page)`. For
    /// Uniform/ResidualWeighted the fire time is `now` (the leader
    /// serializes or paces them); for clocks it is the clock's fire time.
    pub fn next(&mut self, now: f64, rng: &mut Rng) -> (f64, usize) {
        match self {
            Sampler::Uniform { n } => (now, rng.below(*n)),
            Sampler::ExponentialClocks { clocks } => {
                let ev = clocks.pop().expect("clocks never drain");
                let page = ev.event;
                let t = ev.time;
                // re-arm this page's clock
                let dt = rng.exponential(1.0);
                clocks.schedule(t + dt, page);
                (t.max(now), page)
            }
            Sampler::ResidualWeighted { tree, .. } => (now, tree.sample(rng)),
        }
    }

    /// Inform the sampler that page `k`'s residual changed.
    pub fn on_residual(&mut self, k: usize, r: f64) {
        if let Sampler::ResidualWeighted { tree, floor } = self {
            tree.update(k, (r * r).max(*floor));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_sampler_is_uniform() {
        let mut rng = Rng::seeded(152);
        let mut s = Sampler::new(SamplerKind::Uniform, 5, &mut rng);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            let (_, k) = s.next(0.0, &mut rng);
            counts[k] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn exponential_clocks_marginal_is_uniform() {
        // Remark 1: the firing-page sequence is i.i.d. U[1,N].
        let mut rng = Rng::seeded(153);
        let mut s = Sampler::new(SamplerKind::ExponentialClocks, 4, &mut rng);
        let mut counts = [0usize; 4];
        let mut last_t = 0.0;
        for _ in 0..40_000 {
            let (t, k) = s.next(last_t, &mut rng);
            assert!(t >= last_t);
            last_t = t;
            counts[k] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
        // inter-activation times average 1/N (superposition of N rate-1
        // Poisson processes is rate N)
        assert!((last_t / 40_000.0 - 0.25).abs() < 0.01, "mean gap {}", last_t / 40_000.0);
    }

    #[test]
    fn residual_weighted_follows_updates() {
        let mut rng = Rng::seeded(154);
        let mut s = Sampler::new(SamplerKind::ResidualWeighted { floor: 1e-12 }, 3, &mut rng);
        // Concentrate all residual mass on page 2.
        s.on_residual(0, 0.0);
        s.on_residual(1, 0.0);
        s.on_residual(2, 10.0);
        let mut counts = [0usize; 3];
        for _ in 0..1000 {
            let (_, k) = s.next(0.0, &mut rng);
            counts[k] += 1;
        }
        assert!(counts[2] > 990, "{counts:?}");
    }
}
