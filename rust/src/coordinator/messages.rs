//! Protocol messages exchanged between page agents.

/// A routed message.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub src: u32,
    pub dst: u32,
    pub payload: Payload,
}

/// Message payloads of the §II-D protocol.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Payload {
    /// Activated page asks an out-neighbour for its residual.
    ReadRequest { activation: u64 },
    /// Out-neighbour returns its residual value.
    ReadReply { activation: u64, r_value: f64 },
    /// Activated page pushes the residual update `r_dst += delta`.
    WriteDelta { activation: u64, delta: f64 },
}

impl Payload {
    /// Wire-size estimate in bytes (activation id + f64 payload + tag),
    /// used for traffic accounting.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::ReadRequest { .. } => 9,
            Payload::ReadReply { .. } | Payload::WriteDelta { .. } => 17,
        }
    }

    /// Whether this is a read-path message (request or reply).
    pub fn is_read(&self) -> bool {
        matches!(self, Payload::ReadRequest { .. } | Payload::ReadReply { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes() {
        assert_eq!(Payload::ReadRequest { activation: 1 }.wire_bytes(), 9);
        assert_eq!(
            Payload::ReadReply { activation: 1, r_value: 0.5 }.wire_bytes(),
            17
        );
        assert_eq!(
            Payload::WriteDelta { activation: 1, delta: 0.5 }.wire_bytes(),
            17
        );
    }

    #[test]
    fn read_classification() {
        assert!(Payload::ReadRequest { activation: 0 }.is_read());
        assert!(Payload::ReadReply { activation: 0, r_value: 0.0 }.is_read());
        assert!(!Payload::WriteDelta { activation: 0, delta: 0.0 }.is_read());
    }
}
