//! Run metrics: message counts by type, traffic bytes, deferral and
//! overlap accounting, and the per-activation read/write verification of
//! the paper's §II-D cost claim.

use super::messages::Payload;

/// Aggregated run metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    pub activations: u64,
    pub deferred: u64,
    pub read_requests: u64,
    pub read_replies: u64,
    pub write_deltas: u64,
    pub bytes: u64,
    /// Virtual time at which the run finished.
    pub makespan: f64,
    /// Max activations simultaneously in flight (async overlap).
    pub peak_overlap: u32,
    /// Σ over activations of (activation duration) — for mean latency.
    pub total_activation_time: f64,
}

impl Metrics {
    pub fn on_send(&mut self, payload: &Payload) {
        self.bytes += payload.wire_bytes() as u64;
        match payload {
            Payload::ReadRequest { .. } => self.read_requests += 1,
            Payload::ReadReply { .. } => self.read_replies += 1,
            Payload::WriteDelta { .. } => self.write_deltas += 1,
        }
    }

    /// Mean messages per activation.
    pub fn messages_per_activation(&self) -> f64 {
        if self.activations == 0 {
            return 0.0;
        }
        (self.read_requests + self.read_replies + self.write_deltas) as f64
            / self.activations as f64
    }

    /// The §II-D invariant: reads == writes == Σ N_k over activations.
    /// (ReadRequest and ReadReply both traverse the read path; the paper
    /// counts logical reads, i.e. request/reply pairs.)
    pub fn logical_reads(&self) -> u64 {
        debug_assert_eq!(self.read_requests, self.read_replies);
        self.read_requests
    }

    pub fn logical_writes(&self) -> u64 {
        self.write_deltas
    }

    /// Mean wall-clock (virtual) duration of an activation.
    pub fn mean_activation_time(&self) -> f64 {
        if self.activations == 0 {
            return 0.0;
        }
        self.total_activation_time / self.activations as f64
    }

    /// Activations per unit virtual time.
    pub fn activation_throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.activations as f64 / self.makespan
    }

    /// Human-readable summary block.
    pub fn render(&self) -> String {
        format!(
            "activations      {}\n\
             deferred         {}\n\
             reads            {} (requests) / {} (replies)\n\
             writes           {}\n\
             traffic          {} bytes\n\
             msgs/activation  {:.2}\n\
             makespan         {:.3} vt\n\
             peak overlap     {}\n\
             mean act. time   {:.4} vt",
            self.activations,
            self.deferred,
            self.read_requests,
            self.read_replies,
            self.write_deltas,
            self.bytes,
            self.messages_per_activation(),
            self.makespan,
            self.peak_overlap,
            self.mean_activation_time()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_by_type() {
        let mut m = Metrics::default();
        m.on_send(&Payload::ReadRequest { activation: 0 });
        m.on_send(&Payload::ReadReply { activation: 0, r_value: 1.0 });
        m.on_send(&Payload::WriteDelta { activation: 0, delta: 0.1 });
        assert_eq!(m.read_requests, 1);
        assert_eq!(m.read_replies, 1);
        assert_eq!(m.write_deltas, 1);
        assert_eq!(m.bytes, 9 + 17 + 17);
    }

    #[test]
    fn derived_quantities() {
        let m = Metrics {
            activations: 4,
            read_requests: 8,
            read_replies: 8,
            write_deltas: 8,
            makespan: 2.0,
            total_activation_time: 1.0,
            ..Default::default()
        };
        assert_eq!(m.messages_per_activation(), 6.0);
        assert_eq!(m.logical_reads(), 8);
        assert_eq!(m.logical_writes(), 8);
        assert_eq!(m.activation_throughput(), 2.0);
        assert_eq!(m.mean_activation_time(), 0.25);
    }

    #[test]
    fn render_contains_key_fields() {
        let m = Metrics { activations: 2, ..Default::default() };
        let txt = m.render();
        assert!(txt.contains("activations      2"));
        assert!(txt.contains("msgs/activation"));
    }

    #[test]
    fn zero_division_guards() {
        let m = Metrics::default();
        assert_eq!(m.messages_per_activation(), 0.0);
        assert_eq!(m.activation_throughput(), 0.0);
        assert_eq!(m.mean_activation_time(), 0.0);
    }
}
