//! # pagerank-mp
//!
//! A full reproduction of *“Fully distributed PageRank computation with
//! exponential convergence”* (Dai & Freris, 2017) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper reformulates (scaled) PageRank as the linear system
//! `(I - αA) x* = (1-α)𝟙` and solves it with a **randomized Matching
//! Pursuit**: at each step a uniformly random page `k` projects the global
//! residual onto the `k`-th column of `B = I - αA`, touching only the
//! out-neighbours of `k`. The residual contracts as
//! `E‖r_t‖² ≤ (1 - σ²(B̂)/N)^t ‖r_0‖²` — exponential in expectation.
//!
//! ## Layer map
//!
//! * [`graph`] — web-graph substrate: CSR storage, generators (including
//!   the paper's ER-threshold model), IO, SCC, degree statistics.
//! * [`linalg`] — dense/sparse linear algebra: hyperlink matrices,
//!   `B = I - αA` column ops, LU solve for the exact reference `x*`,
//!   symmetric eigensolver for the paper's predicted contraction rate.
//! * [`algo`] — Algorithm 1 (MP PageRank), Algorithm 2 (network size
//!   estimation), every baseline the paper compares against ([6] Ishii–
//!   Tempo, [15] You–Tempo–Qiu, [12] Lei–Chen, [9] Monte-Carlo walks,
//!   centralized power iteration) and the §IV future-work extensions
//!   (parallel activation, dynamic graphs, non-uniform sampling, stopping
//!   certification).
//! * [`coordinator`] — the distributed runtime: page agents holding the
//!   paper's two scalars per page, activation samplers (uniform /
//!   exponential clocks / residual-weighted), message protocol, metrics.
//! * [`network`] — deterministic discrete-event message network with
//!   latency models and congestion accounting (the simulated substrate —
//!   see DESIGN.md §6).
//! * [`runtime`] — PJRT executor loading the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) for the dense-batched engine.
//! * [`harness`] — experiment drivers that regenerate the paper's
//!   Figure 1 and Figure 2 plus the ablation studies, with CSV/ASCII
//!   reporting and a micro-bench harness.
//! * [`util`] — deterministic RNG, statistics, CLI parsing.
//!
//! ## Quickstart
//!
//! ```no_run
//! use pagerank_mp::graph::generators::er_threshold;
//! use pagerank_mp::algo::mp::MatchingPursuit;
//! use pagerank_mp::algo::PageRankSolver;
//! use pagerank_mp::util::rng::Rng;
//!
//! let graph = er_threshold(100, 0.5, 42);
//! let mut rng = Rng::seeded(7);
//! let mut mp = MatchingPursuit::new(&graph, 0.85);
//! for _ in 0..5_000 { mp.step(&mut rng); }
//! let x = mp.estimate();
//! println!("top page: {:?}", x.iter().cloned().fold(f64::MIN, f64::max));
//! ```

pub mod algo;
pub mod coordinator;
pub mod graph;
pub mod harness;
pub mod linalg;
pub mod network;
pub mod runtime;
pub mod util;

/// The damping factor suggested by Brin & Page and used throughout the
/// paper's experiments.
pub const DEFAULT_ALPHA: f64 = 0.85;
