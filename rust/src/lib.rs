//! # pagerank-mp
//!
//! A full reproduction of *“Fully distributed PageRank computation with
//! exponential convergence”* (Dai & Freris, 2017) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper reformulates (scaled) PageRank as the linear system
//! `(I - αA) x* = (1-α)𝟙` and solves it with a **randomized Matching
//! Pursuit**: at each step a uniformly random page `k` projects the global
//! residual onto the `k`-th column of `B = I - αA`, touching only the
//! out-neighbours of `k`. The residual contracts as
//! `E‖r_t‖² ≤ (1 - σ²(B̂)/N)^t ‖r_0‖²` — exponential in expectation.
//!
//! ## Layer map
//!
//! * [`graph`] — web-graph substrate: CSR storage, generators (including
//!   the paper's ER-threshold model), IO, SCC, degree statistics.
//! * [`linalg`] — dense/sparse linear algebra: hyperlink matrices,
//!   `B = I - αA` column ops, LU solve for the exact reference `x*`,
//!   symmetric eigensolver for the paper's predicted contraction rate.
//! * [`algo`] — Algorithm 1 (MP PageRank), Algorithm 2 (network size
//!   estimation), every baseline the paper compares against ([6] Ishii–
//!   Tempo, [15] You–Tempo–Qiu, [12] Lei–Chen, [9] Monte-Carlo walks,
//!   centralized power iteration) and the §IV future-work extensions
//!   (parallel activation, dynamic graphs, non-uniform sampling, stopping
//!   certification).
//! * [`coordinator`] — the distributed runtimes: page agents holding the
//!   paper's two scalars per page, activation samplers (uniform /
//!   exponential clocks / residual-weighted), message protocol, metrics;
//!   the multi-threaded `sharded` runtime; and the message-passing
//!   [`coordinator::msgpass`] backend, whose shards communicate *only*
//!   through the metered [`network`] transport.
//! * [`engine`] — the declarative experiment API: [`engine::SolverSpec`]
//!   (a string registry over every solver variant — including the
//!   multi-threaded `sharded:<W>` runtime and the `dense` backend — with
//!   one uniform factory), [`engine::EstimatorSpec`] (the same for
//!   Algorithm-2 size estimators), [`engine::GraphSpec`],
//!   [`engine::ExperimentSpec`] (PageRank race or size-estimation race),
//!   [`engine::Scenario`] (graph + experiment + shape as one
//!   JSON-round-trippable value whose `run()` yields trajectories, decay
//!   rates, communication totals and kind-specific metrics) and
//!   [`engine::Sweep`] (one scenario expanded over a parameter grid —
//!   including a `graph` axis over families — merged into
//!   `BENCH_sweep.json`). Every harness, bench, example and the CLI
//!   build on it — see docs/ENGINE.md.
//! * [`network`] — deterministic discrete-event message network with
//!   latency models, congestion accounting and the metered
//!   [`network::transport`] layer (message counts, bytes on the wire,
//!   queue depths) that carries every cross-shard message of the
//!   `msgpass:*` backend — load-bearing since the msgpass subsystem,
//!   not a decorative simulation.
//! * [`runtime`] — PJRT executor loading the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) for the dense-batched engine.
//! * [`harness`] — experiment drivers that regenerate the paper's
//!   Figure 1 and Figure 2 plus the ablation studies, with CSV/ASCII
//!   reporting and a micro-bench harness.
//! * [`util`] — deterministic RNG, statistics, CLI parsing, JSON, and
//!   the offline `anyhow`-style error shim.
//!
//! ## Quickstart
//!
//! Every algorithm, graph family and experiment shape is reachable
//! through one declarative entry point, [`engine::Scenario`]:
//!
//! ```no_run
//! use pagerank_mp::engine::{GraphSpec, Scenario, SolverSpec};
//!
//! // The paper's §III experiment: N=100 ER-threshold graph, Algorithm 1
//! // against two in-link baselines, 100 averaged rounds.
//! let scenario = Scenario::new("fig1", GraphSpec::ErThreshold { n: 100, threshold: 0.5 })
//!     .with_solvers(vec![SolverSpec::Mp, SolverSpec::YouTempoQiu, SolverSpec::IshiiTempo])
//!     .with_rounds(100);
//! let report = scenario.run().expect("scenario runs");
//! println!("{}", report.render());
//! for r in report.solver_reports() {
//!     println!("{:<16} rate/step {:.6}  final {:.3e}", r.spec.key(), r.decay_rate, r.final_error);
//! }
//! ```
//!
//! Scenarios are data: they round-trip through JSON
//! ([`engine::Scenario::to_json`] / [`engine::Scenario::from_json_str`]),
//! so new experiments ship as config —
//! `pagerank-mp run-scenario examples/fig1_scenario.json`. Solvers come
//! from a string registry (`SolverSpec::parse("mp")`,
//! `"coordinator:async:clocks:const:0.1"`, …; see
//! `pagerank-mp list-solvers`). For direct, low-level access to a single
//! solver, `SolverSpec::Mp.build(&graph, 0.85, seed)` returns a boxed
//! [`algo::PageRankSolver`] ready to `step`.

pub mod algo;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod harness;
pub mod linalg;
pub mod network;
pub mod runtime;
pub mod util;

/// The damping factor suggested by Brin & Page and used throughout the
/// paper's experiments.
pub const DEFAULT_ALPHA: f64 = 0.85;
