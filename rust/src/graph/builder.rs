//! Graph construction: edge accumulation, deduplication and dangling-page
//! handling.
//!
//! The paper assumes "without any loss of generality that there are no
//! dangling pages" (§I) — real crawls have them, so the builder makes the
//! repair policy explicit instead of silently assuming.

use super::csr::Graph;

/// What to do with pages that have no outgoing links.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DanglingPolicy {
    /// Refuse to build (the paper's assumption enforced).
    Error,
    /// Add a self-loop — keeps the repair local to the page.
    SelfLoop,
    /// Link the dangling page to every other page — the classical
    /// PageRank repair (uniform teleport column), used by the paper's
    /// experiment generator in our reading of §III.
    LinkAll,
}

/// Errors produced by [`GraphBuilder::build`].
#[derive(Debug, PartialEq, Eq)]
pub enum BuildError {
    /// A dangling page was found under [`DanglingPolicy::Error`].
    Dangling(usize),
    /// An edge endpoint exceeds the declared node count.
    EdgeOutOfRange { src: u32, dst: u32, n: usize },
}

impl std::fmt::Display for BuildError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BuildError::Dangling(k) => {
                write!(f, "page {k} has no outgoing links (DanglingPolicy::Error)")
            }
            BuildError::EdgeOutOfRange { src, dst, n } => {
                write!(f, "edge ({src},{dst}) out of range for n={n}")
            }
        }
    }
}

impl std::error::Error for BuildError {}

/// Accumulates edges, then produces an immutable [`Graph`].
#[derive(Debug, Clone)]
pub struct GraphBuilder {
    n: usize,
    edges: Vec<(u32, u32)>,
    allow_self_loops: bool,
    dangling: DanglingPolicy,
}

impl GraphBuilder {
    pub fn new(n: usize) -> GraphBuilder {
        GraphBuilder {
            n,
            edges: Vec::new(),
            allow_self_loops: true,
            dangling: DanglingPolicy::LinkAll,
        }
    }

    /// Set the dangling-page policy (default [`DanglingPolicy::LinkAll`]).
    pub fn dangling_policy(mut self, p: DanglingPolicy) -> Self {
        self.dangling = p;
        self
    }

    /// Whether self-loops are kept (default) or dropped on `add_edge`.
    pub fn allow_self_loops(mut self, allow: bool) -> Self {
        self.allow_self_loops = allow;
        self
    }

    /// Add a directed edge `src -> dst` ("src links to dst"). Duplicates
    /// are removed at build time.
    pub fn add_edge(&mut self, src: usize, dst: usize) -> &mut Self {
        if src == dst && !self.allow_self_loops {
            return self;
        }
        self.edges.push((src as u32, dst as u32));
        self
    }

    /// Bulk-add edges.
    pub fn extend<I: IntoIterator<Item = (usize, usize)>>(&mut self, it: I) -> &mut Self {
        for (s, d) in it {
            self.add_edge(s, d);
        }
        self
    }

    /// Number of (pre-dedup) edges currently accumulated.
    pub fn pending_edges(&self) -> usize {
        self.edges.len()
    }

    /// Finalize into a [`Graph`], applying dedup and the dangling policy.
    pub fn build(mut self) -> Result<Graph, BuildError> {
        for &(s, d) in &self.edges {
            if s as usize >= self.n || d as usize >= self.n {
                return Err(BuildError::EdgeOutOfRange { src: s, dst: d, n: self.n });
            }
        }
        self.edges.sort_unstable();
        self.edges.dedup();

        // Detect dangling pages on the deduped list.
        let mut has_out = vec![false; self.n];
        for &(s, _) in &self.edges {
            has_out[s as usize] = true;
        }
        let dangling: Vec<usize> = (0..self.n).filter(|&k| !has_out[k]).collect();
        if !dangling.is_empty() {
            match self.dangling {
                DanglingPolicy::Error => return Err(BuildError::Dangling(dangling[0])),
                DanglingPolicy::SelfLoop => {
                    for &k in &dangling {
                        self.edges.push((k as u32, k as u32));
                    }
                }
                DanglingPolicy::LinkAll => {
                    for &k in &dangling {
                        for d in 0..self.n {
                            if d != k {
                                self.edges.push((k as u32, d as u32));
                            }
                        }
                    }
                }
            }
            self.edges.sort_unstable();
            self.edges.dedup();
        }
        Ok(Graph::from_sorted_edges(self.n, &self.edges))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedup_and_sort() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(2, 0).add_edge(0, 1).add_edge(0, 1).add_edge(1, 2);
        let g = b.build().expect("builds");
        assert_eq!(g.m(), 3);
        assert_eq!(g.out(0), &[1]);
    }

    #[test]
    fn dangling_error_policy() {
        let mut b = GraphBuilder::new(3).dangling_policy(DanglingPolicy::Error);
        b.add_edge(0, 1).add_edge(1, 0);
        assert_eq!(b.build().unwrap_err(), BuildError::Dangling(2));
    }

    #[test]
    fn dangling_self_loop_policy() {
        let mut b = GraphBuilder::new(3).dangling_policy(DanglingPolicy::SelfLoop);
        b.add_edge(0, 1).add_edge(1, 0);
        let g = b.build().expect("builds");
        assert_eq!(g.out(2), &[2]);
        assert!(g.dangling().is_empty());
    }

    #[test]
    fn dangling_link_all_policy() {
        let mut b = GraphBuilder::new(4).dangling_policy(DanglingPolicy::LinkAll);
        b.add_edge(0, 1).add_edge(1, 0).add_edge(3, 0);
        let g = b.build().expect("builds");
        assert_eq!(g.out(2), &[0, 1, 3]); // everything but itself
        assert!(g.dangling().is_empty());
    }

    #[test]
    fn self_loops_dropped_when_disallowed() {
        let mut b = GraphBuilder::new(2).allow_self_loops(false);
        b.add_edge(0, 0).add_edge(0, 1).add_edge(1, 0);
        let g = b.build().expect("builds");
        assert!(!g.has_self_loop(0));
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn out_of_range_is_error() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 1);
        b.edges.push((0, 9));
        match b.build().unwrap_err() {
            BuildError::EdgeOutOfRange { dst, .. } => assert_eq!(dst, 9),
            e => panic!("wrong error {e:?}"),
        }
    }

    #[test]
    fn display_messages() {
        assert!(BuildError::Dangling(7).to_string().contains("page 7"));
        let e = BuildError::EdgeOutOfRange { src: 1, dst: 2, n: 2 };
        assert!(e.to_string().contains("(1,2)"));
    }
}
