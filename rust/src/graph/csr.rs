//! Compressed sparse row graph storage.
//!
//! [`Graph`] always stores the out-CSR (`out_offsets`/`out_targets`) —
//! the set `N_k` the paper's Algorithm 1 reads residuals from and writes
//! residuals to. The in-CSR (the transpose adjacency) is **lazy**: it is
//! built on the first [`Graph::inc`]/[`Graph::in_degree`] call and only
//! then occupies memory. Only the in-link baselines ([6], [12], [15])
//! and the msgpass subscriber precompute pull from incoming neighbours,
//! so the MP/sharded hot paths never pay the 2× graph memory — which is
//! what makes 10⁶–10⁷-page corpus graphs affordable.
//!
//! [`Graph::without_in_links`] additionally *disables* in-link queries:
//! any later `inc()` is a loud panic naming the misuse instead of a
//! silent rebuild, so corpus pipelines that promised "out-only memory"
//! can trust the bound. The engine refuses in-link solvers on such
//! graphs up front (`SolverSpec::needs_in_links`).
//!
//! Out-edges of each node are stored sorted; the structure is immutable
//! after construction (the dynamic-network extension rebuilds via
//! [`crate::graph::GraphBuilder`], mirroring the paper's §IV-2 future-work
//! discussion where topology changes are events, not steady state).

use std::sync::OnceLock;

/// The transpose adjacency, built on demand from the out-CSR.
#[derive(Debug, Clone)]
struct InCsr {
    offsets: Vec<usize>,
    sources: Vec<u32>,
}

/// An immutable directed graph with no dangling (zero out-degree) nodes
/// permitted at PageRank time (the builder repairs or rejects them).
#[derive(Debug, Clone)]
pub struct Graph {
    n: usize,
    out_offsets: Vec<usize>,
    out_targets: Vec<u32>,
    /// When false, in-link queries panic instead of lazily building the
    /// transpose — the corpus pipelines' memory guarantee.
    in_enabled: bool,
    in_csr: OnceLock<InCsr>,
}

/// Equality is over topology (n + out-CSR) only: the in-CSR is derived
/// data and whether it happens to be materialized is not part of the
/// graph's identity.
impl PartialEq for Graph {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n
            && self.out_offsets == other.out_offsets
            && self.out_targets == other.out_targets
    }
}

impl Eq for Graph {}

impl Graph {
    /// Build from a sorted, deduplicated edge list. Prefer
    /// [`crate::graph::GraphBuilder`]; this is the low-level constructor.
    ///
    /// `edges` are `(src, dst)` pairs meaning "src links to dst".
    pub fn from_sorted_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
        debug_assert!(edges.windows(2).all(|w| w[0] <= w[1]), "edges not sorted");
        let mut out_offsets = vec![0usize; n + 1];
        for &(s, d) in edges {
            assert!((s as usize) < n && (d as usize) < n, "edge ({s},{d}) out of range");
            out_offsets[s as usize + 1] += 1;
        }
        for i in 0..n {
            out_offsets[i + 1] += out_offsets[i];
        }
        let out_targets: Vec<u32> = edges.iter().map(|&(_, d)| d).collect();
        Graph::from_csr_parts(n, out_offsets, out_targets)
    }

    /// Assemble a graph directly from prebuilt CSR arrays — the zero-copy
    /// entry point for the streaming loader and the `.csrbin` cache.
    /// Each row of `out_targets` must be sorted and deduplicated.
    pub fn from_csr_parts(n: usize, out_offsets: Vec<usize>, out_targets: Vec<u32>) -> Graph {
        assert_eq!(out_offsets.len(), n + 1, "offsets must have n+1 entries");
        assert_eq!(
            *out_offsets.last().expect("n+1 >= 1 entries"),
            out_targets.len(),
            "last offset must equal the target count"
        );
        debug_assert_eq!(out_offsets[0], 0);
        debug_assert!(out_offsets.windows(2).all(|w| w[0] <= w[1]), "offsets not monotone");
        debug_assert!(out_targets.iter().all(|&d| (d as usize) < n), "target out of range");
        debug_assert!((0..n).all(|k| {
            out_targets[out_offsets[k]..out_offsets[k + 1]].windows(2).all(|w| w[0] < w[1])
        }), "rows must be sorted and deduplicated");
        Graph {
            n,
            out_offsets,
            out_targets,
            in_enabled: true,
            in_csr: OnceLock::new(),
        }
    }

    /// Disable in-link queries: any later [`Graph::inc`]/
    /// [`Graph::in_degree`] panics loudly instead of materializing the
    /// transpose. Use for corpus-scale runs whose solvers are out-only.
    pub fn without_in_links(mut self) -> Graph {
        self.in_enabled = false;
        self.in_csr = OnceLock::new();
        self
    }

    /// Whether in-link queries are permitted on this graph.
    #[inline]
    pub fn in_links_available(&self) -> bool {
        self.in_enabled
    }

    /// Whether the lazy in-CSR has actually been materialized.
    #[inline]
    pub fn in_links_built(&self) -> bool {
        self.in_csr.get().is_some()
    }

    /// Bytes held by the CSR arrays (out-CSR plus the in-CSR if it has
    /// been materialized) — the number the corpus bench tracks.
    pub fn memory_bytes(&self) -> usize {
        use std::mem::size_of;
        let mut bytes = self.out_offsets.len() * size_of::<usize>()
            + self.out_targets.len() * size_of::<u32>();
        if let Some(ic) = self.in_csr.get() {
            bytes += ic.offsets.len() * size_of::<usize>() + ic.sources.len() * size_of::<u32>();
        }
        bytes
    }

    /// The lazily-built transpose adjacency.
    fn in_csr(&self) -> &InCsr {
        assert!(
            self.in_enabled,
            "in-link adjacency is disabled for this graph (built via \
             Graph::without_in_links); in-link solvers must be refused up front"
        );
        self.in_csr.get_or_init(|| {
            let mut offsets = vec![0usize; self.n + 1];
            for &d in &self.out_targets {
                offsets[d as usize + 1] += 1;
            }
            for i in 0..self.n {
                offsets[i + 1] += offsets[i];
            }
            let mut cursor = offsets.clone();
            let mut sources = vec![0u32; self.out_targets.len()];
            for s in 0..self.n {
                for &d in &self.out_targets[self.out_offsets[s]..self.out_offsets[s + 1]] {
                    sources[cursor[d as usize]] = s as u32;
                    cursor[d as usize] += 1;
                }
            }
            InCsr { offsets, sources }
        })
    }

    /// Number of pages.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of directed edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.out_targets.len()
    }

    /// Out-neighbours of `k` — the paper's `N_k` set.
    #[inline]
    pub fn out(&self, k: usize) -> &[u32] {
        &self.out_targets[self.out_offsets[k]..self.out_offsets[k + 1]]
    }

    /// In-neighbours of `k` (pages linking to `k`). Builds the lazy
    /// in-CSR on first use; panics if in-links were disabled.
    #[inline]
    pub fn inc(&self, k: usize) -> &[u32] {
        let ic = self.in_csr();
        &ic.sources[ic.offsets[k]..ic.offsets[k + 1]]
    }

    /// Out-degree `N_k`.
    #[inline]
    pub fn out_degree(&self, k: usize) -> usize {
        self.out_offsets[k + 1] - self.out_offsets[k]
    }

    /// In-degree. Builds the lazy in-CSR on first use; panics if
    /// in-links were disabled.
    #[inline]
    pub fn in_degree(&self, k: usize) -> usize {
        let ic = self.in_csr();
        ic.offsets[k + 1] - ic.offsets[k]
    }

    /// The raw out-CSR row offsets (for serialization).
    #[inline]
    pub fn out_offsets(&self) -> &[usize] {
        &self.out_offsets
    }

    /// The raw out-CSR target array (for serialization).
    #[inline]
    pub fn out_targets(&self) -> &[u32] {
        &self.out_targets
    }

    /// Whether page `k` links to itself (`A_kk = 1/N_k` in the paper's
    /// denominator formula, 0 otherwise).
    #[inline]
    pub fn has_self_loop(&self, k: usize) -> bool {
        self.out(k).binary_search(&(k as u32)).is_ok()
    }

    /// Whether the directed edge `src -> dst` exists.
    #[inline]
    pub fn has_edge(&self, src: usize, dst: usize) -> bool {
        self.out(src).binary_search(&(dst as u32)).is_ok()
    }

    /// Indices of dangling pages (out-degree 0). Empty for graphs produced
    /// by the builder with a repair policy.
    pub fn dangling(&self) -> Vec<usize> {
        (0..self.n).filter(|&k| self.out_degree(k) == 0).collect()
    }

    /// Edge list in sorted order (for IO round-trips and rebuilds).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.m());
        for s in 0..self.n {
            for &d in self.out(s) {
                out.push((s as u32, d));
            }
        }
        out
    }

    /// The hyperlink-matrix entry `A[i][j]` (1/N_j if j links to i).
    #[inline]
    pub fn a_entry(&self, i: usize, j: usize) -> f64 {
        if self.has_edge(j, i) {
            1.0 / self.out_degree(j) as f64
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0 -> 1, 0 -> 2, 1 -> 2, 2 -> 0, 2 -> 2 (self loop)
    fn tiny() -> Graph {
        Graph::from_sorted_edges(3, &[(0, 1), (0, 2), (1, 2), (2, 0), (2, 2)])
    }

    #[test]
    fn sizes() {
        let g = tiny();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 5);
    }

    #[test]
    fn out_adjacency() {
        let g = tiny();
        assert_eq!(g.out(0), &[1, 2]);
        assert_eq!(g.out(1), &[2]);
        assert_eq!(g.out(2), &[0, 2]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_degree(1), 1);
    }

    #[test]
    fn in_adjacency_is_transpose() {
        let g = tiny();
        assert_eq!(g.inc(0), &[2]);
        assert_eq!(g.inc(1), &[0]);
        let mut in2 = g.inc(2).to_vec();
        in2.sort_unstable();
        assert_eq!(in2, vec![0, 1, 2]);
        assert_eq!(g.in_degree(2), 3);
    }

    #[test]
    fn in_csr_is_lazy_and_counted_by_memory_bytes() {
        let g = tiny();
        assert!(g.in_links_available());
        assert!(!g.in_links_built(), "in-CSR must not exist before first use");
        let out_only = g.memory_bytes();
        assert_eq!(g.inc(0), &[2]);
        assert!(g.in_links_built());
        assert!(
            g.memory_bytes() > out_only,
            "materializing the transpose must grow the accounted bytes"
        );
    }

    #[test]
    fn disabled_in_links_report_unavailable() {
        let g = tiny().without_in_links();
        assert!(!g.in_links_available());
        assert!(!g.in_links_built());
        // Out-side queries are unaffected.
        assert_eq!(g.out(0), &[1, 2]);
    }

    #[test]
    #[should_panic(expected = "in-link adjacency is disabled")]
    fn disabled_in_links_panic_loudly_on_inc() {
        let g = tiny().without_in_links();
        let _ = g.inc(0);
    }

    #[test]
    fn equality_ignores_in_csr_materialization() {
        let a = tiny();
        let b = tiny();
        let _ = a.inc(2); // materialize one side only
        assert_eq!(a, b);
        assert_eq!(b, a.clone().without_in_links());
    }

    #[test]
    fn from_csr_parts_matches_from_sorted_edges() {
        let g = tiny();
        let g2 = Graph::from_csr_parts(3, g.out_offsets().to_vec(), g.out_targets().to_vec());
        assert_eq!(g, g2);
        assert_eq!(g2.inc(2), g.inc(2));
    }

    #[test]
    fn self_loops() {
        let g = tiny();
        assert!(!g.has_self_loop(0));
        assert!(g.has_self_loop(2));
    }

    #[test]
    fn has_edge() {
        let g = tiny();
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
    }

    #[test]
    fn a_entries_column_stochastic() {
        let g = tiny();
        for j in 0..3 {
            let col: f64 = (0..3).map(|i| g.a_entry(i, j)).sum();
            assert!((col - 1.0).abs() < 1e-12, "column {j} sums to {col}");
        }
        assert_eq!(g.a_entry(1, 0), 0.5); // 0 links to 1, N_0 = 2
        assert_eq!(g.a_entry(2, 2), 0.5); // self loop, N_2 = 2
    }

    #[test]
    fn edges_round_trip() {
        let g = tiny();
        let e = g.edges();
        let g2 = Graph::from_sorted_edges(3, &e);
        assert_eq!(g, g2);
    }

    #[test]
    fn dangling_detection() {
        let g = Graph::from_sorted_edges(3, &[(0, 1)]);
        assert_eq!(g.dangling(), vec![1, 2]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_edge_panics() {
        Graph::from_sorted_edges(2, &[(0, 5)]);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_sorted_edges(0, &[]);
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn in_out_degree_sums_match_edge_count() {
        let g = tiny();
        let out_sum: usize = (0..g.n()).map(|k| g.out_degree(k)).sum();
        let in_sum: usize = (0..g.n()).map(|k| g.in_degree(k)).sum();
        assert_eq!(out_sum, g.m());
        assert_eq!(in_sum, g.m());
    }
}
