//! Compressed sparse row graph storage.
//!
//! [`Graph`] stores both directions of adjacency:
//!
//! * out-CSR (`out_offsets`/`out_targets`) — the set `N_k` the paper's
//!   Algorithm 1 reads residuals from and writes residuals to;
//! * in-CSR (`in_offsets`/`in_sources`) — needed only by the baselines
//!   ([6], [12], [15]) whose updates pull from incoming neighbours, and by
//!   transpose-direction linear algebra.
//!
//! Out-edges of each node are stored sorted; the structure is immutable
//! after construction (the dynamic-network extension rebuilds via
//! [`crate::graph::GraphBuilder`], mirroring the paper's §IV-2 future-work
//! discussion where topology changes are events, not steady state).

/// An immutable directed graph with no dangling (zero out-degree) nodes
/// permitted at PageRank time (the builder repairs or rejects them).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    n: usize,
    out_offsets: Vec<usize>,
    out_targets: Vec<u32>,
    in_offsets: Vec<usize>,
    in_sources: Vec<u32>,
}

impl Graph {
    /// Build from a sorted, deduplicated edge list. Prefer
    /// [`crate::graph::GraphBuilder`]; this is the low-level constructor.
    ///
    /// `edges` are `(src, dst)` pairs meaning "src links to dst".
    pub fn from_sorted_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
        debug_assert!(edges.windows(2).all(|w| w[0] <= w[1]), "edges not sorted");
        let mut out_offsets = vec![0usize; n + 1];
        let mut in_degree = vec![0usize; n];
        for &(s, d) in edges {
            assert!((s as usize) < n && (d as usize) < n, "edge ({s},{d}) out of range");
            out_offsets[s as usize + 1] += 1;
            in_degree[d as usize] += 1;
        }
        for i in 0..n {
            out_offsets[i + 1] += out_offsets[i];
        }
        let out_targets: Vec<u32> = edges.iter().map(|&(_, d)| d).collect();

        let mut in_offsets = vec![0usize; n + 1];
        for i in 0..n {
            in_offsets[i + 1] = in_offsets[i] + in_degree[i];
        }
        let mut cursor = in_offsets.clone();
        let mut in_sources = vec![0u32; edges.len()];
        for &(s, d) in edges {
            in_sources[cursor[d as usize]] = s;
            cursor[d as usize] += 1;
        }
        Graph {
            n,
            out_offsets,
            out_targets,
            in_offsets,
            in_sources,
        }
    }

    /// Number of pages.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of directed edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.out_targets.len()
    }

    /// Out-neighbours of `k` — the paper's `N_k` set.
    #[inline]
    pub fn out(&self, k: usize) -> &[u32] {
        &self.out_targets[self.out_offsets[k]..self.out_offsets[k + 1]]
    }

    /// In-neighbours of `k` (pages linking to `k`).
    #[inline]
    pub fn inc(&self, k: usize) -> &[u32] {
        &self.in_sources[self.in_offsets[k]..self.in_offsets[k + 1]]
    }

    /// Out-degree `N_k`.
    #[inline]
    pub fn out_degree(&self, k: usize) -> usize {
        self.out_offsets[k + 1] - self.out_offsets[k]
    }

    /// In-degree.
    #[inline]
    pub fn in_degree(&self, k: usize) -> usize {
        self.in_offsets[k + 1] - self.in_offsets[k]
    }

    /// Whether page `k` links to itself (`A_kk = 1/N_k` in the paper's
    /// denominator formula, 0 otherwise).
    #[inline]
    pub fn has_self_loop(&self, k: usize) -> bool {
        self.out(k).binary_search(&(k as u32)).is_ok()
    }

    /// Whether the directed edge `src -> dst` exists.
    #[inline]
    pub fn has_edge(&self, src: usize, dst: usize) -> bool {
        self.out(src).binary_search(&(dst as u32)).is_ok()
    }

    /// Indices of dangling pages (out-degree 0). Empty for graphs produced
    /// by the builder with a repair policy.
    pub fn dangling(&self) -> Vec<usize> {
        (0..self.n).filter(|&k| self.out_degree(k) == 0).collect()
    }

    /// Edge list in sorted order (for IO round-trips and rebuilds).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.m());
        for s in 0..self.n {
            for &d in self.out(s) {
                out.push((s as u32, d));
            }
        }
        out
    }

    /// The hyperlink-matrix entry `A[i][j]` (1/N_j if j links to i).
    #[inline]
    pub fn a_entry(&self, i: usize, j: usize) -> f64 {
        if self.has_edge(j, i) {
            1.0 / self.out_degree(j) as f64
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0 -> 1, 0 -> 2, 1 -> 2, 2 -> 0, 2 -> 2 (self loop)
    fn tiny() -> Graph {
        Graph::from_sorted_edges(3, &[(0, 1), (0, 2), (1, 2), (2, 0), (2, 2)])
    }

    #[test]
    fn sizes() {
        let g = tiny();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 5);
    }

    #[test]
    fn out_adjacency() {
        let g = tiny();
        assert_eq!(g.out(0), &[1, 2]);
        assert_eq!(g.out(1), &[2]);
        assert_eq!(g.out(2), &[0, 2]);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.out_degree(1), 1);
    }

    #[test]
    fn in_adjacency_is_transpose() {
        let g = tiny();
        assert_eq!(g.inc(0), &[2]);
        assert_eq!(g.inc(1), &[0]);
        let mut in2 = g.inc(2).to_vec();
        in2.sort_unstable();
        assert_eq!(in2, vec![0, 1, 2]);
        assert_eq!(g.in_degree(2), 3);
    }

    #[test]
    fn self_loops() {
        let g = tiny();
        assert!(!g.has_self_loop(0));
        assert!(g.has_self_loop(2));
    }

    #[test]
    fn has_edge() {
        let g = tiny();
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
    }

    #[test]
    fn a_entries_column_stochastic() {
        let g = tiny();
        for j in 0..3 {
            let col: f64 = (0..3).map(|i| g.a_entry(i, j)).sum();
            assert!((col - 1.0).abs() < 1e-12, "column {j} sums to {col}");
        }
        assert_eq!(g.a_entry(1, 0), 0.5); // 0 links to 1, N_0 = 2
        assert_eq!(g.a_entry(2, 2), 0.5); // self loop, N_2 = 2
    }

    #[test]
    fn edges_round_trip() {
        let g = tiny();
        let e = g.edges();
        let g2 = Graph::from_sorted_edges(3, &e);
        assert_eq!(g, g2);
    }

    #[test]
    fn dangling_detection() {
        let g = Graph::from_sorted_edges(3, &[(0, 1)]);
        assert_eq!(g.dangling(), vec![1, 2]);
    }

    #[test]
    #[should_panic]
    fn out_of_range_edge_panics() {
        Graph::from_sorted_edges(2, &[(0, 5)]);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_sorted_edges(0, &[]);
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn in_out_degree_sums_match_edge_count() {
        let g = tiny();
        let out_sum: usize = (0..g.n()).map(|k| g.out_degree(k)).sum();
        let in_sum: usize = (0..g.n()).map(|k| g.in_degree(k)).sum();
        assert_eq!(out_sum, g.m());
        assert_eq!(in_sum, g.m());
    }
}
