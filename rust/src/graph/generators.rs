//! Synthetic graph families.
//!
//! [`er_threshold`] is the exact §III construction the paper evaluates on:
//! an N×N matrix of iid U\[0,1\] entries thresholded at a constant (0.5 in
//! the paper), giving a dense ER digraph with expected out-degree
//! ≈ N·(1-threshold). The other families exercise the algorithms on
//! topologies the paper's motivation section alludes to (power-law webs,
//! small worlds, clustered communities).

use super::builder::{DanglingPolicy, GraphBuilder};
use super::csr::Graph;
use crate::util::rng::Rng;

/// The paper's §III generator: keep edge `(j -> i)` iff `U[0,1] >
/// threshold`, no self-loops, dangling pages repaired by linking to all
/// pages (a dangling column is astronomically unlikely at the paper's
/// N=100, p=0.5, but the policy must be total).
pub fn er_threshold(n: usize, threshold: f64, seed: u64) -> Graph {
    let mut rng = Rng::seeded(seed);
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::LinkAll);
    for j in 0..n {
        for i in 0..n {
            if i != j && rng.uniform() > threshold {
                b.add_edge(j, i);
            }
        }
    }
    b.build().expect("ER-threshold graphs cannot fail to build")
}

/// Sparse directed Erdős–Rényi `G(n, p)`: each ordered pair independently
/// an edge with probability `p`.
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    let mut rng = Rng::seeded(seed);
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::SelfLoop);
    for s in 0..n {
        for d in 0..n {
            if s != d && rng.bernoulli(p) {
                b.add_edge(s, d);
            }
        }
    }
    b.build().expect("ER graphs cannot fail to build")
}

/// Barabási–Albert preferential attachment (directed variant): each new
/// node adds `m` out-links to existing nodes chosen proportionally to
/// in-degree + 1. Produces the heavy-tailed in-degree distribution typical
/// of web graphs.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> Graph {
    assert!(n >= 2, "BA needs at least 2 nodes");
    assert!(m >= 1, "BA needs m >= 1");
    let mut rng = Rng::seeded(seed);
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::SelfLoop);
    // Repeated-target list implements preferential attachment in O(1) per
    // draw: node id appears once per unit of (in-degree + 1).
    let mut targets: Vec<usize> = vec![0];
    b.add_edge(1, 0);
    targets.push(1);
    targets.push(0);
    for v in 2..n {
        let picks = m.min(v);
        let mut chosen = Vec::with_capacity(picks);
        while chosen.len() < picks {
            let t = targets[rng.below(targets.len())];
            if t != v && !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &t in &chosen {
            b.add_edge(v, t);
            targets.push(t);
        }
        targets.push(v);
    }
    b.build().expect("BA graphs cannot fail to build")
}

/// Watts–Strogatz small world (directed): ring of `k` forward neighbours,
/// each edge rewired with probability `beta`.
pub fn watts_strogatz(n: usize, k: usize, beta: f64, seed: u64) -> Graph {
    assert!(k >= 1 && k < n, "need 1 <= k < n");
    let mut rng = Rng::seeded(seed);
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::SelfLoop);
    for s in 0..n {
        for off in 1..=k {
            let mut d = (s + off) % n;
            if rng.bernoulli(beta) {
                // Rewire to a uniform non-self target.
                loop {
                    d = rng.below(n);
                    if d != s {
                        break;
                    }
                }
            }
            b.add_edge(s, d);
        }
    }
    b.build().expect("WS graphs cannot fail to build")
}

/// Two-block stochastic block model: intra-block probability `p_in`,
/// inter-block `p_out`. Models clustered link farms / communities.
pub fn sbm_two_block(n: usize, p_in: f64, p_out: f64, seed: u64) -> Graph {
    let mut rng = Rng::seeded(seed);
    let half = n / 2;
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::SelfLoop);
    for s in 0..n {
        for d in 0..n {
            if s == d {
                continue;
            }
            let same = (s < half) == (d < half);
            let p = if same { p_in } else { p_out };
            if rng.bernoulli(p) {
                b.add_edge(s, d);
            }
        }
    }
    b.build().expect("SBM graphs cannot fail to build")
}

/// Directed ring: `i -> (i+1) % n`. The slowest-mixing strongly-connected
/// topology — a useful adversarial case for convergence-rate ablations.
pub fn ring(n: usize) -> Graph {
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::Error);
    for i in 0..n {
        b.add_edge(i, (i + 1) % n);
    }
    b.build().expect("ring cannot dangle")
}

/// Star: hub 0 links to all leaves, all leaves link back to the hub.
/// Maximum degree skew; the hub's activation touches every page.
pub fn star(n: usize) -> Graph {
    assert!(n >= 2);
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::Error);
    for leaf in 1..n {
        b.add_edge(0, leaf);
        b.add_edge(leaf, 0);
    }
    b.build().expect("star cannot dangle")
}

/// Complete digraph (every ordered pair, no self-loops).
pub fn complete(n: usize) -> Graph {
    assert!(n >= 2);
    let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::Error);
    for s in 0..n {
        for d in 0..n {
            if s != d {
                b.add_edge(s, d);
            }
        }
    }
    b.build().expect("complete cannot dangle")
}

/// Directed chain `0 → 1 → … → n-1` whose tail page keeps its **zero
/// out-degree** — the one family that deliberately ships a dangling page
/// (a crawl's sink page). Solvers repair it on the fly with the implicit
/// self-loop guard of [`crate::linalg::sparse::BColumns`]; use this
/// family to exercise that path end to end.
pub fn chain(n: usize) -> Graph {
    assert!(n >= 2);
    let edges: Vec<(u32, u32)> = (0..n - 1).map(|i| (i as u32, i as u32 + 1)).collect();
    Graph::from_sorted_edges(n, &edges)
}

/// One page's out-links in the synthetic webgraph model — shared by
/// [`webgraph`] and [`write_webgraph_corpus`] so the in-memory graph and
/// the written corpus text are the *same* graph, page for page.
///
/// The model mimics a crawl at corpus scale: ~1.8% of pages are dangling
/// (sink pages a crawler saw but never fetched), out-degrees follow a
/// capped Pareto draw with mean ≈ 10, and targets are drawn as
/// `floor(n·u³)` so low-id pages collect Zipf-like heavy in-degrees.
fn webgraph_row(page: usize, n: usize, rng: &mut Rng, row: &mut Vec<u32>) {
    row.clear();
    if rng.bernoulli(0.018) {
        return; // dangling sink page
    }
    let u = rng.uniform().max(1e-12);
    let deg = (1.0 + 4.0 * u.powf(-0.55)) as usize;
    let deg = deg.min(n - 1).min(10_000);
    for _ in 0..deg {
        let v = rng.uniform();
        let mut t = (n as f64 * v * v * v) as usize;
        if t >= n {
            t = n - 1;
        }
        if t == page {
            t = (t + 1) % n;
        }
        row.push(t as u32);
    }
    row.sort_unstable();
    row.dedup();
}

/// Deterministic webgraph-like corpus graph: power-law in/out degrees
/// and genuine dangling pages, built straight into CSR arrays (no edge
/// buffering) so 10⁶–10⁷-page instances are affordable. Dangling pages
/// are **kept** (like [`chain`]); callers choose the repair policy.
pub fn webgraph(n: usize, seed: u64) -> Graph {
    assert!(n >= 2, "webgraph needs at least 2 pages");
    let mut rng = Rng::seeded(seed);
    let mut offsets = Vec::with_capacity(n + 1);
    offsets.push(0usize);
    let mut targets: Vec<u32> = Vec::new();
    let mut row = Vec::new();
    for page in 0..n {
        webgraph_row(page, n, &mut rng, &mut row);
        targets.extend_from_slice(&row);
        offsets.push(targets.len());
    }
    Graph::from_csr_parts(n, offsets, targets)
}

/// Stream the webgraph corpus as edge-list text (with a `# nodes:`
/// header pinning the dangling tail pages). Page-for-page identical to
/// [`webgraph`] at the same `(n, seed)`.
pub fn write_webgraph_corpus<W: std::io::Write>(
    n: usize,
    seed: u64,
    mut w: W,
) -> std::io::Result<()> {
    assert!(n >= 2, "webgraph needs at least 2 pages");
    writeln!(w, "# synthetic webgraph corpus (deterministic): n={n} seed={seed}")?;
    writeln!(w, "# nodes: {n}")?;
    let mut rng = Rng::seeded(seed);
    let mut row = Vec::new();
    for page in 0..n {
        webgraph_row(page, n, &mut rng, &mut row);
        for &d in &row {
            writeln!(w, "{page} {d}")?;
        }
    }
    Ok(())
}

/// Dispatch a generator by name — used by the CLI and the benches.
/// `spec` examples: `er100` is not parsed here; pass name and params
/// explicitly.
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Graph> {
    match name {
        "er-threshold" | "paper" => Some(er_threshold(n, 0.5, seed)),
        "er-sparse" => Some(erdos_renyi(n, (8.0 / n as f64).min(1.0), seed)),
        "ba" => Some(barabasi_albert(n, 4, seed)),
        "ws" => Some(watts_strogatz(n, 4, 0.1, seed)),
        "sbm" => Some(sbm_two_block(n, 0.2, 0.02, seed)),
        "ring" => Some(ring(n)),
        "star" => Some(star(n)),
        "complete" => Some(complete(n)),
        "chain" => Some(chain(n)),
        "webgraph" => Some(webgraph(n, seed)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_threshold_density_matches_paper_model() {
        let n = 100;
        let g = er_threshold(n, 0.5, 1);
        // Expected out-degree ~ (n-1)/2 ~ 49.5; allow generous slack.
        let avg = g.m() as f64 / n as f64;
        assert!((avg - 49.5).abs() < 5.0, "avg out-degree {avg}");
        assert!(g.dangling().is_empty());
        // No self loops in this model.
        assert!((0..n).all(|k| !g.has_self_loop(k)));
    }

    #[test]
    fn er_threshold_deterministic_per_seed() {
        assert_eq!(er_threshold(50, 0.5, 9), er_threshold(50, 0.5, 9));
        assert_ne!(er_threshold(50, 0.5, 9), er_threshold(50, 0.5, 10));
    }

    #[test]
    fn er_threshold_extreme_thresholds() {
        // threshold 1.0 -> no random edges survive; all pages dangling ->
        // LinkAll repair yields the complete graph.
        let g = er_threshold(10, 1.0, 3);
        assert_eq!(g.m(), 10 * 9);
        // threshold 0.0 -> complete digraph directly.
        let g = er_threshold(10, 0.0, 3);
        assert_eq!(g.m(), 10 * 9);
    }

    #[test]
    fn erdos_renyi_density() {
        let g = erdos_renyi(200, 0.05, 5);
        let expected = 200.0 * 199.0 * 0.05;
        assert!((g.m() as f64 - expected).abs() < 0.25 * expected);
    }

    #[test]
    fn ba_no_dangling_and_heavy_hub() {
        let g = barabasi_albert(300, 3, 7);
        assert!(g.dangling().is_empty());
        let max_in = (0..g.n()).map(|k| g.in_degree(k)).max().expect("nonempty");
        let avg_in = g.m() as f64 / g.n() as f64;
        assert!(max_in as f64 > 4.0 * avg_in, "max_in={max_in} avg={avg_in}");
    }

    #[test]
    fn ws_degree_regular_before_rewire() {
        let g = watts_strogatz(50, 3, 0.0, 11);
        assert!((0..50).all(|k| g.out_degree(k) == 3));
        assert!(g.has_edge(0, 1) && g.has_edge(0, 3));
    }

    #[test]
    fn ws_rewiring_changes_topology() {
        let a = watts_strogatz(50, 3, 0.0, 11);
        let b = watts_strogatz(50, 3, 0.9, 11);
        assert_ne!(a, b);
    }

    #[test]
    fn sbm_blocks_denser_inside() {
        let g = sbm_two_block(100, 0.3, 0.02, 13);
        let mut intra = 0usize;
        let mut inter = 0usize;
        for (s, d) in g.edges() {
            if ((s as usize) < 50) == ((d as usize) < 50) {
                intra += 1;
            } else {
                inter += 1;
            }
        }
        assert!(intra > 5 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn ring_structure() {
        let g = ring(5);
        assert_eq!(g.m(), 5);
        assert!(g.has_edge(4, 0));
        assert!((0..5).all(|k| g.out_degree(k) == 1));
    }

    #[test]
    fn star_structure() {
        let g = star(6);
        assert_eq!(g.out_degree(0), 5);
        assert!((1..6).all(|k| g.out(k) == [0]));
    }

    #[test]
    fn complete_structure() {
        let g = complete(4);
        assert_eq!(g.m(), 12);
        assert!((0..4).all(|k| g.out_degree(k) == 3));
    }

    #[test]
    fn by_name_dispatch() {
        assert!(by_name("paper", 20, 1).is_some());
        assert!(by_name("ba", 20, 1).is_some());
        assert!(by_name("chain", 20, 1).is_some());
        assert!(by_name("webgraph", 10, 1).is_some()); // the registry probe size
        assert!(by_name("nope", 20, 1).is_none());
    }

    #[test]
    fn webgraph_is_deterministic_heavy_tailed_and_keeps_danglers() {
        let g = webgraph(5_000, 42);
        assert_eq!(g, webgraph(5_000, 42));
        assert_ne!(g, webgraph(5_000, 43));
        // Mean out-degree ≈ 10 (capped Pareto draw).
        let mean = g.m() as f64 / g.n() as f64;
        assert!((4.0..30.0).contains(&mean), "mean out-degree {mean}");
        // A real dangling fraction near 1.8%.
        let dangling = g.dangling().len() as f64 / g.n() as f64;
        assert!((0.005..0.05).contains(&dangling), "dangling fraction {dangling}");
        // Zipf-ish in-degree skew: low ids collect far more than average.
        let mut in_deg = vec![0usize; g.n()];
        for (_, d) in g.edges() {
            in_deg[d as usize] += 1;
        }
        let max_in = *in_deg.iter().max().expect("nonempty");
        assert!(
            max_in as f64 > 20.0 * mean,
            "max in-degree {max_in} not heavy-tailed vs mean {mean}"
        );
    }

    #[test]
    fn webgraph_corpus_text_replays_the_generator_graph() {
        use crate::graph::io;
        let (n, seed) = (800, 7);
        let g = webgraph(n, seed);
        let mut text = Vec::new();
        write_webgraph_corpus(n, seed, &mut text).expect("writes");
        // Loading the corpus with self-loop repair must equal the
        // generator graph repaired the same way.
        let loaded = io::read_edge_list(text.as_slice(), DanglingPolicy::SelfLoop)
            .expect("corpus parses");
        let mut b = GraphBuilder::new(n).dangling_policy(DanglingPolicy::SelfLoop);
        b.extend(g.edges().iter().map(|&(s, d)| (s as usize, d as usize)));
        let repaired = b.build().expect("builds");
        assert_eq!(loaded, repaired, "corpus text and generator graph diverged");
    }

    #[test]
    fn chain_keeps_its_dangling_tail() {
        let g = chain(6);
        assert_eq!(g.n(), 6);
        assert_eq!(g.m(), 5);
        assert_eq!(g.out(2), &[3]);
        assert_eq!(g.dangling(), vec![5], "the tail must stay dangling");
    }
}
