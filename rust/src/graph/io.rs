//! Plain-text edge-list IO.
//!
//! Format: one `src dst` pair per line (whitespace separated), `#` starts
//! a comment. Node count is `max id + 1` unless a `# nodes: N` header is
//! present (lets files pin isolated trailing nodes).

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use super::builder::{DanglingPolicy, GraphBuilder};
use super::csr::Graph;

/// IO / parse errors.
#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Parse { line: usize, content: String },
    Build(super::builder::BuildError),
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "io error: {e}"),
            IoError::Parse { line, content } => {
                write!(f, "parse error at line {line}: {content:?}")
            }
            IoError::Build(e) => write!(f, "graph build error: {e}"),
        }
    }
}

impl std::error::Error for IoError {}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        IoError::Io(e)
    }
}

/// Parse an edge list from any reader.
pub fn read_edge_list<R: Read>(reader: R, dangling: DanglingPolicy) -> Result<Graph, IoError> {
    let buf = BufReader::new(reader);
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let mut declared_n: Option<usize> = None;
    let mut max_id = 0usize;
    for (lineno, line) in buf.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if let Some(rest) = trimmed.strip_prefix('#') {
            // Optional "# nodes: N" header.
            if let Some(v) = rest.trim().strip_prefix("nodes:") {
                declared_n = v.trim().parse::<usize>().ok();
            }
            continue;
        }
        let mut it = trimmed.split_whitespace();
        let (s, d) = match (it.next(), it.next(), it.next()) {
            (Some(s), Some(d), None) => (s, d),
            _ => {
                return Err(IoError::Parse { line: lineno + 1, content: line.clone() });
            }
        };
        let (s, d) = match (s.parse::<usize>(), d.parse::<usize>()) {
            (Ok(s), Ok(d)) => (s, d),
            _ => {
                return Err(IoError::Parse { line: lineno + 1, content: line.clone() });
            }
        };
        max_id = max_id.max(s).max(d);
        edges.push((s, d));
    }
    let n = declared_n.unwrap_or(if edges.is_empty() { 0 } else { max_id + 1 });
    let mut b = GraphBuilder::new(n).dangling_policy(dangling);
    b.extend(edges);
    b.build().map_err(IoError::Build)
}

/// Load a graph from a file path.
pub fn load<P: AsRef<Path>>(path: P, dangling: DanglingPolicy) -> Result<Graph, IoError> {
    let f = std::fs::File::open(path)?;
    read_edge_list(f, dangling)
}

/// Serialize a graph as an edge list (with a `# nodes:` header).
pub fn write_edge_list<W: Write>(g: &Graph, mut w: W) -> std::io::Result<()> {
    writeln!(w, "# nodes: {}", g.n())?;
    for (s, d) in g.edges() {
        writeln!(w, "{s} {d}")?;
    }
    Ok(())
}

/// Save to a file path.
pub fn save<P: AsRef<Path>>(g: &Graph, path: P) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    write_edge_list(g, std::io::BufWriter::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn parse_basic() {
        let text = "# a comment\n0 1\n1 2\n2 0\n";
        let g = read_edge_list(text.as_bytes(), DanglingPolicy::Error).expect("parses");
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert!(g.has_edge(2, 0));
    }

    #[test]
    fn nodes_header_respected() {
        let text = "# nodes: 5\n0 1\n1 0\n";
        let g = read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop).expect("parses");
        assert_eq!(g.n(), 5);
        assert!(g.has_self_loop(4)); // repaired dangling trailing node
    }

    #[test]
    fn bad_line_reports_position() {
        let text = "0 1\nnot an edge\n";
        match read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop) {
            Err(IoError::Parse { line, .. }) => assert_eq!(line, 2),
            other => panic!("expected parse error, got {other:?}"),
        }
    }

    #[test]
    fn three_fields_is_error() {
        let text = "0 1 7\n";
        assert!(matches!(
            read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop),
            Err(IoError::Parse { .. })
        ));
    }

    #[test]
    fn empty_input_is_empty_graph() {
        let g = read_edge_list("".as_bytes(), DanglingPolicy::Error).expect("ok");
        assert_eq!(g.n(), 0);
    }

    #[test]
    fn round_trip() {
        let g = generators::er_threshold(40, 0.5, 77);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).expect("writes");
        let g2 = read_edge_list(buf.as_slice(), DanglingPolicy::Error).expect("parses");
        assert_eq!(g, g2);
    }

    #[test]
    fn file_round_trip() {
        let g = generators::ring(10);
        let dir = std::env::temp_dir().join(format!("prmp_io_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = dir.join("g.txt");
        save(&g, &path).expect("saves");
        let g2 = load(&path, DanglingPolicy::Error).expect("loads");
        assert_eq!(g, g2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(matches!(
            load("/definitely/not/here.txt", DanglingPolicy::Error),
            Err(IoError::Io(_))
        ));
    }
}
