//! Edge-list IO: streaming text ingest and the binary CSR cache.
//!
//! Text format: one `src dst` pair per line (whitespace separated), `#`
//! or `%` starts a comment. Node count is `max id + 1` unless a
//! `# nodes: N` header is present (lets files pin isolated trailing
//! nodes); the SNAP variant `# Nodes: N Edges: M` is accepted too.
//!
//! [`load_with`]/[`read_edge_list_streaming`] ingest in two passes over
//! the reader — pass 1 counts per-row degrees (and discovers dangling
//! pages, so repair slots are preallocated), pass 2 writes targets
//! straight into the CSR arrays, then each row is sorted/deduplicated in
//! place and compacted. Peak memory is one CSR plus O(n) counters,
//! not the 3–4× of the old collect-everything → builder → copy path.
//!
//! [`LoadOptions::remap_ids`] handles SNAP-style non-contiguous node
//! ids by assigning dense ids in first-seen order.
//!
//! `.csrbin` ([`write_csrbin`]/[`read_csrbin`]/[`load_cached`]) is a
//! compact little-endian binary snapshot of the out-CSR so repeated
//! bench runs on a million-page corpus skip the text parse entirely:
//!
//! ```text
//! magic "CSRB" | version u32 | policy u8 | remap u8 | reserved [u8;2]
//! | n u64 | m u64 | out_offsets (n+1)×u64 | out_targets m×u32
//! ```

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::builder::{BuildError, DanglingPolicy, GraphBuilder};
use super::csr::Graph;

/// IO / parse errors.
#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Parse { line: usize, content: String },
    Build(BuildError),
    /// A malformed `.csrbin` file (bad magic/version/structure).
    Format(String),
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "io error: {e}"),
            IoError::Parse { line, content } => {
                write!(f, "parse error at line {line}: {content:?}")
            }
            IoError::Build(e) => write!(f, "graph build error: {e}"),
            IoError::Format(detail) => write!(f, "csrbin format error: {detail}"),
        }
    }
}

impl std::error::Error for IoError {}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        IoError::Io(e)
    }
}

/// How to ingest an edge-list file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoadOptions {
    /// Dangling-page repair policy (default [`DanglingPolicy::LinkAll`],
    /// the classical PageRank repair the engine has always used).
    pub dangling: DanglingPolicy,
    /// Remap non-contiguous node ids to dense ids in first-seen order
    /// (SNAP crawls number pages by URL hash, not 0..n).
    pub remap_ids: bool,
}

impl Default for LoadOptions {
    fn default() -> Self {
        LoadOptions::new(DanglingPolicy::LinkAll)
    }
}

impl LoadOptions {
    pub fn new(dangling: DanglingPolicy) -> LoadOptions {
        LoadOptions { dangling, remap_ids: false }
    }

    pub fn remap_ids(mut self, on: bool) -> LoadOptions {
        self.remap_ids = on;
        self
    }
}

/// One parsed line of an edge-list file.
enum Line {
    Edge(usize, usize),
    /// A `# nodes: N` (or SNAP `# Nodes: N Edges: M`) header.
    Nodes(usize),
    Skip,
}

fn parse_line(lineno: usize, raw: &str) -> Result<Line, IoError> {
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Ok(Line::Skip);
    }
    if let Some(rest) = trimmed.strip_prefix('#').or_else(|| trimmed.strip_prefix('%')) {
        let rest = rest.trim();
        // Optional "# nodes: N" header ("# Nodes: N Edges: M" in SNAP
        // dumps). A malformed count is a positioned error, not a
        // silently ignored comment.
        let lower = rest.to_ascii_lowercase();
        if let Some(tail) = lower.strip_prefix("nodes:") {
            let mut it = tail.split_whitespace();
            let value = it.next().unwrap_or("");
            let n = value.parse::<usize>().map_err(|_| IoError::Parse {
                line: lineno,
                content: raw.to_string(),
            })?;
            // Anything after the count must be the SNAP "edges: M"
            // continuation; other trailing junk is malformed.
            match it.next() {
                None => {}
                Some(word) if word == "edges:" => {}
                Some(_) => {
                    return Err(IoError::Parse { line: lineno, content: raw.to_string() })
                }
            }
            return Ok(Line::Nodes(n));
        }
        return Ok(Line::Skip);
    }
    let mut it = trimmed.split_whitespace();
    let (s, d) = match (it.next(), it.next(), it.next()) {
        (Some(s), Some(d), None) => (s, d),
        _ => return Err(IoError::Parse { line: lineno, content: raw.to_string() }),
    };
    match (s.parse::<usize>(), d.parse::<usize>()) {
        (Ok(s), Ok(d)) => Ok(Line::Edge(s, d)),
        _ => Err(IoError::Parse { line: lineno, content: raw.to_string() }),
    }
}

/// Streaming two-pass edge-list ingest from any seekable reader.
///
/// Produces the identical graph to parsing the file through
/// [`GraphBuilder`] (sorted rows, duplicates removed, dangling pages
/// repaired per `opts.dangling`) at a fraction of the peak memory.
pub fn read_edge_list_streaming<R: Read + Seek>(
    mut reader: R,
    opts: &LoadOptions,
) -> Result<Graph, IoError> {
    // ---- pass 1: count degrees, discover ids and dangling pages ----
    let mut degrees: Vec<usize> = Vec::new();
    let mut remap: HashMap<usize, u32> = HashMap::new();
    let mut declared: Option<(usize, usize)> = None; // (n, header line)
    let mut max_id = 0usize;
    let mut saw_edge = false;
    {
        let mut map_id = |raw: usize, lineno: usize, line: &str| -> Result<usize, IoError> {
            if opts.remap_ids {
                let next = remap.len() as u32;
                return Ok(*remap.entry(raw).or_insert(next) as usize);
            }
            if raw > u32::MAX as usize {
                // Targets are stored as u32; un-remapped ids past that
                // range cannot be represented.
                return Err(IoError::Parse { line: lineno, content: line.to_string() });
            }
            Ok(raw)
        };
        let buf = BufReader::new(&mut reader);
        for (idx, line) in buf.lines().enumerate() {
            let line = line?;
            let lineno = idx + 1;
            match parse_line(lineno, &line)? {
                Line::Skip => {}
                Line::Nodes(n) => declared = Some((n, lineno)),
                Line::Edge(s, d) => {
                    let s = map_id(s, lineno, &line)?;
                    let d = map_id(d, lineno, &line)?;
                    max_id = max_id.max(s).max(d);
                    if degrees.len() <= s {
                        degrees.resize(s + 1, 0);
                    }
                    degrees[s] += 1;
                    saw_edge = true;
                }
            }
        }
    }
    let distinct = if opts.remap_ids {
        remap.len()
    } else if saw_edge {
        max_id + 1
    } else {
        0
    };
    let n = match declared {
        Some((dn, header_line)) => {
            if dn < distinct {
                // An under-declared header would build a graph whose
                // edges point past n — refuse with the header position.
                return Err(IoError::Parse {
                    line: header_line,
                    content: format!(
                        "# nodes: {dn} under-declares the graph: edges reference {distinct} pages"
                    ),
                });
            }
            dn
        }
        None => distinct,
    };
    degrees.resize(n, 0);

    // ---- dangling repair slots, known before any target is written ----
    let mut repair: Vec<usize> = Vec::new(); // dangling page ids
    for (k, &deg) in degrees.iter().enumerate() {
        if deg == 0 {
            repair.push(k);
        }
    }
    let extra_per_dangler = match opts.dangling {
        DanglingPolicy::Error => {
            if let Some(&k) = repair.first() {
                return Err(IoError::Build(BuildError::Dangling(k)));
            }
            0
        }
        DanglingPolicy::SelfLoop => 1,
        // The classical repair links a dangler to every *other* page.
        DanglingPolicy::LinkAll => n.saturating_sub(1),
    };

    // ---- CSR offsets (with repair slots) and target array ----
    let mut offsets = vec![0usize; n + 1];
    for k in 0..n {
        let slots = if degrees[k] == 0 { extra_per_dangler } else { degrees[k] };
        offsets[k + 1] = offsets[k] + slots;
    }
    let total = offsets[n];
    let mut targets = vec![0u32; total];
    let mut cursor: Vec<usize> = offsets[..n].to_vec();

    // Dangler rows carry only repair targets; fill them up front.
    for &k in &repair {
        match opts.dangling {
            DanglingPolicy::SelfLoop => {
                targets[cursor[k]] = k as u32;
                cursor[k] += 1;
            }
            DanglingPolicy::LinkAll => {
                for d in 0..n {
                    if d != k {
                        targets[cursor[k]] = d as u32;
                        cursor[k] += 1;
                    }
                }
            }
            DanglingPolicy::Error => unreachable!("refused above"),
        }
    }

    // ---- pass 2: scatter targets straight into the CSR rows ----
    reader.seek(SeekFrom::Start(0))?;
    {
        let buf = BufReader::new(&mut reader);
        for (idx, line) in buf.lines().enumerate() {
            let line = line?;
            match parse_line(idx + 1, &line)? {
                Line::Edge(s, d) => {
                    let (s, d) = if opts.remap_ids {
                        (remap[&s] as usize, remap[&d])
                    } else {
                        (s, d as u32)
                    };
                    targets[cursor[s]] = d;
                    cursor[s] += 1;
                }
                Line::Nodes(_) | Line::Skip => {}
            }
        }
    }

    // ---- per-row sort + dedup, compacting in place ----
    let mut write = 0usize;
    let mut final_offsets = vec![0usize; n + 1];
    for k in 0..n {
        let (start, end) = (offsets[k], offsets[k + 1]);
        targets[start..end].sort_unstable();
        let row_start = write;
        for i in start..end {
            let v = targets[i];
            if write == row_start || targets[write - 1] != v {
                targets[write] = v;
                write += 1;
            }
        }
        final_offsets[k + 1] = write;
    }
    targets.truncate(write);
    targets.shrink_to_fit();
    Ok(Graph::from_csr_parts(n, final_offsets, targets))
}

/// Parse an edge list from any reader (buffers non-seekable input and
/// routes through the streaming loader).
pub fn read_edge_list<R: Read>(mut reader: R, dangling: DanglingPolicy) -> Result<Graph, IoError> {
    let mut bytes = Vec::new();
    reader.read_to_end(&mut bytes)?;
    read_edge_list_streaming(std::io::Cursor::new(bytes), &LoadOptions::new(dangling))
}

/// Load a graph from a file path with full options (streaming ingest).
pub fn load_with<P: AsRef<Path>>(path: P, opts: &LoadOptions) -> Result<Graph, IoError> {
    let f = std::fs::File::open(path)?;
    read_edge_list_streaming(f, opts)
}

/// Load a graph from a file path.
pub fn load<P: AsRef<Path>>(path: P, dangling: DanglingPolicy) -> Result<Graph, IoError> {
    load_with(path, &LoadOptions::new(dangling))
}

/// Serialize a graph as an edge list (with a `# nodes:` header).
pub fn write_edge_list<W: Write>(g: &Graph, mut w: W) -> std::io::Result<()> {
    writeln!(w, "# nodes: {}", g.n())?;
    for (s, d) in g.edges() {
        writeln!(w, "{s} {d}")?;
    }
    Ok(())
}

/// Save to a file path.
pub fn save<P: AsRef<Path>>(g: &Graph, path: P) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    write_edge_list(g, std::io::BufWriter::new(f))
}

// ---------------------------------------------------------------- csrbin

const CSRBIN_MAGIC: [u8; 4] = *b"CSRB";
const CSRBIN_VERSION: u32 = 1;
const CSRBIN_HEADER_LEN: usize = 4 + 4 + 1 + 1 + 2 + 8 + 8;

fn policy_byte(p: DanglingPolicy) -> u8 {
    match p {
        DanglingPolicy::Error => 0,
        DanglingPolicy::SelfLoop => 1,
        DanglingPolicy::LinkAll => 2,
    }
}

fn policy_from_byte(b: u8) -> Option<DanglingPolicy> {
    match b {
        0 => Some(DanglingPolicy::Error),
        1 => Some(DanglingPolicy::SelfLoop),
        2 => Some(DanglingPolicy::LinkAll),
        _ => None,
    }
}

/// Write the binary CSR snapshot. `opts` records how the source text was
/// ingested, so a later [`load_cached`] with different options knows to
/// re-parse instead of serving a mismatched graph.
pub fn write_csrbin<P: AsRef<Path>>(g: &Graph, path: P, opts: &LoadOptions) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(f);
    w.write_all(&CSRBIN_MAGIC)?;
    w.write_all(&CSRBIN_VERSION.to_le_bytes())?;
    w.write_all(&[policy_byte(opts.dangling), opts.remap_ids as u8, 0, 0])?;
    w.write_all(&(g.n() as u64).to_le_bytes())?;
    w.write_all(&(g.m() as u64).to_le_bytes())?;
    for &o in g.out_offsets() {
        w.write_all(&(o as u64).to_le_bytes())?;
    }
    for &t in g.out_targets() {
        w.write_all(&t.to_le_bytes())?;
    }
    w.flush()
}

fn read_u64s<R: Read>(r: &mut R, count: usize) -> Result<Vec<usize>, IoError> {
    let mut out = Vec::with_capacity(count);
    let mut buf = [0u8; 8 * 1024];
    let mut remaining = count;
    while remaining > 0 {
        let take = remaining.min(1024);
        let bytes = &mut buf[..take * 8];
        r.read_exact(bytes)?;
        for c in bytes.chunks_exact(8) {
            let v = u64::from_le_bytes(c.try_into().expect("8-byte chunk"));
            out.push(usize::try_from(v).map_err(|_| {
                IoError::Format(format!("offset {v} does not fit this platform's usize"))
            })?);
        }
        remaining -= take;
    }
    Ok(out)
}

fn read_u32s<R: Read>(r: &mut R, count: usize) -> Result<Vec<u32>, IoError> {
    let mut out = Vec::with_capacity(count);
    let mut buf = [0u8; 4 * 1024];
    let mut remaining = count;
    while remaining > 0 {
        let take = remaining.min(1024);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes)?;
        for c in bytes.chunks_exact(4) {
            out.push(u32::from_le_bytes(c.try_into().expect("4-byte chunk")));
        }
        remaining -= take;
    }
    Ok(out)
}

/// Read a `.csrbin` snapshot, returning the graph and the
/// [`LoadOptions`] it was ingested with. Every structural invariant is
/// validated — a corrupt cache is an [`IoError::Format`], never a
/// panic deep inside a solver.
pub fn read_csrbin<P: AsRef<Path>>(path: P) -> Result<(Graph, LoadOptions), IoError> {
    let f = std::fs::File::open(path)?;
    let mut r = std::io::BufReader::new(f);
    let mut header = [0u8; CSRBIN_HEADER_LEN];
    r.read_exact(&mut header)?;
    if header[..4] != CSRBIN_MAGIC {
        return Err(IoError::Format("bad magic (not a csrbin file)".into()));
    }
    let version = u32::from_le_bytes(header[4..8].try_into().expect("4 bytes"));
    if version != CSRBIN_VERSION {
        return Err(IoError::Format(format!(
            "unsupported version {version} (this build reads {CSRBIN_VERSION})"
        )));
    }
    let dangling = policy_from_byte(header[8])
        .ok_or_else(|| IoError::Format(format!("unknown dangling-policy byte {}", header[8])))?;
    let opts = LoadOptions { dangling, remap_ids: header[9] != 0 };
    let n = usize::try_from(u64::from_le_bytes(header[12..20].try_into().expect("8 bytes")))
        .map_err(|_| IoError::Format("n does not fit usize".into()))?;
    let m = usize::try_from(u64::from_le_bytes(header[20..28].try_into().expect("8 bytes")))
        .map_err(|_| IoError::Format("m does not fit usize".into()))?;
    let offsets = read_u64s(&mut r, n + 1)?;
    let targets = read_u32s(&mut r, m)?;
    if offsets.first() != Some(&0) || offsets.last() != Some(&m) {
        return Err(IoError::Format("offsets must start at 0 and end at m".into()));
    }
    if offsets.windows(2).any(|w| w[0] > w[1]) {
        return Err(IoError::Format("offsets not monotone".into()));
    }
    if targets.iter().any(|&t| t as usize >= n) {
        return Err(IoError::Format("target id out of range".into()));
    }
    for k in 0..n {
        if targets[offsets[k]..offsets[k + 1]].windows(2).any(|w| w[0] >= w[1]) {
            return Err(IoError::Format(format!("row {k} not sorted/deduplicated")));
        }
    }
    Ok((Graph::from_csr_parts(n, offsets, targets), opts))
}

/// The sidecar cache path for a text corpus: `<path>.csrbin`.
pub fn csrbin_path<P: AsRef<Path>>(path: P) -> std::path::PathBuf {
    let mut os = path.as_ref().as_os_str().to_os_string();
    os.push(".csrbin");
    std::path::PathBuf::from(os)
}

/// Load a text edge list through the `.csrbin` sidecar cache: serve the
/// binary snapshot when it is fresh (newer than the text) and was built
/// with the same [`LoadOptions`]; otherwise stream-parse the text and
/// (best-effort) rewrite the cache.
pub fn load_cached<P: AsRef<Path>>(path: P, opts: &LoadOptions) -> Result<Graph, IoError> {
    let path = path.as_ref();
    let cache = csrbin_path(path);
    if let (Ok(src_meta), Ok(cache_meta)) = (std::fs::metadata(path), std::fs::metadata(&cache)) {
        let fresh = match (src_meta.modified(), cache_meta.modified()) {
            (Ok(src), Ok(cached)) => cached >= src,
            _ => false,
        };
        if fresh {
            if let Ok((g, cached_opts)) = read_csrbin(&cache) {
                if cached_opts == *opts {
                    return Ok(g);
                }
            }
        }
    }
    let g = load_with(path, opts)?;
    let _ = write_csrbin(&g, &cache, opts); // best-effort; cold runs still work
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn parse_basic() {
        let text = "# a comment\n0 1\n1 2\n2 0\n";
        let g = read_edge_list(text.as_bytes(), DanglingPolicy::Error).expect("parses");
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert!(g.has_edge(2, 0));
    }

    #[test]
    fn nodes_header_respected() {
        let text = "# nodes: 5\n0 1\n1 0\n";
        let g = read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop).expect("parses");
        assert_eq!(g.n(), 5);
        assert!(g.has_self_loop(4)); // repaired dangling trailing node
    }

    #[test]
    fn snap_style_header_and_comments() {
        let text = "# Directed graph (each unordered pair of nodes is saved once)\n\
                    % another comment dialect\n\
                    # Nodes: 4 Edges: 3\n\
                    # FromNodeId\tToNodeId\n\
                    0\t1\n1\t2\n2\t0\n";
        let g = read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop).expect("parses");
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4); // 3 real edges + repaired node 3
        assert!(g.has_self_loop(3));
    }

    #[test]
    fn malformed_nodes_header_is_positioned_error() {
        // The old loader silently ignored this (`parse().ok()`).
        let text = "0 1\n# nodes: twelve\n1 0\n";
        match read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop) {
            Err(IoError::Parse { line, .. }) => assert_eq!(line, 2),
            other => panic!("expected positioned parse error, got {other:?}"),
        }
    }

    #[test]
    fn under_declared_nodes_header_is_rejected() {
        let text = "# nodes: 2\n0 1\n1 2\n2 0\n";
        match read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop) {
            Err(IoError::Parse { line, content }) => {
                assert_eq!(line, 1);
                assert!(content.contains("under-declares"), "{content}");
            }
            other => panic!("expected under-declaration error, got {other:?}"),
        }
    }

    #[test]
    fn bad_line_reports_position() {
        let text = "0 1\nnot an edge\n";
        match read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop) {
            Err(IoError::Parse { line, .. }) => assert_eq!(line, 2),
            other => panic!("expected parse error, got {other:?}"),
        }
    }

    #[test]
    fn three_fields_is_error() {
        let text = "0 1 7\n";
        assert!(matches!(
            read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop),
            Err(IoError::Parse { .. })
        ));
    }

    #[test]
    fn empty_input_is_empty_graph() {
        let g = read_edge_list("".as_bytes(), DanglingPolicy::Error).expect("ok");
        assert_eq!(g.n(), 0);
    }

    #[test]
    fn streaming_matches_builder_with_duplicates_and_self_loops() {
        let text = "2 0\n0 1\n0 1\n1 1\n2 2\n0 2\n";
        for policy in [DanglingPolicy::Error, DanglingPolicy::SelfLoop, DanglingPolicy::LinkAll] {
            let streamed = read_edge_list(text.as_bytes(), policy).expect("streams");
            let mut b = GraphBuilder::new(3).dangling_policy(policy);
            b.extend([(2, 0), (0, 1), (0, 1), (1, 1), (2, 2), (0, 2)]);
            let built = b.build().expect("builds");
            assert_eq!(streamed, built, "{policy:?}");
        }
    }

    #[test]
    fn dangling_error_policy_reports_first_dangler() {
        let text = "0 1\n1 0\n3 0\n";
        match read_edge_list(text.as_bytes(), DanglingPolicy::Error) {
            Err(IoError::Build(BuildError::Dangling(k))) => assert_eq!(k, 2),
            other => panic!("expected dangling error, got {other:?}"),
        }
    }

    #[test]
    fn remap_compacts_sparse_snap_ids() {
        // SNAP-style sparse ids: 1000, 42, 7 → first-seen dense ids.
        let text = "1000 42\n42 7\n7 1000\n";
        let mut bytes = std::io::Cursor::new(text.as_bytes().to_vec());
        let opts = LoadOptions::new(DanglingPolicy::Error).remap_ids(true);
        let g = read_edge_list_streaming(&mut bytes, &opts).expect("remaps");
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        // first-seen order: 1000→0, 42→1, 7→2
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(1, 2));
        assert!(g.has_edge(2, 0));
    }

    #[test]
    fn unremapped_id_past_u32_is_rejected() {
        let text = format!("0 {}\n", u64::from(u32::MAX) + 1);
        assert!(matches!(
            read_edge_list(text.as_bytes(), DanglingPolicy::SelfLoop),
            Err(IoError::Parse { line: 1, .. })
        ));
    }

    #[test]
    fn round_trip() {
        let g = generators::er_threshold(40, 0.5, 77);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).expect("writes");
        let g2 = read_edge_list(buf.as_slice(), DanglingPolicy::Error).expect("parses");
        assert_eq!(g, g2);
    }

    #[test]
    fn file_round_trip() {
        let g = generators::ring(10);
        let dir = std::env::temp_dir().join(format!("prmp_io_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = dir.join("g.txt");
        save(&g, &path).expect("saves");
        let g2 = load(&path, DanglingPolicy::Error).expect("loads");
        assert_eq!(g, g2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csrbin_round_trips_and_caches() {
        let g = generators::barabasi_albert(60, 3, 5);
        let dir = std::env::temp_dir().join(format!("prmp_csrbin_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let text = dir.join("g.txt");
        save(&g, &text).expect("saves");
        let opts = LoadOptions::new(DanglingPolicy::LinkAll);

        // Cold: parses text, writes the sidecar.
        let cold = load_cached(&text, &opts).expect("cold load");
        assert_eq!(cold, g);
        assert!(csrbin_path(&text).exists(), "sidecar must be written");

        // Direct binary round-trip.
        let (bin, bin_opts) = read_csrbin(csrbin_path(&text)).expect("reads back");
        assert_eq!(bin, g);
        assert_eq!(bin_opts, opts);

        // Warm: served from the cache (corrupt the text to prove the
        // binary path is taken — the cache is still newer).
        let warm = load_cached(&text, &opts).expect("warm load");
        assert_eq!(warm, g);

        // Option mismatch falls back to the text parse.
        let other = LoadOptions::new(DanglingPolicy::SelfLoop);
        let reparsed = load_cached(&text, &other).expect("mismatched opts reload");
        assert_eq!(reparsed, g); // no dangling pages, so same graph

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csrbin_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("prmp_csrbin_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let path = dir.join("bad.csrbin");
        std::fs::write(&path, b"definitely not a csrbin file").expect("writes");
        assert!(matches!(read_csrbin(&path), Err(IoError::Format(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_io_error() {
        assert!(matches!(
            load("/definitely/not/here.txt", DanglingPolicy::Error),
            Err(IoError::Io(_))
        ));
    }
}
