//! Strongly-connected components (iterative Tarjan).
//!
//! Algorithm 2 (network size estimation) assumes the web graph is
//! strongly connected — the nullspace of `C = (I-A)ᵀ` is one-dimensional
//! exactly then. [`is_strongly_connected`] gates the estimator with a
//! clear error instead of silently returning garbage.

use super::csr::Graph;

/// Tarjan's algorithm, iterative (explicit stack; web-scale graphs would
/// blow the call stack recursively). Returns a component id per node;
/// ids are in reverse topological order of the condensation.
pub fn tarjan_scc(g: &Graph) -> Vec<usize> {
    let n = g.n();
    const UNSET: usize = usize::MAX;
    let mut index = vec![UNSET; n];
    let mut lowlink = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut comp = vec![UNSET; n];
    let mut stack: Vec<usize> = Vec::new();
    let mut next_index = 0usize;
    let mut next_comp = 0usize;

    // Explicit DFS frames: (node, out-edge cursor).
    let mut frames: Vec<(usize, usize)> = Vec::new();

    for root in 0..n {
        if index[root] != UNSET {
            continue;
        }
        frames.push((root, 0));
        index[root] = next_index;
        lowlink[root] = next_index;
        next_index += 1;
        stack.push(root);
        on_stack[root] = true;

        while let Some(&mut (v, ref mut cursor)) = frames.last_mut() {
            if *cursor < g.out_degree(v) {
                let w = g.out(v)[*cursor] as usize;
                *cursor += 1;
                if index[w] == UNSET {
                    index[w] = next_index;
                    lowlink[w] = next_index;
                    next_index += 1;
                    stack.push(w);
                    on_stack[w] = true;
                    frames.push((w, 0));
                } else if on_stack[w] {
                    lowlink[v] = lowlink[v].min(index[w]);
                }
            } else {
                frames.pop();
                if let Some(&mut (parent, _)) = frames.last_mut() {
                    lowlink[parent] = lowlink[parent].min(lowlink[v]);
                }
                if lowlink[v] == index[v] {
                    // v is an SCC root: pop its component.
                    loop {
                        let w = stack.pop().expect("tarjan stack underflow");
                        on_stack[w] = false;
                        comp[w] = next_comp;
                        if w == v {
                            break;
                        }
                    }
                    next_comp += 1;
                }
            }
        }
    }
    comp
}

/// Number of strongly-connected components.
pub fn scc_count(g: &Graph) -> usize {
    if g.n() == 0 {
        return 0;
    }
    tarjan_scc(g).iter().max().expect("nonempty") + 1
}

/// Whether the graph is strongly connected (Algorithm 2's requirement).
pub fn is_strongly_connected(g: &Graph) -> bool {
    g.n() > 0 && scc_count(g) == 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::graph::GraphBuilder;

    #[test]
    fn ring_is_one_scc() {
        assert!(is_strongly_connected(&generators::ring(10)));
    }

    #[test]
    fn star_is_one_scc() {
        assert!(is_strongly_connected(&generators::star(7)));
    }

    #[test]
    fn two_rings_are_two_sccs() {
        let mut b = GraphBuilder::new(6);
        for i in 0..3 {
            b.add_edge(i, (i + 1) % 3);
            b.add_edge(3 + i, 3 + (i + 1) % 3);
        }
        // one-way bridge keeps them separate components
        b.add_edge(0, 3);
        let g = b.build().expect("builds");
        assert_eq!(scc_count(&g), 2);
        assert!(!is_strongly_connected(&g));
        let comp = tarjan_scc(&g);
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[3], comp[4]);
        assert_ne!(comp[0], comp[3]);
    }

    #[test]
    fn dag_chain_all_singletons() {
        let mut b = GraphBuilder::new(4).dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 1).add_edge(1, 2).add_edge(2, 3);
        let g = b.build().expect("builds");
        assert_eq!(scc_count(&g), 4);
    }

    #[test]
    fn dense_er_is_strongly_connected() {
        // At p=0.5, N=100 the digraph is strongly connected w.h.p.
        assert!(is_strongly_connected(&generators::er_threshold(100, 0.5, 5)));
    }

    #[test]
    fn reverse_topological_component_ids() {
        // 0 -> 1 (two singleton SCCs): sink component gets the smaller id.
        let mut b = GraphBuilder::new(2).dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 1);
        let g = b.build().expect("builds");
        let comp = tarjan_scc(&g);
        assert!(comp[1] < comp[0]);
    }

    #[test]
    fn empty_graph() {
        let g = GraphBuilder::new(0).build().expect("builds");
        assert_eq!(scc_count(&g), 0);
        assert!(!is_strongly_connected(&g));
    }

    #[test]
    fn deep_path_no_stack_overflow() {
        // 50k-node path — recursion would overflow; iterative must not.
        let n = 50_000;
        let mut b = GraphBuilder::new(n).dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        for i in 0..n - 1 {
            b.add_edge(i, i + 1);
        }
        let g = b.build().expect("builds");
        assert_eq!(scc_count(&g), n);
    }
}
