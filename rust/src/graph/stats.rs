//! Degree statistics and structural summaries — used by the CLI's
//! `graph-info` command and by experiment reports to describe workloads.

use super::csr::Graph;

/// Summary of a graph's degree structure.
#[derive(Debug, Clone, PartialEq)]
pub struct DegreeStats {
    pub n: usize,
    pub m: usize,
    pub min_out: usize,
    pub max_out: usize,
    pub mean_out: f64,
    pub min_in: usize,
    pub max_in: usize,
    pub self_loops: usize,
    pub dangling: usize,
    /// Edge density m / (n * (n-1)).
    pub density: f64,
}

impl DegreeStats {
    pub fn compute(g: &Graph) -> DegreeStats {
        let n = g.n();
        let m = g.m();
        let mut min_out = usize::MAX;
        let mut max_out = 0;
        let mut min_in = usize::MAX;
        let mut max_in = 0;
        let mut self_loops = 0;
        let mut dangling = 0;
        // In-degrees via a counting scan over the out-CSR: stats must not
        // force (or trip over) the lazy in-CSR — `graph-info` on an
        // in-link-free corpus graph stays out-only.
        let mut in_deg = vec![0usize; n];
        for k in 0..n {
            for &d in g.out(k) {
                in_deg[d as usize] += 1;
            }
        }
        for k in 0..n {
            let od = g.out_degree(k);
            let id = in_deg[k];
            min_out = min_out.min(od);
            max_out = max_out.max(od);
            min_in = min_in.min(id);
            max_in = max_in.max(id);
            if g.has_self_loop(k) {
                self_loops += 1;
            }
            if od == 0 {
                dangling += 1;
            }
        }
        if n == 0 {
            min_out = 0;
            min_in = 0;
        }
        DegreeStats {
            n,
            m,
            min_out,
            max_out,
            mean_out: if n == 0 { 0.0 } else { m as f64 / n as f64 },
            min_in,
            max_in,
            self_loops,
            dangling,
            density: if n > 1 {
                m as f64 / (n as f64 * (n as f64 - 1.0))
            } else {
                0.0
            },
        }
    }

    /// Multi-line human-readable rendering.
    pub fn render(&self) -> String {
        format!(
            "nodes            {}\n\
             edges            {}\n\
             out-degree       min {} / mean {:.2} / max {}\n\
             in-degree        min {} / max {}\n\
             self-loops       {}\n\
             dangling         {}\n\
             density          {:.4}",
            self.n,
            self.m,
            self.min_out,
            self.mean_out,
            self.max_out,
            self.min_in,
            self.max_in,
            self.self_loops,
            self.dangling,
            self.density
        )
    }
}

/// Out-degree histogram with power-of-two buckets: entry `i` counts nodes
/// with out-degree in `[2^i, 2^(i+1))` (entry 0 additionally counts 0).
pub fn out_degree_histogram(g: &Graph) -> Vec<usize> {
    let mut hist: Vec<usize> = Vec::new();
    for k in 0..g.n() {
        let d = g.out_degree(k);
        let bucket = if d <= 1 { 0 } else { (usize::BITS - (d as usize).leading_zeros()) as usize - 1 };
        if hist.len() <= bucket {
            hist.resize(bucket + 1, 0);
        }
        hist[bucket] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn stats_on_star() {
        let g = generators::star(5);
        let s = DegreeStats::compute(&g);
        assert_eq!(s.n, 5);
        assert_eq!(s.m, 8);
        assert_eq!(s.max_out, 4);
        assert_eq!(s.min_out, 1);
        assert_eq!(s.dangling, 0);
        assert_eq!(s.self_loops, 0);
        assert!((s.mean_out - 1.6).abs() < 1e-12);
    }

    #[test]
    fn stats_density_complete() {
        let g = generators::complete(6);
        let s = DegreeStats::compute(&g);
        assert!((s.density - 1.0).abs() < 1e-12);
    }

    #[test]
    fn render_contains_fields() {
        let g = generators::ring(4);
        let txt = DegreeStats::compute(&g).render();
        assert!(txt.contains("nodes            4"));
        assert!(txt.contains("edges            4"));
    }

    #[test]
    fn histogram_buckets() {
        let g = generators::star(9); // hub out-degree 8, leaves 1
        let h = out_degree_histogram(&g);
        assert_eq!(h[0], 8); // eight leaves with degree 1
        assert_eq!(*h.last().expect("nonempty"), 1); // hub in [8,16)
        assert_eq!(h.len(), 4);
    }

    #[test]
    fn stats_never_touch_the_lazy_in_csr() {
        let g = generators::star(5).without_in_links();
        let s = DegreeStats::compute(&g);
        assert_eq!(s.max_in, 4); // hub receives a link from every leaf
        assert_eq!(s.min_in, 1);
        assert!(!g.in_links_built());
    }

    #[test]
    fn empty_graph_stats() {
        let g = crate::graph::GraphBuilder::new(0).build().expect("builds");
        let s = DegreeStats::compute(&g);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean_out, 0.0);
    }
}
