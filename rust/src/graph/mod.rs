//! Web-graph substrate.
//!
//! The paper models the web as a directed graph whose column-stochastic
//! hyperlink matrix `A` has `A[i][j] = 1/N_j` iff page `j` links to page
//! `i` (`N_j` = out-degree of `j`). Everything downstream — Algorithm 1's
//! out-neighbour reads/writes, the baselines' in-neighbour requirements,
//! the simulated network topology — is derived from the [`Graph`] type
//! defined here.
//!
//! * [`csr`] — compressed sparse row storage: the out-CSR always, the
//!   in-adjacency built lazily on first use (MP needs only out-links;
//!   the baselines [6]/[12]/[15] need in-links, which is exactly the
//!   paper's critique of them — so corpus-scale out-only runs never pay
//!   the transpose's memory).
//! * [`builder`] — edge accumulation, dedup, dangling-page repair.
//! * [`generators`] — synthetic families including the paper §III
//!   ER-threshold model and the corpus-scale `webgraph` family.
//! * [`io`] — streaming edge-list ingest (two-pass, straight into CSR),
//!   plain-text writing, and the `.csrbin` binary cache.
//! * [`stats`] — degree summaries.
//! * [`scc`] — Tarjan strongly-connected components (Algorithm 2 assumes
//!   strong connectivity).
//! * [`partition`] — topology-aware page→shard owner tables (seeded
//!   label propagation and SCC condensation, balance-bounded packing)
//!   behind the `cluster`/`scc` shard maps.

pub mod builder;
pub mod csr;
pub mod generators;
pub mod io;
pub mod partition;
pub mod scc;
pub mod stats;

pub use builder::{DanglingPolicy, GraphBuilder};
pub use csr::Graph;
pub use io::LoadOptions;
