//! Topology-aware page→shard partitions (ROADMAP "topology-aware
//! sharding").
//!
//! Suzuki–Ishii get their distributed-PageRank speedup from web
//! *clustering*: most hyperlinks are intra-cluster, so placing whole
//! clusters on one shard makes most neighbourhood claims (sharded
//! runtime) and most `ResidualUpdate` subscriptions (msgpass backend)
//! local. This module builds the owner tables behind the `cluster` and
//! `scc` shard maps:
//!
//! * [`label_propagation`] — deterministic seeded label propagation over
//!   the out-CSR only (no in-links: the sharded runtime must resolve on
//!   graphs loaded `without_in_links`).
//! * [`scc_labels`] — condensation components from the existing
//!   iterative [`tarjan_scc`].
//! * [`pack_labels`] — balance-bounded largest-first greedy bin-packing
//!   of clusters onto shards: locality comes from keeping clusters
//!   whole, while a hard [`BALANCE_SLACK`] capacity cap keeps one giant
//!   cluster from starving the other workers (clusters above the cap
//!   are split — balance wins over locality at the margin).
//! * [`OwnerTable`] — the Arc-shared table form implementing the same
//!   `owner` / `owned_count` / `owned_page` / `local_index` contract as
//!   the closed-form `mod`/`block` maps, with pages ascending within
//!   each shard so `local_index` stays monotone in page id (the
//!   residual samplers rely on a deterministic ascending update order).
//!
//! Partitions are resolved with a *fixed* internal seed
//! ([`PARTITION_SEED`]), deliberately not the scenario seed: both
//! runtimes must resolve the identical partition for the same
//! `(graph, shards)` so sharded-vs-msgpass locality cells are
//! comparable and the `sharded:1:1:cluster:worker ≡ mp` equivalence pin
//! holds for every run seed.

use std::sync::Arc;

use crate::graph::scc::tarjan_scc;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// Fixed internal seed for label propagation (see module docs for why
/// this is not the scenario seed).
pub const PARTITION_SEED: u64 = 0x7061_7274; // "part"

/// Maximum label-propagation sweeps before accepting the labels as-is
/// (the sweep loop stops earlier as soon as a pass changes nothing).
pub const MAX_SWEEPS: usize = 10;

/// Per-shard capacity slack over the perfectly balanced `n/shards`.
pub const BALANCE_SLACK: f64 = 1.25;

/// Per-shard page capacity under the balance bound:
/// `max(⌈BALANCE_SLACK·n/shards⌉, ⌈n/shards⌉)`. The second term makes
/// the packing always feasible (`shards · capacity ≥ n`).
pub fn shard_capacity(n: usize, shards: usize) -> usize {
    assert!(shards > 0, "capacity needs at least one shard");
    let slack = (BALANCE_SLACK * n as f64 / shards as f64).ceil() as usize;
    slack.max(n.div_ceil(shards))
}

/// Table-backed page→shard map: a shared owner array plus the per-shard
/// owned-page index. Cheap to clone (all Arcs) so every worker thread
/// holds its own handle.
#[derive(Debug, Clone)]
pub struct OwnerTable {
    shards: usize,
    /// `owner[k]` = shard that owns page `k`.
    owner: Arc<[u32]>,
    /// Pages grouped by shard, ascending within each shard.
    pages: Arc<[u32]>,
    /// `pages[starts[w]..starts[w+1]]` = shard `w`'s pages (len shards+1).
    starts: Arc<[usize]>,
    /// `local[k]` = index of `k` within its shard's page slice.
    local: Arc<[u32]>,
}

impl OwnerTable {
    /// Build the grouped index from a raw owner vector. Every entry must
    /// be `< shards`; pages stay ascending within each shard.
    pub fn from_owner_vec(owner: Vec<u32>, shards: usize) -> OwnerTable {
        assert!(shards > 0, "owner table needs at least one shard");
        let n = owner.len();
        let mut starts = vec![0usize; shards + 1];
        for &w in &owner {
            assert!((w as usize) < shards, "owner {w} out of range (shards = {shards})");
            starts[w as usize + 1] += 1;
        }
        for w in 0..shards {
            starts[w + 1] += starts[w];
        }
        let mut cursor = starts.clone();
        let mut pages = vec![0u32; n];
        let mut local = vec![0u32; n];
        for (k, &w) in owner.iter().enumerate() {
            let at = cursor[w as usize];
            pages[at] = k as u32;
            local[k] = (at - starts[w as usize]) as u32;
            cursor[w as usize] += 1;
        }
        OwnerTable {
            shards,
            owner: owner.into(),
            pages: pages.into(),
            starts: starts.into(),
            local: local.into(),
        }
    }

    /// Number of pages in the table.
    pub fn n(&self) -> usize {
        self.owner.len()
    }

    /// Number of shards the table partitions onto.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Shard that owns page `k`.
    #[inline]
    pub fn owner(&self, k: usize) -> usize {
        self.owner[k] as usize
    }

    /// Number of pages shard `w` owns.
    #[inline]
    pub fn owned_count(&self, w: usize) -> usize {
        self.starts[w + 1] - self.starts[w]
    }

    /// The `i`-th page owned by shard `w` (ascending in `i`).
    #[inline]
    pub fn owned_page(&self, w: usize, i: usize) -> usize {
        self.pages[self.starts[w] + i] as usize
    }

    /// Index of page `k` within its owner's page slice
    /// (`owned_page(owner(k), local_index(k)) == k`).
    #[inline]
    pub fn local_index(&self, k: usize) -> usize {
        self.local[k] as usize
    }
}

/// Deterministic seeded label propagation over the out-CSR.
///
/// Labels start as page ids; each sweep visits pages in a freshly
/// shuffled order and adopts the most frequent label among the closed
/// out-neighbourhood `{k} ∪ out(k)` (ties break to the smallest label).
/// Updates are asynchronous (within-sweep), which is what lets labels
/// flood through a cluster in a handful of sweeps. Single-threaded on
/// purpose: determinism is the contract, and resolution is a one-off
/// cost per `(graph, shards)`.
pub fn label_propagation(g: &Graph, seed: u64) -> Vec<u32> {
    let n = g.n();
    let mut labels: Vec<u32> = (0..n as u32).collect();
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = Rng::seeded(seed);
    let mut neigh: Vec<u32> = Vec::new();
    for _ in 0..MAX_SWEEPS {
        rng.shuffle(&mut order);
        let mut changed = 0usize;
        for &ku in &order {
            let k = ku as usize;
            neigh.clear();
            neigh.push(labels[k]);
            for &j in g.out(k) {
                neigh.push(labels[j as usize]);
            }
            neigh.sort_unstable();
            // Longest run wins; on equal counts the earlier (smaller)
            // label is kept.
            let mut best = neigh[0];
            let mut best_count = 0usize;
            let mut at = 0usize;
            while at < neigh.len() {
                let label = neigh[at];
                let mut end = at + 1;
                while end < neigh.len() && neigh[end] == label {
                    end += 1;
                }
                if end - at > best_count {
                    best = label;
                    best_count = end - at;
                }
                at = end;
            }
            if labels[k] != best {
                labels[k] = best;
                changed += 1;
            }
        }
        if changed == 0 {
            break;
        }
    }
    labels
}

/// Condensation-component labels from iterative Tarjan (out-CSR only).
pub fn scc_labels(g: &Graph) -> Vec<u32> {
    tarjan_scc(g).into_iter().map(|c| c as u32).collect()
}

/// Pack cluster labels onto shards: balance-bounded largest-first
/// greedy. Clusters (pages sharing a label) are placed whole where
/// possible — largest first, each into the shard with the most free
/// capacity (ties → lowest shard id) — and split across shards only
/// when they exceed the [`shard_capacity`] cap, so no shard ever owns
/// more than the cap.
pub fn pack_labels(labels: &[u32], shards: usize) -> Vec<u32> {
    assert!(shards > 0, "packing needs at least one shard");
    let n = labels.len();
    // Group pages by label; within a group pages stay ascending.
    let mut by_label: Vec<u32> = (0..n as u32).collect();
    by_label.sort_unstable_by_key(|&k| (labels[k as usize], k));
    let mut clusters: Vec<(usize, usize)> = Vec::new(); // (start, len) runs
    let mut at = 0usize;
    while at < n {
        let label = labels[by_label[at] as usize];
        let mut end = at + 1;
        while end < n && labels[by_label[end] as usize] == label {
            end += 1;
        }
        clusters.push((at, end - at));
        at = end;
    }
    // Largest first; equal sizes break on the smallest member page so
    // the order (and thus the packing) is fully deterministic.
    clusters.sort_unstable_by_key(|&(start, len)| (std::cmp::Reverse(len), by_label[start]));

    let cap = shard_capacity(n, shards);
    let mut free = vec![cap; shards];
    let mut owner = vec![0u32; n];
    for &(start, len) in &clusters {
        let mut placed = 0usize;
        while placed < len {
            let w = (0..shards)
                .max_by_key(|&w| (free[w], std::cmp::Reverse(w)))
                .expect("at least one shard");
            debug_assert!(free[w] > 0, "packing infeasible: total capacity < n");
            let take = (len - placed).min(free[w]);
            for &k in &by_label[start + placed..start + placed + take] {
                owner[k as usize] = w as u32;
            }
            free[w] -= take;
            placed += take;
        }
    }
    owner
}

/// The `cluster` map: seeded label propagation + balance-bounded
/// packing, as an [`OwnerTable`].
pub fn cluster_partition(g: &Graph, shards: usize) -> OwnerTable {
    let labels = label_propagation(g, PARTITION_SEED);
    OwnerTable::from_owner_vec(pack_labels(&labels, shards), shards)
}

/// The `scc` map: condensation components + balance-bounded packing.
pub fn scc_partition(g: &Graph, shards: usize) -> OwnerTable {
    let labels = scc_labels(g);
    OwnerTable::from_owner_vec(pack_labels(&labels, shards), shards)
}

/// Fraction of out-edges `(k → j)` whose endpoints live on different
/// shards under `owner` — the locality gauge both runtimes report.
/// `0.0` on edge-free graphs.
pub fn cross_edge_fraction<F: Fn(usize) -> usize>(g: &Graph, owner: F) -> f64 {
    let mut total = 0u64;
    let mut cross = 0u64;
    for k in 0..g.n() {
        let wk = owner(k);
        for &j in g.out(k) {
            total += 1;
            if owner(j as usize) != wk {
                cross += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        cross as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn check_contract(t: &OwnerTable, n: usize, shards: usize) {
        assert_eq!(t.n(), n);
        assert_eq!(t.shards(), shards);
        let mut seen = vec![false; n];
        let mut total = 0usize;
        for w in 0..shards {
            let owned = t.owned_count(w);
            total += owned;
            let mut prev: Option<usize> = None;
            for i in 0..owned {
                let k = t.owned_page(w, i);
                assert!(k < n);
                assert!(!seen[k], "page {k} owned twice");
                seen[k] = true;
                assert_eq!(t.owner(k), w);
                assert_eq!(t.local_index(k), i);
                if let Some(p) = prev {
                    assert!(k > p, "pages not ascending within shard {w}");
                }
                prev = Some(k);
            }
        }
        assert_eq!(total, n, "pages not partitioned exactly once");
    }

    #[test]
    fn owner_table_contract_on_every_family_and_shard_count() {
        let graphs = [
            generators::sbm_two_block(60, 0.3, 0.02, 7),
            generators::chain(23),
            generators::erdos_renyi(40, 0.1, 11),
        ];
        for g in &graphs {
            for shards in [1usize, 2, 4, 7] {
                check_contract(&cluster_partition(g, shards), g.n(), shards);
                check_contract(&scc_partition(g, shards), g.n(), shards);
            }
        }
    }

    #[test]
    fn balance_bound_holds_even_with_one_giant_cluster() {
        // ring(n) is one SCC and label propagation coalesces chains —
        // the single giant cluster must be split to respect the cap.
        let g = generators::ring(30);
        for shards in [2usize, 3, 4] {
            let cap = shard_capacity(30, shards);
            for t in [cluster_partition(&g, shards), scc_partition(&g, shards)] {
                for w in 0..shards {
                    assert!(
                        t.owned_count(w) <= cap,
                        "shard {w} owns {} > cap {cap}",
                        t.owned_count(w)
                    );
                }
            }
        }
    }

    #[test]
    fn label_propagation_is_deterministic_for_a_fixed_seed() {
        let g = generators::sbm_two_block(50, 0.3, 0.02, 3);
        let a = label_propagation(&g, PARTITION_SEED);
        let b = label_propagation(&g, PARTITION_SEED);
        assert_eq!(a, b);
        let c = label_propagation(&g, PARTITION_SEED + 1);
        assert_eq!(a.len(), c.len()); // different seed may differ, same shape
    }

    #[test]
    fn single_shard_tables_are_the_identity() {
        let g = generators::sbm_two_block(20, 0.3, 0.05, 5);
        for t in [cluster_partition(&g, 1), scc_partition(&g, 1)] {
            assert_eq!(t.owned_count(0), 20);
            for k in 0..20 {
                assert_eq!(t.owner(k), 0);
                assert_eq!(t.owned_page(0, k), k);
                assert_eq!(t.local_index(k), k);
            }
        }
    }

    #[test]
    fn cluster_map_beats_modulo_on_a_clustered_graph() {
        // Two dense blocks with sparse cross links: modulo interleaves
        // the blocks across shards (~half the edges cross), the cluster
        // map keeps each block nearly whole.
        let g = generators::sbm_two_block(80, 0.3, 0.02, 13);
        let shards = 2usize;
        let t = cluster_partition(&g, shards);
        let cluster_frac = cross_edge_fraction(&g, |k| t.owner(k));
        let mod_frac = cross_edge_fraction(&g, |k| k % shards);
        assert!(
            cluster_frac < mod_frac,
            "cluster {cluster_frac} not below modulo {mod_frac}"
        );
    }

    #[test]
    fn scc_map_keeps_small_components_whole() {
        // Two 3-rings joined by a one-way bridge: two SCCs, each should
        // land whole on its own shard (sizes fit the cap).
        let mut b = crate::graph::GraphBuilder::new(6);
        for i in 0..3 {
            b.add_edge(i, (i + 1) % 3);
            b.add_edge(3 + i, 3 + (i + 1) % 3);
        }
        b.add_edge(0, 3);
        let g = b.build().expect("builds");
        let t = scc_partition(&g, 2);
        assert_eq!(t.owner(0), t.owner(1));
        assert_eq!(t.owner(1), t.owner(2));
        assert_eq!(t.owner(3), t.owner(4));
        assert_eq!(t.owner(4), t.owner(5));
        assert_ne!(t.owner(0), t.owner(3));
    }

    #[test]
    fn capacity_is_always_feasible() {
        for n in [0usize, 1, 5, 100, 101] {
            for shards in [1usize, 2, 3, 8] {
                assert!(shards * shard_capacity(n, shards) >= n);
            }
        }
    }

    #[test]
    fn cross_edge_fraction_edge_cases() {
        let g = crate::graph::GraphBuilder::new(0).build().expect("builds");
        assert_eq!(cross_edge_fraction(&g, |_| 0), 0.0);
        let ring = generators::ring(4);
        // Everything on one shard: no cross edges.
        assert_eq!(cross_edge_fraction(&ring, |_| 0), 0.0);
        // Alternating owners on a ring: every edge crosses.
        assert_eq!(cross_edge_fraction(&ring, |k| k % 2), 1.0);
    }
}
