//! `bench_diff` — diff two perf artifacts and flag regressions.
//!
//! Compares a baseline and a candidate `BENCH_scenario.json`,
//! `BENCH_sweep.json`, `BENCH_throughput.json`, `BENCH_network.json`,
//! `BENCH_faults.json`, `BENCH_partitions.json` or `BENCH_locality.json`
//! (the artifacts CI uploads as `bench-json` on every push) and prints
//! one line per metric
//! that moved past the threshold. Exit code 1 when a regression is
//! found, 0 otherwise — the CI step runs it advisory
//! (`continue-on-error`), humans run it via `scripts/bench_diff`.
//!
//! ```text
//! bench_diff old/BENCH_sweep.json BENCH_sweep.json --threshold 0.15
//! ```
//!
//! Metrics and their direction (the threshold always means "worsened by
//! more than this fraction *of the baseline*", so 0.15 fires at the same
//! severity for every metric; keep it < 1 — losing an entire decay gap
//! caps that metric's worsening at 1.0):
//!
//! * `decay_rate`   — smaller is better (per-step error contraction);
//!   compared on `1 - rate` (the *gap to stagnation*), because rates sit
//!   near 1 and a relative test on the rate itself would never fire.
//! * `final_error`  — smaller is better.
//! * `final_size_rel_err` — smaller is better (size-estimation runs:
//!   the mean relative error of the per-page network-size estimates).
//! * `acts_per_sec` — larger is better (throughput sweep cells).
//! * `vtime_to_eps` — smaller is better (network race cells: virtual
//!   time to drive the scaled residual to the artifact's ε).
//! * `bytes_on_wire` — smaller is better (network race cells: total
//!   bytes the msgpass transport metered before reaching ε; fixed at 0
//!   for the shared-memory sharded opponent, so only msgpass cells can
//!   regress on it).
//! * `cross_conflict_rate` — smaller is better (locality race cells:
//!   the fraction of sampled candidates a *cross-shard* neighbour
//!   knocked out under optimistic packing — the dynamic price of the
//!   shard map; `BENCH_locality.json` runs one spec per graph family,
//!   so those cells are keyed `family :: spec`).
//!
//! `wall_ms` is deliberately ignored (CI runner noise); `null` decay
//! rates (diverged/instant-converged trajectories, see docs/ENGINE.md)
//! are skipped on either side, but a rate that *became* null is itself
//! reported as a regression. Entries present on only one side are
//! listed informationally and never fail the diff.

use std::collections::BTreeMap;
use std::process::ExitCode;

use pagerank_mp::util::json::Json;

/// One comparable row extracted from an artifact: a stable key plus the
/// metrics we track.
#[derive(Debug, Default, Clone)]
struct Row {
    decay_rate: Option<f64>,
    final_error: Option<f64>,
    final_size_rel_err: Option<f64>,
    acts_per_sec: Option<f64>,
    vtime_to_eps: Option<f64>,
    bytes_on_wire: Option<f64>,
    cross_conflict_rate: Option<f64>,
    load_ms: Option<f64>,
}

fn finite(v: Option<&Json>) -> Option<f64> {
    v.and_then(Json::as_f64).filter(|x| x.is_finite())
}

/// Flatten a run-summary object (the shared shape of
/// `BENCH_scenario.json` solvers/estimators and `BENCH_sweep.json` cell
/// entries).
fn run_row(s: &Json) -> Row {
    Row {
        decay_rate: finite(s.get("decay_rate")),
        final_error: finite(s.get("final_error")),
        final_size_rel_err: finite(s.get("final_size_rel_err")),
        acts_per_sec: finite(s.get("acts_per_sec")),
        vtime_to_eps: finite(s.get("vtime_to_eps")),
        bytes_on_wire: finite(s.get("bytes_on_wire")),
        cross_conflict_rate: finite(s.get("cross_conflict_rate")),
        load_ms: finite(s.get("load_ms")),
    }
}

/// The run-summary array of a scenario-shaped object: `"solvers"` for
/// PageRank runs, `"estimators"` for size-estimation runs.
fn runs_of(obj: &Json) -> Option<&[Json]> {
    obj.get("solvers")
        .or_else(|| obj.get("estimators"))
        .and_then(Json::as_array)
}

/// Extract `key -> Row` from any of the artifact kinds.
fn extract(doc: &Json) -> Result<BTreeMap<String, Row>, String> {
    let mut rows = BTreeMap::new();
    if doc.get("cells").is_some() {
        // BENCH_sweep.json (cells have "solvers"/"estimators") or
        // BENCH_throughput.json / BENCH_network.json (cells have "spec"
        // + metric fields — keyed by the full registry spec, so new
        // cell kinds like the sampling-policy sweep or the msgpass
        // network race land in the diff automatically).
        for (i, cell) in doc
            .get("cells")
            .and_then(Json::as_array)
            .unwrap_or(&[])
            .iter()
            .enumerate()
        {
            if let Some(runs) = runs_of(cell) {
                let name = cell.get("name").and_then(Json::as_str).unwrap_or("cell");
                for s in runs {
                    let run = s.get("name").and_then(Json::as_str).unwrap_or("?");
                    rows.insert(format!("{name} :: {run}"), run_row(s));
                }
            } else if let Some(spec) = cell.get("spec").and_then(Json::as_str) {
                // BENCH_locality.json runs the same registry spec once
                // per graph family — key those cells `family :: spec`
                // so they diff independently instead of silently
                // overwriting one another.
                let key = match cell.get("family").and_then(Json::as_str) {
                    Some(family) => format!("{family} :: {spec}"),
                    None => spec.to_string(),
                };
                rows.insert(key, run_row(cell));
            } else {
                // A cell this tool cannot key would silently fall out of
                // the regression diff — refuse instead, so schema drift
                // surfaces as a loud parse error, never as a metric that
                // quietly stopped being compared.
                return Err(format!(
                    "cell #{i} has neither \"solvers\"/\"estimators\" nor \"spec\" — \
                     unknown cell shape, refusing to silently skip it"
                ));
            }
        }
    } else if let Some(runs) = runs_of(doc) {
        // BENCH_scenario.json (PageRank or size-estimation experiment)
        let name = doc
            .get("scenario")
            .and_then(|s| s.get("name"))
            .and_then(Json::as_str)
            .unwrap_or("scenario");
        for s in runs {
            let run = s.get("name").and_then(Json::as_str).unwrap_or("?");
            rows.insert(format!("{name} :: {run}"), run_row(s));
        }
    } else {
        return Err(
            "unrecognized artifact: expected \"cells\", \"solvers\" or \"estimators\"".into(),
        );
    }
    if rows.is_empty() {
        return Err("artifact contains no comparable entries".into());
    }
    Ok(rows)
}

/// Relative worsening of a lower-is-better metric (`new` vs `old`),
/// measured against the baseline: `(new - old) / old`.
fn rel_increase(old: f64, new: f64) -> f64 {
    if old.abs() < f64::MIN_POSITIVE {
        if new.abs() < f64::MIN_POSITIVE {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (new - old) / old.abs()
    }
}

/// Fraction of a higher-is-better baseline value lost: `(old - new) /
/// old`. Keeps every metric's threshold on the same scale — "lost X% of
/// the baseline" — rather than silently tightening for drops.
fn rel_drop(old: f64, new: f64) -> f64 {
    if old.abs() < f64::MIN_POSITIVE {
        0.0 // no baseline to lose
    } else {
        (old - new) / old.abs()
    }
}

/// Compare one metric; returns a description when it regressed past the
/// threshold.
fn check(
    key: &str,
    metric: &str,
    old: Option<f64>,
    new: Option<f64>,
    threshold: f64,
    lower_is_better: bool,
) -> Option<String> {
    let (old, new) = match (old, new) {
        (Some(o), Some(n)) => (o, n),
        // A metric that *disappeared* (e.g. decay_rate fitted before,
        // null now: the solver stopped converging cleanly) is a
        // regression in its own right.
        (Some(o), None) if metric == "decay_rate" => {
            return Some(format!(
                "REGRESSION {key} :: {metric}: {o:.6} -> null (trajectory no longer fittable)"
            ))
        }
        _ => return None,
    };
    let worsening = if metric == "decay_rate" {
        // Rates live just below 1; compare the contraction gap 1-rate
        // (shrinking gap = slower convergence; losing the whole gap
        // caps the worsening at 1.0, so keep thresholds < 1).
        rel_drop(1.0 - old.min(1.0), 1.0 - new.min(1.0))
    } else if lower_is_better {
        rel_increase(old, new)
    } else {
        rel_drop(old, new)
    };
    if worsening > threshold {
        Some(format!(
            "REGRESSION {key} :: {metric}: {old:.6e} -> {new:.6e} ({:+.1}% worse)",
            worsening * 100.0
        ))
    } else {
        None
    }
}

fn run(old_path: &str, new_path: &str, threshold: f64) -> Result<Vec<String>, String> {
    let load = |p: &str| -> Result<BTreeMap<String, Row>, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("reading {p}: {e}"))?;
        extract(&Json::parse(&text).map_err(|e| format!("{p}: {e}"))?)
            .map_err(|e| format!("{p}: {e}"))
    };
    let old = load(old_path)?;
    let new = load(new_path)?;
    let mut findings = Vec::new();
    let mut compared = 0usize;
    for (key, o) in &old {
        let Some(n) = new.get(key) else {
            println!("note: {key} only in baseline (grid changed?)");
            continue;
        };
        compared += 1;
        for f in [
            check(key, "decay_rate", o.decay_rate, n.decay_rate, threshold, true),
            check(key, "final_error", o.final_error, n.final_error, threshold, true),
            check(
                key,
                "final_size_rel_err",
                o.final_size_rel_err,
                n.final_size_rel_err,
                threshold,
                true,
            ),
            check(key, "acts_per_sec", o.acts_per_sec, n.acts_per_sec, threshold, false),
            check(key, "vtime_to_eps", o.vtime_to_eps, n.vtime_to_eps, threshold, true),
            check(key, "bytes_on_wire", o.bytes_on_wire, n.bytes_on_wire, threshold, true),
            check(
                key,
                "cross_conflict_rate",
                o.cross_conflict_rate,
                n.cross_conflict_rate,
                threshold,
                true,
            ),
            check(key, "load_ms", o.load_ms, n.load_ms, threshold, true),
        ]
        .into_iter()
        .flatten()
        {
            findings.push(f);
        }
    }
    for key in new.keys() {
        if !old.contains_key(key) {
            println!("note: {key} only in candidate (new cell)");
        }
    }
    println!(
        "compared {compared} entr{} at threshold {:.0}%: {} regression(s)",
        if compared == 1 { "y" } else { "ies" },
        threshold * 100.0,
        findings.len()
    );
    Ok(findings)
}

const USAGE: &str = "usage: bench_diff <old.json> <new.json> [--threshold 0.15]";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut paths = Vec::new();
    let mut threshold = 0.15f64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--threshold" | "-t" => {
                threshold = match it.next().map(|v| v.parse::<f64>()) {
                    Some(Ok(t)) if t > 0.0 => t,
                    _ => {
                        eprintln!("bad --threshold\n{USAGE}");
                        return ExitCode::from(2);
                    }
                }
            }
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            _ => paths.push(a.clone()),
        }
    }
    let [old_path, new_path] = paths.as_slice() else {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    };
    match run(old_path, new_path, threshold) {
        Ok(findings) if findings.is_empty() => ExitCode::SUCCESS,
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario_doc(rate: f64, err: f64) -> String {
        format!(
            r#"{{"scenario": {{"name": "s"}}, "solvers": [
                 {{"name": "mp", "decay_rate": {rate}, "final_error": {err},
                  "reads": 10, "writes": 10, "activated": 5, "conflicts": 0,
                  "wall_ms": 1.0}}]}}"#
        )
    }

    #[test]
    fn extract_handles_all_three_artifact_shapes() {
        let scenario = Json::parse(&scenario_doc(0.999, 1e-9)).expect("json");
        let rows = extract(&scenario).expect("scenario shape");
        assert!(rows.contains_key("s :: mp"));

        let sweep = Json::parse(
            r#"{"sweep": "g", "cells": [
                 {"name": "g[n=10]", "params": {"n": 10},
                  "solvers": [{"name": "mp", "decay_rate": 0.99,
                               "final_error": 1e-8}]}]}"#,
        )
        .expect("json");
        let rows = extract(&sweep).expect("sweep shape");
        assert!(rows.contains_key("g[n=10] :: mp"));

        let thr = Json::parse(
            r#"{"bench": "throughput.sharded_sweep", "cells": [
                 {"spec": "sharded:8:64:mod:worker", "acts_per_sec": 1e6}]}"#,
        )
        .expect("json");
        let rows = extract(&thr).expect("throughput shape");
        assert_eq!(
            rows["sharded:8:64:mod:worker"].acts_per_sec,
            Some(1e6)
        );

        assert!(extract(&Json::parse("{}").expect("json")).is_err());
    }

    #[test]
    fn throughput_sampling_policy_cells_are_compared_not_skipped() {
        // The sampling-policy sweep keys its cells by the full registry
        // spec (":residual" suffix), so uniform and residual cells diff
        // independently…
        let doc = |uni: f64, res: f64| {
            format!(
                r#"{{"bench": "throughput.sharded_sweep", "cells": [
                     {{"spec": "sharded:8:1024:mod:worker", "packer": "worker",
                       "sampling": "uniform", "acts_per_sec": {uni}}},
                     {{"spec": "sharded:8:1024:mod:worker:residual", "packer": "worker",
                       "sampling": "residual", "acts_per_sec": {res}}}]}}"#
            )
        };
        let old = Json::parse(&doc(1e6, 5e5)).expect("json");
        let new = Json::parse(&doc(1e6, 3e5)).expect("json");
        let old_rows = extract(&old).expect("extracts");
        let new_rows = extract(&new).expect("extracts");
        assert_eq!(old_rows.len(), 2);
        let key = "sharded:8:1024:mod:worker:residual";
        let flagged = check(
            key,
            "acts_per_sec",
            old_rows[key].acts_per_sec,
            new_rows[key].acts_per_sec,
            0.15,
            false,
        );
        assert!(flagged.is_some(), "residual-cell throughput drop must flag");

        // …and a cell shape the tool cannot key is a loud error instead
        // of a silent skip.
        let unknown = Json::parse(
            r#"{"bench": "x", "cells": [{"mystery": 1, "acts_per_sec": 1e6}]}"#,
        )
        .expect("json");
        let err = extract(&unknown).expect_err("unknown cell shape must refuse");
        assert!(err.contains("cell #0"), "{err}");
    }

    #[test]
    fn webgraph_load_time_regressions_are_flagged() {
        // The webgraph section reports corpus load times keyed like any
        // other throughput cell; load_ms is a lower-is-better metric.
        let doc = |ms: f64| {
            format!(
                r#"{{"bench": "throughput.sharded_sweep", "cells": [
                     {{"spec": "webgraph-load:text", "load_ms": {ms},
                       "peak_rss_bytes": 123456.0}}]}}"#
            )
        };
        let old = extract(&Json::parse(&doc(1000.0)).expect("json")).expect("extracts");
        let new = extract(&Json::parse(&doc(1600.0)).expect("json")).expect("extracts");
        let key = "webgraph-load:text";
        assert_eq!(old[key].load_ms, Some(1000.0));
        let flagged = check(key, "load_ms", old[key].load_ms, new[key].load_ms, 0.15, true);
        assert!(flagged.is_some(), "a 60% slower corpus load must flag");
        let quiet = check(key, "load_ms", old[key].load_ms, Some(1050.0), 0.15, true);
        assert!(quiet.is_none(), "5% load-time jitter stays quiet");
    }

    #[test]
    fn extract_handles_size_estimation_artifacts() {
        // BENCH_scenario.json from a size-estimation experiment.
        let scenario = Json::parse(
            r#"{"scenario": {"name": "fig2"}, "estimators": [
                 {"name": "kaczmarz", "decay_rate": 0.997, "final_error": 1e-20,
                  "final_size_rel_err": 1e-8, "reads": 10, "writes": 10,
                  "activated": 5, "wall_ms": 1.0}]}"#,
        )
        .expect("json");
        let rows = extract(&scenario).expect("estimator scenario shape");
        assert_eq!(rows["fig2 :: kaczmarz"].final_size_rel_err, Some(1e-8));

        // A sweep whose cells carry estimators.
        let sweep = Json::parse(
            r#"{"sweep": "se", "cells": [
                 {"name": "se[n=10]", "params": {"n": 10},
                  "estimators": [{"name": "walk", "decay_rate": 0.99,
                                  "final_error": 1e-12, "final_size_rel_err": 1e-5}]}]}"#,
        )
        .expect("json");
        let rows = extract(&sweep).expect("estimator sweep shape");
        assert_eq!(rows["se[n=10] :: walk"].final_size_rel_err, Some(1e-5));
    }

    #[test]
    fn size_rel_err_regressions_flagged() {
        let worse = check("k", "final_size_rel_err", Some(1e-8), Some(1e-6), 0.15, true);
        assert!(worse.is_some(), "100x worse size recovery must flag");
        let better = check("k", "final_size_rel_err", Some(1e-6), Some(1e-8), 0.15, true);
        assert!(better.is_none(), "improvements never flag");
        let absent = check("k", "final_size_rel_err", None, None, 0.15, true);
        assert!(absent.is_none(), "PageRank rows have no size metric");
    }

    #[test]
    fn flags_decay_and_throughput_regressions_but_not_noise() {
        // decay gap 1-0.99=1e-2 shrinking to 1-0.999=1e-3 means 10x
        // slower convergence — a regression; the reverse is a win.
        let worse = check("k", "decay_rate", Some(0.99), Some(0.999), 0.15, true);
        assert!(worse.is_some(), "gap shrank 10x: must flag");
        let better = check("k", "decay_rate", Some(0.999), Some(0.99), 0.15, true);
        assert!(better.is_none(), "improvements never flag");
        let gone = check("k", "decay_rate", Some(0.99), None, 0.15, true);
        assert!(gone.expect("flagged").contains("null"));

        let slow = check("k", "acts_per_sec", Some(1e6), Some(7e5), 0.15, false);
        assert!(slow.is_some(), "30% throughput drop must flag");
        let noise = check("k", "acts_per_sec", Some(1e6), Some(0.95e6), 0.15, false);
        assert!(noise.is_none(), "5% jitter within threshold");

        let err_up = check("k", "final_error", Some(1e-9), Some(1e-7), 0.15, true);
        assert!(err_up.is_some());
    }

    /// A trimmed-down `BENCH_network.json` fixture: one msgpass cell
    /// (with the wire ledger) and its shared-memory sharded opponent.
    fn network_doc(bytes: f64, vtime: f64) -> String {
        format!(
            r#"{{"bench": "throughput.network_sweep", "eps": 1e-6, "cells": [
                 {{"spec": "msgpass:4:64:mod", "backend": "msgpass", "shards": 4,
                   "latency": "zero", "converged": true, "super_steps": 900,
                   "acts_per_sec": 1e6, "messages_sent": 5000,
                   "bytes_on_wire": {bytes}, "vtime_to_eps": {vtime},
                   "peak_queue_depth": 12, "peak_in_flight": 3}},
                 {{"spec": "sharded:4:64:mod:worker", "backend": "sharded", "shards": 4,
                   "latency": "shared-memory", "converged": true, "super_steps": 900,
                   "acts_per_sec": 2e6, "messages_sent": 0,
                   "bytes_on_wire": 0, "vtime_to_eps": 900,
                   "peak_queue_depth": 0, "peak_in_flight": 0}}]}}"#
        )
    }

    #[test]
    fn network_artifact_diffs_bytes_and_vtime_to_eps() {
        let dir = std::env::temp_dir().join(format!("bench_diff_net_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let old = dir.join("old.json");
        let new = dir.join("new.json");
        std::fs::write(&old, network_doc(8.0e4, 950.0)).expect("write");
        // Candidate ships 50% more bytes and 30% more virtual time to
        // the same eps — both lower-is-better metrics must flag.
        std::fs::write(&new, network_doc(1.2e5, 1235.0)).expect("write");
        let findings = run(
            old.to_str().expect("utf8"),
            new.to_str().expect("utf8"),
            0.15,
        )
        .expect("network shape diffs");
        assert_eq!(findings.len(), 2, "{findings:?}");
        assert!(findings.iter().any(|f| f.contains("bytes_on_wire")), "{findings:?}");
        assert!(findings.iter().any(|f| f.contains("vtime_to_eps")), "{findings:?}");
        assert!(
            findings.iter().all(|f| f.contains("msgpass:4:64:mod")),
            "the zero-byte sharded opponent must not flag: {findings:?}"
        );
        // Identical artifacts diff clean (the sharded cell's 0-byte
        // ledger must not divide by zero into a phantom regression).
        let clean = run(
            old.to_str().expect("utf8"),
            old.to_str().expect("utf8"),
            0.15,
        )
        .expect("runs");
        assert!(clean.is_empty(), "{clean:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A trimmed-down `BENCH_locality.json` fixture: the same sharded
    /// and msgpass specs on two graph families — the shape that forces
    /// family-qualified keys.
    fn locality_doc(sbm_rate: f64, sbm_bytes: f64) -> String {
        format!(
            r#"{{"bench": "throughput.locality", "eps": 1e-6, "shards": 4, "cells": [
                 {{"spec": "sharded:4:64:cluster:worker", "backend": "sharded",
                   "family": "sbm", "map": "cluster", "activations": 50000,
                   "intra_conflicts": 900, "cross_conflicts": 400,
                   "cross_conflict_rate": {sbm_rate}, "cross_edge_fraction": 0.08,
                   "acts_per_sec": 1e6, "wall_ms": 10.0}},
                 {{"spec": "sharded:4:64:cluster:worker", "backend": "sharded",
                   "family": "er", "map": "cluster", "activations": 50000,
                   "intra_conflicts": 700, "cross_conflicts": 2100,
                   "cross_conflict_rate": 0.040, "cross_edge_fraction": 0.74,
                   "acts_per_sec": 1e6, "wall_ms": 10.0}},
                 {{"spec": "msgpass:4:64:cluster", "backend": "msgpass",
                   "family": "sbm", "map": "cluster", "converged": true,
                   "cross_messages": 4000, "cross_bytes": 64000,
                   "bytes_on_wire": {sbm_bytes}, "subscriber_fanout": 1.1,
                   "cross_edge_fraction": 0.08, "vtime_to_eps": 800.0,
                   "acts_per_sec": 1e6, "wall_ms": 10.0}}]}}"#
        )
    }

    #[test]
    fn locality_artifact_keys_by_family_and_diffs_cross_conflict_rate() {
        let old = extract(&Json::parse(&locality_doc(0.008, 9.0e4)).expect("json"))
            .expect("locality shape extracts");
        // Same spec, two families: both survive under family-qualified
        // keys instead of the last one silently winning.
        assert_eq!(old.len(), 3);
        assert_eq!(
            old["sbm :: sharded:4:64:cluster:worker"].cross_conflict_rate,
            Some(0.008)
        );
        assert_eq!(
            old["er :: sharded:4:64:cluster:worker"].cross_conflict_rate,
            Some(0.040)
        );
        assert_eq!(old["sbm :: msgpass:4:64:cluster"].bytes_on_wire, Some(9.0e4));

        // End to end: the candidate's cluster map crossing 50% more
        // often (and shipping 40% more bytes to ε) must flag on the
        // right family-qualified keys, and nothing else moves.
        let dir = std::env::temp_dir().join(format!("bench_diff_loc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let old_p = dir.join("old.json");
        let new_p = dir.join("new.json");
        std::fs::write(&old_p, locality_doc(0.008, 9.0e4)).expect("write");
        std::fs::write(&new_p, locality_doc(0.012, 1.26e5)).expect("write");
        let findings = run(
            old_p.to_str().expect("utf8"),
            new_p.to_str().expect("utf8"),
            0.15,
        )
        .expect("locality shape diffs");
        assert_eq!(findings.len(), 2, "{findings:?}");
        assert!(
            findings
                .iter()
                .any(|f| f.contains("sbm :: sharded:4:64:cluster:worker")
                    && f.contains("cross_conflict_rate")),
            "{findings:?}"
        );
        assert!(
            findings
                .iter()
                .any(|f| f.contains("sbm :: msgpass:4:64:cluster")
                    && f.contains("bytes_on_wire")),
            "{findings:?}"
        );
        // Identical artifacts diff clean.
        let clean = run(
            old_p.to_str().expect("utf8"),
            old_p.to_str().expect("utf8"),
            0.15,
        )
        .expect("runs");
        assert!(clean.is_empty(), "{clean:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faults_artifact_cells_are_keyed_by_their_full_fault_spec() {
        // BENCH_faults.json keys each (plan, mode) cell by the canonical
        // registry spec — drop/crash/rel segments and all — so raw and
        // reliable cells under the same plan diff independently, and the
        // wire metrics ride the existing lower-is-better machinery.
        let doc = |vtime: f64, bytes: f64| {
            format!(
                r#"{{"bench": "throughput.faults", "eps": 1e-6, "shards": 4, "cells": [
                     {{"spec": "msgpass:4:64:mod:drop0.05:rel", "mode": "rel",
                       "drop": 0.05, "converged": true, "final_residual": 9e-7,
                       "vtime_to_eps": {vtime}, "bytes_on_wire": {bytes},
                       "messages_dropped": 400, "duplicates_suppressed": 0,
                       "retransmits": 410, "recoveries": 0,
                       "residual_divergence_at_crash": 0.0, "abandoned": 0,
                       "wall_ms": 10.0}},
                     {{"spec": "msgpass:4:64:mod:drop0.05", "mode": "raw",
                       "drop": 0.05, "converged": false, "final_residual": 3e-3,
                       "vtime_to_eps": 9000, "bytes_on_wire": 5.0e5,
                       "messages_dropped": 420, "duplicates_suppressed": 0,
                       "retransmits": 0, "recoveries": 0,
                       "residual_divergence_at_crash": 0.0, "abandoned": 0,
                       "wall_ms": 10.0}}]}}"#
            )
        };
        let old = extract(&Json::parse(&doc(1500.0, 1.0e5)).expect("json")).expect("extracts");
        assert_eq!(old.len(), 2);
        assert_eq!(old["msgpass:4:64:mod:drop0.05:rel"].vtime_to_eps, Some(1500.0));
        assert_eq!(old["msgpass:4:64:mod:drop0.05"].bytes_on_wire, Some(5.0e5));
        // The reliable cell taking 40% more vtime (or wire bytes) to the
        // same eps is a protocol regression and must flag.
        let new = extract(&Json::parse(&doc(2100.0, 1.0e5)).expect("json")).expect("extracts");
        let key = "msgpass:4:64:mod:drop0.05:rel";
        let flagged = check(
            key,
            "vtime_to_eps",
            old[key].vtime_to_eps,
            new[key].vtime_to_eps,
            0.15,
            true,
        );
        assert!(flagged.is_some(), "reliable-mode vtime regression must flag");
    }

    #[test]
    fn partitions_artifact_cells_are_keyed_by_their_window_specs() {
        // BENCH_partitions.json cells carry the full registry spec with
        // link/partition/overlapping-crash segments, plus the divergence
        // gauges and heal counters — all of which must key and diff
        // like any other spec-shaped throughput cell.
        let doc = |vtime: f64| {
            format!(
                r#"{{"bench": "throughput.partitions", "eps": 1e-6, "shards": 4, "cells": [
                     {{"spec": "msgpass:4:64:mod:link0-1@400+200:rel", "mode": "rel",
                       "shape": "asymmetric-link", "drop": 0.0, "converged": true,
                       "final_residual": 9e-7, "vtime_to_eps": {vtime},
                       "bytes_on_wire": 1.0e5, "link_downs": 120,
                       "partitions_healed": 0, "rtt_estimate": 1.0,
                       "partition_divergence_onset": 0.0,
                       "partition_divergence_heal": 0.0,
                       "retransmits": 130, "abandoned": 0, "wall_ms": 10.0}},
                     {{"spec": "msgpass:4:64:mod:part0.1@400+200", "mode": "raw",
                       "shape": "healing-bipartition", "drop": 0.0, "converged": false,
                       "final_residual": 2e-4, "vtime_to_eps": 9000,
                       "bytes_on_wire": 3.0e5, "link_downs": 600,
                       "partitions_healed": 1, "rtt_estimate": 0.0,
                       "partition_divergence_onset": 1.2e-7,
                       "partition_divergence_heal": 4.0e-6,
                       "retransmits": 0, "abandoned": 0, "wall_ms": 10.0}},
                     {{"spec": "msgpass:4:64:mod:crash1@400+200:crash2@500+200:rel",
                       "mode": "rel", "shape": "overlapping-crashes", "drop": 0.0,
                       "converged": true, "final_residual": 8e-7,
                       "vtime_to_eps": 2200, "bytes_on_wire": 1.4e5,
                       "link_downs": 0, "partitions_healed": 0, "rtt_estimate": 1.0,
                       "partition_divergence_onset": 0.0,
                       "partition_divergence_heal": 0.0,
                       "retransmits": 300, "abandoned": 0, "wall_ms": 10.0}}]}}"#
            )
        };
        let old = extract(&Json::parse(&doc(1500.0)).expect("json")).expect("extracts");
        assert_eq!(old.len(), 3);
        assert_eq!(
            old["msgpass:4:64:mod:link0-1@400+200:rel"].vtime_to_eps,
            Some(1500.0)
        );
        assert_eq!(
            old["msgpass:4:64:mod:crash1@400+200:crash2@500+200:rel"].bytes_on_wire,
            Some(1.4e5)
        );
        // A reliable link-window cell taking 40% longer to recover to ε
        // is a protocol regression and must flag on its window-qualified
        // key.
        let new = extract(&Json::parse(&doc(2100.0)).expect("json")).expect("extracts");
        let key = "msgpass:4:64:mod:link0-1@400+200:rel";
        let flagged =
            check(key, "vtime_to_eps", old[key].vtime_to_eps, new[key].vtime_to_eps, 0.15, true);
        assert!(flagged.is_some(), "link-window recovery regression must flag");
    }

    #[test]
    fn run_end_to_end_on_disk() {
        let dir = std::env::temp_dir().join(format!("bench_diff_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let old = dir.join("old.json");
        let new = dir.join("new.json");
        std::fs::write(&old, scenario_doc(0.99, 1e-9)).expect("write");
        std::fs::write(&new, scenario_doc(0.999, 1e-9)).expect("write");
        let findings = run(
            old.to_str().expect("utf8"),
            new.to_str().expect("utf8"),
            0.15,
        )
        .expect("runs");
        assert_eq!(findings.len(), 1, "{findings:?}");
        // Identical artifacts diff clean.
        let clean = run(
            old.to_str().expect("utf8"),
            old.to_str().expect("utf8"),
            0.15,
        )
        .expect("runs");
        assert!(clean.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
