//! **FIG2** — the paper's Figure 2 (Appendix experiment), as a thin
//! layer over the engine.
//!
//! Same §III graph model; Algorithm 2 run 1000 times; trajectories of
//! `‖s_t - s‖²` with the thick average line decaying exponentially in
//! the mean.
//!
//! All construction goes through [`crate::engine::Scenario`] with the
//! size-estimation experiment kind — this file contains no estimator
//! wiring, only the figure's claim checking; the same experiment is
//! runnable from config via
//! `pagerank-mp run-scenario examples/fig2_scenario.json` (which also
//! races the degree-weighted and random-walk site baselines).

use crate::engine::{EstimatorSpec, GraphSpec, Scenario};

use super::experiment::AveragedTrajectory;

/// Experiment parameters (defaults = the paper's).
#[derive(Debug, Clone)]
pub struct Fig2Config {
    pub n: usize,
    pub threshold: f64,
    pub rounds: usize,
    pub steps: usize,
    pub stride: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            n: 100,
            threshold: 0.5,
            rounds: 1000,
            steps: 20_000,
            stride: 200,
            seed: 2017,
            threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4),
        }
    }
}

impl Fig2Config {
    /// The equivalent declarative scenario (the engine value `run`
    /// drives; `examples/fig2_scenario.json` serializes the same shape
    /// with the baseline estimators added).
    pub fn scenario(&self) -> Scenario {
        Scenario::new("fig2", GraphSpec::ErThreshold { n: self.n, threshold: self.threshold })
            .with_estimators(vec![EstimatorSpec::Kaczmarz])
            .with_steps(self.steps)
            .with_stride(self.stride)
            .with_rounds(self.rounds)
            .with_threads(self.threads)
            .with_seed(self.seed)
    }
}

/// Figure-2 result: the averaged error trajectory plus rate checks.
#[derive(Debug, Clone)]
pub struct Fig2Result {
    pub config: Fig2Config,
    pub avg: AveragedTrajectory,
    /// Fitted per-activation decay rate of E‖s_t - s‖².
    pub rate: f64,
    /// The Appendix bound 1 - σ₂(Ĉ)/N.
    pub predicted_bound: f64,
    /// Mean relative error of per-page size estimates 1/s_i at the end
    /// of the run, averaged across rounds.
    pub final_size_rel_err: f64,
}

/// Run the Figure-2 experiment through the engine.
pub fn run(cfg: &Fig2Config) -> Fig2Result {
    let scenario = cfg.scenario();
    let report = scenario.run().expect("the fig2 scenario is well-formed");
    let est = report.get_estimator("kaczmarz").expect("Algorithm 2 ran").clone();

    let graph = scenario.graph.build(cfg.seed).expect("paper graph builds");
    let predicted_bound = crate::linalg::spectral::size_est_contraction_rate(&graph);

    // Historical trajectory name, pinned by the fig2 CSV column headers.
    let mut avg = est.trajectory;
    avg.name = "size_est".to_string();

    Fig2Result {
        config: cfg.clone(),
        avg,
        rate: est.decay_rate,
        predicted_bound,
        final_size_rel_err: est.final_size_rel_err,
    }
}

impl Fig2Result {
    pub fn to_csv(&self) -> String {
        super::report::trajectories_csv(&[self.avg.clone()])
    }

    pub fn render(&self) -> String {
        let series = super::plot::Series {
            label: self.avg.name.clone(),
            xs: self.avg.ts.iter().map(|&t| t as f64).collect(),
            ys: self.avg.mean.clone(),
            glyph: '*',
        };
        let plot = super::plot::semilogy(
            &[series],
            72,
            18,
            &format!(
                "Fig. 2 — ‖s_t - s‖², N={}, {} rounds",
                self.config.n, self.config.rounds
            ),
        );
        let tbl = super::report::table(
            &["quantity", "value", "paper expectation"],
            &[
                vec![
                    "per-step rate".into(),
                    format!("{:.6}", self.rate),
                    format!("exp., ≤ bound {:.6}", self.predicted_bound),
                ],
                vec![
                    "mean size rel. error".into(),
                    format!("{:.2e}", self.final_size_rel_err),
                    "→ 0 (every page recovers N)".into(),
                ],
            ],
        );
        format!("{plot}\n{tbl}")
    }

    pub fn claims(&self) -> Vec<(&'static str, bool)> {
        vec![
            ("mean error decays exponentially", self.rate < 0.9999),
            (
                "measured rate at least as fast as the Appendix bound",
                self.rate <= self.predicted_bound + 1e-4,
            ),
            (
                "pages recover the network size",
                self.final_size_rel_err < 1e-2,
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_fig2_reproduces_claims() {
        let cfg = Fig2Config {
            n: 30,
            rounds: 20,
            steps: 6_000,
            stride: 100,
            seed: 7,
            threads: 4,
            ..Default::default()
        };
        let res = run(&cfg);
        for (claim, ok) in res.claims() {
            assert!(ok, "claim failed: {claim}\nrate={} bound={}", res.rate, res.predicted_bound);
        }
    }

    #[test]
    fn csv_and_render() {
        let cfg = Fig2Config {
            n: 20,
            rounds: 5,
            steps: 1_000,
            stride: 100,
            seed: 8,
            threads: 2,
            ..Default::default()
        };
        let res = run(&cfg);
        assert!(res.to_csv().starts_with("t,size_est_mean"));
        assert!(res.render().contains("Fig. 2"));
    }

    #[test]
    fn deterministic() {
        let cfg = Fig2Config {
            n: 15,
            rounds: 3,
            steps: 500,
            stride: 50,
            seed: 9,
            threads: 2,
            ..Default::default()
        };
        assert_eq!(run(&cfg).avg.mean, run(&cfg).avg.mean);
    }

    #[test]
    fn config_scenario_json_round_trips() {
        let cfg = Fig2Config { n: 25, rounds: 7, ..Default::default() };
        let scenario = cfg.scenario();
        let text = scenario.to_json().render();
        let back = Scenario::from_json_str(&text).expect("round trips");
        assert_eq!(back, scenario);
    }

    #[test]
    fn harness_is_a_thin_preset_over_the_engine() {
        // The fig2 harness and a hand-built size-estimation scenario with
        // the same shape must produce the identical trajectory — fig2 is
        // a preset, not a second code path.
        let cfg = Fig2Config {
            n: 15,
            rounds: 3,
            steps: 600,
            stride: 100,
            seed: 11,
            threads: 2,
            ..Default::default()
        };
        let via_harness = run(&cfg);
        let via_engine = cfg.scenario().run().expect("runs");
        let kacz = via_engine.get_estimator("kaczmarz").expect("ran");
        assert_eq!(via_harness.avg.mean, kacz.trajectory.mean);
        assert_eq!(via_harness.avg.variance, kacz.trajectory.variance);
        assert_eq!(via_harness.final_size_rel_err, kacz.final_size_rel_err);
        assert_eq!(via_harness.rate, kacz.decay_rate);
    }
}
