//! **FIG2** — the paper's Figure 2 (Appendix experiment).
//!
//! Same §III graph model; Algorithm 2 run 1000 times; trajectories of
//! `‖s_t - s‖²` with the thick average line decaying exponentially in
//! the mean.

use crate::algo::size_estimation::SizeEstimator;
use crate::engine::GraphSpec;
use crate::util::rng::Rng;
use crate::util::stats;

use super::experiment::{run_rounds, with_stride, AveragedTrajectory};

/// Experiment parameters (defaults = the paper's).
#[derive(Debug, Clone)]
pub struct Fig2Config {
    pub n: usize,
    pub threshold: f64,
    pub rounds: usize,
    pub steps: usize,
    pub stride: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for Fig2Config {
    fn default() -> Self {
        Fig2Config {
            n: 100,
            threshold: 0.5,
            rounds: 1000,
            steps: 20_000,
            stride: 200,
            seed: 2017,
            threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4),
        }
    }
}

/// Figure-2 result: the averaged error trajectory plus rate checks.
#[derive(Debug, Clone)]
pub struct Fig2Result {
    pub config: Fig2Config,
    pub avg: AveragedTrajectory,
    /// Fitted per-activation decay rate of E‖s_t - s‖².
    pub rate: f64,
    /// The Appendix bound 1 - σ₂(Ĉ)/N.
    pub predicted_bound: f64,
    /// Mean relative error of per-page size estimates 1/s_i at the end of
    /// round 0.
    pub final_size_rel_err: f64,
}

/// Run the Figure-2 experiment. The graph comes from the engine's
/// [`GraphSpec`] so Fig. 2 names the same workload substrate as every
/// scenario; the size estimator itself is not a PageRank solver and
/// keeps its own recording loop.
pub fn run(cfg: &Fig2Config) -> Fig2Result {
    let g = GraphSpec::ErThreshold { n: cfg.n, threshold: cfg.threshold }
        .build(cfg.seed)
        .expect("paper graph builds");
    let base = Rng::seeded(cfg.seed ^ 0xF162);

    let avg = with_stride(
        run_rounds("size_est", cfg.rounds, &base, cfg.threads, |mut rng| {
            let mut est = SizeEstimator::new(&g).expect("ER-threshold graphs are connected");
            let mut traj = Vec::with_capacity(cfg.steps / cfg.stride + 1);
            traj.push(est.error_sq());
            for t in 1..=cfg.steps {
                est.step(&mut rng);
                if t % cfg.stride == 0 {
                    traj.push(est.error_sq());
                }
            }
            traj
        }),
        cfg.stride,
    );

    let skip = avg.mean.len() / 5;
    // Fit only above the f64 noise floor: a converged trajectory flattens
    // near ~1e-30 and would bias the fitted rate toward 1.
    let rate = stats::decay_rate_above(&avg.mean[skip..], 1e-26).powf(1.0 / cfg.stride as f64);
    let predicted_bound = crate::linalg::spectral::size_est_contraction_rate(&g);

    // Size recovery on a fresh full-length run.
    let mut est = SizeEstimator::new(&g).expect("connected");
    let mut rng = base.fork(0);
    for _ in 0..cfg.steps {
        est.step(&mut rng);
    }
    let rel_errs: Vec<f64> = (0..g.n())
        .filter_map(|i| est.estimate_at(i))
        .map(|nd| (nd - g.n() as f64).abs() / g.n() as f64)
        .collect();
    let final_size_rel_err = stats::mean(&rel_errs);

    Fig2Result {
        config: cfg.clone(),
        avg,
        rate,
        predicted_bound,
        final_size_rel_err,
    }
}

impl Fig2Result {
    pub fn to_csv(&self) -> String {
        super::report::trajectories_csv(&[self.avg.clone()])
    }

    pub fn render(&self) -> String {
        let series = super::plot::Series {
            label: self.avg.name.clone(),
            xs: self.avg.ts.iter().map(|&t| t as f64).collect(),
            ys: self.avg.mean.clone(),
            glyph: '*',
        };
        let plot = super::plot::semilogy(
            &[series],
            72,
            18,
            &format!(
                "Fig. 2 — ‖s_t - s‖², N={}, {} rounds",
                self.config.n, self.config.rounds
            ),
        );
        let tbl = super::report::table(
            &["quantity", "value", "paper expectation"],
            &[
                vec![
                    "per-step rate".into(),
                    format!("{:.6}", self.rate),
                    format!("exp., ≤ bound {:.6}", self.predicted_bound),
                ],
                vec![
                    "mean size rel. error".into(),
                    format!("{:.2e}", self.final_size_rel_err),
                    "→ 0 (every page recovers N)".into(),
                ],
            ],
        );
        format!("{plot}\n{tbl}")
    }

    pub fn claims(&self) -> Vec<(&'static str, bool)> {
        vec![
            ("mean error decays exponentially", self.rate < 0.9999),
            (
                "measured rate at least as fast as the Appendix bound",
                self.rate <= self.predicted_bound + 1e-4,
            ),
            (
                "pages recover the network size",
                self.final_size_rel_err < 1e-2,
            ),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_fig2_reproduces_claims() {
        let cfg = Fig2Config {
            n: 30,
            rounds: 20,
            steps: 6_000,
            stride: 100,
            seed: 7,
            threads: 4,
            ..Default::default()
        };
        let res = run(&cfg);
        for (claim, ok) in res.claims() {
            assert!(ok, "claim failed: {claim}\nrate={} bound={}", res.rate, res.predicted_bound);
        }
    }

    #[test]
    fn csv_and_render() {
        let cfg = Fig2Config {
            n: 20,
            rounds: 5,
            steps: 1_000,
            stride: 100,
            seed: 8,
            threads: 2,
            ..Default::default()
        };
        let res = run(&cfg);
        assert!(res.to_csv().starts_with("t,size_est_mean"));
        assert!(res.render().contains("Fig. 2"));
    }

    #[test]
    fn deterministic() {
        let cfg = Fig2Config {
            n: 15,
            rounds: 3,
            steps: 500,
            stride: 50,
            seed: 9,
            threads: 2,
            ..Default::default()
        };
        assert_eq!(run(&cfg).avg.mean, run(&cfg).avg.mean);
    }
}
