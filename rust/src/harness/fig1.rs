//! **FIG1** — the paper's Figure 1, as a thin layer over the engine.
//!
//! Setup (§III): N = 100, hyperlink matrix from iid U\[0,1\] entries
//! thresholded at 0.5, α = 0.85, 100 simulation rounds averaged.
//! Trajectories of `(1/N)‖x_t - x*‖²` for:
//!
//! * the proposed Matching-Pursuit method (expected: exponential decay),
//! * \[15\] You–Tempo–Qiu, initialized at 0 (expected: exponential, at a
//!   similar rate),
//! * \[6\] Ishii–Tempo, initialized at 𝟙 (expected: sub-exponential decay
//!   with larger cross-round variance).
//!
//! All construction goes through [`crate::engine::Scenario`] — this file
//! contains no solver wiring, only the figure's claim checking; the same
//! experiment is runnable from config via
//! `pagerank-mp run-scenario examples/fig1_scenario.json`.

use crate::engine::{GraphSpec, Scenario, SolverSpec};
use crate::util::stats;

use super::experiment::AveragedTrajectory;

/// Experiment parameters (defaults = the paper's §III).
#[derive(Debug, Clone)]
pub struct Fig1Config {
    pub n: usize,
    pub threshold: f64,
    pub alpha: f64,
    pub rounds: usize,
    /// Total activations per round.
    pub steps: usize,
    /// Error-sampling stride (in activations).
    pub stride: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config {
            n: 100,
            threshold: 0.5,
            alpha: 0.85,
            rounds: 100,
            steps: 60_000,
            stride: 500,
            seed: 2017,
            threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4),
        }
    }
}

impl Fig1Config {
    /// The equivalent declarative scenario (the engine value `run`
    /// drives; also what `examples/fig1_scenario.json` serializes).
    pub fn scenario(&self) -> Scenario {
        Scenario::new("fig1", GraphSpec::ErThreshold { n: self.n, threshold: self.threshold })
            .with_solvers(vec![
                SolverSpec::Mp,
                SolverSpec::YouTempoQiu,
                SolverSpec::IshiiTempo,
            ])
            .with_alpha(self.alpha)
            .with_steps(self.steps)
            .with_stride(self.stride)
            .with_rounds(self.rounds)
            .with_threads(self.threads)
            .with_seed(self.seed)
    }
}

/// Machine-checked qualitative claims of Figure 1.
#[derive(Debug, Clone)]
pub struct Fig1Verdict {
    /// Per-activation decay rate of E‖x_t - x*‖² for MP (should be < 1).
    pub mp_rate: f64,
    /// Same for [15].
    pub ytq_rate: f64,
    /// The paper's Prop. 2 bound 1 - σ²(B̂)/N.
    pub predicted_mp_bound: f64,
    /// Final mean error of [6] / final mean error of MP (≫ 1 expected).
    pub it_over_mp_final: f64,
    /// Mean trajectory variance of [6] / MP over the tail (≫ 1 expected).
    pub it_over_mp_variance: f64,
}

/// Full Figure-1 result.
#[derive(Debug, Clone)]
pub struct Fig1Result {
    pub config: Fig1Config,
    pub mp: AveragedTrajectory,
    pub ytq: AveragedTrajectory,
    pub it: AveragedTrajectory,
    pub verdict: Fig1Verdict,
}

/// Run the Figure-1 experiment through the engine.
pub fn run(cfg: &Fig1Config) -> Fig1Result {
    let scenario = cfg.scenario();
    let report = scenario.run().expect("the fig1 scenario is well-formed");

    let mp_rep = report.get("mp").expect("mp ran").clone();
    let ytq_rep = report.get("you-tempo-qiu").expect("[15] ran").clone();
    let it_rep = report.get("ishii-tempo").expect("[6] ran").clone();

    let graph = scenario.graph.build(cfg.seed).expect("paper graph builds");
    let predicted_mp_bound = crate::linalg::spectral::mp_contraction_rate(&graph, cfg.alpha);

    let tail = mp_rep.trajectory.mean.len() * 3 / 4;
    let it_var = stats::mean(&it_rep.trajectory.variance[tail..]);
    let mp_var = stats::mean(&mp_rep.trajectory.variance[tail..]).max(f64::MIN_POSITIVE);

    let verdict = Fig1Verdict {
        mp_rate: mp_rep.decay_rate,
        ytq_rate: ytq_rep.decay_rate,
        predicted_mp_bound,
        it_over_mp_final: it_rep.final_error / mp_rep.final_error.max(f64::MIN_POSITIVE),
        it_over_mp_variance: it_var / mp_var,
    };

    Fig1Result {
        config: cfg.clone(),
        mp: mp_rep.trajectory,
        ytq: ytq_rep.trajectory,
        it: it_rep.trajectory,
        verdict,
    }
}

impl Fig1Result {
    /// CSV of all three averaged trajectories.
    pub fn to_csv(&self) -> String {
        super::report::trajectories_csv(&[self.mp.clone(), self.ytq.clone(), self.it.clone()])
    }

    /// Terminal rendering: plot + verdict table.
    pub fn render(&self) -> String {
        let mk = |tr: &AveragedTrajectory, glyph: char| super::plot::Series {
            label: tr.name.clone(),
            xs: tr.ts.iter().map(|&t| t as f64).collect(),
            ys: tr.mean.clone(),
            glyph,
        };
        let plot = super::plot::semilogy(
            &[mk(&self.mp, '*'), mk(&self.ytq, '+'), mk(&self.it, 'o')],
            72,
            20,
            &format!(
                "Fig. 1 — (1/N)‖x_t - x*‖², N={}, α={}, {} rounds",
                self.config.n, self.config.alpha, self.config.rounds
            ),
        );
        let v = &self.verdict;
        let tbl = super::report::table(
            &["quantity", "value", "paper expectation"],
            &[
                vec![
                    "MP per-step rate".into(),
                    format!("{:.6}", v.mp_rate),
                    format!("exp., ≤ bound {:.6}", v.predicted_mp_bound),
                ],
                vec![
                    "[15] per-step rate".into(),
                    format!("{:.6}", v.ytq_rate),
                    "exp., similar to MP".into(),
                ],
                vec![
                    "[6]/MP final error".into(),
                    format!("{:.3e}", v.it_over_mp_final),
                    "≫ 1 (sub-exponential)".into(),
                ],
                vec![
                    "[6]/MP tail variance".into(),
                    format!("{:.3e}", v.it_over_mp_variance),
                    "≫ 1 (larger variance)".into(),
                ],
            ],
        );
        format!("{plot}\n{tbl}")
    }

    /// The paper's qualitative claims as a pass/fail list.
    pub fn claims(&self) -> Vec<(&'static str, bool)> {
        let v = &self.verdict;
        vec![
            ("MP decays exponentially (rate < 1)", v.mp_rate < 0.99999),
            (
                "MP rate is at least as fast as the Prop.2 bound",
                v.mp_rate <= v.predicted_mp_bound + 1e-4,
            ),
            (
                "[15] decays exponentially at a similar rate (within 2x of MP's decade count)",
                v.ytq_rate < 1.0
                    && (1.0 - v.ytq_rate) > 0.4 * (1.0 - v.mp_rate)
                    && (1.0 - v.ytq_rate) < 2.5 * (1.0 - v.mp_rate),
            ),
            ("[6] is far behind both at the horizon", v.it_over_mp_final > 1e2),
            ("[6] has larger trajectory variance", v.it_over_mp_variance > 1.0),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scaled-down Fig. 1 (N=30, 10 rounds) — the full-size run lives in
    /// the bench / CLI; this pins the machinery and the claims.
    #[test]
    fn small_fig1_reproduces_qualitative_claims() {
        let cfg = Fig1Config {
            n: 30,
            rounds: 10,
            steps: 12_000,
            stride: 200,
            seed: 3,
            threads: 4,
            ..Default::default()
        };
        let res = run(&cfg);
        for (claim, ok) in res.claims() {
            assert!(ok, "claim failed: {claim}\n{:#?}", res.verdict);
        }
    }

    #[test]
    fn csv_and_render_shapes() {
        let cfg = Fig1Config {
            n: 20,
            rounds: 4,
            steps: 2_000,
            stride: 200,
            seed: 4,
            threads: 2,
            ..Default::default()
        };
        let res = run(&cfg);
        let csv = res.to_csv();
        assert!(csv.lines().count() > 5);
        assert!(csv.starts_with("t,mp_mean,mp_var,you-tempo-qiu_mean"));
        let txt = res.render();
        assert!(txt.contains("Fig. 1"));
        assert!(txt.contains("MP per-step rate"));
    }

    #[test]
    fn deterministic() {
        let cfg = Fig1Config {
            n: 15,
            rounds: 3,
            steps: 1_000,
            stride: 100,
            seed: 5,
            threads: 3,
            ..Default::default()
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.mp.mean, b.mp.mean);
        assert_eq!(a.it.variance, b.it.variance);
    }

    #[test]
    fn config_scenario_json_round_trips() {
        let cfg = Fig1Config { n: 25, rounds: 7, ..Default::default() };
        let scenario = cfg.scenario();
        let text = scenario.to_json().render();
        let back = Scenario::from_json_str(&text).expect("round trips");
        assert_eq!(back, scenario);
    }
}
