//! **FIG1** — the paper's Figure 1.
//!
//! Setup (§III): N = 100, hyperlink matrix from iid U\[0,1\] entries
//! thresholded at 0.5, α = 0.85, 100 simulation rounds averaged.
//! Trajectories of `(1/N)‖x_t - x*‖²` for:
//!
//! * the proposed Matching-Pursuit method (expected: exponential decay),
//! * \[15\] You–Tempo–Qiu, initialized at 0 (expected: exponential, at a
//!   similar rate),
//! * \[6\] Ishii–Tempo, initialized at 𝟙 (expected: sub-exponential decay
//!   with larger cross-round variance).
//!
//! `run` reproduces all three averaged trajectories plus the qualitative
//! claims as machine-checkable [`Fig1Verdict`] fields.

use crate::algo::common::Trajectory;
use crate::algo::ishii_tempo::IshiiTempo;
use crate::algo::mp::MatchingPursuit;
use crate::algo::you_tempo_qiu::YouTempoQiu;
use crate::graph::generators;
use crate::linalg::solve::exact_pagerank;
use crate::util::rng::Rng;
use crate::util::stats;

use super::experiment::{run_rounds, with_stride, AveragedTrajectory};

/// Experiment parameters (defaults = the paper's §III).
#[derive(Debug, Clone)]
pub struct Fig1Config {
    pub n: usize,
    pub threshold: f64,
    pub alpha: f64,
    pub rounds: usize,
    /// Total activations per round.
    pub steps: usize,
    /// Error-sampling stride (in activations).
    pub stride: usize,
    pub seed: u64,
    pub threads: usize,
}

impl Default for Fig1Config {
    fn default() -> Self {
        Fig1Config {
            n: 100,
            threshold: 0.5,
            alpha: 0.85,
            rounds: 100,
            steps: 60_000,
            stride: 500,
            seed: 2017,
            threads: std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4),
        }
    }
}

/// Machine-checked qualitative claims of Figure 1.
#[derive(Debug, Clone)]
pub struct Fig1Verdict {
    /// Per-activation decay rate of E‖x_t - x*‖² for MP (should be < 1).
    pub mp_rate: f64,
    /// Same for [15].
    pub ytq_rate: f64,
    /// The paper's Prop. 2 bound 1 - σ²(B̂)/N.
    pub predicted_mp_bound: f64,
    /// Final mean error of [6] / final mean error of MP (≫ 1 expected).
    pub it_over_mp_final: f64,
    /// Mean trajectory variance of [6] / MP over the tail (≫ 1 expected).
    pub it_over_mp_variance: f64,
}

/// Full Figure-1 result.
#[derive(Debug, Clone)]
pub struct Fig1Result {
    pub config: Fig1Config,
    pub mp: AveragedTrajectory,
    pub ytq: AveragedTrajectory,
    pub it: AveragedTrajectory,
    pub verdict: Fig1Verdict,
}

/// Run the Figure-1 experiment.
pub fn run(cfg: &Fig1Config) -> Fig1Result {
    let g = generators::er_threshold(cfg.n, cfg.threshold, cfg.seed);
    let x_star = exact_pagerank(&g, cfg.alpha);
    let base = Rng::seeded(cfg.seed ^ 0xF161);

    let record =
        |mut solver: Box<dyn crate::algo::common::PageRankSolver>, mut rng: Rng| -> Vec<f64> {
            Trajectory::record(&mut *solver, &x_star, cfg.steps, cfg.stride, &mut rng).errors
        };

    let mp = with_stride(
        run_rounds("mp", cfg.rounds, &base, cfg.threads, |rng| {
            record(Box::new(MatchingPursuit::new(&g, cfg.alpha)), rng)
        }),
        cfg.stride,
    );
    let ytq = with_stride(
        run_rounds("ytq15", cfg.rounds, &base, cfg.threads, |rng| {
            record(Box::new(YouTempoQiu::new(&g, cfg.alpha)), rng)
        }),
        cfg.stride,
    );
    let it = with_stride(
        run_rounds("ishii_tempo6", cfg.rounds, &base, cfg.threads, |rng| {
            record(Box::new(IshiiTempo::new(&g, cfg.alpha)), rng)
        }),
        cfg.stride,
    );

    // Fit rates on the decaying tail (skip the initial transient).
    let skip = mp.mean.len() / 5;
    let mp_rate = stats::decay_rate(&mp.mean[skip..]).powf(1.0 / cfg.stride as f64);
    let ytq_rate = stats::decay_rate(&ytq.mean[skip..]).powf(1.0 / cfg.stride as f64);
    let predicted_mp_bound = crate::linalg::spectral::mp_contraction_rate(&g, cfg.alpha);

    let tail = mp.mean.len() * 3 / 4;
    let it_var = stats::mean(&it.variance[tail..]);
    let mp_var = stats::mean(&mp.variance[tail..]).max(f64::MIN_POSITIVE);

    let verdict = Fig1Verdict {
        mp_rate,
        ytq_rate,
        predicted_mp_bound,
        it_over_mp_final: it.final_mean() / mp.final_mean().max(f64::MIN_POSITIVE),
        it_over_mp_variance: it_var / mp_var,
    };

    Fig1Result { config: cfg.clone(), mp, ytq, it, verdict }
}

impl Fig1Result {
    /// CSV of all three averaged trajectories.
    pub fn to_csv(&self) -> String {
        super::report::trajectories_csv(&[self.mp.clone(), self.ytq.clone(), self.it.clone()])
    }

    /// Terminal rendering: plot + verdict table.
    pub fn render(&self) -> String {
        let mk = |tr: &AveragedTrajectory, glyph: char| super::plot::Series {
            label: tr.name.clone(),
            xs: tr.ts.iter().map(|&t| t as f64).collect(),
            ys: tr.mean.clone(),
            glyph,
        };
        let plot = super::plot::semilogy(
            &[mk(&self.mp, '*'), mk(&self.ytq, '+'), mk(&self.it, 'o')],
            72,
            20,
            &format!(
                "Fig. 1 — (1/N)‖x_t - x*‖², N={}, α={}, {} rounds",
                self.config.n, self.config.alpha, self.config.rounds
            ),
        );
        let v = &self.verdict;
        let tbl = super::report::table(
            &["quantity", "value", "paper expectation"],
            &[
                vec![
                    "MP per-step rate".into(),
                    format!("{:.6}", v.mp_rate),
                    format!("exp., ≤ bound {:.6}", v.predicted_mp_bound),
                ],
                vec![
                    "[15] per-step rate".into(),
                    format!("{:.6}", v.ytq_rate),
                    "exp., similar to MP".into(),
                ],
                vec![
                    "[6]/MP final error".into(),
                    format!("{:.3e}", v.it_over_mp_final),
                    "≫ 1 (sub-exponential)".into(),
                ],
                vec![
                    "[6]/MP tail variance".into(),
                    format!("{:.3e}", v.it_over_mp_variance),
                    "≫ 1 (larger variance)".into(),
                ],
            ],
        );
        format!("{plot}\n{tbl}")
    }

    /// The paper's qualitative claims as a pass/fail list.
    pub fn claims(&self) -> Vec<(&'static str, bool)> {
        let v = &self.verdict;
        vec![
            ("MP decays exponentially (rate < 1)", v.mp_rate < 0.99999),
            (
                "MP rate is at least as fast as the Prop.2 bound",
                v.mp_rate <= v.predicted_mp_bound + 1e-4,
            ),
            (
                "[15] decays exponentially at a similar rate (within 2x of MP's decade count)",
                v.ytq_rate < 1.0
                    && (1.0 - v.ytq_rate) > 0.4 * (1.0 - v.mp_rate)
                    && (1.0 - v.ytq_rate) < 2.5 * (1.0 - v.mp_rate),
            ),
            ("[6] is far behind both at the horizon", v.it_over_mp_final > 1e2),
            ("[6] has larger trajectory variance", v.it_over_mp_variance > 1.0),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Scaled-down Fig. 1 (N=30, 10 rounds) — the full-size run lives in
    /// the bench / CLI; this pins the machinery and the claims.
    #[test]
    fn small_fig1_reproduces_qualitative_claims() {
        let cfg = Fig1Config {
            n: 30,
            rounds: 10,
            steps: 12_000,
            stride: 200,
            seed: 3,
            threads: 4,
            ..Default::default()
        };
        let res = run(&cfg);
        for (claim, ok) in res.claims() {
            assert!(ok, "claim failed: {claim}\n{:#?}", res.verdict);
        }
    }

    #[test]
    fn csv_and_render_shapes() {
        let cfg = Fig1Config {
            n: 20,
            rounds: 4,
            steps: 2_000,
            stride: 200,
            seed: 4,
            threads: 2,
            ..Default::default()
        };
        let res = run(&cfg);
        let csv = res.to_csv();
        assert!(csv.lines().count() > 5);
        assert!(csv.starts_with("t,mp_mean,mp_var,ytq15_mean"));
        let txt = res.render();
        assert!(txt.contains("Fig. 1"));
        assert!(txt.contains("MP per-step rate"));
    }

    #[test]
    fn deterministic() {
        let cfg = Fig1Config {
            n: 15,
            rounds: 3,
            steps: 1_000,
            stride: 100,
            seed: 5,
            threads: 3,
            ..Default::default()
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.mp.mean, b.mp.mean);
        assert_eq!(a.it.variance, b.it.variance);
    }
}
