//! Multi-round experiment runner.
//!
//! The paper's figures average many independent simulation rounds (100
//! for Fig. 1, 1000 for Fig. 2). Rounds are embarrassingly parallel and
//! deterministic: round `i` uses `base_rng.fork(i)`, so results are
//! identical whatever the thread count.

use crate::algo::common::StepStats;
use crate::util::rng::Rng;
use crate::util::stats;

/// An averaged trajectory with its cross-round variance.
#[derive(Debug, Clone)]
pub struct AveragedTrajectory {
    pub name: String,
    /// Activation index of each sample (t = stride * i).
    pub ts: Vec<usize>,
    pub mean: Vec<f64>,
    pub variance: Vec<f64>,
    /// A few raw rounds for spaghetti plots (paper Fig. 1 shows them).
    pub sample_rounds: Vec<Vec<f64>>,
}

impl AveragedTrajectory {
    /// Fitted per-activation decay rate of the mean trajectory.
    pub fn per_step_rate(&self, stride: usize) -> f64 {
        stats::decay_rate(&self.mean).powf(1.0 / stride as f64)
    }

    pub fn final_mean(&self) -> f64 {
        *self.mean.last().expect("nonempty")
    }
}

/// Run `rounds` independent trajectories of `steps` activations each and
/// average. `make_round(round_rng) -> Vec<f64>` produces one error
/// trajectory sampled every `stride` (including t=0): the closure owns
/// algorithm construction so this runner works for every solver and for
/// the coordinator alike.
pub fn run_rounds<F>(
    name: &str,
    rounds: usize,
    base: &Rng,
    threads: usize,
    make_round: F,
) -> AveragedTrajectory
where
    F: Fn(Rng) -> Vec<f64> + Sync,
{
    run_rounds_stats(name, rounds, base, threads, |rng| {
        (make_round(rng), StepStats::default())
    })
    .0
}

/// Like [`run_rounds`] but the closure also reports the communication
/// cost of its round; the returned [`StepStats`] is the sum over all
/// rounds (accumulated in round order, so it is deterministic and
/// thread-count invariant). This is what [`crate::engine::Scenario`]
/// drives: one uniform runner for trajectory *and* cost accounting.
pub fn run_rounds_stats<F>(
    name: &str,
    rounds: usize,
    base: &Rng,
    threads: usize,
    make_round: F,
) -> (AveragedTrajectory, StepStats)
where
    F: Fn(Rng) -> (Vec<f64>, StepStats) + Sync,
{
    assert!(rounds > 0);
    let threads = threads.max(1).min(rounds);
    let results: Vec<(Vec<f64>, StepStats)> = if threads == 1 {
        (0..rounds).map(|i| make_round(base.fork(i as u64))).collect()
    } else {
        // Static block partition over scoped threads — deterministic
        // regardless of scheduling.
        let mut results: Vec<Option<(Vec<f64>, StepStats)>> = vec![None; rounds];
        let chunk = rounds.div_ceil(threads);
        std::thread::scope(|scope| {
            let handles: Vec<_> = results
                .chunks_mut(chunk)
                .enumerate()
                .map(|(ci, slot)| {
                    let make_round = &make_round;
                    let base = base.clone();
                    scope.spawn(move || {
                        for (off, s) in slot.iter_mut().enumerate() {
                            let round = ci * chunk + off;
                            *s = Some(make_round(base.fork(round as u64)));
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("round thread panicked");
            }
        });
        results.into_iter().map(|r| r.expect("round filled")).collect()
    };

    let mut total_stats = StepStats::default();
    for (_, s) in &results {
        total_stats.accumulate(*s);
    }
    let trajectories: Vec<Vec<f64>> = results.into_iter().map(|(t, _)| t).collect();
    let mean = stats::average_trajectories(&trajectories);
    let variance = stats::trajectory_variance(&trajectories);
    let sample_rounds: Vec<Vec<f64>> = trajectories.iter().take(5).cloned().collect();
    let len = mean.len();
    (
        AveragedTrajectory {
            name: name.to_string(),
            ts: (0..len).collect(),
            mean,
            variance,
            sample_rounds,
        },
        total_stats,
    )
}

/// Fill in the activation indices given the sampling stride.
pub fn with_stride(mut tr: AveragedTrajectory, stride: usize) -> AveragedTrajectory {
    tr.ts = (0..tr.mean.len()).map(|i| i * stride).collect();
    tr
}

/// Split an averaged trajectory whose rounds recorded two metrics
/// back-to-back (`[metric_a(t0..), metric_b(t0..)]`) at index `at`: the
/// head keeps the name, the tail takes `tail_name`. Averaging is
/// element-wise, so the mean/variance of the concatenation is the
/// concatenation of the means/variances — one [`run_rounds_stats`] pass
/// yields both trajectories (the size-estimation scenarios record the
/// Fig.-2 error and the relative size error this way).
pub fn split_concat(
    tr: AveragedTrajectory,
    at: usize,
    tail_name: &str,
) -> (AveragedTrajectory, AveragedTrajectory) {
    assert!(at <= tr.mean.len(), "split point {at} past {} samples", tr.mean.len());
    let (head_mean, tail_mean) = tr.mean.split_at(at);
    let (head_var, tail_var) = tr.variance.split_at(at);
    let split_rounds = |take_head: bool| -> Vec<Vec<f64>> {
        tr.sample_rounds
            .iter()
            .map(|r| {
                let (h, t) = r.split_at(at.min(r.len()));
                (if take_head { h } else { t }).to_vec()
            })
            .collect()
    };
    let head = AveragedTrajectory {
        name: tr.name.clone(),
        ts: (0..head_mean.len()).collect(),
        mean: head_mean.to_vec(),
        variance: head_var.to_vec(),
        sample_rounds: split_rounds(true),
    };
    let tail = AveragedTrajectory {
        name: tail_name.to_string(),
        ts: (0..tail_mean.len()).collect(),
        mean: tail_mean.to_vec(),
        variance: tail_var.to_vec(),
        sample_rounds: split_rounds(false),
    };
    (head, tail)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geometric_round(rng: Rng) -> Vec<f64> {
        // err halves per record, with seed-dependent start
        let mut r = rng;
        let start = 1.0 + r.uniform();
        (0..20).map(|i| start * 0.5f64.powi(i)).collect()
    }

    #[test]
    fn averaging_is_deterministic_and_thread_invariant() {
        let base = Rng::seeded(99);
        let a = run_rounds("x", 16, &base, 1, geometric_round);
        let b = run_rounds("x", 16, &base, 4, geometric_round);
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.variance, b.variance);
    }

    #[test]
    fn averaged_rate_recovered() {
        let base = Rng::seeded(100);
        let tr = run_rounds("x", 8, &base, 2, geometric_round);
        let rate = crate::util::stats::decay_rate(&tr.mean);
        assert!((rate - 0.5).abs() < 1e-9);
        // stride accounting
        let tr = with_stride(tr, 10);
        assert_eq!(tr.ts[3], 30);
        let per_step = tr.per_step_rate(10);
        assert!((per_step - 0.5f64.powf(0.1)).abs() < 1e-9);
    }

    #[test]
    fn sample_rounds_kept() {
        let base = Rng::seeded(101);
        let tr = run_rounds("x", 3, &base, 2, geometric_round);
        assert_eq!(tr.sample_rounds.len(), 3);
    }

    #[test]
    fn stats_summed_across_rounds_thread_invariant() {
        let base = Rng::seeded(103);
        let make = |rng: Rng| {
            let mut r = rng;
            let start = 1.0 + r.uniform();
            let traj: Vec<f64> = (0..6).map(|i| start * 0.5f64.powi(i)).collect();
            (traj, StepStats { reads: 2, writes: 3, activated: 1 })
        };
        let (a, sa) = run_rounds_stats("x", 9, &base, 1, make);
        let (b, sb) = run_rounds_stats("x", 9, &base, 4, make);
        assert_eq!(a.mean, b.mean);
        assert_eq!(sa, sb);
        assert_eq!(sa, StepStats { reads: 18, writes: 27, activated: 9 });
    }

    #[test]
    fn variance_positive_across_distinct_rounds() {
        let base = Rng::seeded(102);
        let tr = run_rounds("x", 10, &base, 3, geometric_round);
        assert!(tr.variance[0] > 0.0);
    }

    #[test]
    fn split_concat_separates_two_metrics() {
        let base = Rng::seeded(104);
        // Each round records metric A (geometric) then metric B (its
        // negation), concatenated.
        let tr = run_rounds("ab", 6, &base, 2, |rng| {
            let a = geometric_round(rng);
            let b: Vec<f64> = a.iter().map(|v| -v).collect();
            let mut both = a;
            both.extend(b);
            both
        });
        let plain = run_rounds("ab", 6, &base, 2, geometric_round);
        let (a, b) = split_concat(tr, 20, "ab_relerr");
        assert_eq!(a.name, "ab");
        assert_eq!(b.name, "ab_relerr");
        assert_eq!(a.mean, plain.mean, "head must equal a single-metric run");
        assert_eq!(a.variance, plain.variance);
        for (x, y) in a.mean.iter().zip(&b.mean) {
            assert_eq!(*y, -x, "tail is the negated metric");
        }
        assert_eq!(a.sample_rounds.len(), b.sample_rounds.len());
        assert_eq!(a.sample_rounds[0].len(), 20);
        assert_eq!(b.sample_rounds[0].len(), 20);
        assert_eq!(a.ts.len(), 20);
    }
}
