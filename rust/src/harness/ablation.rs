//! Ablation studies beyond the paper's figures (DESIGN.md §4):
//!
//! * **ABL-RATE** — measured MP contraction vs the Prop. 2 prediction
//!   `1 - σ²(B̂)/N` across graph families (the bound's tightness).
//! * **ABL-SAMPLER** — uniform vs exponential-clock vs residual-weighted
//!   activation (§IV future-work 3).
//! * **ABL-PARALLEL** — conflict-free batch activation speedup vs batch
//!   size and graph density (§IV future-work 1).
//! * **ABL-GREEDY** — randomized vs best-atom selection: convergence per
//!   iteration vs communication per iteration.
//!
//! Every solver is constructed through the [`crate::engine`] registry —
//! the studies describe *what* runs; the engine owns *how* it is built.

use crate::algo::common::{PageRankSolver, StepStats, Trajectory};
use crate::coordinator::{Mode, SamplerKind};
use crate::engine::{CoordinatorSolver, SolverSpec};
use crate::graph::generators;
use crate::graph::Graph;
use crate::linalg::solve::exact_pagerank;
use crate::linalg::spectral;
use crate::network::LatencyModel;
use crate::util::rng::Rng;
use crate::util::stats;

/// One ABL-RATE row.
#[derive(Debug, Clone)]
pub struct RateRow {
    pub family: String,
    pub n: usize,
    pub predicted_bound: f64,
    pub measured_rate: f64,
    /// measured decades-per-step / predicted decades-per-step (≥ 1 means
    /// the bound is conservative, as expected).
    pub tightness: f64,
}

/// ABL-RATE: contraction-rate bound tightness across graph families.
pub fn rate_study(n: usize, alpha: f64, rounds: usize, steps: usize, seed: u64) -> Vec<RateRow> {
    let families: Vec<(String, Graph)> = vec![
        ("er-threshold(0.5)".into(), generators::er_threshold(n, 0.5, seed)),
        ("er-sparse".into(), generators::erdos_renyi(n, (8.0 / n as f64).min(1.0), seed)),
        ("barabasi-albert".into(), generators::barabasi_albert(n, 4, seed)),
        ("watts-strogatz".into(), generators::watts_strogatz(n, 4, 0.1, seed)),
        ("ring".into(), generators::ring(n)),
        ("star".into(), generators::star(n)),
    ];
    let spec = SolverSpec::Mp;
    let base = Rng::seeded(seed ^ 0xAB1);
    families
        .into_iter()
        .map(|(family, g)| {
            let x_star = exact_pagerank(&g, alpha);
            let stride = (steps / 50).max(1);
            let mut rounds_data = Vec::with_capacity(rounds);
            for round in 0..rounds {
                let mut rng = base.fork(round as u64);
                let mut mp = spec.build(&g, alpha, round as u64);
                let tr = Trajectory::record(&mut *mp, &x_star, steps, stride, &mut rng);
                rounds_data.push(tr.errors);
            }
            let avg = stats::average_trajectories(&rounds_data);
            let skip = avg.len() / 5;
            let measured = stats::decay_rate(&avg[skip..]).powf(1.0 / stride as f64);
            let bound = spectral::mp_contraction_rate(&g, alpha);
            // An unfittable tail (decay_rate = NaN) must surface as NaN,
            // not ride f64::max's NaN-swallowing into a bogus ~1e-15
            // "tighter than the bound" ratio.
            let tightness = if measured.is_nan() {
                f64::NAN
            } else {
                (1.0 - measured).max(1e-15) / (1.0 - bound).max(1e-15)
            };
            RateRow {
                family,
                n: g.n(),
                predicted_bound: bound,
                measured_rate: measured,
                tightness,
            }
        })
        .collect()
}

/// One ABL-SAMPLER row.
#[derive(Debug, Clone)]
pub struct SamplerRow {
    pub sampler: String,
    pub final_error: f64,
    pub deferred: u64,
    pub makespan: f64,
}

/// ABL-SAMPLER: error after a fixed activation budget per sampler.
pub fn sampler_study(n: usize, alpha: f64, activations: u64, seed: u64) -> Vec<SamplerRow> {
    let g = generators::er_threshold(n, 0.5, seed);
    let x_star = exact_pagerank(&g, alpha);
    let kinds: Vec<(String, SamplerKind)> = vec![
        ("uniform".into(), SamplerKind::Uniform),
        ("exp-clocks".into(), SamplerKind::ExponentialClocks),
        ("residual-weighted".into(), SamplerKind::ResidualWeighted { floor: 1e-12 }),
    ];
    kinds
        .into_iter()
        .map(|(name, kind)| {
            let mut coord = CoordinatorSolver::build(
                &g,
                alpha,
                seed,
                Mode::Sequential,
                kind,
                LatencyModel::Zero,
            );
            let rep = coord.drive(activations);
            SamplerRow {
                sampler: name,
                final_error: coord.error_sq_vs(&x_star) / n as f64,
                deferred: rep.metrics.deferred,
                makespan: rep.metrics.makespan,
            }
        })
        .collect()
}

/// One ABL-PARALLEL row.
#[derive(Debug, Clone)]
pub struct ParallelRow {
    pub density: f64,
    pub requested_batch: usize,
    pub effective_batch: f64,
    pub final_error: f64,
}

/// ABL-PARALLEL: effective parallelism vs requested batch and density.
pub fn parallel_study(
    n: usize,
    alpha: f64,
    batches: &[usize],
    densities: &[f64],
    steps_per_batch: usize,
    seed: u64,
) -> Vec<ParallelRow> {
    let mut rows = Vec::new();
    for &density in densities {
        let g = generators::erdos_renyi(n, density, seed);
        let x_star = exact_pagerank(&g, alpha);
        for &b in batches {
            let mut pmp = SolverSpec::ParallelMp { batch: b }.build(&g, alpha, seed);
            let mut rng = Rng::seeded(seed ^ (b as u64) << 8);
            let mut total = StepStats::default();
            for _ in 0..steps_per_batch {
                total.accumulate(pmp.step(&mut rng));
            }
            rows.push(ParallelRow {
                density,
                requested_batch: b,
                // `activated` counts accepted pages per packed batch, so
                // the mean accepted batch size is total/steps.
                effective_batch: total.activated as f64 / steps_per_batch as f64,
                final_error: pmp.error_sq_vs(&x_star) / n as f64,
            });
        }
    }
    rows
}

/// One ABL-GREEDY row.
#[derive(Debug, Clone)]
pub struct GreedyRow {
    pub algo: String,
    pub iterations: usize,
    pub final_error: f64,
    pub total_reads: usize,
}

/// The ABL-GREEDY-SCALE result: greedy-MP at webgraph-ish sizes, where
/// the seed implementation's O(N) per-step argmax scan made the ablation
/// unusable. With the tree-backed selection engine the per-step cost is
/// the touched-neighbourhood rescan, reported here straight from the
/// counters [`GreedyMatchingPursuit::step_at`] returns.
#[derive(Debug, Clone)]
pub struct GreedyScaleRow {
    pub n: usize,
    pub steps: usize,
    /// Σ rescanned pages (== Σ per-step selection maintenance cost).
    pub total_rescans: u64,
    /// Largest single-step rescan (bounded by the largest touched
    /// closed in/out neighbourhood, NOT by N).
    pub max_step_rescans: usize,
    pub mean_step_rescans: f64,
    pub final_residual_sq: f64,
    pub wall_ms: f64,
}

/// ABL-GREEDY-SCALE: run best-atom MP on a sparse ER graph (mean degree
/// ~8) at size `n` and record the per-step selection cost distribution.
/// No exact reference is computed (O(N³) would dwarf the run); progress
/// is measured by the residual norm, which best-atom MP drives down
/// monotonically.
pub fn greedy_scale_study(n: usize, alpha: f64, steps: usize, seed: u64) -> GreedyScaleRow {
    use crate::algo::greedy_mp::GreedyMatchingPursuit;
    let g = generators::erdos_renyi(n, (8.0 / n as f64).min(1.0), seed);
    let t0 = std::time::Instant::now();
    let mut gmp = GreedyMatchingPursuit::new(&g, alpha);
    let mut total = 0u64;
    let mut max_step = 0usize;
    for _ in 0..steps {
        let k = gmp.best_atom();
        let (_touched, rescanned) = gmp.step_at(k);
        total += rescanned as u64;
        max_step = max_step.max(rescanned);
    }
    GreedyScaleRow {
        n,
        steps,
        total_rescans: total,
        max_step_rescans: max_step,
        mean_step_rescans: total as f64 / steps as f64,
        final_residual_sq: gmp.residual_norm_sq(),
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

/// ABL-GREEDY: randomized vs best-atom MP at a fixed iteration budget.
pub fn greedy_study(n: usize, alpha: f64, iterations: usize, seed: u64) -> Vec<GreedyRow> {
    let g = generators::er_threshold(n, 0.5, seed);
    let x_star = exact_pagerank(&g, alpha);
    let cases: [(&str, SolverSpec, u64); 2] = [
        ("randomized (Alg. 1)", SolverSpec::Mp, 1),
        ("greedy best-atom [2]", SolverSpec::GreedyMp, 2),
    ];
    cases
        .into_iter()
        .map(|(label, spec, seed_off)| {
            let mut solver = spec.build(&g, alpha, seed + seed_off);
            let mut rng = Rng::seeded(seed + seed_off);
            let mut reads = 0usize;
            for _ in 0..iterations {
                reads += solver.step(&mut rng).reads;
            }
            GreedyRow {
                algo: label.into(),
                iterations,
                final_error: solver.error_sq_vs(&x_star) / n as f64,
                total_reads: reads,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_study_bound_is_conservative() {
        let rows = rate_study(20, 0.85, 5, 4000, 11);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.predicted_bound < 1.0);
            assert!(r.measured_rate < 1.0, "{}: no decay", r.family);
            // measured at least as fast as predicted (bound conservative)
            assert!(
                r.tightness > 0.8,
                "{}: measured slower than bound: {r:?}",
                r.family
            );
        }
    }

    #[test]
    fn sampler_study_weighted_wins() {
        let rows = sampler_study(30, 0.85, 3000, 12);
        assert_eq!(rows.len(), 3);
        let uni = rows.iter().find(|r| r.sampler == "uniform").expect("uniform");
        let wei = rows
            .iter()
            .find(|r| r.sampler == "residual-weighted")
            .expect("weighted");
        assert!(wei.final_error < uni.final_error);
    }

    #[test]
    fn parallel_study_density_effect() {
        let rows = parallel_study(100, 0.85, &[8], &[0.01, 0.3], 200, 13);
        assert_eq!(rows.len(), 2);
        let sparse = &rows[0];
        let dense = &rows[1];
        assert!(sparse.effective_batch > dense.effective_batch);
    }

    #[test]
    fn greedy_study_tradeoff() {
        let rows = greedy_study(25, 0.85, 2000, 14);
        let rand = &rows[0];
        let greedy = &rows[1];
        // Greedy is at least as good per iteration…
        assert!(greedy.final_error <= rand.final_error * 1.5);
        // …but pays more reads (the in-neighbourhood rescans that keep
        // the cached correlations exact).
        assert!(greedy.total_reads > rand.total_reads);
    }

    #[test]
    fn greedy_scale_selection_cost_is_neighbourhood_bounded() {
        // The acceptance check for the tree-backed argmax, at a size a
        // unit test can afford: per-step selection cost must be bounded
        // by the touched neighbourhood (mean degree ~8 → tens of pages),
        // never by N. The seed implementation's scan cost N per step.
        let n = 2_000;
        let steps = 500;
        let row = greedy_scale_study(n, 0.85, steps, 15);
        assert_eq!(row.n, n);
        assert!(
            row.max_step_rescans < n / 2,
            "selection cost must not scale with N: max {} on n={n}",
            row.max_step_rescans
        );
        assert!(
            row.mean_step_rescans < 400.0,
            "mean rescan {} far above the ~deg² neighbourhood size",
            row.mean_step_rescans
        );
        assert!(
            row.total_rescans < (steps as u64) * (n as u64) / 10,
            "aggregate cost {} looks like the old O(N)-per-step scan",
            row.total_rescans
        );
        // And the run must still be best-atom MP: residual strictly
        // below its starting value (1-α)²·n.
        let r0 = (1.0 - 0.85f64).powi(2) * n as f64;
        assert!(row.final_residual_sq < r0 * 0.5, "no progress: {}", row.final_residual_sq);
    }
}
