//! Ablation studies beyond the paper's figures (DESIGN.md §4):
//!
//! * **ABL-RATE** — measured MP contraction vs the Prop. 2 prediction
//!   `1 - σ²(B̂)/N` across graph families (the bound's tightness).
//! * **ABL-SAMPLER** — uniform vs exponential-clock vs residual-weighted
//!   activation (§IV future-work 3).
//! * **ABL-PARALLEL** — conflict-free batch activation speedup vs batch
//!   size and graph density (§IV future-work 1).
//! * **ABL-GREEDY** — randomized vs best-atom selection: convergence per
//!   iteration vs communication per iteration.
//!
//! Every solver is constructed through the [`crate::engine`] registry —
//! the studies describe *what* runs; the engine owns *how* it is built.

use crate::algo::common::{PageRankSolver, StepStats, Trajectory};
use crate::coordinator::{Mode, SamplerKind};
use crate::engine::{CoordinatorSolver, SolverSpec};
use crate::graph::generators;
use crate::graph::Graph;
use crate::linalg::solve::exact_pagerank;
use crate::linalg::spectral;
use crate::network::LatencyModel;
use crate::util::rng::Rng;
use crate::util::stats;

/// One ABL-RATE row.
#[derive(Debug, Clone)]
pub struct RateRow {
    pub family: String,
    pub n: usize,
    pub predicted_bound: f64,
    pub measured_rate: f64,
    /// measured decades-per-step / predicted decades-per-step (≥ 1 means
    /// the bound is conservative, as expected).
    pub tightness: f64,
}

/// ABL-RATE: contraction-rate bound tightness across graph families.
pub fn rate_study(n: usize, alpha: f64, rounds: usize, steps: usize, seed: u64) -> Vec<RateRow> {
    let families: Vec<(String, Graph)> = vec![
        ("er-threshold(0.5)".into(), generators::er_threshold(n, 0.5, seed)),
        ("er-sparse".into(), generators::erdos_renyi(n, (8.0 / n as f64).min(1.0), seed)),
        ("barabasi-albert".into(), generators::barabasi_albert(n, 4, seed)),
        ("watts-strogatz".into(), generators::watts_strogatz(n, 4, 0.1, seed)),
        ("ring".into(), generators::ring(n)),
        ("star".into(), generators::star(n)),
    ];
    let spec = SolverSpec::Mp;
    let base = Rng::seeded(seed ^ 0xAB1);
    families
        .into_iter()
        .map(|(family, g)| {
            let x_star = exact_pagerank(&g, alpha);
            let stride = (steps / 50).max(1);
            let mut rounds_data = Vec::with_capacity(rounds);
            for round in 0..rounds {
                let mut rng = base.fork(round as u64);
                let mut mp = spec.build(&g, alpha, round as u64);
                let tr = Trajectory::record(&mut *mp, &x_star, steps, stride, &mut rng);
                rounds_data.push(tr.errors);
            }
            let avg = stats::average_trajectories(&rounds_data);
            let skip = avg.len() / 5;
            let measured = stats::decay_rate(&avg[skip..]).powf(1.0 / stride as f64);
            let bound = spectral::mp_contraction_rate(&g, alpha);
            // An unfittable tail (decay_rate = NaN) must surface as NaN,
            // not ride f64::max's NaN-swallowing into a bogus ~1e-15
            // "tighter than the bound" ratio.
            let tightness = if measured.is_nan() {
                f64::NAN
            } else {
                (1.0 - measured).max(1e-15) / (1.0 - bound).max(1e-15)
            };
            RateRow {
                family,
                n: g.n(),
                predicted_bound: bound,
                measured_rate: measured,
                tightness,
            }
        })
        .collect()
}

/// One ABL-SAMPLER row.
#[derive(Debug, Clone)]
pub struct SamplerRow {
    pub sampler: String,
    pub final_error: f64,
    pub deferred: u64,
    pub makespan: f64,
}

/// ABL-SAMPLER: error after a fixed activation budget per sampler.
pub fn sampler_study(n: usize, alpha: f64, activations: u64, seed: u64) -> Vec<SamplerRow> {
    let g = generators::er_threshold(n, 0.5, seed);
    let x_star = exact_pagerank(&g, alpha);
    let kinds: Vec<(String, SamplerKind)> = vec![
        ("uniform".into(), SamplerKind::Uniform),
        ("exp-clocks".into(), SamplerKind::ExponentialClocks),
        ("residual-weighted".into(), SamplerKind::ResidualWeighted { floor: 1e-12 }),
    ];
    kinds
        .into_iter()
        .map(|(name, kind)| {
            let mut coord = CoordinatorSolver::build(
                &g,
                alpha,
                seed,
                Mode::Sequential,
                kind,
                LatencyModel::Zero,
            );
            let rep = coord.drive(activations);
            SamplerRow {
                sampler: name,
                final_error: coord.error_sq_vs(&x_star) / n as f64,
                deferred: rep.metrics.deferred,
                makespan: rep.metrics.makespan,
            }
        })
        .collect()
}

/// One ABL-PARALLEL row.
#[derive(Debug, Clone)]
pub struct ParallelRow {
    pub density: f64,
    pub requested_batch: usize,
    pub effective_batch: f64,
    pub final_error: f64,
}

/// ABL-PARALLEL: effective parallelism vs requested batch and density.
pub fn parallel_study(
    n: usize,
    alpha: f64,
    batches: &[usize],
    densities: &[f64],
    steps_per_batch: usize,
    seed: u64,
) -> Vec<ParallelRow> {
    let mut rows = Vec::new();
    for &density in densities {
        let g = generators::erdos_renyi(n, density, seed);
        let x_star = exact_pagerank(&g, alpha);
        for &b in batches {
            let mut pmp = SolverSpec::ParallelMp { batch: b }.build(&g, alpha, seed);
            let mut rng = Rng::seeded(seed ^ (b as u64) << 8);
            let mut total = StepStats::default();
            for _ in 0..steps_per_batch {
                total.accumulate(pmp.step(&mut rng));
            }
            rows.push(ParallelRow {
                density,
                requested_batch: b,
                // `activated` counts accepted pages per packed batch, so
                // the mean accepted batch size is total/steps.
                effective_batch: total.activated as f64 / steps_per_batch as f64,
                final_error: pmp.error_sq_vs(&x_star) / n as f64,
            });
        }
    }
    rows
}

/// One ABL-GREEDY row.
#[derive(Debug, Clone)]
pub struct GreedyRow {
    pub algo: String,
    pub iterations: usize,
    pub final_error: f64,
    pub total_reads: usize,
}

/// ABL-GREEDY: randomized vs best-atom MP at a fixed iteration budget.
pub fn greedy_study(n: usize, alpha: f64, iterations: usize, seed: u64) -> Vec<GreedyRow> {
    let g = generators::er_threshold(n, 0.5, seed);
    let x_star = exact_pagerank(&g, alpha);
    let cases: [(&str, SolverSpec, u64); 2] = [
        ("randomized (Alg. 1)", SolverSpec::Mp, 1),
        ("greedy best-atom [2]", SolverSpec::GreedyMp, 2),
    ];
    cases
        .into_iter()
        .map(|(label, spec, seed_off)| {
            let mut solver = spec.build(&g, alpha, seed + seed_off);
            let mut rng = Rng::seeded(seed + seed_off);
            let mut reads = 0usize;
            for _ in 0..iterations {
                reads += solver.step(&mut rng).reads;
            }
            GreedyRow {
                algo: label.into(),
                iterations,
                final_error: solver.error_sq_vs(&x_star) / n as f64,
                total_reads: reads,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_study_bound_is_conservative() {
        let rows = rate_study(20, 0.85, 5, 4000, 11);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.predicted_bound < 1.0);
            assert!(r.measured_rate < 1.0, "{}: no decay", r.family);
            // measured at least as fast as predicted (bound conservative)
            assert!(
                r.tightness > 0.8,
                "{}: measured slower than bound: {r:?}",
                r.family
            );
        }
    }

    #[test]
    fn sampler_study_weighted_wins() {
        let rows = sampler_study(30, 0.85, 3000, 12);
        assert_eq!(rows.len(), 3);
        let uni = rows.iter().find(|r| r.sampler == "uniform").expect("uniform");
        let wei = rows
            .iter()
            .find(|r| r.sampler == "residual-weighted")
            .expect("weighted");
        assert!(wei.final_error < uni.final_error);
    }

    #[test]
    fn parallel_study_density_effect() {
        let rows = parallel_study(100, 0.85, &[8], &[0.01, 0.3], 200, 13);
        assert_eq!(rows.len(), 2);
        let sparse = &rows[0];
        let dense = &rows[1];
        assert!(sparse.effective_batch > dense.effective_batch);
    }

    #[test]
    fn greedy_study_tradeoff() {
        let rows = greedy_study(25, 0.85, 2000, 14);
        let rand = &rows[0];
        let greedy = &rows[1];
        // Greedy is at least as good per iteration…
        assert!(greedy.final_error <= rand.final_error * 1.5);
        // …but pays more reads (argmax scans).
        assert!(greedy.total_reads > rand.total_reads);
    }
}
