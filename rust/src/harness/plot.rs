//! ASCII log-scale trajectory plots — the terminal rendition of the
//! paper's semilogy figures.

/// One plotted series.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    pub glyph: char,
}

/// Render series on a log10 y-axis, linear x-axis.
pub fn semilogy(series: &[Series], width: usize, height: usize, title: &str) -> String {
    assert!(width >= 16 && height >= 4, "plot too small");
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            if y > 0.0 && y.is_finite() {
                pts.push((x, y.log10()));
            }
        }
    }
    if pts.is_empty() {
        return format!("{title}\n(no positive data to plot)\n");
    }
    let xmin = pts.iter().map(|p| p.0).fold(f64::MAX, f64::min);
    let xmax = pts.iter().map(|p| p.0).fold(f64::MIN, f64::max);
    let ymin = pts.iter().map(|p| p.1).fold(f64::MAX, f64::min);
    let ymax = pts.iter().map(|p| p.1).fold(f64::MIN, f64::max);
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for (&x, &y) in s.xs.iter().zip(&s.ys) {
            if !(y > 0.0) || !y.is_finite() {
                continue;
            }
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = (((ymax - y.log10()) / yspan) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = s.glyph;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - yspan * i as f64 / (height - 1) as f64;
        let line: String = row.iter().collect();
        out.push_str(&format!("1e{yv:>6.1} |{line}|\n"));
    }
    out.push_str(&format!(
        "{:>9} +{}+\n{:>10} {:<.0}{:>width$.0}\n",
        "",
        "-".repeat(width),
        "t =",
        xmin,
        xmax,
        width = width - 1
    ));
    for s in series {
        out.push_str(&format!("  {} {}\n", s.glyph, s.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(label: &str, glyph: char, rate: f64) -> Series {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| rate.powf(x)).collect();
        Series { label: label.into(), xs, ys, glyph: glyph }
    }

    #[test]
    fn renders_title_legend_and_glyphs() {
        let s = [mk("fast", '*', 0.5), mk("slow", 'o', 0.95)];
        let txt = semilogy(&s, 60, 16, "decay");
        assert!(txt.starts_with("decay\n"));
        assert!(txt.contains("* fast"));
        assert!(txt.contains("o slow"));
        assert!(txt.matches('*').count() > 10);
    }

    #[test]
    fn empty_data_handled() {
        let s = [Series { label: "x".into(), xs: vec![1.0], ys: vec![0.0], glyph: '*' }];
        let txt = semilogy(&s, 40, 8, "t");
        assert!(txt.contains("no positive data"));
    }

    #[test]
    fn faster_series_drops_lower() {
        let s = [mk("fast", '*', 0.5), mk("slow", 'o', 0.99)];
        let txt = semilogy(&s, 60, 20, "t");
        // last grid row (smallest y) should contain the fast glyph only
        let rows: Vec<&str> = txt.lines().collect();
        let low_rows = &rows[15..20];
        let fast_low = low_rows.iter().any(|r| r.contains('*'));
        let slow_low = low_rows.iter().any(|r| r.contains('o'));
        assert!(fast_low && !slow_low, "{txt}");
    }

    #[test]
    #[should_panic]
    fn too_small_panics() {
        semilogy(&[], 4, 2, "t");
    }
}
