//! CSV and table rendering for experiment outputs.

use super::experiment::AveragedTrajectory;

/// Serialize averaged trajectories (shared t-axis) as CSV:
/// `t,<name>_mean,<name>_var,...` per series.
pub fn trajectories_csv(trs: &[AveragedTrajectory]) -> String {
    assert!(!trs.is_empty());
    let len = trs[0].mean.len();
    assert!(
        trs.iter().all(|t| t.mean.len() == len && t.ts.len() == len),
        "trajectory lengths differ"
    );
    let mut out = String::from("t");
    for t in trs {
        let id = t.name.replace([' ', ','], "_");
        out.push_str(&format!(",{id}_mean,{id}_var"));
    }
    out.push('\n');
    for i in 0..len {
        out.push_str(&trs[0].ts[i].to_string());
        for t in trs {
            out.push_str(&format!(",{:e},{:e}", t.mean[i], t.variance[i]));
        }
        out.push('\n');
    }
    out
}

/// A simple aligned text table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let cols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), cols, "row arity mismatch");
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<&str>, widths: &[usize]| -> String {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(widths) {
            line.push_str(&format!("{c:<w$}  ", w = w));
        }
        line.trim_end().to_string()
    };
    out.push_str(&fmt_row(headers.to_vec(), &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row.iter().map(String::as_str).collect(), &widths));
        out.push('\n');
    }
    out
}

/// Write text to a file, creating parent directories.
pub fn write_file(path: &std::path::Path, content: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(name: &str) -> AveragedTrajectory {
        AveragedTrajectory {
            name: name.into(),
            ts: vec![0, 10, 20],
            mean: vec![1.0, 0.5, 0.25],
            variance: vec![0.0, 0.01, 0.02],
            sample_rounds: vec![],
        }
    }

    #[test]
    fn csv_layout() {
        let csv = trajectories_csv(&[tr("mp alg"), tr("it")]);
        let mut lines = csv.lines();
        assert_eq!(lines.next().expect("header"), "t,mp_alg_mean,mp_alg_var,it_mean,it_var");
        let row = lines.next().expect("row0");
        assert!(row.starts_with("0,1e0,0e0"));
        assert_eq!(csv.lines().count(), 4);
    }

    #[test]
    #[should_panic]
    fn csv_rejects_mismatched_lengths() {
        let mut b = tr("b");
        b.mean.pop();
        b.ts.pop();
        b.variance.pop();
        trajectories_csv(&[tr("a"), b]);
    }

    #[test]
    fn table_alignment() {
        let txt = table(
            &["algo", "rate"],
            &[
                vec!["mp".into(), "0.99957".into()],
                vec!["ishii-tempo".into(), "~1/t".into()],
            ],
        );
        let lines: Vec<&str> = txt.lines().collect();
        assert!(lines[0].starts_with("algo"));
        assert!(lines[2].starts_with("mp"));
        assert!(lines[3].starts_with("ishii-tempo"));
    }

    #[test]
    fn write_file_creates_dirs() {
        let dir = std::env::temp_dir().join(format!("prmp_report_{}", std::process::id()));
        let path = dir.join("sub/out.csv");
        write_file(&path, "x\n").expect("writes");
        assert_eq!(std::fs::read_to_string(&path).expect("reads"), "x\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
