//! Experiment harness: regenerates every figure in the paper's evaluation
//! plus the ablations of DESIGN.md §4.
//!
//! * [`experiment`] — multi-round averaged-trajectory runner (the paper
//!   averages 100 rounds for Fig. 1, 1000 for Fig. 2), parallelized over
//!   OS threads.
//! * [`fig1`] — Figure 1: `(1/N)‖x_t - x*‖²` for MP vs \[6\] vs \[15\].
//! * [`fig2`] — Figure 2: `‖s_t - s‖²` for Algorithm 2.
//! * [`ablation`] — rate-vs-prediction, sampler and parallelism studies.
//! * [`plot`] — ASCII log-scale trajectory plots for terminal reports.
//! * [`report`] — CSV serialization of every experiment.

pub mod ablation;
pub mod experiment;
pub mod fig1;
pub mod fig2;
pub mod plot;
pub mod report;
