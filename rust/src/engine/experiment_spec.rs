//! The experiment-kind registry: *what a scenario runs*, as data.
//!
//! The paper's evaluation has two experiment shapes — racing PageRank
//! solvers against a reference solution (Fig. 1) and racing distributed
//! size estimators toward the uniform vector `s = 𝟙/N` (Fig. 2,
//! Appendix). [`ExperimentSpec`] names the shape plus its kind-specific
//! participants, while the shared shape (graph, steps, stride, rounds,
//! threads, seed) stays on [`super::Scenario`]; adding a third
//! experiment kind means a new variant here plus a run arm in
//! `Scenario::run`, not a new harness.
//!
//! [`EstimatorSpec`] is the estimator counterpart of
//! [`super::SolverSpec`]: a compact string registry
//! (`"kaczmarz"`, `"degree"`, `"walk"`) over the
//! [`crate::algo::size_estimation`] iteration with pluggable site
//! selection, behind one `build(&graph)` factory yielding a runnable
//! [`EstimatorRun`].

use std::collections::BTreeMap;

use crate::algo::common::StepStats;
use crate::algo::size_estimation::{SiteSampler, SiteSelection, SizeEstimator};
use crate::graph::Graph;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::solver_spec::SolverSpec;

/// A serializable description of a size-estimation iteration: Algorithm
/// 2's row projection plus the update-site policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EstimatorSpec {
    /// Algorithm 2 (Appendix) as published: uniform site sampling. The
    /// engine's `kaczmarz` runs are bit-identical to
    /// [`SizeEstimator::step`] driven directly.
    Kaczmarz,
    /// Same iteration, sites drawn ∝ out-degree (the source of a
    /// uniformly random edge) — hubs project often, leaves rarely.
    DegreeWeighted,
    /// Same iteration, sites visited by a token random-walking the
    /// out-links — fully local, no global sampling primitive at all.
    RandomWalk,
}

impl EstimatorSpec {
    /// Canonical registry string (inverse of [`EstimatorSpec::parse`]).
    pub fn key(&self) -> String {
        match self {
            EstimatorSpec::Kaczmarz => "kaczmarz".to_string(),
            EstimatorSpec::DegreeWeighted => "degree".to_string(),
            EstimatorSpec::RandomWalk => "walk".to_string(),
        }
    }

    /// One-line description for `pagerank-mp list-solvers` and reports.
    pub fn describe(&self) -> &'static str {
        match self {
            EstimatorSpec::Kaczmarz => {
                "Algorithm 2: randomized Kaczmarz on C=(I-A)ᵀ, uniform sites"
            }
            EstimatorSpec::DegreeWeighted => {
                "Algorithm 2 iteration, sites ∝ out-degree (random edge source)"
            }
            EstimatorSpec::RandomWalk => {
                "Algorithm 2 iteration, sites from a random walk along out-links"
            }
        }
    }

    /// Parse a registry string (canonical keys plus aliases).
    pub fn parse(s: &str) -> Result<EstimatorSpec, String> {
        match s {
            "kaczmarz" | "size" | "algorithm-2" | "alg2" => Ok(EstimatorSpec::Kaczmarz),
            "degree" | "degree-weighted" => Ok(EstimatorSpec::DegreeWeighted),
            "walk" | "random-walk" => Ok(EstimatorSpec::RandomWalk),
            other => Err(format!(
                "unknown estimator {other:?} — try one of: {}",
                EstimatorSpec::all()
                    .iter()
                    .map(EstimatorSpec::key)
                    .collect::<Vec<_>>()
                    .join(", ")
            )),
        }
    }

    /// Every variant — the registry listing.
    pub fn all() -> Vec<EstimatorSpec> {
        vec![
            EstimatorSpec::Kaczmarz,
            EstimatorSpec::DegreeWeighted,
            EstimatorSpec::RandomWalk,
        ]
    }

    /// The site policy this spec names.
    pub fn selection(&self) -> SiteSelection {
        match self {
            EstimatorSpec::Kaczmarz => SiteSelection::Uniform,
            EstimatorSpec::DegreeWeighted => SiteSelection::DegreeWeighted,
            EstimatorSpec::RandomWalk => SiteSelection::RandomWalk,
        }
    }

    /// Uniform factory: a runnable estimator over `graph`. Fails (with
    /// the algorithm's own message) on empty or not-strongly-connected
    /// graphs — the Appendix assumption.
    pub fn build<'g>(&self, graph: &'g Graph) -> Result<EstimatorRun<'g>, String> {
        let est = SizeEstimator::new(graph).map_err(|e| format!("estimator {}: {e}", self.key()))?;
        Ok(EstimatorRun { sampler: SiteSampler::new(graph, self.selection()), est })
    }
}

/// A runnable size-estimation iteration: [`SizeEstimator`] plus its site
/// sampler, stepped like a solver but measured on Fig.-2 axes.
pub struct EstimatorRun<'g> {
    est: SizeEstimator<'g>,
    sampler: SiteSampler,
}

impl<'g> EstimatorRun<'g> {
    /// One eq.-14 update at the next sampled site.
    pub fn step(&mut self, rng: &mut Rng) -> StepStats {
        self.est.step_with(&mut self.sampler, rng)
    }

    /// `‖s_t - 𝟙/N‖²` — the Fig.-2 y-axis.
    pub fn error_sq(&self) -> f64 {
        self.est.error_sq()
    }

    /// Mean relative size error `|N̂_i - N|/N` over defined pages.
    pub fn mean_rel_size_error(&self) -> f64 {
        self.est.mean_rel_size_error()
    }

    /// The wrapped Algorithm-2 state.
    pub fn estimator(&self) -> &SizeEstimator<'g> {
        &self.est
    }
}

/// What a [`super::Scenario`] runs: the experiment kind plus its
/// kind-specific participants.
#[derive(Debug, Clone, PartialEq)]
pub enum ExperimentSpec {
    /// Fig.-1 shape: race PageRank solvers against a reference `x*`.
    PageRank { solvers: Vec<SolverSpec> },
    /// Fig.-2 shape: race size estimators toward `s = 𝟙/N`.
    SizeEstimation { estimators: Vec<EstimatorSpec> },
}

impl ExperimentSpec {
    pub fn pagerank(solvers: Vec<SolverSpec>) -> ExperimentSpec {
        ExperimentSpec::PageRank { solvers }
    }

    pub fn size_estimation(estimators: Vec<EstimatorSpec>) -> ExperimentSpec {
        ExperimentSpec::SizeEstimation { estimators }
    }

    /// The kind's registry name (the JSON `"kind"` value).
    pub fn kind_key(&self) -> &'static str {
        match self {
            ExperimentSpec::PageRank { .. } => "pagerank",
            ExperimentSpec::SizeEstimation { .. } => "size-estimation",
        }
    }

    /// Registry keys of every run in the experiment, in run order.
    pub fn run_keys(&self) -> Vec<String> {
        match self {
            ExperimentSpec::PageRank { solvers } => {
                solvers.iter().map(SolverSpec::key).collect()
            }
            ExperimentSpec::SizeEstimation { estimators } => {
                estimators.iter().map(EstimatorSpec::key).collect()
            }
        }
    }

    /// Number of runs (solvers or estimators).
    pub fn len(&self) -> usize {
        match self {
            ExperimentSpec::PageRank { solvers } => solvers.len(),
            ExperimentSpec::SizeEstimation { estimators } => estimators.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// JSON object form: `{"kind": "...", "solvers"|"estimators": [...]}`.
    ///
    /// Note [`super::Scenario::to_json`] serializes the PageRank kind as
    /// a bare top-level `"solvers"` array instead (the pre-experiment
    /// schema), so existing scenario files and BENCH consumers keep
    /// working; this form is what non-default kinds embed under the
    /// scenario's `"experiment"` key.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), Json::String(self.kind_key().into()));
        let (field, keys) = match self {
            ExperimentSpec::PageRank { .. } => ("solvers", self.run_keys()),
            ExperimentSpec::SizeEstimation { .. } => ("estimators", self.run_keys()),
        };
        m.insert(
            field.to_string(),
            Json::Array(keys.into_iter().map(Json::String).collect()),
        );
        Json::Object(m)
    }

    /// Parse from a string (`"pagerank"`, `"size-estimation"` — default
    /// participants) or the object form of [`ExperimentSpec::to_json`].
    pub fn from_json(v: &Json) -> Result<ExperimentSpec, String> {
        let kind = match v.as_str() {
            Some(k) => k.to_string(),
            None => v
                .get("kind")
                .and_then(Json::as_str)
                .ok_or("experiment needs a \"kind\" string (pagerank | size-estimation)")?
                .to_string(),
        };
        let keys = |field: &str| -> Result<Option<Vec<String>>, String> {
            match v.get(field) {
                None => Ok(None),
                Some(Json::Array(arr)) => {
                    let mut keys = Vec::with_capacity(arr.len());
                    for s in arr {
                        keys.push(
                            s.as_str()
                                .ok_or_else(|| {
                                    format!("\"{field}\" must be an array of registry strings")
                                })?
                                .to_string(),
                        );
                    }
                    Ok(Some(keys))
                }
                Some(_) => Err(format!("\"{field}\" must be an array of registry strings")),
            }
        };
        match kind.as_str() {
            "pagerank" => {
                if v.get("estimators").is_some() {
                    return Err("a pagerank experiment takes \"solvers\", not \"estimators\"".into());
                }
                let solvers = match keys("solvers")? {
                    None => vec![SolverSpec::Mp],
                    Some(keys) => {
                        let mut solvers = Vec::with_capacity(keys.len());
                        for k in keys {
                            solvers.push(SolverSpec::parse(&k)?);
                        }
                        solvers
                    }
                };
                Ok(ExperimentSpec::PageRank { solvers })
            }
            "size-estimation" | "size" | "fig2" => {
                if v.get("solvers").is_some() {
                    return Err(
                        "a size-estimation experiment takes \"estimators\", not \"solvers\"".into(),
                    );
                }
                let estimators = match keys("estimators")? {
                    None => vec![EstimatorSpec::Kaczmarz],
                    Some(keys) => {
                        let mut estimators = Vec::with_capacity(keys.len());
                        for k in keys {
                            estimators.push(EstimatorSpec::parse(&k)?);
                        }
                        estimators
                    }
                };
                Ok(ExperimentSpec::SizeEstimation { estimators })
            }
            other => Err(format!(
                "unknown experiment kind {other:?} (pagerank | size-estimation)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn estimator_registry_round_trips() {
        for spec in EstimatorSpec::all() {
            let key = spec.key();
            assert_eq!(EstimatorSpec::parse(&key).expect("canonical key parses"), spec);
        }
        assert_eq!(EstimatorSpec::parse("size").expect("alias"), EstimatorSpec::Kaczmarz);
        assert_eq!(
            EstimatorSpec::parse("random-walk").expect("alias"),
            EstimatorSpec::RandomWalk
        );
        assert!(EstimatorSpec::parse("bogus").is_err());
    }

    #[test]
    fn every_estimator_builds_and_converges() {
        let g = generators::er_threshold(25, 0.5, 50);
        for spec in EstimatorSpec::all() {
            let mut run = spec.build(&g).expect("ER-threshold graphs are connected");
            let mut rng = Rng::seeded(51);
            let e0 = run.error_sq();
            let mut stats = StepStats::default();
            // Budget sized for the slower non-uniform site streams too.
            for _ in 0..30_000 {
                stats.accumulate(run.step(&mut rng));
            }
            assert!(run.error_sq() < 1e-6 * e0.max(1.0), "{}: {}", spec.key(), run.error_sq());
            assert!(run.mean_rel_size_error() < 1e-2, "{}", spec.key());
            assert_eq!(stats.activated, 30_000, "{}", spec.key());
            assert_eq!(stats.reads, stats.writes, "{}: eq. 14 touches out(k) twice", spec.key());
        }
    }

    #[test]
    fn build_rejects_disconnected_graphs_with_the_algorithm_error() {
        let mut b = crate::graph::GraphBuilder::new(4)
            .dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 1).add_edge(1, 0).add_edge(2, 3).add_edge(3, 2);
        let g = b.build().expect("builds");
        let err = EstimatorSpec::Kaczmarz.build(&g).expect_err("must refuse");
        assert!(err.contains("strongly connected"), "{err}");
        assert!(err.contains("kaczmarz"), "error names the spec: {err}");
    }

    #[test]
    fn experiment_spec_json_round_trips() {
        for spec in [
            ExperimentSpec::pagerank(vec![SolverSpec::Mp, SolverSpec::Dense]),
            ExperimentSpec::size_estimation(EstimatorSpec::all()),
        ] {
            let text = spec.to_json().render();
            let back = ExperimentSpec::from_json(&Json::parse(&text).expect("valid json"))
                .expect("round trips");
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn experiment_spec_string_forms_and_defaults() {
        let pr = ExperimentSpec::from_json(&Json::String("pagerank".into())).expect("parses");
        assert_eq!(pr, ExperimentSpec::pagerank(vec![SolverSpec::Mp]));
        let se = ExperimentSpec::from_json(&Json::String("size-estimation".into())).expect("parses");
        assert_eq!(se, ExperimentSpec::size_estimation(vec![EstimatorSpec::Kaczmarz]));
        assert_eq!(se.kind_key(), "size-estimation");
        assert_eq!(se.run_keys(), vec!["kaczmarz".to_string()]);
    }

    #[test]
    fn experiment_spec_rejects_mismatched_fields() {
        let bad = Json::parse(r#"{"kind": "size-estimation", "solvers": ["mp"]}"#).expect("json");
        assert!(ExperimentSpec::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"kind": "pagerank", "estimators": ["kaczmarz"]}"#).expect("json");
        assert!(ExperimentSpec::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"kind": "teleport"}"#).expect("json");
        assert!(ExperimentSpec::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"kind": "size-estimation", "estimators": ["bogus"]}"#)
            .expect("json");
        assert!(ExperimentSpec::from_json(&bad).is_err());
    }
}
