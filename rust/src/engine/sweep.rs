//! Parameter sweeps: one scenario expanded over a grid, merged into one
//! perf trajectory.
//!
//! A [`Sweep`] is a base [`Scenario`] plus named axes (`n`, `alpha`,
//! `shards`, `batch`, `latency`, `steps`, `stride`, `rounds`, `seed`);
//! [`Sweep::cells`] expands the cartesian product into fully-formed
//! per-cell scenarios, and [`SweepReport`] merges the per-cell
//! [`ScenarioReport`]s into a single machine-readable
//! `BENCH_sweep.json` — the artifact the CI perf history accumulates.
//!
//! JSON form (see `examples/sweep_small.json`):
//!
//! ```json
//! {
//!   "name": "backend-grid",
//!   "scenario": { "graph": "paper:30", "solvers": ["mp", "sharded:2:8"] },
//!   "grid": { "n": [20, 30], "shards": [1, 2] }
//! }
//! ```
//!
//! Axes are applied to the *relevant* specs and are experiment-aware:
//! `shards`/`batch`/`map` rewrite the sharded and msgpass (and, for
//! `batch`, parallel-mp) solver entries, `packer`/`sampling` rewrite the
//! sharded entries, `gossip` rewrites msgpass entries,
//! `drop`/`crash`/`link`/`partition` rewrite msgpass fault plans (each
//! window axis takes a window spec string or `"none"`, so one grid races
//! faulted against fault-free runs), `latency` rewrites
//! coordinator entries,
//! `graph` swaps the whole graph spec (a registry string or object, so a
//! sweep can range over graph *families*), and naming an axis with no
//! applicable solver — or a solver-only axis on a size-estimation
//! scenario, or `n` on a file graph — is an error rather than a silent
//! no-op. Axis order is alphabetical (stable), values keep their listed
//! order, so cell expansion is deterministic; note `graph` sorts before
//! `n`, so a size axis re-sizes whatever family the cell's `graph` chose.

use std::collections::BTreeMap;

use crate::network::LatencyModel;
use crate::util::json::Json;

use super::experiment_spec::ExperimentSpec;
use super::graph_spec::GraphSpec;
use super::report::ScenarioReport;
use super::scenario::Scenario;
use super::solver_spec::SolverSpec;

/// A declarative parameter sweep: base scenario × named grid axes.
#[derive(Debug, Clone, PartialEq)]
pub struct Sweep {
    pub name: String,
    pub base: Scenario,
    /// `(axis, values)` sorted by axis name; every value combination
    /// becomes one cell.
    pub axes: Vec<(String, Vec<Json>)>,
}

/// The grid axes [`Sweep`] understands.
pub const SWEEP_AXES: &[&str] = &[
    "alpha", "batch", "crash", "drop", "gossip", "graph", "latency", "link", "map", "n",
    "packer", "partition", "rounds", "sampling", "seed", "shards", "steps", "stride",
];

fn render_param(v: &Json) -> String {
    match v.as_str() {
        Some(s) => s.to_string(),
        None => v.render(),
    }
}

/// The solver list of a PageRank scenario, or a loud error for axes that
/// only make sense there (a size-estimation run has no shards, batches,
/// latencies or α to sweep).
fn pagerank_solvers<'a>(
    scenario: &'a mut Scenario,
    axis: &str,
) -> Result<&'a mut Vec<SolverSpec>, String> {
    match &mut scenario.experiment {
        ExperimentSpec::PageRank { solvers } => Ok(solvers),
        other => Err(format!(
            "axis {axis:?} applies to PageRank solvers, but the scenario runs a {} experiment",
            other.kind_key()
        )),
    }
}

/// Apply one axis assignment to a scenario.
fn apply_axis(scenario: &mut Scenario, axis: &str, value: &Json) -> Result<(), String> {
    let want_usize = || {
        value
            .as_usize()
            .ok_or_else(|| format!("axis {axis:?}: {} is not a non-negative integer", value.render()))
    };
    match axis {
        "graph" => {
            // A registry string ("ba:100") or a graph object — the axis
            // that sweeps over graph *families*. Applied before "n"
            // (alphabetical order), so an n axis re-sizes the family
            // this cell picked.
            scenario.graph = GraphSpec::from_json(value)
                .map_err(|e| format!("axis \"graph\": {e}"))?;
        }
        "n" => {
            let n = want_usize()?;
            // Every generator family is total for n >= 2 except ws
            // (whose k=4 lattice needs n > 4 — that one surfaces when
            // the cell's graph builds); n < 2 would panic inside
            // chain/star asserts instead of erroring.
            if n < 2 {
                return Err("axis \"n\": must be >= 2".into());
            }
            match &mut scenario.graph {
                GraphSpec::ErThreshold { n: gn, .. } => *gn = n,
                GraphSpec::Family { n: gn, .. } => *gn = n,
                // A silent no-op here would run every "cell" on the same
                // file and report them as different sizes — refuse.
                GraphSpec::File { path, .. } => {
                    return Err(format!(
                        "axis \"n\" cannot resize the file graph {path:?} — drop the axis or \
                         sweep generated families via the \"graph\" axis instead"
                    ))
                }
            }
        }
        "alpha" => {
            if !matches!(scenario.experiment, ExperimentSpec::PageRank { .. }) {
                return Err(
                    "axis \"alpha\": size estimation runs on C = (I-A)ᵀ (the α = 1 analogue); \
                     the axis applies only to PageRank experiments"
                        .into(),
                );
            }
            let alpha = value
                .as_f64()
                .ok_or_else(|| format!("axis \"alpha\": {} is not a number", value.render()))?;
            if !(alpha > 0.0 && alpha < 1.0) {
                return Err(format!("axis \"alpha\": {alpha} out of (0,1)"));
            }
            scenario.alpha = alpha;
        }
        "steps" => {
            let v = want_usize()?;
            if v == 0 {
                return Err("axis \"steps\": must be >= 1".into());
            }
            scenario.steps = v;
        }
        "stride" => {
            let v = want_usize()?;
            if v == 0 {
                return Err("axis \"stride\": must be >= 1".into());
            }
            scenario.stride = v;
        }
        "rounds" => {
            let v = want_usize()?;
            if v == 0 {
                return Err("axis \"rounds\": must be >= 1".into());
            }
            scenario.rounds = v;
        }
        "seed" => {
            scenario.seed = want_usize()? as u64;
        }
        "shards" => {
            let shards = want_usize()?;
            if shards == 0 {
                return Err("axis \"shards\": must be >= 1".into());
            }
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                match s {
                    SolverSpec::Sharded { shards: sh, batch, .. } => {
                        // Keep the parse-time claim-word bound: an axis must
                        // not assemble a cell the runtime would panic on.
                        let max = crate::coordinator::sharded::max_batch_budget(shards);
                        if *batch > max {
                            return Err(format!(
                                "axis \"shards\": {shards} shard(s) cap the packable batch \
                                 at {max}, but the solver batch is {batch}"
                            ));
                        }
                        *sh = shards;
                        hit = true;
                    }
                    SolverSpec::Msgpass { shards: sh, .. } => {
                        *sh = shards;
                        hit = true;
                    }
                    _ => {}
                }
            }
            if !hit {
                return Err(
                    "axis \"shards\" needs a sharded or msgpass solver in the scenario \
                     (e.g. \"sharded:2:8\", \"msgpass:2:8\")"
                        .into(),
                );
            }
        }
        "batch" => {
            let batch = want_usize()?;
            if batch == 0 {
                return Err("axis \"batch\": must be >= 1".into());
            }
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                match s {
                    SolverSpec::Sharded { shards, batch: b, .. } => {
                        let max = crate::coordinator::sharded::max_batch_budget(*shards);
                        if batch > max {
                            return Err(format!(
                                "axis \"batch\": {batch} exceeds the packable maximum \
                                 {max} at {shards} shard(s)"
                            ));
                        }
                        *b = batch;
                        hit = true;
                    }
                    SolverSpec::Msgpass { batch: b, .. } => {
                        *b = batch;
                        hit = true;
                    }
                    SolverSpec::ParallelMp { batch: b } => {
                        *b = batch;
                        hit = true;
                    }
                    _ => {}
                }
            }
            if !hit {
                return Err(
                    "axis \"batch\" needs a sharded, msgpass or parallel-mp solver in the \
                     scenario"
                        .into(),
                );
            }
        }
        "gossip" => {
            let gossip = want_usize()?;
            if gossip == 0 {
                return Err("axis \"gossip\": must be >= 1".into());
            }
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Msgpass { gossip: g, .. } = s {
                    *g = gossip;
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"gossip\" needs a msgpass solver in the scenario (e.g. \
                     \"msgpass:2:8\")"
                        .into(),
                );
            }
        }
        "drop" => {
            let p = value
                .as_f64()
                .ok_or_else(|| format!("axis \"drop\": {} is not a number", value.render()))?;
            if !(0.0..1.0).contains(&p) {
                return Err(format!("axis \"drop\": probability {p} out of [0, 1)"));
            }
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Msgpass { drop: d, .. } = s {
                    *d = p;
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"drop\" needs a msgpass solver in the scenario (e.g. \
                     \"msgpass:2:8:mod:rel\")"
                        .into(),
                );
            }
        }
        "crash" => {
            // A crash-window string ("1@64+32") or "none" to clear the
            // windows for this cell — so a sweep can race crashed
            // against crash-free runs on one grid. The axis replaces
            // the solver's whole crash list with the one window.
            let spec = value
                .as_str()
                .ok_or_else(|| format!("axis \"crash\": {} is not a string", value.render()))?;
            let window = if spec == "none" {
                None
            } else {
                Some(
                    crate::network::CrashWindow::parse(spec)
                        .map_err(|e| format!("axis \"crash\": {e}"))?,
                )
            };
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Msgpass { shards, crashes, .. } = s {
                    if let Some(w) = &window {
                        if w.shard >= *shards {
                            return Err(format!(
                                "axis \"crash\": window names shard {} but the solver has \
                                 {shards} shard(s)",
                                w.shard
                            ));
                        }
                    }
                    *crashes = window.iter().copied().collect();
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"crash\" needs a msgpass solver in the scenario (e.g. \
                     \"msgpass:2:8:mod:rel\")"
                        .into(),
                );
            }
        }
        "link" => {
            // A directional link-window string ("0-1@64+32") or "none"
            // to clear the windows — the partition-tolerance race axis
            // for asymmetric failures.
            let spec = value
                .as_str()
                .ok_or_else(|| format!("axis \"link\": {} is not a string", value.render()))?;
            let window = if spec == "none" {
                None
            } else {
                Some(
                    crate::network::LinkWindow::parse(spec)
                        .map_err(|e| format!("axis \"link\": {e}"))?,
                )
            };
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Msgpass { shards, links, .. } = s {
                    if let Some(w) = &window {
                        for (role, sh) in [("src", w.src), ("dst", w.dst)] {
                            if sh >= *shards {
                                return Err(format!(
                                    "axis \"link\": window names {role} shard {sh} but the \
                                     solver has {shards} shard(s)"
                                ));
                            }
                        }
                    }
                    *links = window.iter().copied().collect();
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"link\" needs a msgpass solver in the scenario (e.g. \
                     \"msgpass:2:8:mod:rel\")"
                        .into(),
                );
            }
        }
        "partition" => {
            // A bipartition-window string ("0.1@64+32") or "none" — the
            // healing-partition race axis. Left-side members before the
            // `@`, dot-separated.
            let spec = value
                .as_str()
                .ok_or_else(|| format!("axis \"partition\": {} is not a string", value.render()))?;
            let window = if spec == "none" {
                None
            } else {
                Some(
                    crate::network::PartitionWindow::parse(spec)
                        .map_err(|e| format!("axis \"partition\": {e}"))?,
                )
            };
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Msgpass { shards, partitions, .. } = s {
                    if let Some(w) = &window {
                        for &m in &w.left {
                            if m >= *shards {
                                return Err(format!(
                                    "axis \"partition\": window names shard {m} but the \
                                     solver has {shards} shard(s)"
                                ));
                            }
                        }
                        if w.left.len() >= *shards {
                            return Err(format!(
                                "axis \"partition\": window is not a proper bipartition \
                                 at {shards} shard(s): both sides must be non-empty"
                            ));
                        }
                    }
                    *partitions = window.iter().cloned().collect();
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"partition\" needs a msgpass solver in the scenario (e.g. \
                     \"msgpass:2:8:mod:rel\")"
                        .into(),
                );
            }
        }
        "map" => {
            // Races shard maps (mod/block/cluster/scc) across a grid —
            // the locality experiment's axis. Rewrites both sharded and
            // msgpass entries so one cell compares like with like.
            let spec = value
                .as_str()
                .ok_or_else(|| format!("axis \"map\": {} is not a string", value.render()))?;
            let map = crate::coordinator::ShardMap::parse(spec)
                .map_err(|e| format!("axis \"map\": {e}"))?;
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                match s {
                    SolverSpec::Sharded { map: m, .. } => {
                        *m = map;
                        hit = true;
                    }
                    SolverSpec::Msgpass { map: m, .. } => {
                        *m = map;
                        hit = true;
                    }
                    _ => {}
                }
            }
            if !hit {
                return Err(
                    "axis \"map\" needs a sharded or msgpass solver in the scenario \
                     (e.g. \"sharded:2:8:cluster\", \"msgpass:2:8:scc\")"
                        .into(),
                );
            }
        }
        "packer" => {
            let spec = value
                .as_str()
                .ok_or_else(|| format!("axis \"packer\": {} is not a string", value.render()))?;
            let packer = crate::coordinator::Packer::parse(spec)
                .ok_or_else(|| format!("axis \"packer\": bad policy {spec:?} (leader|worker)"))?;
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Sharded { packer: p, .. } = s {
                    *p = packer;
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"packer\" needs a sharded solver in the scenario (e.g. \"sharded:2:8\")"
                        .into(),
                );
            }
        }
        "sampling" => {
            let spec = value
                .as_str()
                .ok_or_else(|| format!("axis \"sampling\": {} is not a string", value.render()))?;
            let sampling = crate::coordinator::Sampling::parse(spec).ok_or_else(|| {
                format!("axis \"sampling\": bad policy {spec:?} (uniform|residual)")
            })?;
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Sharded { sampling: sm, .. } = s {
                    *sm = sampling;
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"sampling\" needs a sharded solver in the scenario (e.g. \
                     \"sharded:2:8\")"
                        .into(),
                );
            }
        }
        "latency" => {
            let spec = value
                .as_str()
                .ok_or_else(|| format!("axis \"latency\": {} is not a string", value.render()))?;
            let latency = LatencyModel::parse(spec).ok_or_else(|| {
                format!("axis \"latency\": bad model {spec:?} (zero|const:L|uniform:lo:hi|exp:mean)")
            })?;
            let mut hit = false;
            for s in pagerank_solvers(scenario, axis)? {
                if let SolverSpec::Coordinator { latency: l, .. } = s {
                    *l = latency;
                    hit = true;
                }
            }
            if !hit {
                return Err(
                    "axis \"latency\" needs a coordinator solver in the scenario".into(),
                );
            }
        }
        other => {
            return Err(format!(
                "unknown sweep axis {other:?} — known axes: {}",
                SWEEP_AXES.join(", ")
            ))
        }
    }
    Ok(())
}

impl Sweep {
    /// Parse from the object form (`name`, `scenario`, `grid`). A bare
    /// (non-array) grid value is treated as a one-value axis.
    pub fn from_json(v: &Json) -> Result<Sweep, String> {
        let base = Scenario::from_json(
            v.get("scenario").ok_or("sweep needs a \"scenario\" object")?,
        )?;
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(&base.name)
            .to_string();
        let grid = match v.get("grid") {
            Some(Json::Object(m)) => m.clone(),
            Some(_) => return Err("\"grid\" must be an object of axis -> values".into()),
            None => BTreeMap::new(),
        };
        let mut axes = Vec::with_capacity(grid.len());
        for (axis, values) in grid {
            let values: Vec<Json> = match values {
                Json::Array(vs) => vs,
                single => vec![single],
            };
            if values.is_empty() {
                return Err(format!("axis {axis:?} has no values"));
            }
            axes.push((axis, values));
        }
        // BTreeMap iteration already sorted; keep the invariant explicit.
        axes.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(Sweep { name, base, axes })
    }

    /// Parse from JSON text (the `sweep` CLI path).
    pub fn from_json_str(text: &str) -> Result<Sweep, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        Sweep::from_json(&v)
    }

    /// Number of cells the grid expands to.
    pub fn cell_count(&self) -> usize {
        self.axes.iter().map(|(_, vs)| vs.len()).product()
    }

    /// Expand the grid: every cell as `(params, ready-to-run scenario)`.
    /// Axis application is validated here, so errors surface before any
    /// cell runs.
    pub fn cells(&self) -> Result<Vec<ExpandedCell>, String> {
        let total = self.cell_count();
        let mut cells = Vec::with_capacity(total);
        // Mixed-radix counter over the axes (first axis slowest, so cells
        // group by the alphabetically-first axis).
        for idx in 0..total {
            let mut rem = idx;
            let mut radix = total;
            let mut params = Vec::with_capacity(self.axes.len());
            let mut scenario = self.base.clone();
            for (axis, values) in &self.axes {
                radix /= values.len();
                let v = &values[rem / radix];
                rem %= radix;
                apply_axis(&mut scenario, axis, v)?;
                params.push((axis.clone(), v.clone()));
            }
            let suffix: Vec<String> = params
                .iter()
                .map(|(k, v)| format!("{k}={}", render_param(v)))
                .collect();
            // Cells are named after the *sweep* (the base scenario is
            // often an anonymous inline object defaulting to "scenario").
            scenario.name = if suffix.is_empty() {
                self.name.clone()
            } else {
                format!("{}[{}]", self.name, suffix.join(","))
            };
            cells.push((params, scenario));
        }
        Ok(cells)
    }

    /// Run every cell and merge the reports.
    pub fn run(&self) -> Result<SweepReport, String> {
        self.run_with_progress(|_, _, _| {})
    }

    /// Like [`Sweep::run`], reporting `(cell_index, total, cell_name)`
    /// before each cell runs — the CLI's progress hook, kept here so
    /// there is exactly one place that assembles a [`SweepReport`].
    pub fn run_with_progress<F>(&self, mut progress: F) -> Result<SweepReport, String>
    where
        F: FnMut(usize, usize, &str),
    {
        let cells = self.cells()?;
        let total = cells.len();
        let mut done = Vec::with_capacity(total);
        for (i, (params, scenario)) in cells.into_iter().enumerate() {
            progress(i + 1, total, &scenario.name);
            let report = scenario.run()?;
            done.push(SweepCell { params, report });
        }
        Ok(SweepReport {
            name: self.name.clone(),
            base: self.base.clone(),
            axes: self.axes.clone(),
            cells: done,
        })
    }
}

/// One expanded-but-unrun grid cell: the axis assignment (in axis
/// order) plus the fully-formed scenario it produced.
pub type ExpandedCell = (Vec<(String, Json)>, Scenario);

/// One grid cell's outcome.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// The axis assignment that produced this cell, in axis order.
    pub params: Vec<(String, Json)>,
    pub report: ScenarioReport,
}

/// Everything a sweep produces — renderable as a summary table and
/// serializable as the merged `BENCH_sweep.json` perf artifact.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub name: String,
    pub base: Scenario,
    pub axes: Vec<(String, Vec<Json>)>,
    pub cells: Vec<SweepCell>,
}

impl SweepReport {
    /// Summary table: one row per (cell, run). The `conflicts` column
    /// doubles as the kind-specific metric slot — packing drops for
    /// solvers, final relative size error for estimators.
    pub fn render(&self) -> String {
        let fmt_rate = super::report::render_rate;
        let mut rows: Vec<Vec<String>> = Vec::new();
        for cell in &self.cells {
            let params: Vec<String> = cell
                .params
                .iter()
                .map(|(k, v)| format!("{k}={}", render_param(v)))
                .collect();
            let params = params.join(",");
            for r in cell.report.solver_reports() {
                rows.push(vec![
                    params.clone(),
                    r.spec.key(),
                    format!("{:.3e}", r.final_error),
                    fmt_rate(r.decay_rate),
                    r.conflicts.to_string(),
                    format!("{:.0}", r.wall.as_secs_f64() * 1e3),
                ]);
            }
            for r in cell.report.estimator_reports() {
                rows.push(vec![
                    params.clone(),
                    r.spec.key(),
                    format!("{:.3e}", r.final_error),
                    fmt_rate(r.decay_rate),
                    format!("relerr {:.2e}", r.final_size_rel_err),
                    format!("{:.0}", r.wall.as_secs_f64() * 1e3),
                ]);
            }
        }
        let table = crate::harness::report::table(
            &["cell", "run", "final error", "rate/step", "conflicts", "wall ms"],
            &rows,
        );
        format!(
            "sweep {:?}: {} cells × {} runs\n{table}",
            self.name,
            self.cells.len(),
            self.base.experiment.len()
        )
    }

    /// The merged perf trajectory: sweep config plus, per cell, the axis
    /// assignment and the same per-solver summaries as
    /// `BENCH_scenario.json`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("sweep".to_string(), Json::String(self.name.clone()));
        m.insert("base".to_string(), self.base.to_json());
        let mut grid = BTreeMap::new();
        for (axis, values) in &self.axes {
            grid.insert(axis.clone(), Json::Array(values.clone()));
        }
        m.insert("grid".to_string(), Json::Object(grid));
        m.insert(
            "cells".to_string(),
            Json::Array(
                self.cells
                    .iter()
                    .map(|cell| {
                        let mut c = BTreeMap::new();
                        let mut params = BTreeMap::new();
                        for (k, v) in &cell.params {
                            params.insert(k.clone(), v.clone());
                        }
                        c.insert("params".to_string(), Json::Object(params));
                        c.insert(
                            "name".to_string(),
                            Json::String(cell.report.scenario.name.clone()),
                        );
                        // "solvers" for PageRank cells, "estimators" for
                        // size-estimation cells — same shape bench_diff
                        // consumes from BENCH_scenario.json.
                        let (field, summaries) = cell.report.run_summaries();
                        c.insert(field.to_string(), summaries);
                        Json::Object(c)
                    })
                    .collect(),
            ),
        );
        Json::Object(m)
    }

    /// Dump [`SweepReport::to_json`] to disk (`BENCH_sweep.json` at the
    /// repo root by convention).
    pub fn write_bench_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::harness::report::write_file(path, &self.to_json().render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ShardMap;

    fn base_json(grid: &str) -> String {
        format!(
            r#"{{
              "name": "grid-test",
              "scenario": {{
                "graph": "paper:15",
                "solvers": ["mp", "sharded:2:4"],
                "steps": 200, "stride": 100, "rounds": 2, "threads": 1, "seed": 3
              }},
              "grid": {grid}
            }}"#
        )
    }

    #[test]
    fn grid_expands_cartesian_product_in_axis_order() {
        let sweep = Sweep::from_json_str(&base_json(r#"{"n": [10, 15], "shards": [1, 2]}"#))
            .expect("parses");
        assert_eq!(sweep.cell_count(), 4);
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 4);
        // axes sorted: n before shards; first axis slowest.
        let names: Vec<&str> = cells.iter().map(|(_, s)| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "grid-test[n=10,shards=1]",
                "grid-test[n=10,shards=2]",
                "grid-test[n=15,shards=1]",
                "grid-test[n=15,shards=2]",
            ]
        );
        // the assignment really lands in the scenario
        let (_, last) = &cells[3];
        assert_eq!(last.graph, GraphSpec::ErThreshold { n: 15, threshold: 0.5 });
        assert!(last.solvers().iter().any(|s| matches!(
            s,
            SolverSpec::Sharded { shards: 2, batch: 4, map: ShardMap::Modulo, .. }
        )));
    }

    #[test]
    fn packer_axis_rewrites_sharded_entries() {
        use crate::coordinator::Packer;
        let sweep = Sweep::from_json_str(&base_json(r#"{"packer": ["leader", "worker"]}"#))
            .expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 2);
        assert!(cells[0].1.solvers().iter().any(
            |s| matches!(s, SolverSpec::Sharded { packer: Packer::Leader, .. })
        ));
        assert!(cells[1].1.solvers().iter().any(
            |s| matches!(s, SolverSpec::Sharded { packer: Packer::Worker, .. })
        ));
        assert_eq!(cells[1].1.name, "grid-test[packer=worker]");
        // Bad values and packer-less scenarios are rejected up front.
        let bad = Sweep::from_json_str(&base_json(r#"{"packer": ["boss"]}"#)).expect("parses");
        assert!(bad.cells().is_err());
        let no_sharded = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["mp"]},
          "grid": {"packer": ["worker"]}
        }"#;
        let sweep = Sweep::from_json_str(no_sharded).expect("parses");
        assert!(sweep.cells().expect_err("must fail").contains("sharded"));
    }

    #[test]
    fn sampling_axis_rewrites_sharded_entries() {
        use crate::coordinator::Sampling;
        let sweep = Sweep::from_json_str(&base_json(r#"{"sampling": ["uniform", "residual"]}"#))
            .expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 2);
        assert!(cells[0].1.solvers().iter().any(
            |s| matches!(s, SolverSpec::Sharded { sampling: Sampling::Uniform, .. })
        ));
        assert!(cells[1].1.solvers().iter().any(
            |s| matches!(s, SolverSpec::Sharded { sampling: Sampling::Residual, .. })
        ));
        assert_eq!(cells[1].1.name, "grid-test[sampling=residual]");
        // Bad values and sharded-less scenarios are rejected up front.
        let bad =
            Sweep::from_json_str(&base_json(r#"{"sampling": ["importance"]}"#)).expect("parses");
        assert!(bad.cells().is_err());
        let no_sharded = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["mp"]},
          "grid": {"sampling": ["residual"]}
        }"#;
        let sweep = Sweep::from_json_str(no_sharded).expect("parses");
        assert!(sweep.cells().expect_err("must fail").contains("sharded"));
        // And it is refused on size-estimation scenarios like the other
        // solver-only axes.
        let se = r#"{
          "scenario": {
            "graph": "paper:10",
            "experiment": {"kind": "size-estimation", "estimators": ["kaczmarz"]}
          },
          "grid": {"sampling": ["residual"]}
        }"#;
        let err = Sweep::from_json_str(se).expect("parses").cells().expect_err("must fail");
        assert!(err.contains("sampling"), "{err}");
    }

    #[test]
    fn map_axis_rewrites_sharded_and_msgpass_entries() {
        let text = r#"{
          "name": "map-grid",
          "scenario": {
            "graph": "paper:12", "solvers": ["sharded:2:4:mod:worker", "msgpass:2:4:mod"],
            "steps": 100, "stride": 50, "rounds": 1, "threads": 1, "seed": 3
          },
          "grid": {"map": ["mod", "cluster", "scc"]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 3);
        let want = [ShardMap::Modulo, ShardMap::Cluster, ShardMap::Scc];
        for (i, want) in want.iter().enumerate() {
            // Both backend entries move together, so a cell compares
            // like with like.
            assert!(cells[i].1.solvers().iter().all(|s| matches!(
                s,
                SolverSpec::Sharded { map, .. } | SolverSpec::Msgpass { map, .. }
                    if map == want
            )));
        }
        assert_eq!(cells[1].1.name, "map-grid[map=cluster]");
        // Bad values fail up front, and the error names the valid set.
        let bad = Sweep::from_json_str(&base_json(r#"{"map": ["diagonal"]}"#)).expect("parses");
        let err = bad.cells().expect_err("must fail");
        assert!(err.contains("mod|block|cluster|scc"), "{err}");
        // And the axis is loud without a sharded or msgpass solver.
        let no_sharded = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["mp"]},
          "grid": {"map": ["cluster"]}
        }"#;
        let sweep = Sweep::from_json_str(no_sharded).expect("parses");
        assert!(sweep.cells().expect_err("must fail").contains("sharded"));
    }

    #[test]
    fn shards_batch_and_gossip_axes_rewrite_msgpass_entries() {
        let text = r#"{
          "name": "msgpass-grid",
          "scenario": {
            "graph": "paper:12", "solvers": ["msgpass:2:4:mod"],
            "steps": 100, "stride": 50, "rounds": 1, "threads": 1, "seed": 3
          },
          "grid": {"batch": [16], "gossip": [2], "shards": [4]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 1);
        assert!(cells[0].1.solvers().contains(&SolverSpec::Msgpass {
            shards: 4,
            batch: 16,
            map: ShardMap::Modulo,
            gossip: 2,
            drop: 0.0,
            crashes: vec![],
            links: vec![],
            partitions: vec![],
            reliable: false,
        }));
        // gossip is a msgpass-only axis: loud error without one.
        let no_msgpass = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["mp", "sharded:2:4"]},
          "grid": {"gossip": [4]}
        }"#;
        let sweep = Sweep::from_json_str(no_msgpass).expect("parses");
        assert!(sweep.cells().expect_err("must fail").contains("msgpass"));
        // And gossip=0 is rejected up front.
        let zero = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["msgpass:2:4"]},
          "grid": {"gossip": [0]}
        }"#;
        assert!(Sweep::from_json_str(zero).expect("parses").cells().is_err());
    }

    #[test]
    fn drop_and_crash_axes_rewrite_msgpass_fault_fields() {
        use crate::network::CrashWindow;
        let text = r#"{
          "name": "fault-grid",
          "scenario": {
            "graph": "paper:12", "solvers": ["msgpass:4:8:mod:rel"],
            "steps": 100, "stride": 50, "rounds": 1, "threads": 1, "seed": 3
          },
          "grid": {"crash": ["1@64+32", "none"], "drop": [0.05, 0.0]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 4);
        let specs: Vec<SolverSpec> =
            cells.iter().map(|(_, s)| s.solvers()[0].clone()).collect();
        assert!(specs.contains(&SolverSpec::Msgpass {
            shards: 4,
            batch: 8,
            map: ShardMap::Modulo,
            gossip: crate::coordinator::msgpass::DEFAULT_GOSSIP_PERIOD,
            drop: 0.05,
            crashes: vec![CrashWindow { shard: 1, at: 64.0, down_for: 32.0 }],
            links: vec![],
            partitions: vec![],
            reliable: true,
        }));
        // "none" clears the windows so one grid races crashed vs crash-free.
        assert!(specs.iter().any(|s| matches!(
            s,
            SolverSpec::Msgpass { drop, crashes, .. } if *drop == 0.0 && crashes.is_empty()
        )));
        // Both axes are msgpass-only: loud error without one.
        for grid in [r#"{"drop": [0.1]}"#, r#"{"crash": ["0@10+5"]}"#] {
            let text = format!(
                r#"{{"scenario": {{"graph": "paper:10", "solvers": ["mp"]}}, "grid": {grid}}}"#
            );
            let sweep = Sweep::from_json_str(&text).expect("parses");
            assert!(sweep.cells().expect_err("must fail").contains("msgpass"));
        }
        // Out-of-range probability, malformed window, and a window naming
        // a shard the solver does not have are all rejected up front.
        for grid in [
            r#"{"drop": [1.0]}"#,
            r#"{"drop": [-0.1]}"#,
            r#"{"crash": ["1@64"]}"#,
            r#"{"crash": ["9@64+32"]}"#,
        ] {
            let text = format!(
                r#"{{"scenario": {{"graph": "paper:10", "solvers": ["msgpass:2:4"]}},
                     "grid": {grid}}}"#
            );
            let sweep = Sweep::from_json_str(&text).expect("parses");
            assert!(sweep.cells().is_err(), "grid {grid} should be rejected");
        }
    }

    #[test]
    fn link_and_partition_axes_rewrite_msgpass_fault_fields() {
        use crate::network::{LinkWindow, PartitionWindow};
        let text = r#"{
          "name": "partition-grid",
          "scenario": {
            "graph": "paper:12", "solvers": ["msgpass:4:8:mod:rel"],
            "steps": 100, "stride": 50, "rounds": 1, "threads": 1, "seed": 3
          },
          "grid": {"link": ["0-1@64+32", "none"], "partition": ["0.1@64+32", "none"]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 4);
        let specs: Vec<SolverSpec> =
            cells.iter().map(|(_, s)| s.solvers()[0].clone()).collect();
        assert!(specs.contains(&SolverSpec::Msgpass {
            shards: 4,
            batch: 8,
            map: ShardMap::Modulo,
            gossip: crate::coordinator::msgpass::DEFAULT_GOSSIP_PERIOD,
            drop: 0.0,
            crashes: vec![],
            links: vec![LinkWindow { src: 0, dst: 1, at: 64.0, down_for: 32.0 }],
            partitions: vec![PartitionWindow::new(vec![0, 1], 64.0, 32.0)],
            reliable: true,
        }));
        // "none"/"none" clears both lists — the fault-free control cell.
        assert!(specs.iter().any(|s| matches!(
            s,
            SolverSpec::Msgpass { links, partitions, .. }
                if links.is_empty() && partitions.is_empty()
        )));
        // Both axes are msgpass-only: loud error without one.
        for grid in [r#"{"link": ["0-1@10+5"]}"#, r#"{"partition": ["0@10+5"]}"#] {
            let text = format!(
                r#"{{"scenario": {{"graph": "paper:10", "solvers": ["mp"]}}, "grid": {grid}}}"#
            );
            let sweep = Sweep::from_json_str(&text).expect("parses");
            assert!(sweep.cells().expect_err("must fail").contains("msgpass"));
        }
        // Malformed windows, out-of-range shards, self-links and
        // degenerate bipartitions are all rejected up front.
        for grid in [
            r#"{"link": ["0-1@64"]}"#,
            r#"{"link": ["0-9@64+32"]}"#,
            r#"{"link": ["1-1@64+32"]}"#,
            r#"{"partition": ["9@64+32"]}"#,
            r#"{"partition": ["0.1@64+32"]}"#,
        ] {
            let text = format!(
                r#"{{"scenario": {{"graph": "paper:10", "solvers": ["msgpass:2:4"]}},
                     "grid": {grid}}}"#
            );
            let sweep = Sweep::from_json_str(&text).expect("parses");
            assert!(sweep.cells().is_err(), "grid {grid} should be rejected");
        }
    }

    #[test]
    fn scalar_axis_values_and_alpha_apply() {
        let sweep = Sweep::from_json_str(&base_json(r#"{"alpha": 0.6}"#)).expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].1.alpha, 0.6);
    }

    #[test]
    fn invalid_axes_rejected_before_running() {
        for (grid, what) in [
            (r#"{"banana": [1]}"#, "unknown axis"),
            (r#"{"alpha": [1.5]}"#, "alpha out of range"),
            (r#"{"n": [0]}"#, "n zero"),
            (r#"{"n": [1]}"#, "n below the generator families' minimum"),
            (r#"{"shards": []}"#, "empty axis"),
            (r#"{"latency": ["const:0.1"]}"#, "latency without coordinator"),
            (r#"{"batch": [2000000]}"#, "batch beyond the claim-word bound"),
        ] {
            let sweep = Sweep::from_json_str(&base_json(grid));
            let failed = match sweep {
                Err(_) => true,
                Ok(s) => s.cells().is_err(),
            };
            assert!(failed, "{what}: grid {grid} should be rejected");
        }
    }

    #[test]
    fn shards_axis_requires_a_sharded_solver() {
        let text = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["mp"]},
          "grid": {"shards": [2]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let err = sweep.cells().expect_err("must fail");
        assert!(err.contains("sharded"), "unhelpful error: {err}");
    }

    #[test]
    fn run_merges_cells_into_valid_bench_json() {
        let sweep = Sweep::from_json_str(&base_json(r#"{"n": [10, 12], "shards": [1, 2]}"#))
            .expect("parses");
        let report = sweep.run().expect("runs");
        assert_eq!(report.cells.len(), 4);
        let text = report.to_json().render();
        let parsed = Json::parse(&text).expect("valid json");
        let cells = parsed.get("cells").and_then(Json::as_array).expect("cells");
        assert_eq!(cells.len(), 4);
        for cell in cells {
            let solvers = cell.get("solvers").and_then(Json::as_array).expect("solvers");
            assert_eq!(solvers.len(), 2);
            assert!(cell.get("params").and_then(|p| p.get("n")).is_some());
            assert!(solvers[0].get("conflicts").is_some());
        }
        // The summary table mentions every cell once per solver.
        let rendered = report.render();
        assert!(rendered.contains("n=10,shards=2"));
        assert!(rendered.contains("sharded:2:4:mod"));
    }

    #[test]
    fn batch_axis_rewrites_sharded_and_parallel_mp() {
        let text = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["parallel-mp:2", "sharded:2:2"]},
          "grid": {"batch": [16]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let cells = sweep.cells().expect("expands");
        let solvers = cells[0].1.solvers();
        assert!(solvers.contains(&SolverSpec::ParallelMp { batch: 16 }));
        assert!(solvers
            .iter()
            .any(|s| matches!(s, SolverSpec::Sharded { batch: 16, .. })));
    }

    #[test]
    fn graph_axis_sweeps_over_families_and_composes_with_n() {
        let text = r#"{
          "name": "family-grid",
          "scenario": {
            "graph": "paper:12", "solvers": ["mp"],
            "steps": 200, "stride": 100, "rounds": 2, "threads": 1, "seed": 3
          },
          "grid": {"graph": ["paper:12", "ba:12", "ring:12"], "n": [10, 14]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let cells = sweep.cells().expect("expands");
        assert_eq!(cells.len(), 6);
        // graph sorts before n: the n axis resizes whatever family the
        // cell's graph value picked.
        assert_eq!(cells[0].1.graph, GraphSpec::ErThreshold { n: 10, threshold: 0.5 });
        assert_eq!(cells[3].1.graph, GraphSpec::Family { family: "ba".into(), n: 14 });
        assert_eq!(cells[4].1.graph, GraphSpec::Family { family: "ring".into(), n: 10 });
        assert_eq!(cells[4].1.name, "family-grid[graph=ring:12,n=10]");
        // Bad family values fail at expansion, not mid-run.
        let bad = r#"{
          "scenario": {"graph": "paper:10", "solvers": ["mp"]},
          "grid": {"graph": ["banana:10"]}
        }"#;
        assert!(Sweep::from_json_str(bad).expect("parses").cells().is_err());
    }

    #[test]
    fn graph_axis_cells_run_end_to_end() {
        let text = r#"{
          "name": "family-run",
          "scenario": {
            "graph": "paper:10", "solvers": ["mp"],
            "steps": 200, "stride": 100, "rounds": 2, "threads": 1, "seed": 5
          },
          "grid": {"graph": ["paper:10", "ring:10"]}
        }"#;
        let report = Sweep::from_json_str(text).expect("parses").run().expect("runs");
        assert_eq!(report.cells.len(), 2);
        for cell in &report.cells {
            let r = &cell.report.solver_reports()[0];
            assert!(r.final_error < r.trajectory.mean[0], "{}", cell.report.scenario.name);
        }
        assert!(report.render().contains("graph=ring:10"));
    }

    #[test]
    fn n_axis_on_a_file_graph_is_a_loud_error() {
        let text = r#"{
          "scenario": {
            "graph": {"kind": "file", "path": "web/crawl.txt"},
            "solvers": ["mp"]
          },
          "grid": {"n": [10, 20]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let err = sweep.cells().expect_err("must refuse, not silently no-op");
        assert!(err.contains("file graph"), "{err}");
        assert!(err.contains("crawl.txt"), "error names the file: {err}");
        assert!(err.contains("\"graph\""), "error points at the graph axis: {err}");
    }

    #[test]
    fn solver_axes_on_size_estimation_scenarios_are_rejected() {
        for (grid, axis) in [
            (r#"{"shards": [2]}"#, "shards"),
            (r#"{"batch": [4]}"#, "batch"),
            (r#"{"packer": ["worker"]}"#, "packer"),
            (r#"{"map": ["cluster"]}"#, "map"),
            (r#"{"gossip": [4]}"#, "gossip"),
            (r#"{"latency": ["const:0.1"]}"#, "latency"),
            (r#"{"alpha": [0.5]}"#, "alpha"),
        ] {
            let text = format!(
                r#"{{
                  "scenario": {{
                    "graph": "paper:10",
                    "experiment": {{"kind": "size-estimation", "estimators": ["kaczmarz"]}}
                  }},
                  "grid": {grid}
                }}"#
            );
            let sweep = Sweep::from_json_str(&text).expect("parses");
            let err = sweep.cells().expect_err("solver axis must be rejected");
            assert!(err.contains(axis), "axis {axis}: {err}");
        }
    }

    #[test]
    fn size_estimation_sweep_runs_and_merges() {
        let text = r#"{
          "name": "se-grid",
          "scenario": {
            "graph": "paper:10",
            "experiment": {"kind": "size-estimation", "estimators": ["kaczmarz", "walk"]},
            "steps": 400, "stride": 200, "rounds": 2, "threads": 1, "seed": 9
          },
          "grid": {"n": [10, 12]}
        }"#;
        let sweep = Sweep::from_json_str(text).expect("parses");
        let report = sweep.run().expect("runs");
        assert_eq!(report.cells.len(), 2);
        let parsed = Json::parse(&report.to_json().render()).expect("valid json");
        let cells = parsed.get("cells").and_then(Json::as_array).expect("cells");
        for cell in cells {
            let ests = cell.get("estimators").and_then(Json::as_array).expect("estimators");
            assert_eq!(ests.len(), 2);
            assert!(ests[0].get("final_size_rel_err").is_some());
            assert!(cell.get("solvers").is_none());
        }
        assert!(report.render().contains("kaczmarz"));
    }
}
