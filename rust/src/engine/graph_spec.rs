//! Declarative graph descriptions.
//!
//! A [`GraphSpec`] names a workload graph without constructing it: the
//! paper's §III ER-threshold model with explicit parameters, any of the
//! [`crate::graph::generators::by_name`] synthetic families, or an
//! edge-list file on disk. Specs are pure data — they parse from compact
//! registry strings (`"er-threshold:100:0.5"`, `"ba:1000"`,
//! `"file:web.txt"`), round-trip through [`crate::util::json::Json`], and
//! build deterministically from a seed.

use std::collections::BTreeMap;

use crate::graph::{generators, io as graph_io, DanglingPolicy, Graph};
use crate::util::json::Json;

/// A serializable description of a workload graph.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphSpec {
    /// The paper's §III model: N×N iid U\[0,1\] entries thresholded.
    ErThreshold { n: usize, threshold: f64 },
    /// Any family registered in [`generators::by_name`] (`"ba"`, `"ws"`,
    /// `"er-sparse"`, `"sbm"`, `"ring"`, `"star"`, `"complete"`, and
    /// `"chain"` — the one family that deliberately keeps a dangling
    /// tail page, for exercising the solvers' implicit self-loop guard).
    Family { family: String, n: usize },
    /// A plain-text edge list loaded from disk (dangling pages repaired
    /// with the LinkAll policy, as the CLI does).
    File { path: String },
}

impl GraphSpec {
    /// The paper's experiment graph at size `n`.
    pub fn paper(n: usize) -> GraphSpec {
        GraphSpec::ErThreshold { n, threshold: 0.5 }
    }

    /// Canonical registry string (inverse of [`GraphSpec::parse`]).
    pub fn key(&self) -> String {
        match self {
            GraphSpec::ErThreshold { n, threshold } => format!("er-threshold:{n}:{threshold}"),
            GraphSpec::Family { family, n } => format!("{family}:{n}"),
            GraphSpec::File { path } => format!("file:{path}"),
        }
    }

    /// Parse a registry string: `er-threshold:<n>[:<threshold>]`,
    /// `paper:<n>`, `<family>:<n>`, or `file:<path>`.
    pub fn parse(s: &str) -> Result<GraphSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let usage = "graph spec: er-threshold:<n>[:<thr>] | <family>:<n> | file:<path>";
        match parts.as_slice() {
            ["er-threshold", n] | ["paper", n] => Ok(GraphSpec::ErThreshold {
                n: n.parse().map_err(|_| format!("bad n in {s:?}"))?,
                threshold: 0.5,
            }),
            ["er-threshold", n, thr] => Ok(GraphSpec::ErThreshold {
                n: n.parse().map_err(|_| format!("bad n in {s:?}"))?,
                threshold: thr.parse().map_err(|_| format!("bad threshold in {s:?}"))?,
            }),
            ["file"] => Err(usage.to_string()),
            ["file", ..] => {
                // Re-join: file paths may themselves contain ':'.
                let path = s["file:".len()..].to_string();
                if path.is_empty() {
                    return Err(usage.to_string());
                }
                Ok(GraphSpec::File { path })
            }
            [family, n] => {
                let n: usize = n.parse().map_err(|_| format!("bad n in {s:?}"))?;
                // Validate the family name early. The probe size must
                // satisfy every family's parameter asserts (ws needs
                // n > 4 for its default k).
                if generators::by_name(family, 10, 0).is_none() {
                    return Err(format!("unknown graph family {family:?} — {usage}"));
                }
                Ok(GraphSpec::Family { family: family.to_string(), n })
            }
            _ => Err(format!("cannot parse graph spec {s:?} — {usage}")),
        }
    }

    /// Number of pages the spec will produce (unknown for files).
    pub fn n(&self) -> Option<usize> {
        match self {
            GraphSpec::ErThreshold { n, .. } | GraphSpec::Family { n, .. } => Some(*n),
            GraphSpec::File { .. } => None,
        }
    }

    /// Materialize the graph. Generated families consume `seed`; file
    /// graphs ignore it.
    pub fn build(&self, seed: u64) -> Result<Graph, String> {
        match self {
            GraphSpec::ErThreshold { n, threshold } => {
                if *n == 0 {
                    return Err("er-threshold graph needs n > 0".into());
                }
                Ok(generators::er_threshold(*n, *threshold, seed))
            }
            GraphSpec::Family { family, n } => generators::by_name(family, *n, seed)
                .ok_or_else(|| format!("unknown graph family {family:?}")),
            GraphSpec::File { path } => graph_io::load(path, DanglingPolicy::LinkAll)
                .map_err(|e| format!("loading graph {path:?}: {e}")),
        }
    }

    /// JSON object form: `{"kind": "er-threshold", "n": 100, "threshold": 0.5}`,
    /// `{"kind": "ba", "n": 1000}`, `{"kind": "file", "path": "web.txt"}`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        match self {
            GraphSpec::ErThreshold { n, threshold } => {
                m.insert("kind".to_string(), Json::String("er-threshold".into()));
                m.insert("n".to_string(), Json::Number(*n as f64));
                m.insert("threshold".to_string(), Json::Number(*threshold));
            }
            GraphSpec::Family { family, n } => {
                m.insert("kind".to_string(), Json::String(family.clone()));
                m.insert("n".to_string(), Json::Number(*n as f64));
            }
            GraphSpec::File { path } => {
                m.insert("kind".to_string(), Json::String("file".into()));
                m.insert("path".to_string(), Json::String(path.clone()));
            }
        }
        Json::Object(m)
    }

    /// Parse from either the object form of [`GraphSpec::to_json`] or a
    /// registry string.
    pub fn from_json(v: &Json) -> Result<GraphSpec, String> {
        if let Some(s) = v.as_str() {
            return GraphSpec::parse(s);
        }
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("graph spec object needs a \"kind\" string")?;
        match kind {
            "er-threshold" | "paper" => {
                let n = v
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or("er-threshold graph needs an integer \"n\"")?;
                let threshold = v.get("threshold").and_then(Json::as_f64).unwrap_or(0.5);
                Ok(GraphSpec::ErThreshold { n, threshold })
            }
            "file" => {
                let path = v
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or("file graph needs a \"path\" string")?;
                Ok(GraphSpec::File { path: path.to_string() })
            }
            family => {
                let n = v
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("graph family {family:?} needs an integer \"n\""))?;
                if generators::by_name(family, 10, 0).is_none() {
                    return Err(format!("unknown graph family {family:?}"));
                }
                Ok(GraphSpec::Family { family: family.to_string(), n })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_key_round_trip() {
        for s in ["er-threshold:40:0.5", "ba:100", "ring:12", "file:graphs/web.txt"] {
            let spec = GraphSpec::parse(s).expect("parses");
            assert_eq!(
                GraphSpec::parse(&spec.key()).expect("key re-parses"),
                spec,
                "round trip failed for {s}"
            );
        }
    }

    #[test]
    fn paper_alias() {
        assert_eq!(
            GraphSpec::parse("paper:100").expect("parses"),
            GraphSpec::ErThreshold { n: 100, threshold: 0.5 }
        );
    }

    #[test]
    fn unknown_family_rejected() {
        assert!(GraphSpec::parse("banana:10").is_err());
        assert!(GraphSpec::parse("").is_err());
    }

    #[test]
    fn chain_family_builds_with_its_dangling_tail() {
        let spec = GraphSpec::parse("chain:9").expect("parses");
        assert_eq!(spec, GraphSpec::Family { family: "chain".into(), n: 9 });
        let g = spec.build(1).expect("builds");
        assert_eq!(g.dangling(), vec![8], "the sink must survive spec building");
    }

    #[test]
    fn builds_deterministically() {
        let spec = GraphSpec::paper(20);
        let a = spec.build(7).expect("builds");
        let b = spec.build(7).expect("builds");
        assert_eq!(a, b);
        assert_eq!(a.n(), 20);
    }

    #[test]
    fn json_round_trip() {
        for spec in [
            GraphSpec::ErThreshold { n: 30, threshold: 0.4 },
            GraphSpec::Family { family: "ba".into(), n: 50 },
            GraphSpec::File { path: "x/y.txt".into() },
        ] {
            let j = spec.to_json();
            let text = j.render();
            let back = GraphSpec::from_json(&Json::parse(&text).expect("valid json"))
                .expect("round trips");
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn json_string_form_accepted() {
        let v = Json::String("er-threshold:25:0.5".into());
        assert_eq!(
            GraphSpec::from_json(&v).expect("string form"),
            GraphSpec::ErThreshold { n: 25, threshold: 0.5 }
        );
    }
}
