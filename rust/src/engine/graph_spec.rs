//! Declarative graph descriptions.
//!
//! A [`GraphSpec`] names a workload graph without constructing it: the
//! paper's §III ER-threshold model with explicit parameters, any of the
//! [`crate::graph::generators::by_name`] synthetic families, or an
//! edge-list file on disk. Specs are pure data — they parse from compact
//! registry strings (`"er-threshold:100:0.5"`, `"ba:1000"`,
//! `"file:web.txt"`, `"file:web.txt:selfloop"`), round-trip through
//! [`crate::util::json::Json`], and build deterministically from a seed.
//!
//! [`GraphSpec::build_cached`] adds a per-process cache keyed by
//! `(spec key, seed)` so a sweep over solvers does not reload a
//! 10⁷-edge corpus once per cell.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::graph::{generators, io as graph_io, DanglingPolicy, Graph, LoadOptions};
use crate::util::json::Json;

/// Registry string for a dangling policy (`file:` spec suffix and the
/// JSON `"dangling"` key).
pub fn dangling_key(p: DanglingPolicy) -> &'static str {
    match p {
        DanglingPolicy::Error => "error",
        DanglingPolicy::SelfLoop => "selfloop",
        DanglingPolicy::LinkAll => "linkall",
    }
}

/// Inverse of [`dangling_key`].
pub fn dangling_from_key(s: &str) -> Option<DanglingPolicy> {
    match s {
        "error" => Some(DanglingPolicy::Error),
        "selfloop" => Some(DanglingPolicy::SelfLoop),
        "linkall" => Some(DanglingPolicy::LinkAll),
        _ => None,
    }
}

/// A serializable description of a workload graph.
#[derive(Debug, Clone, PartialEq)]
pub enum GraphSpec {
    /// The paper's §III model: N×N iid U\[0,1\] entries thresholded.
    ErThreshold { n: usize, threshold: f64 },
    /// Any family registered in [`generators::by_name`] (`"ba"`, `"ws"`,
    /// `"er-sparse"`, `"sbm"`, `"ring"`, `"star"`, `"complete"`,
    /// `"webgraph"` — the deterministic corpus model — and `"chain"`;
    /// chain and webgraph deliberately keep dangling pages, for
    /// exercising the solvers' implicit self-loop guard).
    Family { family: String, n: usize },
    /// A plain-text edge list loaded from disk via the streaming
    /// loader. `dangling` selects the repair policy (default LinkAll —
    /// the behaviour file specs have always had; use `selfloop` for
    /// corpus-scale files, where LinkAll would materialize n-1 edges
    /// per sink page).
    File { path: String, dangling: DanglingPolicy },
}

/// Bounded per-process graph cache: most-recently-used at the back.
fn graph_cache() -> &'static Mutex<Vec<((String, u64), Arc<Graph>)>> {
    static CACHE: OnceLock<Mutex<Vec<((String, u64), Arc<Graph>)>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(Vec::new()))
}

const GRAPH_CACHE_CAP: usize = 4;

impl GraphSpec {
    /// The paper's experiment graph at size `n`.
    pub fn paper(n: usize) -> GraphSpec {
        GraphSpec::ErThreshold { n, threshold: 0.5 }
    }

    /// A file spec with the default (LinkAll) dangling policy.
    pub fn file<S: Into<String>>(path: S) -> GraphSpec {
        GraphSpec::File { path: path.into(), dangling: DanglingPolicy::LinkAll }
    }

    /// Canonical registry string (inverse of [`GraphSpec::parse`]).
    /// File specs with the default LinkAll policy render bare
    /// (`file:<path>`), so pre-existing keys are unchanged.
    pub fn key(&self) -> String {
        match self {
            GraphSpec::ErThreshold { n, threshold } => format!("er-threshold:{n}:{threshold}"),
            GraphSpec::Family { family, n } => format!("{family}:{n}"),
            GraphSpec::File { path, dangling: DanglingPolicy::LinkAll } => format!("file:{path}"),
            GraphSpec::File { path, dangling } => {
                format!("file:{path}:{}", dangling_key(*dangling))
            }
        }
    }

    /// Parse a registry string: `er-threshold:<n>[:<threshold>]`,
    /// `paper:<n>`, `<family>:<n>`, or
    /// `file:<path>[:<error|selfloop|linkall>]`.
    pub fn parse(s: &str) -> Result<GraphSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let usage = "graph spec: er-threshold:<n>[:<thr>] | <family>:<n> | \
                     file:<path>[:<error|selfloop|linkall>]";
        match parts.as_slice() {
            ["er-threshold", n] | ["paper", n] => Ok(GraphSpec::ErThreshold {
                n: n.parse().map_err(|_| format!("bad n in {s:?}"))?,
                threshold: 0.5,
            }),
            ["er-threshold", n, thr] => Ok(GraphSpec::ErThreshold {
                n: n.parse().map_err(|_| format!("bad n in {s:?}"))?,
                threshold: thr.parse().map_err(|_| format!("bad threshold in {s:?}"))?,
            }),
            ["file"] => Err(usage.to_string()),
            ["file", ..] => {
                // Re-join: file paths may themselves contain ':'. A
                // trailing segment is treated as the dangling policy
                // only when it is exactly a policy name.
                let rest = &s["file:".len()..];
                let (path, dangling) = match rest.rsplit_once(':') {
                    Some((head, tail)) if !head.is_empty() => match dangling_from_key(tail) {
                        Some(p) => (head.to_string(), p),
                        None => (rest.to_string(), DanglingPolicy::LinkAll),
                    },
                    _ => (rest.to_string(), DanglingPolicy::LinkAll),
                };
                if path.is_empty() {
                    return Err(usage.to_string());
                }
                Ok(GraphSpec::File { path, dangling })
            }
            [family, n] => {
                let n: usize = n.parse().map_err(|_| format!("bad n in {s:?}"))?;
                // Validate the family name early. The probe size must
                // satisfy every family's parameter asserts (ws needs
                // n > 4 for its default k).
                if generators::by_name(family, 10, 0).is_none() {
                    return Err(format!("unknown graph family {family:?} — {usage}"));
                }
                Ok(GraphSpec::Family { family: family.to_string(), n })
            }
            _ => Err(format!("cannot parse graph spec {s:?} — {usage}")),
        }
    }

    /// Number of pages the spec will produce (unknown for files).
    pub fn n(&self) -> Option<usize> {
        match self {
            GraphSpec::ErThreshold { n, .. } | GraphSpec::Family { n, .. } => Some(*n),
            GraphSpec::File { .. } => None,
        }
    }

    /// Materialize the graph. Generated families consume `seed`; file
    /// graphs ignore it.
    pub fn build(&self, seed: u64) -> Result<Graph, String> {
        match self {
            GraphSpec::ErThreshold { n, threshold } => {
                if *n == 0 {
                    return Err("er-threshold graph needs n > 0".into());
                }
                Ok(generators::er_threshold(*n, *threshold, seed))
            }
            GraphSpec::Family { family, n } => generators::by_name(family, *n, seed)
                .ok_or_else(|| format!("unknown graph family {family:?}")),
            GraphSpec::File { path, dangling } => {
                graph_io::load_with(path, &LoadOptions::new(*dangling))
                    .map_err(|e| format!("loading graph {path:?}: {e}"))
            }
        }
    }

    /// [`GraphSpec::build`] through the bounded per-process cache keyed
    /// by `(spec key, seed)` — a sweep racing many solvers on one
    /// 10⁷-edge corpus loads it once, not once per cell. The shared
    /// [`Graph`] is immutable (its lazy in-CSR is thread-safe), so
    /// handing the same `Arc` to every cell is sound.
    pub fn build_cached(&self, seed: u64) -> Result<Arc<Graph>, String> {
        let key = (self.key(), seed);
        if let Ok(mut cache) = graph_cache().lock() {
            if let Some(pos) = cache.iter().position(|(k, _)| *k == key) {
                let entry = cache.remove(pos);
                let g = Arc::clone(&entry.1);
                cache.push(entry); // refresh LRU position
                return Ok(g);
            }
        }
        let g = Arc::new(self.build(seed)?);
        if let Ok(mut cache) = graph_cache().lock() {
            if cache.len() >= GRAPH_CACHE_CAP {
                cache.remove(0);
            }
            cache.push((key, Arc::clone(&g)));
        }
        Ok(g)
    }

    /// JSON object form: `{"kind": "er-threshold", "n": 100, "threshold": 0.5}`,
    /// `{"kind": "ba", "n": 1000}`,
    /// `{"kind": "file", "path": "web.txt", "dangling": "selfloop"}`
    /// (the `"dangling"` key is omitted for the default LinkAll, so
    /// pre-existing scenario files serialize unchanged).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        match self {
            GraphSpec::ErThreshold { n, threshold } => {
                m.insert("kind".to_string(), Json::String("er-threshold".into()));
                m.insert("n".to_string(), Json::Number(*n as f64));
                m.insert("threshold".to_string(), Json::Number(*threshold));
            }
            GraphSpec::Family { family, n } => {
                m.insert("kind".to_string(), Json::String(family.clone()));
                m.insert("n".to_string(), Json::Number(*n as f64));
            }
            GraphSpec::File { path, dangling } => {
                m.insert("kind".to_string(), Json::String("file".into()));
                m.insert("path".to_string(), Json::String(path.clone()));
                if *dangling != DanglingPolicy::LinkAll {
                    m.insert(
                        "dangling".to_string(),
                        Json::String(dangling_key(*dangling).into()),
                    );
                }
            }
        }
        Json::Object(m)
    }

    /// Parse from either the object form of [`GraphSpec::to_json`] or a
    /// registry string.
    pub fn from_json(v: &Json) -> Result<GraphSpec, String> {
        if let Some(s) = v.as_str() {
            return GraphSpec::parse(s);
        }
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("graph spec object needs a \"kind\" string")?;
        match kind {
            "er-threshold" | "paper" => {
                let n = v
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or("er-threshold graph needs an integer \"n\"")?;
                let threshold = v.get("threshold").and_then(Json::as_f64).unwrap_or(0.5);
                Ok(GraphSpec::ErThreshold { n, threshold })
            }
            "file" => {
                let path = v
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or("file graph needs a \"path\" string")?;
                let dangling = match v.get("dangling") {
                    None => DanglingPolicy::LinkAll,
                    Some(d) => {
                        let key = d.as_str().ok_or("\"dangling\" must be a string")?;
                        dangling_from_key(key).ok_or_else(|| {
                            format!(
                                "unknown dangling policy {key:?} (error | selfloop | linkall)"
                            )
                        })?
                    }
                };
                Ok(GraphSpec::File { path: path.to_string(), dangling })
            }
            family => {
                let n = v
                    .get("n")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("graph family {family:?} needs an integer \"n\""))?;
                if generators::by_name(family, 10, 0).is_none() {
                    return Err(format!("unknown graph family {family:?}"));
                }
                Ok(GraphSpec::Family { family: family.to_string(), n })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_key_round_trip() {
        for s in [
            "er-threshold:40:0.5",
            "ba:100",
            "ring:12",
            "webgraph:64",
            "file:graphs/web.txt",
            "file:graphs/web.txt:selfloop",
            "file:graphs/web.txt:error",
        ] {
            let spec = GraphSpec::parse(s).expect("parses");
            assert_eq!(
                GraphSpec::parse(&spec.key()).expect("key re-parses"),
                spec,
                "round trip failed for {s}"
            );
        }
    }

    #[test]
    fn paper_alias() {
        assert_eq!(
            GraphSpec::parse("paper:100").expect("parses"),
            GraphSpec::ErThreshold { n: 100, threshold: 0.5 }
        );
    }

    #[test]
    fn file_spec_policy_suffix_grammar() {
        // Bare form: LinkAll, and the key stays bare (back-compat).
        let bare = GraphSpec::parse("file:web.txt").expect("parses");
        assert_eq!(bare, GraphSpec::file("web.txt"));
        assert_eq!(bare.key(), "file:web.txt");

        // Policy suffix.
        let sl = GraphSpec::parse("file:web.txt:selfloop").expect("parses");
        assert_eq!(
            sl,
            GraphSpec::File { path: "web.txt".into(), dangling: DanglingPolicy::SelfLoop }
        );
        assert_eq!(sl.key(), "file:web.txt:selfloop");

        // A trailing segment that is NOT a policy name stays in the path
        // (paths may contain ':').
        let windowsy = GraphSpec::parse("file:C:/graphs/web.txt").expect("parses");
        assert_eq!(windowsy, GraphSpec::file("C:/graphs/web.txt"));
    }

    #[test]
    fn unknown_family_rejected() {
        assert!(GraphSpec::parse("banana:10").is_err());
        assert!(GraphSpec::parse("").is_err());
    }

    #[test]
    fn chain_family_builds_with_its_dangling_tail() {
        let spec = GraphSpec::parse("chain:9").expect("parses");
        assert_eq!(spec, GraphSpec::Family { family: "chain".into(), n: 9 });
        let g = spec.build(1).expect("builds");
        assert_eq!(g.dangling(), vec![8], "the sink must survive spec building");
    }

    #[test]
    fn builds_deterministically() {
        let spec = GraphSpec::paper(20);
        let a = spec.build(7).expect("builds");
        let b = spec.build(7).expect("builds");
        assert_eq!(a, b);
        assert_eq!(a.n(), 20);
    }

    #[test]
    fn build_cached_shares_one_graph_per_spec_and_seed() {
        let spec = GraphSpec::paper(23);
        let a = spec.build_cached(911).expect("builds");
        let b = spec.build_cached(911).expect("builds");
        assert!(Arc::ptr_eq(&a, &b), "same (spec, seed) must share one graph");
        let c = spec.build_cached(912).expect("builds");
        assert!(!Arc::ptr_eq(&a, &c), "a different seed is a different graph");
        assert_eq!(*a, spec.build(911).expect("builds"));
    }

    #[test]
    fn json_round_trip() {
        for spec in [
            GraphSpec::ErThreshold { n: 30, threshold: 0.4 },
            GraphSpec::Family { family: "ba".into(), n: 50 },
            GraphSpec::file("x/y.txt"),
            GraphSpec::File { path: "x/y.txt".into(), dangling: DanglingPolicy::SelfLoop },
        ] {
            let j = spec.to_json();
            let text = j.render();
            let back = GraphSpec::from_json(&Json::parse(&text).expect("valid json"))
                .expect("round trips");
            assert_eq!(back, spec);
        }
        // The default policy serializes without a "dangling" key — the
        // pre-existing schema.
        let rendered = GraphSpec::file("x/y.txt").to_json().render();
        assert!(!rendered.contains("dangling"), "{rendered}");
    }

    #[test]
    fn json_dangling_key_parsed_and_validated() {
        let v = Json::parse(r#"{"kind": "file", "path": "w.txt", "dangling": "error"}"#)
            .expect("json");
        assert_eq!(
            GraphSpec::from_json(&v).expect("parses"),
            GraphSpec::File { path: "w.txt".into(), dangling: DanglingPolicy::Error }
        );
        let bad = Json::parse(r#"{"kind": "file", "path": "w.txt", "dangling": "nope"}"#)
            .expect("json");
        assert!(GraphSpec::from_json(&bad).is_err());
    }

    #[test]
    fn json_string_form_accepted() {
        let v = Json::String("er-threshold:25:0.5".into());
        assert_eq!(
            GraphSpec::from_json(&v).expect("string form"),
            GraphSpec::ErThreshold { n: 25, threshold: 0.5 }
        );
    }
}
