//! The declarative experiment: graph + experiment kind + shape, as one
//! value.
//!
//! A [`Scenario`] is the single entry point for every experiment in the
//! repository: it names a [`GraphSpec`], an [`ExperimentSpec`] (PageRank
//! solvers racing a reference solution, or size estimators racing
//! toward `𝟙/N`) and the shared experiment shape (steps, stride,
//! rounds, threads, seed, reference policy), round-trips through JSON,
//! and [`Scenario::run`] drives
//! [`crate::harness::experiment::run_rounds_stats`] uniformly for every
//! run — the Fig.-1/Fig.-2 harnesses, the CLI `run-scenario`
//! subcommand, the benches and the examples are all thin layers over it.
//!
//! ## Determinism contract
//!
//! Round `i` of every solver derives one `solver_seed` from
//! `base.fork(i)`; the solver is built with that seed and stepped with
//! the stream `Rng::seeded(solver_seed).fork(1)`. That is exactly the
//! sampler stream the distributed coordinator forks internally, so a
//! sequential zero-latency [`SolverSpec::Coordinator`] replays the
//! *identical* activation sequence as the matrix-form [`SolverSpec::Mp`]
//! — the distributed runtime and the matrix form are interchangeable
//! inside one scenario (bit-for-bit; tested in `tests/engine.rs`). The
//! multi-threaded sharded backend draws its candidates from the same
//! stream (under worker packing, worker 0 clones it and the remaining
//! shards fork decorrelated streams), so `sharded:1:1` is the same
//! equivalence anchor executed on a worker thread under **either**
//! packer. Leader-packed results are shard-count- and
//! shard-map-invariant (disjoint batch supports commute); worker-packed
//! results additionally depend on the shard layout — each worker
//! samples its own shard — but stay deterministic per seed.

use std::collections::BTreeMap;

use crate::algo::common::{StepStats, Trajectory};
use crate::algo::power_iteration::JacobiPowerIteration;
use crate::algo::size_estimation::SizeEstimator;
use crate::algo::PageRankSolver;
use crate::graph::Graph;
use crate::harness::experiment::{run_rounds_stats, split_concat, with_stride};
use crate::linalg::solve::exact_pagerank;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::experiment_spec::{EstimatorSpec, ExperimentSpec};
use super::graph_spec::GraphSpec;
use super::report::{
    fitted_decay, EstimatorReport, ExperimentReports, ScenarioReport, SolverReport,
};
use super::solver_spec::{CoordinatorSolver, SolverSpec};

/// Largest graph the dense/quadratic paths (the dense Jacobi backend's
/// n×n hyperlink matrix, the exact reference's O(n³) elimination) will
/// accept before [`Scenario::run`] refuses with a named error: 20k pages
/// is already a 3.2 GB dense matrix, and a corpus-scale run would be an
/// allocator abort, not a slow experiment.
pub const DENSE_MAX_N: usize = 20_000;

/// How the reference solution `x*` is obtained.
#[derive(Debug, Clone, PartialEq)]
pub enum ReferencePolicy {
    /// Exact LU solve of `(I-αA)x = (1-α)𝟙` (Proposition 1) — O(N³),
    /// the right default at paper scale.
    Exact,
    /// Jacobi power iteration to the given l∞ tolerance — O(m) per
    /// sweep, for graphs too large to factor densely.
    Power { tol: f64 },
}

/// A complete, serializable experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub graph: GraphSpec,
    /// What runs: PageRank solvers (Fig.-1 shape) or size estimators
    /// (Fig.-2 shape). The shape fields below are shared by every kind.
    pub experiment: ExperimentSpec,
    /// Damping factor — PageRank experiments only (Algorithm 2 works on
    /// `C = (I-A)ᵀ`, the α = 1 analogue).
    pub alpha: f64,
    /// Activations per round.
    pub steps: usize,
    /// Error-sampling stride (in activations).
    pub stride: usize,
    /// Independent rounds averaged.
    pub rounds: usize,
    /// Worker threads; 0 = all available cores. Results are identical
    /// whatever the thread count.
    pub threads: usize,
    pub seed: u64,
    pub reference: ReferencePolicy,
}

impl Scenario {
    /// A scenario with the paper's §III defaults (steps, stride, rounds
    /// and α as in Fig. 1) over the given graph, solving with MP only —
    /// extend via the `with_*` builders.
    pub fn new(name: &str, graph: GraphSpec) -> Scenario {
        Scenario {
            name: name.to_string(),
            graph,
            experiment: ExperimentSpec::pagerank(vec![SolverSpec::Mp]),
            alpha: crate::DEFAULT_ALPHA,
            steps: 60_000,
            stride: 500,
            rounds: 100,
            threads: 0,
            seed: 2017,
            reference: ReferencePolicy::Exact,
        }
    }

    /// The paper's experiment graph at size `n`.
    pub fn paper(name: &str, n: usize) -> Scenario {
        Scenario::new(name, GraphSpec::paper(n))
    }

    /// Run a PageRank race over these solvers (sets the experiment kind).
    pub fn with_solvers(mut self, solvers: Vec<SolverSpec>) -> Scenario {
        self.experiment = ExperimentSpec::pagerank(solvers);
        self
    }

    /// Run a size-estimation race over these estimators (sets the
    /// experiment kind).
    pub fn with_estimators(mut self, estimators: Vec<EstimatorSpec>) -> Scenario {
        self.experiment = ExperimentSpec::size_estimation(estimators);
        self
    }

    pub fn with_experiment(mut self, experiment: ExperimentSpec) -> Scenario {
        self.experiment = experiment;
        self
    }

    /// The PageRank solvers, if that is the experiment kind (empty slice
    /// otherwise).
    pub fn solvers(&self) -> &[SolverSpec] {
        match &self.experiment {
            ExperimentSpec::PageRank { solvers } => solvers,
            ExperimentSpec::SizeEstimation { .. } => &[],
        }
    }

    /// The size estimators, if that is the experiment kind (empty slice
    /// otherwise).
    pub fn estimators(&self) -> &[EstimatorSpec] {
        match &self.experiment {
            ExperimentSpec::SizeEstimation { estimators } => estimators,
            ExperimentSpec::PageRank { .. } => &[],
        }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Scenario {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha in (0,1)");
        self.alpha = alpha;
        self
    }

    pub fn with_steps(mut self, steps: usize) -> Scenario {
        self.steps = steps;
        self
    }

    pub fn with_stride(mut self, stride: usize) -> Scenario {
        self.stride = stride;
        self
    }

    pub fn with_rounds(mut self, rounds: usize) -> Scenario {
        self.rounds = rounds;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Scenario {
        self.threads = threads;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Scenario {
        self.seed = seed;
        self
    }

    pub fn with_reference(mut self, reference: ReferencePolicy) -> Scenario {
        self.reference = reference;
        self
    }

    /// Compute the reference `x*` for a built graph.
    pub fn reference_solution(&self, graph: &Graph) -> Vec<f64> {
        match self.reference {
            ReferencePolicy::Exact => exact_pagerank(graph, self.alpha),
            ReferencePolicy::Power { tol } => {
                let mut pi = JacobiPowerIteration::new(graph, self.alpha);
                pi.run_to_tolerance(tol, 200_000);
                pi.estimate()
            }
        }
    }

    /// Run every solver or estimator through the uniform multi-round
    /// experiment runner and collect trajectories, communication totals
    /// and fitted decay rates.
    pub fn run(&self) -> Result<ScenarioReport, String> {
        if self.experiment.is_empty() {
            return Err(format!(
                "scenario {:?} has no {} to run",
                self.name,
                match self.experiment {
                    ExperimentSpec::PageRank { .. } => "solvers",
                    ExperimentSpec::SizeEstimation { .. } => "estimators",
                }
            ));
        }
        if self.steps == 0 || self.stride == 0 || self.rounds == 0 {
            return Err(format!(
                "scenario {:?}: steps, stride and rounds must all be > 0",
                self.name
            ));
        }
        // Per-process cache: racing many solvers (or re-running a spec
        // under a sweep) against one corpus-scale file loads it once.
        let graph_arc = self.graph.build_cached(self.seed)?;
        let graph: &Graph = &graph_arc;
        let threads = if self.threads == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4)
        } else {
            self.threads
        };
        // One base stream shared by all runs: round i of run A and round
        // i of run B see the same derived seed, which is what makes
        // cross-run replay comparisons exact.
        let base = Rng::seeded(self.seed ^ 0x5CE9_A810);
        let runs = match &self.experiment {
            ExperimentSpec::PageRank { solvers } => {
                ExperimentReports::PageRank(self.run_pagerank(graph, solvers, threads, &base)?)
            }
            ExperimentSpec::SizeEstimation { estimators } => ExperimentReports::SizeEstimation(
                self.run_size_estimation(graph, estimators, threads, &base)?,
            ),
        };
        Ok(ScenarioReport { scenario: self.clone(), runs })
    }

    /// The Fig.-1 experiment shape: every solver races the reference
    /// solution over averaged rounds.
    fn run_pagerank(
        &self,
        graph: &Graph,
        solvers: &[SolverSpec],
        threads: usize,
        base: &Rng,
    ) -> Result<Vec<SolverReport>, String> {
        // Dangling pages are fine for every registry backend carrying
        // the shared implicit self-loop guard (as of PR-6 that includes
        // the in-link baselines and the random-walk estimator), but the
        // simulated coordinator's per-page agents still count one wire
        // reply per raw out-neighbour — refuse that combination up
        // front with a usable error instead of poisoning results.
        let dangling = graph.dangling();
        if !dangling.is_empty() {
            if let Some(bad) = solvers.iter().find(|s| !s.supports_dangling()) {
                return Err(format!(
                    "scenario {:?}: graph has {} dangling page(s) (e.g. page {}) but solver \
                     {} requires a repaired graph — repair it (DanglingPolicy) or drop the \
                     simulated coordinator (every other registry backend carries the \
                     implicit self-loop guard)",
                    self.name,
                    dangling.len(),
                    dangling[0],
                    bad.key()
                ));
            }
        }
        // A graph built without its in-link adjacency (corpus-scale
        // out-only loads) cannot serve the transpose-reading backends —
        // refuse with a named error instead of the deep in-CSR panic.
        if !graph.in_links_available() {
            if let Some(bad) = solvers.iter().find(|s| s.needs_in_links()) {
                return Err(format!(
                    "scenario {:?}: solver {} reads in-links, but the graph was built \
                     without its in-link adjacency (Graph::without_in_links) — rebuild the \
                     graph with in-links or drop the in-link backends (greedy-mp, \
                     you-tempo-qiu, lei-chen, msgpass)",
                    self.name,
                    bad.key()
                ));
            }
        }
        // Dense/quadratic paths materialize n×n state (the dense Jacobi
        // backend) or run O(n³) elimination (the exact reference) — at
        // corpus scale that is an OOM/forever, not a slow run. Refuse by
        // name instead of letting the allocator abort.
        if graph.n() > DENSE_MAX_N {
            if let Some(bad) = solvers.iter().find(|s| matches!(s, SolverSpec::Dense)) {
                return Err(format!(
                    "scenario {:?}: solver {} materializes a dense {n}×{n} matrix but the \
                     graph has {n} pages (limit {DENSE_MAX_N}) — use a sparse backend for \
                     corpus-scale graphs",
                    self.name,
                    bad.key(),
                    n = graph.n(),
                ));
            }
            if matches!(self.reference, ReferencePolicy::Exact) {
                return Err(format!(
                    "scenario {:?}: the exact (dense elimination) reference is limited to \
                     {DENSE_MAX_N} pages but the graph has {} — use the \"power\" reference \
                     policy for corpus-scale graphs",
                    self.name,
                    graph.n(),
                ));
            }
        }
        let x_star = self.reference_solution(graph);

        let mut reports = Vec::with_capacity(solvers.len());
        for spec in solvers {
            let t0 = std::time::Instant::now();
            // Conflict drops (sharded backend only) summed across rounds;
            // an atomic because rounds may run on worker threads. u64
            // addition commutes, so the total stays thread-invariant.
            let conflicts = std::sync::atomic::AtomicU64::new(0);
            // Fault ledgers (msgpass backend only) absorbed across rounds
            // — counters sum, the divergence gauge maxes, both commute.
            let faults = std::sync::Mutex::new(crate::network::FaultCounters::default());
            // Locality ledgers (sharded/msgpass backends only), same
            // absorb discipline: counts sum, the static gauge maxes.
            let locality =
                std::sync::Mutex::new(crate::coordinator::LocalityCounters::default());
            let (avg, total_stats) =
                run_rounds_stats(&spec.key(), self.rounds, base, threads, |round_rng| {
                    let mut seed_rng = round_rng;
                    let solver_seed = seed_rng.next_u64();
                    match spec {
                        // The distributed runtime records in stride-sized
                        // chunks so asynchronous activations keep their
                        // overlap between samples (a per-activation step
                        // loop would drain the pipeline each activation
                        // and serialize async runs).
                        SolverSpec::Coordinator { .. } => {
                            let mut coord = CoordinatorSolver::from_spec(
                                graph,
                                self.alpha,
                                solver_seed,
                                spec,
                            )
                            .expect("spec is a coordinator");
                            coord.record(&x_star, self.steps, self.stride)
                        }
                        _ => {
                            let mut solver = spec.build(graph, self.alpha, solver_seed);
                            let mut step_rng = Rng::seeded(solver_seed).fork(1);
                            let tr = Trajectory::record(
                                &mut *solver,
                                &x_star,
                                self.steps,
                                self.stride,
                                &mut step_rng,
                            );
                            // Packer-dropped candidates (sharded backend;
                            // 0 everywhere else) summed across rounds.
                            conflicts.fetch_add(
                                solver.conflicts(),
                                std::sync::atomic::Ordering::Relaxed,
                            );
                            faults
                                .lock()
                                .expect("fault ledger lock")
                                .absorb(&solver.fault_counters());
                            locality
                                .lock()
                                .expect("locality ledger lock")
                                .absorb(&solver.locality());
                            (tr.errors, tr.total_stats)
                        }
                    }
                });
            let trajectory = with_stride(avg, self.stride);
            let decay_rate = fitted_decay(&trajectory.mean, self.stride);
            let final_error = trajectory.final_mean();
            reports.push(SolverReport {
                spec: spec.clone(),
                trajectory,
                total_stats,
                decay_rate,
                final_error,
                conflicts: conflicts.load(std::sync::atomic::Ordering::Relaxed),
                faults: faults.into_inner().expect("fault ledger lock"),
                locality: locality.into_inner().expect("locality ledger lock"),
                wall: t0.elapsed(),
            });
        }
        Ok(reports)
    }

    /// The Fig.-2 experiment shape: every estimator races toward the
    /// uniform vector `𝟙/N`, recording both the squared error (the
    /// Fig.-2 axis) and the mean relative size error per stride in one
    /// pass.
    fn run_size_estimation(
        &self,
        graph: &Graph,
        estimators: &[EstimatorSpec],
        threads: usize,
        base: &Rng,
    ) -> Result<Vec<EstimatorReport>, String> {
        // Algorithm 2's row norms need positive out-degrees and its
        // fixed point needs strong connectivity — validate once, with
        // the scenario named in the error, instead of panicking on a
        // round worker thread.
        let dangling = graph.dangling();
        if !dangling.is_empty() {
            return Err(format!(
                "scenario {:?}: graph has {} dangling page(s) (e.g. page {}) but Algorithm 2 \
                 needs positive out-degrees — repair the graph (DanglingPolicy) first",
                self.name,
                dangling.len(),
                dangling[0]
            ));
        }
        if let Err(e) = SizeEstimator::new(graph) {
            return Err(format!("scenario {:?}: {e}", self.name));
        }
        let samples = self.steps / self.stride + 1;

        let mut reports = Vec::with_capacity(estimators.len());
        for spec in estimators {
            let t0 = std::time::Instant::now();
            let (avg, total_stats) =
                run_rounds_stats(&spec.key(), self.rounds, base, threads, |round_rng| {
                    // Same per-round seed protocol as the PageRank kind,
                    // so estimator rounds are replay-comparable with
                    // solver rounds under one scenario seed.
                    let mut seed_rng = round_rng;
                    let solver_seed = seed_rng.next_u64();
                    let mut run = spec.build(graph).expect("validated before the rounds");
                    let mut step_rng = Rng::seeded(solver_seed).fork(1);
                    let mut stats = StepStats::default();
                    let mut errs = Vec::with_capacity(2 * samples);
                    let mut rels = Vec::with_capacity(samples);
                    errs.push(run.error_sq());
                    rels.push(run.mean_rel_size_error());
                    for t in 1..=self.steps {
                        stats.accumulate(run.step(&mut step_rng));
                        if t % self.stride == 0 {
                            errs.push(run.error_sq());
                            rels.push(run.mean_rel_size_error());
                        }
                    }
                    // Both metrics ride one round vector; split after
                    // averaging (element-wise, so the halves stay exact).
                    errs.extend(rels);
                    (errs, stats)
                });
            let (err_avg, rel_avg) =
                split_concat(avg, samples, &format!("{}_relerr", spec.key()));
            let trajectory = with_stride(err_avg, self.stride);
            let size_rel_err = with_stride(rel_avg, self.stride);
            let decay_rate = fitted_decay(&trajectory.mean, self.stride);
            reports.push(EstimatorReport {
                spec: *spec,
                decay_rate,
                final_error: trajectory.final_mean(),
                final_size_rel_err: size_rel_err.final_mean(),
                trajectory,
                size_rel_err,
                total_stats,
                wall: t0.elapsed(),
            });
        }
        Ok(reports)
    }

    /// JSON object form (see `examples/fig1_scenario.json` and
    /// `examples/fig2_scenario.json`). The PageRank kind serializes as a
    /// bare top-level `"solvers"` array — the pre-experiment schema — so
    /// existing scenario files and BENCH consumers keep working; other
    /// kinds serialize under `"experiment"`.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::String(self.name.clone()));
        m.insert("graph".to_string(), self.graph.to_json());
        match &self.experiment {
            ExperimentSpec::PageRank { .. } => {
                m.insert(
                    "solvers".to_string(),
                    Json::Array(
                        self.experiment.run_keys().into_iter().map(Json::String).collect(),
                    ),
                );
            }
            other => {
                m.insert("experiment".to_string(), other.to_json());
            }
        }
        m.insert("alpha".to_string(), Json::Number(self.alpha));
        m.insert("steps".to_string(), Json::Number(self.steps as f64));
        m.insert("stride".to_string(), Json::Number(self.stride as f64));
        m.insert("rounds".to_string(), Json::Number(self.rounds as f64));
        m.insert("threads".to_string(), Json::Number(self.threads as f64));
        m.insert("seed".to_string(), Json::Number(self.seed as f64));
        m.insert(
            "reference".to_string(),
            match self.reference {
                ReferencePolicy::Exact => Json::String("exact".into()),
                ReferencePolicy::Power { tol } => {
                    let mut r = BTreeMap::new();
                    r.insert("kind".to_string(), Json::String("power".into()));
                    r.insert("tol".to_string(), Json::Number(tol));
                    Json::Object(r)
                }
            },
        );
        Json::Object(m)
    }

    /// Parse from the object form. Only `graph` is mandatory; everything
    /// else falls back to the paper defaults of [`Scenario::new`]. A
    /// bare top-level `"solvers"` array still means the PageRank kind —
    /// the pre-experiment schema — while an `"experiment"` key selects
    /// the kind explicitly (the two together are rejected as ambiguous).
    pub fn from_json(v: &Json) -> Result<Scenario, String> {
        let graph = GraphSpec::from_json(v.get("graph").ok_or("scenario needs a \"graph\"")?)?;
        let mut scenario =
            Scenario::new(v.get("name").and_then(Json::as_str).unwrap_or("scenario"), graph);
        if v.get("estimators").is_some() {
            // Without this guard a mirrored-legacy spelling would fall
            // through to the default mp race and run the wrong experiment
            // without a word.
            return Err(
                "scenario has a top-level \"estimators\" key — estimators belong inside the \
                 experiment object: \"experiment\": {\"kind\": \"size-estimation\", \
                 \"estimators\": [...]}"
                    .into(),
            );
        }
        match (v.get("experiment"), v.get("solvers")) {
            (Some(_), Some(_)) => {
                return Err(
                    "scenario has both \"experiment\" and a top-level \"solvers\" — put the \
                     solvers inside the experiment object (or drop the \"experiment\" key for \
                     a plain PageRank race)"
                        .into(),
                )
            }
            (Some(exp), None) => {
                scenario.experiment = ExperimentSpec::from_json(exp)?;
            }
            (None, Some(arr)) => {
                let arr = arr
                    .as_array()
                    .ok_or("\"solvers\" must be an array of registry strings")?;
                let mut solvers = Vec::with_capacity(arr.len());
                for s in arr {
                    let key = s
                        .as_str()
                        .ok_or("\"solvers\" must be an array of registry strings")?;
                    solvers.push(SolverSpec::parse(key)?);
                }
                scenario.experiment = ExperimentSpec::pagerank(solvers);
            }
            (None, None) => {}
        }
        if let Some(alpha) = v.get("alpha").and_then(Json::as_f64) {
            if !(alpha > 0.0 && alpha < 1.0) {
                return Err(format!("alpha {alpha} out of (0,1)"));
            }
            scenario.alpha = alpha;
        }
        let get_usize = |key: &str| -> Result<Option<usize>, String> {
            match v.get(key) {
                None => Ok(None),
                Some(j) => j
                    .as_usize()
                    .map(Some)
                    .ok_or_else(|| format!("\"{key}\" must be a non-negative integer")),
            }
        };
        if let Some(steps) = get_usize("steps")? {
            scenario.steps = steps;
        }
        if let Some(stride) = get_usize("stride")? {
            scenario.stride = stride;
        }
        if let Some(rounds) = get_usize("rounds")? {
            scenario.rounds = rounds;
        }
        if let Some(threads) = get_usize("threads")? {
            scenario.threads = threads;
        }
        if let Some(seed) = get_usize("seed")? {
            scenario.seed = seed as u64;
        }
        if let Some(r) = v.get("reference") {
            scenario.reference = match r.as_str() {
                Some("exact") => ReferencePolicy::Exact,
                Some(other) => return Err(format!("unknown reference policy {other:?}")),
                None => {
                    let kind = r
                        .get("kind")
                        .and_then(Json::as_str)
                        .ok_or("reference object needs a \"kind\"")?;
                    match kind {
                        "exact" => ReferencePolicy::Exact,
                        "power" => ReferencePolicy::Power {
                            tol: r.get("tol").and_then(Json::as_f64).unwrap_or(1e-12),
                        },
                        other => return Err(format!("unknown reference policy {other:?}")),
                    }
                }
            };
        }
        Ok(scenario)
    }

    /// Parse a scenario from JSON text (the `run-scenario` CLI path).
    pub fn from_json_str(text: &str) -> Result<Scenario, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        Scenario::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scenario {
        Scenario::paper("tiny", 15)
            .with_solvers(vec![SolverSpec::Mp, SolverSpec::LeiChen])
            .with_steps(600)
            .with_stride(100)
            .with_rounds(3)
            .with_threads(2)
            .with_seed(5)
    }

    #[test]
    fn run_produces_one_report_per_solver() {
        let report = tiny().run().expect("runs");
        assert_eq!(report.solver_reports().len(), 2);
        let mp = &report.solver_reports()[0];
        assert_eq!(mp.trajectory.name, "mp");
        assert_eq!(mp.trajectory.mean.len(), 7); // t = 0,100,…,600
        assert_eq!(mp.trajectory.ts[1], 100);
        assert!(mp.final_error < mp.trajectory.mean[0], "mp must make progress");
        assert!(mp.total_stats.reads > 0);
        assert!(mp.decay_rate > 0.0 && mp.decay_rate < 1.0);
    }

    #[test]
    fn deterministic_and_thread_invariant() {
        let a = tiny().run().expect("runs");
        let b = tiny().with_threads(1).run().expect("runs");
        let (a, b) = (a.solver_reports(), b.solver_reports());
        assert_eq!(a[0].trajectory.mean, b[0].trajectory.mean);
        assert_eq!(a[1].trajectory.variance, b[1].trajectory.variance);
        assert_eq!(a[0].total_stats, b[0].total_stats);
    }

    #[test]
    fn json_round_trip_preserves_scenario() {
        let s = tiny().with_reference(ReferencePolicy::Power { tol: 1e-10 });
        let text = s.to_json().render();
        let back = Scenario::from_json_str(&text).expect("round trips");
        assert_eq!(back, s);
    }

    #[test]
    fn from_json_applies_paper_defaults() {
        let s = Scenario::from_json_str(r#"{"graph": "paper:40"}"#).expect("parses");
        assert_eq!(s.graph, GraphSpec::ErThreshold { n: 40, threshold: 0.5 });
        assert_eq!(s.solvers(), &[SolverSpec::Mp]);
        assert_eq!(s.rounds, 100);
        assert_eq!(s.alpha, crate::DEFAULT_ALPHA);
        assert_eq!(s.reference, ReferencePolicy::Exact);
    }

    #[test]
    fn malformed_scenarios_rejected() {
        assert!(Scenario::from_json_str("{}").is_err(), "graph is mandatory");
        assert!(Scenario::from_json_str(r#"{"graph": "paper:10", "alpha": 1.5}"#).is_err());
        assert!(Scenario::from_json_str(r#"{"graph": "paper:10", "solvers": ["bogus"]}"#).is_err());
        assert!(tiny().with_solvers(vec![]).run().is_err());
        let mut zero_stride = tiny();
        zero_stride.stride = 0;
        assert!(zero_stride.run().is_err());
    }

    #[test]
    fn dangling_graph_with_unguarded_solver_is_refused_up_front() {
        let scenario = Scenario::new(
            "dangling-vs-baseline",
            GraphSpec::Family { family: "chain".into(), n: 10 },
        )
        .with_solvers(vec![SolverSpec::Mp, SolverSpec::sequential_coordinator()])
        .with_steps(100)
        .with_stride(50)
        .with_rounds(1)
        .with_threads(1);
        let err = scenario.run().expect_err("must refuse, not panic/poison");
        assert!(err.contains("coordinator"), "error should name the solver: {err}");
        assert!(err.contains("dangling"), "error should explain why: {err}");
    }

    #[test]
    fn in_link_free_graph_with_transpose_solver_is_refused_up_front() {
        let s = tiny(); // races Mp and LeiChen — the latter reads in-links
        let g = crate::graph::generators::er_threshold(15, 0.5, 5).without_in_links();
        let base = Rng::seeded(1);
        let err = s
            .run_pagerank(&g, s.solvers(), 1, &base)
            .expect_err("must refuse, not hit the in-CSR panic");
        assert!(err.contains("lei-chen"), "error should name the solver: {err}");
        assert!(err.contains("in-link"), "error should explain why: {err}");
        // The in-link-free half of the same scenario still runs.
        assert!(s.run_pagerank(&g, &[SolverSpec::Mp], 1, &base).is_ok());
    }

    #[test]
    fn corpus_scale_dense_paths_are_refused_by_name() {
        // chain is O(n) to build, so crossing DENSE_MAX_N is cheap here;
        // what must NOT happen is the n×n allocation.
        let base = Scenario::new(
            "corpus",
            GraphSpec::Family { family: "chain".into(), n: DENSE_MAX_N + 1 },
        )
        .with_steps(10)
        .with_stride(5)
        .with_rounds(1)
        .with_threads(1);
        let err = base
            .clone()
            .with_solvers(vec![SolverSpec::Dense])
            .run()
            .expect_err("dense backend must be refused at corpus scale");
        assert!(err.contains("dense"), "{err}");
        let err = base
            .with_solvers(vec![SolverSpec::Mp])
            .run()
            .expect_err("exact reference must be refused at corpus scale");
        assert!(err.contains("exact"), "{err}");
        assert!(err.contains("power"), "the error should point at the fix: {err}");
    }

    #[test]
    fn sharded_scenario_records_conflicts_and_converges() {
        // The dense paper graph forces packing conflicts; the scenario
        // must surface them in the report and still converge.
        let report = Scenario::paper("sharded-tiny", 20)
            .with_solvers(vec![SolverSpec::parse("sharded:2:8").expect("registry")])
            .with_steps(400)
            .with_stride(100)
            .with_rounds(2)
            .with_threads(1)
            .with_seed(6)
            .run()
            .expect("runs");
        let r = &report.solver_reports()[0];
        assert!(r.final_error < r.trajectory.mean[0], "no progress");
        assert!(r.conflicts > 0, "dense graphs must drop candidates");
        assert!(r.total_stats.activated > 0);
        // Leader packing reports no conflict split but the resolved
        // map's static gauge still makes the ledger non-empty.
        assert_eq!(r.locality.cross_conflicts, 0);
        assert!(r.locality.cross_edge_fraction > 0.0);
        assert!(r.locality.any());
        // Non-sharded solvers report zero conflicts and no locality.
        let mp = tiny().run().expect("runs");
        assert_eq!(mp.solver_reports()[0].conflicts, 0);
        assert!(!mp.solver_reports()[0].locality.any());
    }

    #[test]
    fn worker_packed_scenario_splits_conflicts_by_shard() {
        // Worker packing on a dense graph: the report's ledger must
        // carry the intra/cross conflict split the claim words encode.
        let report = Scenario::paper("sharded-worker-split", 24)
            .with_solvers(vec![
                SolverSpec::parse("sharded:4:16:mod:worker").expect("registry")
            ])
            .with_steps(800)
            .with_stride(200)
            .with_rounds(2)
            .with_threads(1)
            .with_seed(7)
            .run()
            .expect("runs");
        let r = &report.solver_reports()[0];
        assert!(r.conflicts > 0, "dense graphs must drop candidates");
        assert_eq!(
            r.locality.intra_conflicts + r.locality.cross_conflicts,
            r.conflicts,
            "the split must partition the total"
        );
        assert!(r.locality.cross_conflicts > 0, "mod map interleaves neighbours");
    }

    #[test]
    fn cluster_map_scenario_converges_like_mod() {
        // The topology-aware maps are drop-in: a cluster-mapped sharded
        // race converges on the paper graph just like the closed-form
        // maps (exactness pins live in tests/engine.rs).
        let report = Scenario::paper("sharded-cluster", 20)
            .with_solvers(vec![
                SolverSpec::parse("sharded:2:8:cluster:worker").expect("registry")
            ])
            .with_steps(400)
            .with_stride(100)
            .with_rounds(2)
            .with_threads(1)
            .with_seed(9)
            .run()
            .expect("runs");
        let r = &report.solver_reports()[0];
        assert!(r.final_error < r.trajectory.mean[0], "no progress");
        assert!(r.locality.any(), "multi-shard runs carry a locality ledger");
    }

    fn tiny_size_est() -> Scenario {
        Scenario::paper("tiny-se", 20)
            .with_estimators(EstimatorSpec::all())
            .with_steps(2_000)
            .with_stride(500)
            .with_rounds(3)
            .with_threads(2)
            .with_seed(8)
    }

    #[test]
    fn size_estimation_scenario_races_every_estimator() {
        let report = tiny_size_est().run().expect("runs");
        assert!(report.solver_reports().is_empty(), "no PageRank runs in a Fig.-2 scenario");
        let ests = report.estimator_reports();
        assert_eq!(ests.len(), 3);
        for r in ests {
            assert_eq!(r.trajectory.mean.len(), 5, "{}: t = 0,500,…,2000", r.spec.key());
            assert_eq!(r.size_rel_err.mean.len(), 5, "{}", r.spec.key());
            assert!(
                r.final_error < r.trajectory.mean[0],
                "{} must contract toward 1/N",
                r.spec.key()
            );
            assert!(
                r.final_size_rel_err < r.size_rel_err.mean[0],
                "{}: size estimates must sharpen",
                r.spec.key()
            );
            assert!(r.total_stats.activated == 3 * 2_000, "{}", r.spec.key());
            assert_eq!(r.total_stats.reads, r.total_stats.writes, "{}", r.spec.key());
        }
        // The rate ordering covers estimators, too.
        assert_eq!(report.rate_ordering().len(), 3);
    }

    #[test]
    fn size_estimation_scenario_is_deterministic_and_thread_invariant() {
        let a = tiny_size_est().run().expect("runs");
        let b = tiny_size_est().with_threads(1).run().expect("runs");
        for (ra, rb) in a.estimator_reports().iter().zip(b.estimator_reports()) {
            assert_eq!(ra.trajectory.mean, rb.trajectory.mean, "{}", ra.spec.key());
            assert_eq!(ra.size_rel_err.mean, rb.size_rel_err.mean, "{}", ra.spec.key());
            assert_eq!(ra.total_stats, rb.total_stats, "{}", ra.spec.key());
        }
    }

    #[test]
    fn size_estimation_json_round_trips_and_bare_solvers_stay_pagerank() {
        let s = tiny_size_est();
        let text = s.to_json().render();
        assert!(text.contains("\"experiment\""), "non-default kinds serialize explicitly");
        assert!(!text.contains("\"solvers\""), "no stray solvers key: {text}");
        let back = Scenario::from_json_str(&text).expect("round trips");
        assert_eq!(back, s);

        // The pre-experiment schema still parses as the PageRank kind.
        let legacy = Scenario::from_json_str(
            r#"{"graph": "paper:10", "solvers": ["mp", "dense"]}"#,
        )
        .expect("parses");
        assert_eq!(
            legacy.experiment,
            ExperimentSpec::pagerank(vec![SolverSpec::Mp, SolverSpec::Dense])
        );
        // And the PageRank kind keeps serializing in that schema.
        let round = legacy.to_json().render();
        assert!(round.contains("\"solvers\""));
        assert!(!round.contains("\"experiment\""));

        // String and default forms of the experiment key.
        let s = Scenario::from_json_str(
            r#"{"graph": "paper:10", "experiment": "size-estimation"}"#,
        )
        .expect("parses");
        assert_eq!(s.estimators(), &[EstimatorSpec::Kaczmarz]);

        // Ambiguous combinations are rejected loudly.
        let err = Scenario::from_json_str(
            r#"{"graph": "paper:10", "experiment": "size-estimation", "solvers": ["mp"]}"#,
        )
        .expect_err("must reject");
        assert!(err.contains("experiment"), "{err}");
        // A mirrored-legacy top-level "estimators" must not silently run
        // the default mp race.
        let err = Scenario::from_json_str(
            r#"{"graph": "paper:10", "estimators": ["kaczmarz"]}"#,
        )
        .expect_err("must reject");
        assert!(err.contains("estimators"), "{err}");
        assert!(err.contains("experiment"), "error points at the right key: {err}");
    }

    #[test]
    fn size_estimation_refuses_unsuitable_graphs() {
        // The chain family ships a genuine sink: Algorithm 2's row norms
        // would assert on the zero out-degree — refuse with a message
        // naming the scenario instead.
        let err = Scenario::new("se-dangling", GraphSpec::Family { family: "chain".into(), n: 8 })
            .with_estimators(vec![EstimatorSpec::Kaczmarz])
            .with_steps(100)
            .with_stride(50)
            .with_rounds(1)
            .with_threads(1)
            .run()
            .expect_err("dangling sink must be refused");
        assert!(err.contains("dangling"), "{err}");
        assert!(err.contains("se-dangling"), "{err}");
        // And no estimators at all is an error, like no solvers.
        assert!(tiny_size_est().with_estimators(vec![]).run().is_err());
    }
}
