//! The declarative experiment engine — one entry point for every
//! algorithm, runtime, and experiment in the repository.
//!
//! The paper's evaluation (and every extension of it in this repo) has
//! one shape: *build a graph, run a set of PageRank iterations against a
//! reference solution, average trajectories over rounds, compare decay
//! rates and communication cost*. This module names each ingredient as
//! data so that shape is config, not harness code:
//!
//! * [`SolverSpec`] — the solver registry: every variant (Algorithm 1,
//!   its §IV extensions, all five published baselines, the full
//!   distributed coordinator, the multi-threaded sharded runtime, the
//!   message-passing msgpass backend and the dense backend) behind one
//!   `build(&graph, alpha, seed)` factory and a compact string form
//!   (`"mp"`, `"parallel-mp:16"`,
//!   `"coordinator:async:clocks:const:0.1"`, `"sharded:4:16:block"`,
//!   `"msgpass:4:8:mod"`, `"dense"`).
//! * [`EstimatorSpec`] — the size-estimation counterpart: Algorithm 2's
//!   randomized Kaczmarz iteration with pluggable site selection
//!   (`"kaczmarz"`, `"degree"`, `"walk"`) behind one `build(&graph)`
//!   factory.
//! * [`GraphSpec`] — workload graphs: the paper's ER-threshold model,
//!   every synthetic family, or edge-list files.
//! * [`ExperimentSpec`] — what a scenario runs: PageRank solvers racing
//!   a reference solution (Fig. 1) or size estimators racing toward
//!   `𝟙/N` (Fig. 2). Adding an experiment kind is a variant here plus a
//!   run arm, not a new harness.
//! * [`Scenario`] — graph + experiment + shared shape (steps / stride /
//!   rounds / threads / α / seed / reference policy), JSON round-trip
//!   included. [`Scenario::run`] drives the multi-round experiment
//!   runner uniformly and yields a [`ScenarioReport`].
//! * [`ScenarioReport`] — polymorphic per-run reports
//!   ([`SolverReport`]s or [`EstimatorReport`]s): averaged trajectories,
//!   fitted decay rates, read/write totals, kind-specific metrics, wall
//!   time; renderable as a terminal plot, CSV, or the machine-readable
//!   `BENCH_scenario.json` perf artifact.
//!
//! * [`Sweep`] — one scenario expanded over a grid (`graph`, `n`,
//!   `alpha`, `shards`, `batch`, `latency`, …); per-cell reports merge
//!   into the single `BENCH_sweep.json` perf trajectory (CLI: `sweep`).
//!
//! The Figure-1/Figure-2 harnesses, the ablations, the CLI
//! `run-scenario` and `sweep` subcommands, the benches and the examples
//! are all thin layers over these types; new workloads (webgraph files,
//! new grids, new experiment kinds) are new `Scenario`/`Sweep` values.

pub mod experiment_spec;
pub mod graph_spec;
pub mod report;
pub mod scenario;
pub mod solver_spec;
pub mod sweep;

pub use experiment_spec::{EstimatorRun, EstimatorSpec, ExperimentSpec};
pub use graph_spec::GraphSpec;
pub use report::{EstimatorReport, ExperimentReports, ScenarioReport, SolverReport};
pub use scenario::{ReferencePolicy, Scenario};
pub use solver_spec::{
    CoordinatorSolver, DynamicSolver, MsgpassSolver, ShardedSolver, SolverSpec,
};
pub use sweep::{Sweep, SweepCell, SweepReport};
