//! The solver registry: every PageRank iteration in the repository,
//! nameable as data.
//!
//! [`SolverSpec`] is a serializable description of a solver variant with
//! a uniform factory, `spec.build(&graph, alpha, seed)`, that yields a
//! boxed [`PageRankSolver`]. The string registry
//! (`SolverSpec::parse("mp")`, `"parallel-mp:16"`,
//! `"coordinator:async:clocks:const:0.1"`) is the JSON form used by
//! [`super::Scenario`], so adding a workload to an experiment means
//! editing config, not harness code. (Its size-estimation counterpart,
//! [`super::experiment_spec::EstimatorSpec`], follows the same pattern
//! for the Fig.-2 experiment kind.)
//!
//! Three adapters close the gap between the trait and the non-conforming
//! runtimes: [`DynamicSolver`] (owns its mutable graph),
//! [`CoordinatorSolver`] (drives the full message-passing coordinator one
//! activation per `step`, so the distributed runtime slots into Fig.-1
//! style trajectory recording unchanged) and [`ShardedSolver`] (one
//! `step` = one conflict-free super-step on the multi-threaded
//! [`ShardedRuntime`], surfacing its conflict and read/write counters).

use crate::algo::common::{PageRankSolver, StepStats};
use crate::algo::{
    dense_engine, dynamic, greedy_mp, ishii_tempo, lei_chen, monte_carlo, mp, parallel_mp,
    power_iteration, you_tempo_qiu,
};
use crate::coordinator::msgpass::DEFAULT_GOSSIP_PERIOD;
use crate::coordinator::{
    Coordinator, CoordinatorConfig, LocalityCounters, Mode, MsgpassConfig, MsgpassRuntime, Packer,
    RunReport, SamplerKind, Sampling, ShardMap, ShardedRuntime,
};
use crate::graph::Graph;
use crate::linalg::select::DEFAULT_WEIGHT_FLOOR;
use crate::network::faults::{CrashWindow, FaultPlan, LinkWindow, PartitionWindow};
use crate::network::{FaultCounters, LatencyModel};
use crate::util::rng::Rng;

/// A serializable description of any solver variant in the repository.
#[derive(Debug, Clone, PartialEq)]
pub enum SolverSpec {
    /// Algorithm 1 — randomized Matching Pursuit (matrix form).
    Mp,
    /// Algorithm 1 with §IV-3 residual-weighted activation: `k ∝
    /// max(r_k², floor)` over the shared Fenwick tree
    /// (`mp:residual[:<floor>]`; `floor > 0` keeps every page live).
    MpResidual { floor: f64 },
    /// Original best-atom MP (centralized argmax selection).
    GreedyMp,
    /// §IV-1 conflict-free parallel activation with a requested batch.
    ParallelMp { batch: usize },
    /// Centralized Jacobi iteration on `(I-αA)x = (1-α)𝟙`.
    PowerIteration,
    /// Classical power iteration on the Google matrix.
    GooglePower,
    /// \[6\] Ishii–Tempo randomized power iteration + Polyak averaging.
    IshiiTempo,
    /// \[15\] You–Tempo–Qiu randomized incremental (row Kaczmarz).
    YouTempoQiu,
    /// \[12\] Lei–Chen stochastic approximation.
    LeiChen,
    /// \[9\] Monte-Carlo random-walk frequency estimator.
    MonteCarlo,
    /// §IV-2 dynamic-network MP (owns a mutable copy of the graph).
    DynamicMp,
    /// The full distributed runtime: page agents over the simulated
    /// network, parameterized by execution mode, activation sampler and
    /// link-latency model.
    Coordinator {
        mode: Mode,
        sampler: SamplerKind,
        latency: LatencyModel,
    },
    /// The real multi-threaded deployment:
    /// [`crate::coordinator::ShardedRuntime`] with `shards` OS workers,
    /// conflict-free super-steps of up to `batch` candidates, a
    /// pluggable page→shard ownership map, a pluggable packing policy
    /// (`leader` = serial leader-side packing, `worker` = decentralized
    /// claim-array packing in the workers) and a pluggable candidate
    /// sampling policy (`uniform` = the paper's law, `residual` =
    /// residual-weighted local trees).
    Sharded {
        shards: usize,
        batch: usize,
        map: ShardMap,
        packer: Packer,
        sampling: Sampling,
    },
    /// The message-passing distributed backend:
    /// [`crate::coordinator::MsgpassRuntime`] — per-shard event loops
    /// over the virtual-time network, communicating only by metered
    /// `ResidualUpdate` / `WeightSummary` messages. `gossip` is the
    /// activations-per-shard between weight-summary broadcasts.
    /// `drop`/`crash`/`link`/`part` compose a seeded fault plan onto
    /// the wire (`drop<p>` = per-frame loss probability,
    /// `crash<w>@<t>+<d>` = one shard down-window — repeatable, and
    /// overlapping windows are legal; `link<s>-<d>@<t>+<d>` = one
    /// directional link cut; `part<s1>.<s2>…@<t>+<d>` = a healing
    /// bipartition cutting every crossing link), and `reliable`
    /// switches on the sequence-number/ack/retransmit protocol
    /// (`:rel`; fire-and-forget `:raw` is the default and is omitted
    /// from the key).
    Msgpass {
        shards: usize,
        batch: usize,
        map: ShardMap,
        gossip: usize,
        drop: f64,
        crashes: Vec<CrashWindow>,
        links: Vec<LinkWindow>,
        partitions: Vec<PartitionWindow>,
        reliable: bool,
    },
    /// The dense backend: Jacobi sweeps on a materialized hyperlink
    /// matrix ([`dense_engine::DenseJacobi`], the host twin of the PJRT
    /// `jacobi_chunk` artifact).
    Dense,
}

fn mode_key(mode: Mode) -> &'static str {
    match mode {
        Mode::Sequential => "sequential",
        Mode::Async => "async",
    }
}

fn sampler_key(sampler: SamplerKind) -> &'static str {
    match sampler {
        SamplerKind::Uniform => "uniform",
        SamplerKind::ExponentialClocks => "clocks",
        SamplerKind::ResidualWeighted { .. } => "weighted",
    }
}

fn latency_key(latency: LatencyModel) -> String {
    match latency {
        LatencyModel::Zero => "zero".to_string(),
        LatencyModel::Constant(l) => format!("const:{l}"),
        LatencyModel::Uniform { lo, hi } => format!("uniform:{lo}:{hi}"),
        LatencyModel::Exponential { mean } => format!("exp:{mean}"),
    }
}

impl SolverSpec {
    /// The coordinator spec with the paper's Algorithm-1 semantics
    /// (sequential activations, uniform sampling, ideal network) — with
    /// zero latency this is bit-equivalent to [`SolverSpec::Mp`] when
    /// both are driven by [`super::Scenario::run`] (tested).
    pub fn sequential_coordinator() -> SolverSpec {
        SolverSpec::Coordinator {
            mode: Mode::Sequential,
            sampler: SamplerKind::Uniform,
            latency: LatencyModel::Zero,
        }
    }

    /// Canonical registry string (inverse of [`SolverSpec::parse`]).
    pub fn key(&self) -> String {
        match self {
            SolverSpec::Mp => "mp".to_string(),
            SolverSpec::MpResidual { floor } => {
                if *floor == DEFAULT_WEIGHT_FLOOR {
                    "mp:residual".to_string()
                } else {
                    format!("mp:residual:{floor}")
                }
            }
            SolverSpec::GreedyMp => "greedy-mp".to_string(),
            SolverSpec::ParallelMp { batch } => format!("parallel-mp:{batch}"),
            SolverSpec::PowerIteration => "power".to_string(),
            SolverSpec::GooglePower => "google-power".to_string(),
            SolverSpec::IshiiTempo => "ishii-tempo".to_string(),
            SolverSpec::YouTempoQiu => "you-tempo-qiu".to_string(),
            SolverSpec::LeiChen => "lei-chen".to_string(),
            SolverSpec::MonteCarlo => "monte-carlo".to_string(),
            SolverSpec::DynamicMp => "dynamic-mp".to_string(),
            SolverSpec::Coordinator { mode, sampler, latency } => format!(
                "coordinator:{}:{}:{}",
                mode_key(*mode),
                sampler_key(*sampler),
                latency_key(*latency)
            ),
            SolverSpec::Sharded { shards, batch, map, packer, sampling } => {
                // The sampling segment is omitted when default, so PR-3
                // era keys (and the BENCH cell names built from them)
                // are unchanged.
                let base = format!("sharded:{shards}:{batch}:{}:{}", map.key(), packer.key());
                match sampling {
                    Sampling::Uniform => base,
                    Sampling::Residual => format!("{base}:residual"),
                }
            }
            SolverSpec::Msgpass {
                shards,
                batch,
                map,
                gossip,
                drop,
                crashes,
                links,
                partitions,
                reliable,
            } => {
                // Segments are omitted when default (gossip, drop=0,
                // no windows, raw), mirroring the sharded
                // sampling-segment convention — PR-6 era keys and the
                // BENCH cell names built from them are unchanged.
                // Windows print one segment each, in construction
                // order within their kind.
                let mut key = format!("msgpass:{shards}:{batch}:{}", map.key());
                if *gossip != DEFAULT_GOSSIP_PERIOD {
                    key.push_str(&format!(":{gossip}"));
                }
                if *drop > 0.0 {
                    key.push_str(&format!(":drop{drop}"));
                }
                for c in crashes {
                    key.push_str(&format!(":crash{}", c.key()));
                }
                for l in links {
                    key.push_str(&format!(":link{}", l.key()));
                }
                for p in partitions {
                    key.push_str(&format!(":part{}", p.key()));
                }
                if *reliable {
                    key.push_str(":rel");
                }
                key
            }
            SolverSpec::Dense => "dense".to_string(),
        }
    }

    /// One-line description for `pagerank-mp list-solvers` and reports.
    pub fn describe(&self) -> &'static str {
        match self {
            SolverSpec::Mp => "Algorithm 1: randomized Matching Pursuit (out-links only)",
            SolverSpec::MpResidual { .. } => {
                "Algorithm 1 with §IV-3 residual-weighted activation (Fenwick-sampled)"
            }
            SolverSpec::GreedyMp => "best-atom MP [2]: centralized argmax selection",
            SolverSpec::ParallelMp { .. } => "§IV-1 conflict-free batched activation",
            SolverSpec::PowerIteration => "centralized Jacobi sweeps on (I-αA)x = (1-α)1",
            SolverSpec::GooglePower => "centralized power iteration on the Google matrix",
            SolverSpec::IshiiTempo => "[6] randomized power iteration + Polyak averaging",
            SolverSpec::YouTempoQiu => "[15] randomized incremental (row Kaczmarz)",
            SolverSpec::LeiChen => "[12] stochastic approximation (Robbins–Monro gains)",
            SolverSpec::MonteCarlo => "[9] Monte-Carlo random-walk frequency estimator",
            SolverSpec::DynamicMp => "§IV-2 MP over a mutable graph (warm restart)",
            SolverSpec::Coordinator { .. } => {
                "distributed runtime: page agents + samplers + simulated network"
            }
            SolverSpec::Sharded { packer: Packer::Leader, .. } => {
                "sharded runtime: OS worker threads, leader-packed super-steps"
            }
            SolverSpec::Sharded { packer: Packer::Worker, .. } => {
                "sharded runtime: OS worker threads, worker-packed (atomic claim array)"
            }
            SolverSpec::Msgpass { .. } => {
                "msgpass runtime: per-shard event loops, metered residual + gossip messages"
            }
            SolverSpec::Dense => "dense backend: Jacobi sweeps on a materialized A (O(N²))",
        }
    }

    /// Whether the backend repairs dangling (zero out-degree) pages on
    /// the fly via the shared implicit self-loop guard of
    /// [`crate::linalg::sparse::BColumns`] /
    /// [`crate::linalg::dense::DenseMatrix::hyperlink`]. As of PR-6 the
    /// in-link baselines (`ishii-tempo`, `you-tempo-qiu`, `lei-chen`)
    /// and the random-walk estimator carry the same guard (a sink keeps
    /// its mass / parks the walk — the self-loop semantics), so every
    /// registry backend handles sinks except the simulated coordinator,
    /// whose per-page agents count one wire reply per out-neighbour and
    /// still require an explicitly repaired graph;
    /// [`super::Scenario::run`] refuses that combination up front.
    pub fn supports_dangling(&self) -> bool {
        !matches!(self, SolverSpec::Coordinator { .. })
    }

    /// Whether the backend reads the in-link adjacency (`Graph::inc` /
    /// `Graph::in_degree`): the original best-atom MP scans in-links of
    /// the activated page to update residual norms, the baselines
    /// \[12\]/\[15\] are built on in-neighbour reads, and the
    /// message-passing runtime precomputes per-page subscriber lists
    /// from the transpose. A graph built with
    /// [`Graph::without_in_links`](crate::graph::Graph::without_in_links)
    /// cannot serve these backends; [`super::Scenario::run`] refuses
    /// the combination up front instead of panicking mid-solve.
    pub fn needs_in_links(&self) -> bool {
        matches!(
            self,
            SolverSpec::GreedyMp
                | SolverSpec::YouTempoQiu
                | SolverSpec::LeiChen
                | SolverSpec::Msgpass { .. }
        )
    }

    /// Parse a registry string. Accepts the canonical keys plus short
    /// aliases (`"ytq"`, `"it"`, `"mc"`, `"jacobi"`, `"greedy"`,
    /// `"pmp:<batch>"`, `"coord:…"`).
    pub fn parse(s: &str) -> Result<SolverSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let head = *parts.first().ok_or("empty solver spec")?;
        let arity_err = |want: &str| format!("solver spec {s:?}: expected {want}");
        match head {
            "mp" | "matching-pursuit" => match parts.get(1) {
                None => Ok(SolverSpec::Mp),
                Some(&"residual") => {
                    let floor = match parts.get(2) {
                        None => DEFAULT_WEIGHT_FLOOR,
                        Some(f) => {
                            let floor: f64 = f
                                .parse()
                                .map_err(|_| arity_err("mp:residual[:<floor>]"))?;
                            if !(floor > 0.0 && floor.is_finite()) {
                                return Err(arity_err("a floor > 0 (keeps every page live)"));
                            }
                            floor
                        }
                    };
                    if parts.len() > 3 {
                        return Err(arity_err("mp:residual[:<floor>]"));
                    }
                    Ok(SolverSpec::MpResidual { floor })
                }
                Some(m) => Err(format!("bad mp variant {m:?} (mp | mp:residual[:<floor>])")),
            },
            "greedy-mp" | "greedy" => Ok(SolverSpec::GreedyMp),
            "parallel-mp" | "pmp" => {
                let batch = match parts.get(1) {
                    None => 8,
                    Some(b) => b
                        .parse()
                        .map_err(|_| arity_err("parallel-mp:<batch>"))?,
                };
                if batch == 0 {
                    return Err(arity_err("a batch size >= 1"));
                }
                Ok(SolverSpec::ParallelMp { batch })
            }
            "power" | "power-iteration" | "jacobi" => Ok(SolverSpec::PowerIteration),
            "dense" => Ok(SolverSpec::Dense),
            "sharded" | "sh" => {
                let grammar = "sharded:<shards>[:<batch>[:<mod|block|cluster|scc>\
                               [:<leader|worker>[:<uniform|residual>]]]]";
                let shards = match parts.get(1) {
                    None => 4,
                    Some(v) => v.parse().map_err(|_| arity_err(grammar))?,
                };
                if shards == 0 {
                    return Err(arity_err("a shard count >= 1"));
                }
                let batch = match parts.get(2) {
                    None => 8,
                    Some(v) => v.parse().map_err(|_| arity_err(grammar))?,
                };
                if batch == 0 {
                    return Err(arity_err("a batch budget >= 1"));
                }
                let map = match parts.get(3) {
                    None => ShardMap::Modulo,
                    Some(m) => {
                        ShardMap::parse(m).map_err(|e| format!("solver spec {s:?}: {e}"))?
                    }
                };
                let packer = match parts.get(4) {
                    None => Packer::Leader,
                    Some(p) => Packer::parse(p)
                        .ok_or_else(|| format!("bad packer {p:?} (leader|worker)"))?,
                };
                let sampling = match parts.get(5) {
                    None => Sampling::Uniform,
                    Some(p) => Sampling::parse(p)
                        .ok_or_else(|| format!("bad sampling policy {p:?} (uniform|residual)"))?,
                };
                if parts.len() > 6 {
                    return Err(arity_err(grammar));
                }
                // Bound the budget the worker packer's claim words can
                // encode (uniform across packers so a spec stays valid
                // when only its packer segment changes).
                let max = crate::coordinator::sharded::max_batch_budget(shards);
                if batch > max {
                    return Err(format!(
                        "solver spec {s:?}: batch {batch} exceeds the packable \
                         maximum {max} at {shards} shard(s)"
                    ));
                }
                Ok(SolverSpec::Sharded { shards, batch, map, packer, sampling })
            }
            "msgpass" | "msg" => {
                let grammar =
                    "msgpass:<shards>[:<batch>[:<mod|block|cluster|scc>[:<gossip-period>]]]\
                     [:drop<p>][:crash<shard>@<at>+<down-for>]\
                     [:link<src>-<dst>@<at>+<down-for>]\
                     [:part<s1>.<s2>...@<at>+<down-for>][:rel|raw]";
                // Positional prefix runs until the first tagged fault/
                // reliability segment; everything after must be tagged.
                let is_tagged = |p: &str| {
                    p.starts_with("drop")
                        || p.starts_with("crash")
                        || p.starts_with("link")
                        || p.starts_with("part")
                        || matches!(p, "rel" | "reliable" | "raw")
                };
                let mut pos: Vec<&str> = Vec::new();
                let mut tail_start = parts.len();
                for (i, p) in parts.iter().enumerate().skip(1) {
                    if is_tagged(p) {
                        tail_start = i;
                        break;
                    }
                    pos.push(p);
                }
                if pos.len() > 4 {
                    return Err(arity_err(grammar));
                }
                let shards = match pos.first() {
                    None => 4,
                    Some(v) => v.parse().map_err(|_| arity_err(grammar))?,
                };
                if shards == 0 {
                    return Err(arity_err("a shard count >= 1"));
                }
                let batch = match pos.get(1) {
                    None => 8,
                    Some(v) => v.parse().map_err(|_| arity_err(grammar))?,
                };
                if batch == 0 {
                    return Err(arity_err("a batch size >= 1"));
                }
                let map = match pos.get(2) {
                    None => ShardMap::Modulo,
                    Some(m) => {
                        ShardMap::parse(m).map_err(|e| format!("solver spec {s:?}: {e}"))?
                    }
                };
                let gossip = match pos.get(3) {
                    None => DEFAULT_GOSSIP_PERIOD,
                    Some(v) => v.parse().map_err(|_| arity_err(grammar))?,
                };
                if gossip == 0 {
                    return Err(arity_err("a gossip period >= 1"));
                }
                let mut drop = 0.0;
                let mut crashes: Vec<CrashWindow> = Vec::new();
                let mut links: Vec<LinkWindow> = Vec::new();
                let mut partitions: Vec<PartitionWindow> = Vec::new();
                let mut reliable = false;
                for p in &parts[tail_start..] {
                    if let Some(body) = p.strip_prefix("drop") {
                        let v: f64 = body.parse().map_err(|_| {
                            format!("bad drop probability {body:?} ({grammar})")
                        })?;
                        if !(0.0..1.0).contains(&v) {
                            return Err(format!(
                                "drop probability must be in [0, 1), got {v}"
                            ));
                        }
                        drop = v;
                    } else if let Some(body) = p.strip_prefix("crash") {
                        let c = CrashWindow::parse(body)
                            .map_err(|e| format!("solver spec {s:?}: {e}"))?;
                        crashes.push(c);
                    } else if let Some(body) = p.strip_prefix("link") {
                        let l = LinkWindow::parse(body)
                            .map_err(|e| format!("solver spec {s:?}: {e}"))?;
                        links.push(l);
                    } else if let Some(body) = p.strip_prefix("part") {
                        let w = PartitionWindow::parse(body)
                            .map_err(|e| format!("solver spec {s:?}: {e}"))?;
                        partitions.push(w);
                    } else if matches!(*p, "rel" | "reliable") {
                        reliable = true;
                    } else if *p == "raw" {
                        reliable = false;
                    } else {
                        return Err(format!("bad msgpass segment {p:?} ({grammar})"));
                    }
                }
                // Range/topology validation happens here at parse time
                // (positioned errors naming the valid shard range), not
                // at runtime construction.
                let probe = FaultPlan {
                    crashes: crashes.clone(),
                    links: links.clone(),
                    partitions: partitions.clone(),
                    ..FaultPlan::default()
                };
                probe.validate(shards).map_err(|e| format!("solver spec {s:?}: {e}"))?;
                Ok(SolverSpec::Msgpass {
                    shards,
                    batch,
                    map,
                    gossip,
                    drop,
                    crashes,
                    links,
                    partitions,
                    reliable,
                })
            }
            "google-power" | "google" => Ok(SolverSpec::GooglePower),
            "ishii-tempo" | "it" => Ok(SolverSpec::IshiiTempo),
            "you-tempo-qiu" | "ytq" => Ok(SolverSpec::YouTempoQiu),
            "lei-chen" | "lc" => Ok(SolverSpec::LeiChen),
            "monte-carlo" | "mc" => Ok(SolverSpec::MonteCarlo),
            "dynamic-mp" | "dynamic" => Ok(SolverSpec::DynamicMp),
            "coordinator" | "coord" => {
                let mode = match parts.get(1).copied().unwrap_or("sequential") {
                    "sequential" | "seq" => Mode::Sequential,
                    "async" => Mode::Async,
                    m => return Err(format!("bad coordinator mode {m:?} (sequential|async)")),
                };
                let sampler = match parts.get(2).copied().unwrap_or("uniform") {
                    "uniform" => SamplerKind::Uniform,
                    "clocks" => SamplerKind::ExponentialClocks,
                    "weighted" => SamplerKind::ResidualWeighted { floor: 1e-12 },
                    sm => {
                        return Err(format!(
                            "bad coordinator sampler {sm:?} (uniform|clocks|weighted)"
                        ))
                    }
                };
                let latency = if parts.len() <= 3 {
                    LatencyModel::Zero
                } else {
                    let spec = parts[3..].join(":");
                    LatencyModel::parse(&spec).ok_or_else(|| {
                        format!("bad latency {spec:?} (zero|const:L|uniform:lo:hi|exp:mean)")
                    })?
                };
                Ok(SolverSpec::Coordinator { mode, sampler, latency })
            }
            _ => Err(format!(
                "unknown solver {head:?} — try one of: {}",
                SolverSpec::all()
                    .iter()
                    .map(SolverSpec::key)
                    .collect::<Vec<_>>()
                    .join(", ")
            )),
        }
    }

    /// One of every variant (default parameters) — the registry listing.
    pub fn all() -> Vec<SolverSpec> {
        vec![
            SolverSpec::Mp,
            SolverSpec::MpResidual { floor: DEFAULT_WEIGHT_FLOOR },
            SolverSpec::GreedyMp,
            SolverSpec::ParallelMp { batch: 8 },
            SolverSpec::PowerIteration,
            SolverSpec::GooglePower,
            SolverSpec::IshiiTempo,
            SolverSpec::YouTempoQiu,
            SolverSpec::LeiChen,
            SolverSpec::MonteCarlo,
            SolverSpec::DynamicMp,
            SolverSpec::sequential_coordinator(),
            SolverSpec::Sharded {
                shards: 2,
                batch: 8,
                map: ShardMap::Modulo,
                packer: Packer::Leader,
                sampling: Sampling::Uniform,
            },
            SolverSpec::Sharded {
                shards: 2,
                batch: 8,
                map: ShardMap::Modulo,
                packer: Packer::Worker,
                sampling: Sampling::Uniform,
            },
            SolverSpec::Sharded {
                shards: 2,
                batch: 8,
                map: ShardMap::Modulo,
                packer: Packer::Worker,
                sampling: Sampling::Residual,
            },
            SolverSpec::Sharded {
                shards: 2,
                batch: 8,
                map: ShardMap::Cluster,
                packer: Packer::Worker,
                sampling: Sampling::Uniform,
            },
            SolverSpec::Msgpass {
                shards: 2,
                batch: 4,
                map: ShardMap::Modulo,
                gossip: DEFAULT_GOSSIP_PERIOD,
                drop: 0.0,
                crashes: vec![],
                links: vec![],
                partitions: vec![],
                reliable: false,
            },
            SolverSpec::Msgpass {
                shards: 2,
                batch: 4,
                map: ShardMap::Scc,
                gossip: DEFAULT_GOSSIP_PERIOD,
                drop: 0.0,
                crashes: vec![],
                links: vec![],
                partitions: vec![],
                reliable: false,
            },
            SolverSpec::Dense,
        ]
    }

    /// The paper's comparison set: Algorithm 1 plus the five published
    /// baselines it is evaluated against.
    pub fn all_baselines() -> Vec<SolverSpec> {
        vec![
            SolverSpec::Mp,
            SolverSpec::YouTempoQiu,
            SolverSpec::IshiiTempo,
            SolverSpec::LeiChen,
            SolverSpec::MonteCarlo,
            SolverSpec::PowerIteration,
        ]
    }

    /// Uniform factory: construct the described solver over `graph`.
    ///
    /// `seed` parameterizes solvers with internal randomness streams (the
    /// coordinator); matrix-form solvers are deterministic and driven
    /// entirely by the `Rng` passed to `step`. [`super::Scenario::run`]
    /// seeds both from the same per-round value so the two kinds stay
    /// replay-equivalent.
    pub fn build<'g>(
        &self,
        graph: &'g Graph,
        alpha: f64,
        seed: u64,
    ) -> Box<dyn PageRankSolver + 'g> {
        match self {
            SolverSpec::Mp => Box::new(mp::MatchingPursuit::new(graph, alpha)),
            SolverSpec::MpResidual { floor } => {
                Box::new(mp::ResidualMatchingPursuit::new(graph, alpha, *floor))
            }
            SolverSpec::GreedyMp => Box::new(greedy_mp::GreedyMatchingPursuit::new(graph, alpha)),
            SolverSpec::ParallelMp { batch } => {
                Box::new(parallel_mp::ParallelMatchingPursuit::new(graph, alpha, *batch))
            }
            SolverSpec::PowerIteration => {
                Box::new(power_iteration::JacobiPowerIteration::new(graph, alpha))
            }
            SolverSpec::GooglePower => {
                Box::new(power_iteration::GooglePowerIteration::new(graph, alpha))
            }
            SolverSpec::IshiiTempo => Box::new(ishii_tempo::IshiiTempo::new(graph, alpha)),
            SolverSpec::YouTempoQiu => Box::new(you_tempo_qiu::YouTempoQiu::new(graph, alpha)),
            SolverSpec::LeiChen => Box::new(lei_chen::LeiChen::new(graph, alpha)),
            SolverSpec::MonteCarlo => Box::new(monte_carlo::MonteCarlo::new(graph, alpha)),
            SolverSpec::DynamicMp => Box::new(DynamicSolver::new(graph.clone(), alpha)),
            SolverSpec::Coordinator { mode, sampler, latency } => Box::new(
                CoordinatorSolver::build(graph, alpha, seed, *mode, *sampler, *latency),
            ),
            SolverSpec::Sharded { shards, batch, map, packer, sampling } => Box::new(
                ShardedSolver::new(graph, alpha, *shards, *batch, *map, *packer, *sampling),
            ),
            SolverSpec::Msgpass {
                shards,
                batch,
                map,
                gossip,
                drop,
                crashes,
                links,
                partitions,
                reliable,
            } => {
                let mut cfg =
                    MsgpassConfig::new(*shards, *batch, *map, *gossip, LatencyModel::Zero);
                let mut plan = FaultPlan::default();
                if *drop > 0.0 {
                    plan = plan.with_drop(*drop);
                }
                for c in crashes {
                    plan = plan.with_crash(*c);
                }
                for l in links {
                    plan = plan.with_link(*l);
                }
                for p in partitions {
                    plan = plan.with_partition(p.clone());
                }
                cfg = cfg.with_faults(plan);
                if *reliable {
                    cfg = cfg.reliable();
                }
                Box::new(MsgpassSolver::new(graph, alpha, cfg))
            }
            SolverSpec::Dense => Box::new(dense_engine::DenseJacobi::new(graph, alpha)),
        }
    }
}

/// [`PageRankSolver`] adapter over the message-passing
/// [`MsgpassRuntime`]: one trait `step` = one super-step of up to
/// `batch` activations distributed across the shard event loops, with
/// all resulting messages drained. The candidate streams seed from the
/// `rng` handed to the first `step` (shard 0 clones it verbatim —
/// exactly the sharded worker-packing protocol), so inside a
/// [`super::Scenario`] a `msgpass:1:1:mod` run at zero latency replays
/// the *identical* activation sequence as [`SolverSpec::Mp`] — the
/// equivalence anchor tested in `tests/engine.rs`.
///
/// The runtime owns a clone of the graph; the registry builds it with
/// zero link latency (latency sweeps drive [`MsgpassRuntime`] directly,
/// as `benches/throughput.rs` does), composing whatever fault plan and
/// reliability mode the spec's `drop`/`crash`/`rel` segments describe.
pub struct MsgpassSolver {
    rt: MsgpassRuntime,
    prev_reads: u64,
    prev_writes: u64,
    prev_activations: u64,
}

impl MsgpassSolver {
    pub fn new(graph: &Graph, alpha: f64, cfg: MsgpassConfig) -> MsgpassSolver {
        MsgpassSolver {
            rt: MsgpassRuntime::with_config(graph.clone(), alpha, cfg),
            prev_reads: 0,
            prev_writes: 0,
            prev_activations: 0,
        }
    }

    /// Typed access to the wrapped runtime (message/byte/queue meters).
    pub fn runtime(&self) -> &MsgpassRuntime {
        &self.rt
    }
}

impl PageRankSolver for MsgpassSolver {
    fn n(&self) -> usize {
        self.rt.n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        self.rt.run_super_step(rng);
        let (reads, writes, activations) =
            (self.rt.logical_reads(), self.rt.logical_writes(), self.rt.activations());
        let stats = StepStats {
            reads: (reads - self.prev_reads) as usize,
            writes: (writes - self.prev_writes) as usize,
            activated: (activations - self.prev_activations) as usize,
        };
        self.prev_reads = reads;
        self.prev_writes = writes;
        self.prev_activations = activations;
        stats
    }

    fn estimate(&self) -> Vec<f64> {
        self.rt.estimate()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        self.rt.error_sq_vs(x_star)
    }

    fn fault_counters(&self) -> FaultCounters {
        self.rt.fault_counters()
    }

    fn locality(&self) -> LocalityCounters {
        self.rt.locality()
    }

    fn name(&self) -> &'static str {
        "msgpass runtime (per-shard event loops)"
    }
}

/// [`PageRankSolver`] adapter over the multi-threaded
/// [`ShardedRuntime`]: one trait `step` = one conflict-free super-step of
/// up to `batch` candidate activations, executed on the runtime's worker
/// threads. The candidate stream comes from the `rng` handed to `step`
/// (under worker packing it seeds the per-worker streams on the first
/// step, worker 0 cloning it verbatim), so inside a [`super::Scenario`] a
/// `shards=1, batch=1` run replays the *identical* activation sequence
/// as [`SolverSpec::Mp`] under **either** packer (packing one candidate
/// never conflicts) — the backend-equivalence anchor tested in
/// `tests/engine.rs`.
///
/// The runtime owns a clone of the graph (workers need `'static` shared
/// state), so the adapter is self-contained; worker threads are joined on
/// drop.
pub struct ShardedSolver {
    rt: ShardedRuntime,
    batch: usize,
    prev_reads: u64,
    prev_writes: u64,
    prev_activations: u64,
}

impl ShardedSolver {
    pub fn new(
        graph: &Graph,
        alpha: f64,
        shards: usize,
        batch: usize,
        map: ShardMap,
        packer: Packer,
        sampling: Sampling,
    ) -> ShardedSolver {
        assert!(batch >= 1);
        ShardedSolver {
            rt: ShardedRuntime::new_with_sampling(
                graph.clone(),
                alpha,
                shards,
                map,
                packer,
                sampling,
            ),
            batch,
            prev_reads: 0,
            prev_writes: 0,
            prev_activations: 0,
        }
    }

    /// Typed access to the wrapped runtime.
    pub fn runtime(&self) -> &ShardedRuntime {
        &self.rt
    }
}

impl PageRankSolver for ShardedSolver {
    fn n(&self) -> usize {
        self.rt.n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        self.rt.run(1, self.batch, rng);
        let (reads, writes, activations) =
            (self.rt.logical_reads(), self.rt.logical_writes(), self.rt.activations());
        let stats = StepStats {
            reads: (reads - self.prev_reads) as usize,
            writes: (writes - self.prev_writes) as usize,
            activated: (activations - self.prev_activations) as usize,
        };
        self.prev_reads = reads;
        self.prev_writes = writes;
        self.prev_activations = activations;
        stats
    }

    fn estimate(&self) -> Vec<f64> {
        self.rt.estimate()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        self.rt.error_sq_vs(x_star)
    }

    /// The "conflicts dropped" column of the scenario report — candidates
    /// the runtime's packer rejected (thinned-uniform accounting).
    fn conflicts(&self) -> u64 {
        self.rt.conflicts()
    }

    fn locality(&self) -> LocalityCounters {
        self.rt.locality()
    }

    fn name(&self) -> &'static str {
        match (self.rt.packer(), self.rt.sampling()) {
            (Packer::Leader, Sampling::Uniform) => "sharded runtime (leader-packed)",
            (Packer::Worker, Sampling::Uniform) => "sharded runtime (worker-packed)",
            (Packer::Leader, Sampling::Residual) => {
                "sharded runtime (leader-packed, residual-weighted)"
            }
            (Packer::Worker, Sampling::Residual) => {
                "sharded runtime (worker-packed, residual-weighted)"
            }
        }
    }
}

/// [`PageRankSolver`] adapter over the §IV-2 dynamic tracker (which owns
/// its graph so it can mutate topology mid-run).
pub struct DynamicSolver {
    inner: dynamic::DynamicMatchingPursuit,
}

impl DynamicSolver {
    pub fn new(graph: Graph, alpha: f64) -> DynamicSolver {
        DynamicSolver { inner: dynamic::DynamicMatchingPursuit::new(graph, alpha) }
    }

    /// Access the wrapped tracker (topology events, conservation checks).
    pub fn inner_mut(&mut self) -> &mut dynamic::DynamicMatchingPursuit {
        &mut self.inner
    }
}

impl PageRankSolver for DynamicSolver {
    fn n(&self) -> usize {
        self.inner.graph().n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        self.inner.step(rng)
    }

    fn estimate(&self) -> Vec<f64> {
        self.inner.estimate().to_vec()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(self.inner.estimate(), x_star)
    }

    fn name(&self) -> &'static str {
        "dynamic mp (warm restart)"
    }
}

/// [`PageRankSolver`] adapter over the full distributed coordinator: one
/// trait `step` = one completed activation of the §II-D message protocol
/// over the simulated network.
///
/// The `rng` handed to `step` is ignored — the coordinator owns its
/// sampler and latency streams, forked from the `seed` it was built with.
/// [`super::Scenario::run`] derives that seed and the matrix-form step
/// stream from the same value, which makes the sequential zero-latency
/// coordinator replay the *identical* activation sequence as
/// [`SolverSpec::Mp`] (and, with an ideal network, produce bit-identical
/// estimates — tested in `tests/engine.rs`).
pub struct CoordinatorSolver<'g> {
    coord: Coordinator<'g>,
    prev_reads: u64,
    prev_writes: u64,
}

impl<'g> CoordinatorSolver<'g> {
    /// Construct from explicit runtime parameters.
    pub fn build(
        graph: &'g Graph,
        alpha: f64,
        seed: u64,
        mode: Mode,
        sampler: SamplerKind,
        latency: LatencyModel,
    ) -> CoordinatorSolver<'g> {
        let cfg = CoordinatorConfig::default()
            .with_alpha(alpha)
            .with_seed(seed)
            .with_mode(mode)
            .with_sampler(sampler)
            .with_latency(latency);
        CoordinatorSolver { coord: Coordinator::new(graph, cfg), prev_reads: 0, prev_writes: 0 }
    }

    /// Construct from a [`SolverSpec::Coordinator`] value (typed access
    /// to the runtime where the boxed trait object is not enough).
    pub fn from_spec(
        graph: &'g Graph,
        alpha: f64,
        seed: u64,
        spec: &SolverSpec,
    ) -> Result<CoordinatorSolver<'g>, String> {
        match spec {
            SolverSpec::Coordinator { mode, sampler, latency } => {
                Ok(CoordinatorSolver::build(graph, alpha, seed, *mode, *sampler, *latency))
            }
            other => Err(format!("not a coordinator spec: {}", other.key())),
        }
    }

    /// Run a whole budget of activations at once (cheaper than repeated
    /// `step` calls) and return the cumulative run report.
    pub fn drive(&mut self, activations: u64) -> RunReport {
        let report = self.coord.run(activations);
        self.prev_reads = report.metrics.logical_reads();
        self.prev_writes = report.metrics.logical_writes();
        report
    }

    /// Record an error trajectory by driving the runtime in stride-sized
    /// chunks — the coordinator counterpart of
    /// [`crate::algo::common::Trajectory::record`].
    ///
    /// The runtime only yields consistent snapshots at quiescence, so
    /// errors are sampled at chunk boundaries; *within* a chunk
    /// asynchronous activations overlap freely. (A per-activation `step`
    /// loop would drain the pipeline after every single activation and
    /// silently serialize async runs.) In sequential mode the chunked
    /// drive replays the identical activation stream as per-activation
    /// stepping, so the [`SolverSpec::Mp`] equivalence is unaffected.
    pub fn record(
        &mut self,
        x_star: &[f64],
        steps: usize,
        stride: usize,
    ) -> (Vec<f64>, StepStats) {
        assert!(stride > 0);
        let n = x_star.len() as f64;
        let (r0, w0, a0) = {
            let m = self.coord.metrics();
            (m.logical_reads(), m.logical_writes(), m.activations)
        };
        let mut errors = Vec::with_capacity(steps / stride + 1);
        errors.push(self.coord.error_sq_vs(x_star) / n);
        for _ in 0..steps / stride {
            self.drive(stride as u64);
            errors.push(self.coord.error_sq_vs(x_star) / n);
        }
        let remainder = steps % stride;
        if remainder > 0 {
            self.drive(remainder as u64);
        }
        let m = self.coord.metrics();
        let stats = StepStats {
            reads: (m.logical_reads() - r0) as usize,
            writes: (m.logical_writes() - w0) as usize,
            // Actual completions (drain can finish in-flight activations
            // beyond the requested budget in async mode).
            activated: (m.activations - a0) as usize,
        };
        (errors, stats)
    }

    /// Cumulative runtime metrics (message counts, deferrals, makespan).
    pub fn metrics(&self) -> &crate::coordinator::metrics::Metrics {
        self.coord.metrics()
    }

    /// Current residual snapshot (quiescent between runs).
    pub fn residual(&self) -> Vec<f64> {
        self.coord.residual()
    }

    /// Virtual time consumed so far.
    pub fn virtual_time(&self) -> f64 {
        self.coord.virtual_time()
    }
}

impl PageRankSolver for CoordinatorSolver<'_> {
    fn n(&self) -> usize {
        self.coord.n()
    }

    // NOTE: per-activation stepping quiesces the runtime each call, so it
    // carries Algorithm-1 sequential semantics; `Scenario::run` and
    // callers that care about async overlap use `record`/`drive` instead.
    fn step(&mut self, _rng: &mut Rng) -> StepStats {
        self.coord.run(1);
        let m = self.coord.metrics();
        let reads = m.logical_reads();
        let writes = m.logical_writes();
        let stats = StepStats {
            reads: (reads - self.prev_reads) as usize,
            writes: (writes - self.prev_writes) as usize,
            activated: 1,
        };
        self.prev_reads = reads;
        self.prev_writes = writes;
        stats
    }

    fn estimate(&self) -> Vec<f64> {
        self.coord.estimate()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        self.coord.error_sq_vs(x_star)
    }

    fn name(&self) -> &'static str {
        "coordinator (agents + simulated network)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;

    #[test]
    fn every_registry_key_round_trips() {
        for spec in SolverSpec::all() {
            let key = spec.key();
            let back = SolverSpec::parse(&key).expect("canonical key parses");
            assert_eq!(back, spec, "round trip failed for {key}");
        }
    }

    #[test]
    fn aliases_resolve() {
        assert_eq!(SolverSpec::parse("ytq").expect("ok"), SolverSpec::YouTempoQiu);
        assert_eq!(SolverSpec::parse("jacobi").expect("ok"), SolverSpec::PowerIteration);
        assert_eq!(
            SolverSpec::parse("pmp:32").expect("ok"),
            SolverSpec::ParallelMp { batch: 32 }
        );
        assert_eq!(
            SolverSpec::parse("coord:async:clocks:const:0.1").expect("ok"),
            SolverSpec::Coordinator {
                mode: Mode::Async,
                sampler: SamplerKind::ExponentialClocks,
                latency: LatencyModel::Constant(0.1),
            }
        );
    }

    #[test]
    fn residual_specs_parse_and_round_trip() {
        assert_eq!(
            SolverSpec::parse("mp:residual").expect("ok"),
            SolverSpec::MpResidual { floor: DEFAULT_WEIGHT_FLOOR }
        );
        assert_eq!(SolverSpec::parse("mp:residual").expect("ok").key(), "mp:residual");
        let custom = SolverSpec::MpResidual { floor: 1e-6 };
        assert_eq!(SolverSpec::parse(&custom.key()).expect("ok"), custom);
        assert_eq!(
            SolverSpec::parse("sharded:2:8:mod:worker:residual").expect("ok"),
            SolverSpec::Sharded {
                shards: 2,
                batch: 8,
                map: ShardMap::Modulo,
                packer: Packer::Worker,
                sampling: Sampling::Residual,
            }
        );
        assert_eq!(
            SolverSpec::parse("sharded:2:8:mod:worker:residual").expect("ok").key(),
            "sharded:2:8:mod:worker:residual"
        );
        // The explicit uniform segment is the PR-3 default — same spec,
        // same canonical key, so the new segment cannot perturb existing
        // scenarios or their determinism pins.
        assert_eq!(
            SolverSpec::parse("sharded:1:1:mod:worker:uniform").expect("ok"),
            SolverSpec::parse("sharded:1:1:mod:worker").expect("ok")
        );
        assert_eq!(
            SolverSpec::parse("sharded:1:1:mod:worker:uniform").expect("ok").key(),
            "sharded:1:1:mod:worker"
        );
    }

    #[test]
    fn msgpass_specs_parse_and_round_trip() {
        assert_eq!(
            SolverSpec::parse("msgpass").expect("ok"),
            SolverSpec::Msgpass {
                shards: 4,
                batch: 8,
                map: ShardMap::Modulo,
                gossip: DEFAULT_GOSSIP_PERIOD,
                drop: 0.0,
                crashes: vec![],
                links: vec![],
                partitions: vec![],
                reliable: false,
            }
        );
        assert_eq!(
            SolverSpec::parse("msg:2:4:block:16").expect("ok"),
            SolverSpec::Msgpass {
                shards: 2,
                batch: 4,
                map: ShardMap::Block,
                gossip: 16,
                drop: 0.0,
                crashes: vec![],
                links: vec![],
                partitions: vec![],
                reliable: false,
            }
        );
        assert_eq!(
            SolverSpec::parse("msg:2:4:block:16").expect("ok").key(),
            "msgpass:2:4:block:16"
        );
        // The gossip segment is omitted when default — explicit and
        // implicit forms are the same spec with the same canonical key.
        assert_eq!(
            SolverSpec::parse(&format!("msgpass:1:1:mod:{DEFAULT_GOSSIP_PERIOD}")).expect("ok"),
            SolverSpec::parse("msgpass:1:1:mod").expect("ok")
        );
        assert_eq!(
            SolverSpec::parse(&format!("msgpass:1:1:mod:{DEFAULT_GOSSIP_PERIOD}"))
                .expect("ok")
                .key(),
            "msgpass:1:1:mod"
        );
    }

    #[test]
    fn msgpass_fault_segments_parse_and_round_trip() {
        let full = SolverSpec::parse("msgpass:4:8:mod:drop0.05:crash1@64+32:rel").expect("ok");
        assert_eq!(
            full,
            SolverSpec::Msgpass {
                shards: 4,
                batch: 8,
                map: ShardMap::Modulo,
                gossip: DEFAULT_GOSSIP_PERIOD,
                drop: 0.05,
                crashes: vec![CrashWindow { shard: 1, at: 64.0, down_for: 32.0 }],
                links: vec![],
                partitions: vec![],
                reliable: true,
            }
        );
        assert_eq!(full.key(), "msgpass:4:8:mod:drop0.05:crash1@64+32:rel");
        assert_eq!(SolverSpec::parse(&full.key()).expect("ok"), full);
        // Tags compose with an explicit gossip segment.
        let gossiped = SolverSpec::parse("msgpass:2:4:block:16:drop0.2").expect("ok");
        assert_eq!(gossiped.key(), "msgpass:2:4:block:16:drop0.2");
        assert_eq!(SolverSpec::parse(&gossiped.key()).expect("ok"), gossiped);
        // Explicit raw is the default — same spec, same canonical key
        // as no tag at all, so existing pins and BENCH cells are safe.
        assert_eq!(
            SolverSpec::parse("msgpass:2:4:mod:raw").expect("ok"),
            SolverSpec::parse("msgpass:2:4:mod").expect("ok")
        );
        assert_eq!(SolverSpec::parse("msgpass:2:4:mod:raw").expect("ok").key(), "msgpass:2:4:mod");
        // `reliable` is accepted as an alias but canonicalizes to `rel`.
        assert_eq!(
            SolverSpec::parse("msgpass:2:4:mod:reliable").expect("ok").key(),
            "msgpass:2:4:mod:rel"
        );
    }

    #[test]
    fn msgpass_link_and_partition_segments_parse_and_round_trip() {
        let spec = SolverSpec::parse("msgpass:4:8:mod:link0-1@64+32:part0.1@100+16:rel")
            .expect("ok");
        assert_eq!(
            spec,
            SolverSpec::Msgpass {
                shards: 4,
                batch: 8,
                map: ShardMap::Modulo,
                gossip: DEFAULT_GOSSIP_PERIOD,
                drop: 0.0,
                crashes: vec![],
                links: vec![LinkWindow { src: 0, dst: 1, at: 64.0, down_for: 32.0 }],
                partitions: vec![PartitionWindow::new(vec![0, 1], 100.0, 16.0)],
                reliable: true,
            }
        );
        assert_eq!(spec.key(), "msgpass:4:8:mod:link0-1@64+32:part0.1@100+16:rel");
        assert_eq!(SolverSpec::parse(&spec.key()).expect("ok"), spec);
        // Windows repeat: two crash segments and a link compose into
        // one plan, overlapping legally, and keep construction order.
        let multi =
            SolverSpec::parse("msgpass:4:8:mod:crash1@40+30:crash2@50+30:link3-0@10+5:rel")
                .expect("ok");
        assert_eq!(
            multi.key(),
            "msgpass:4:8:mod:crash1@40+30:crash2@50+30:link3-0@10+5:rel"
        );
        assert_eq!(SolverSpec::parse(&multi.key()).expect("ok"), multi);
        if let SolverSpec::Msgpass { crashes, links, .. } = &multi {
            assert_eq!(crashes.len(), 2);
            assert_eq!(links.len(), 1);
        } else {
            panic!("parsed a non-msgpass spec");
        }
    }

    #[test]
    fn msgpass_window_validation_is_positioned_and_names_the_range() {
        // Out-of-range shards and self-links are rejected at parse
        // time with the window's index, its spec, and the valid range.
        let err = SolverSpec::parse("msgpass:2:4:mod:link0-7@1+1").expect_err("bad dst");
        assert!(err.contains("link window #0"), "positions the window: {err}");
        assert!(err.contains("0..2"), "names the valid range: {err}");
        let err = SolverSpec::parse("msgpass:2:4:mod:link1-1@1+1").expect_err("self-link");
        assert!(err.contains("self-link"), "{err}");
        let err = SolverSpec::parse("msgpass:2:4:mod:part0.5@1+1").expect_err("bad member");
        assert!(err.contains("partition window #0"), "{err}");
        assert!(err.contains("0..2"), "{err}");
        let err = SolverSpec::parse("msgpass:2:4:mod:part0.1@1+1").expect_err("degenerate");
        assert!(err.contains("bipartition"), "{err}");
        let err = SolverSpec::parse("msgpass:2:4:mod:crash9@64+32").expect_err("bad shard");
        assert!(err.contains("crash window #0"), "{err}");
        assert!(err.contains("0..2"), "{err}");
    }

    #[test]
    fn bad_msgpass_specs_rejected() {
        assert!(SolverSpec::parse("msgpass:0").is_err());
        assert!(SolverSpec::parse("msgpass:2:0").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:diagonal").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:0").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:8:extra").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:eight").is_err());
        // Fault segments: range, grammar and topology checks are loud.
        assert!(SolverSpec::parse("msgpass:2:4:mod:drop1.5").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:drop-0.1").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:dropx").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:crash1@64").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:crash9@64+32").is_err(), "shard 9 of 2");
        assert!(SolverSpec::parse("msgpass:2:4:mod:rel:extra").is_err());
        assert!(SolverSpec::parse("msgpass:2:4:mod:drop0.1:8").is_err(), "gossip after a tag");
        assert!(SolverSpec::parse("msgpass:2:4:mod:link0-1@64").is_err(), "no duration");
        assert!(SolverSpec::parse("msgpass:2:4:mod:link01@64+32").is_err(), "no dash");
        assert!(SolverSpec::parse("msgpass:2:4:mod:part0@64").is_err(), "no duration");
        assert!(SolverSpec::parse("msgpass:4:8:mod:part@64+32").is_err(), "no members");
    }

    #[test]
    fn topology_map_specs_parse_and_round_trip() {
        // The cluster/scc map segment rides the existing grammar slot —
        // historical mod/block keys are untouched (round-trip pinned in
        // every_registry_key_round_trips) and the new maps canonicalize
        // to themselves on both backends.
        assert_eq!(
            SolverSpec::parse("sharded:4:16:cluster:worker").expect("ok"),
            SolverSpec::Sharded {
                shards: 4,
                batch: 16,
                map: ShardMap::Cluster,
                packer: Packer::Worker,
                sampling: Sampling::Uniform,
            }
        );
        assert_eq!(
            SolverSpec::parse("sharded:4:16:cluster:worker").expect("ok").key(),
            "sharded:4:16:cluster:worker"
        );
        assert_eq!(
            SolverSpec::parse("sharded:2:8:scc").expect("ok").key(),
            "sharded:2:8:scc:leader"
        );
        assert_eq!(
            SolverSpec::parse("msgpass:2:4:cluster").expect("ok"),
            SolverSpec::Msgpass {
                shards: 2,
                batch: 4,
                map: ShardMap::Cluster,
                gossip: DEFAULT_GOSSIP_PERIOD,
                drop: 0.0,
                crashes: vec![],
                links: vec![],
                partitions: vec![],
                reliable: false,
            }
        );
        assert_eq!(
            SolverSpec::parse("msgpass:2:4:scc:16:rel").expect("ok").key(),
            "msgpass:2:4:scc:16:rel"
        );
        // Historical canonical keys stay byte-identical — the map and
        // packer segments print exactly as before the cluster/scc maps
        // existed.
        for key in ["sharded:2:8:mod:leader", "sharded:8:64:block:worker", "msgpass:2:4:block:16"]
        {
            assert_eq!(SolverSpec::parse(key).expect("ok").key(), key);
        }
    }

    #[test]
    fn bad_shard_map_error_names_the_valid_set() {
        let err = SolverSpec::parse("sharded:2:8:diagonal").expect_err("bad map");
        assert!(err.contains("diagonal"), "names the offender: {err}");
        assert!(err.contains("mod|block|cluster|scc"), "names the valid set: {err}");
        let err = SolverSpec::parse("msgpass:2:4:diagonal").expect_err("bad map");
        assert!(err.contains("mod|block|cluster|scc"), "names the valid set: {err}");
    }

    #[test]
    fn bad_specs_rejected() {
        assert!(SolverSpec::parse("bogus").is_err());
        assert!(SolverSpec::parse("mp:bogus").is_err());
        assert!(SolverSpec::parse("mp:residual:0").is_err());
        assert!(SolverSpec::parse("mp:residual:-1e-9").is_err());
        assert!(SolverSpec::parse("mp:residual:nan").is_err());
        assert!(SolverSpec::parse("mp:residual:1e-9:extra").is_err());
        assert!(SolverSpec::parse("sharded:2:8:mod:worker:importance").is_err());
        assert!(SolverSpec::parse("sharded:2:8:mod:worker:residual:extra").is_err());
        assert!(SolverSpec::parse("parallel-mp:0").is_err());
        assert!(SolverSpec::parse("coordinator:teleport").is_err());
        assert!(SolverSpec::parse("coordinator:async:psychic").is_err());
        assert!(SolverSpec::parse("coordinator:async:clocks:warp:9").is_err());
        assert!(SolverSpec::parse("sharded:0").is_err());
        assert!(SolverSpec::parse("sharded:2:0").is_err());
        assert!(SolverSpec::parse("sharded:2:8:diagonal").is_err());
        assert!(SolverSpec::parse("sharded:2:8:mod:boss").is_err());
        assert!(SolverSpec::parse("sharded:2:8:mod:worker:extra").is_err());
        // Budget beyond the claim-word priority field is refused at parse
        // time (for either packer) instead of panicking mid-run.
        assert!(SolverSpec::parse("sharded:2:2000000:mod:worker").is_err());
        assert!(SolverSpec::parse("sharded:2:2000000").is_err());
        let max = crate::coordinator::sharded::max_batch_budget(2);
        assert!(SolverSpec::parse(&format!("sharded:2:{max}:mod:worker")).is_ok());
    }

    #[test]
    fn sharded_and_dense_specs_parse_with_defaults() {
        assert_eq!(SolverSpec::parse("dense").expect("ok"), SolverSpec::Dense);
        assert_eq!(
            SolverSpec::parse("sharded").expect("ok"),
            SolverSpec::Sharded {
                shards: 4,
                batch: 8,
                map: ShardMap::Modulo,
                packer: Packer::Leader,
                sampling: Sampling::Uniform,
            }
        );
        assert_eq!(
            SolverSpec::parse("sharded:2").expect("ok"),
            SolverSpec::Sharded {
                shards: 2,
                batch: 8,
                map: ShardMap::Modulo,
                packer: Packer::Leader,
                sampling: Sampling::Uniform,
            }
        );
        assert_eq!(
            SolverSpec::parse("sh:8:32:block").expect("ok"),
            SolverSpec::Sharded {
                shards: 8,
                batch: 32,
                map: ShardMap::Block,
                packer: Packer::Leader,
                sampling: Sampling::Uniform,
            }
        );
        assert_eq!(
            SolverSpec::parse("sharded:8:64:mod:worker").expect("ok"),
            SolverSpec::Sharded {
                shards: 8,
                batch: 64,
                map: ShardMap::Modulo,
                packer: Packer::Worker,
                sampling: Sampling::Uniform,
            }
        );
        assert_eq!(
            SolverSpec::parse("sharded:8:64:mod:worker").expect("ok").key(),
            "sharded:8:64:mod:worker"
        );
    }

    #[test]
    fn dangling_supported_backends_stay_finite_on_a_sink_graph() {
        // supports_dangling must tell the truth: every backend that
        // claims the guard steps a sink-tailed chain without poisoning
        // its estimate.
        let g = generators::chain(10);
        for spec in SolverSpec::all() {
            if !spec.supports_dangling() {
                continue;
            }
            let mut solver = spec.build(&g, 0.85, 3);
            let mut rng = Rng::seeded(4);
            for _ in 0..50 {
                solver.step(&mut rng);
            }
            assert!(
                solver.estimate().iter().all(|v| v.is_finite()),
                "{} poisoned by the sink page",
                spec.key()
            );
        }
        // PR-6 extended the guard to the in-link baselines and the
        // random-walk estimator; only the simulated coordinator still
        // needs an explicitly repaired graph.
        assert!(SolverSpec::MonteCarlo.supports_dangling());
        assert!(SolverSpec::YouTempoQiu.supports_dangling());
        assert!(SolverSpec::IshiiTempo.supports_dangling());
        assert!(SolverSpec::LeiChen.supports_dangling());
        assert!(!SolverSpec::sequential_coordinator().supports_dangling());
    }

    #[test]
    fn in_link_free_backends_run_without_the_transpose() {
        // needs_in_links must tell the truth in both directions: every
        // backend that claims to be in-link-free must step a graph whose
        // in-CSR is disabled (it would panic loudly otherwise), and the
        // four transpose readers must declare themselves.
        let g = generators::ring(12).without_in_links();
        for spec in SolverSpec::all() {
            if spec.needs_in_links() {
                continue;
            }
            let mut solver = spec.build(&g, 0.85, 3);
            let mut rng = Rng::seeded(9);
            for _ in 0..30 {
                solver.step(&mut rng);
            }
            assert!(
                solver.estimate().iter().all(|v| v.is_finite()),
                "{} should run in-link-free",
                spec.key()
            );
        }
        assert!(SolverSpec::GreedyMp.needs_in_links());
        assert!(SolverSpec::YouTempoQiu.needs_in_links());
        assert!(SolverSpec::LeiChen.needs_in_links());
        assert!(SolverSpec::parse("msgpass:2:8:mod").expect("ok").needs_in_links());
        assert!(!SolverSpec::Mp.needs_in_links());
        assert!(!SolverSpec::IshiiTempo.needs_in_links());
    }

    #[test]
    fn sharded_adapter_reports_batch_stats_and_conflicts() {
        // Dense paper graph: batches conflict, so the adapter must count
        // both applied activations and dropped candidates — under either
        // packing policy.
        for packer in [Packer::Leader, Packer::Worker] {
            let g = generators::er_threshold(40, 0.5, 33);
            let mut sh =
                ShardedSolver::new(&g, 0.85, 2, 16, ShardMap::Modulo, packer, Sampling::Uniform);
            let mut rng = Rng::seeded(34);
            let mut activated = 0;
            for _ in 0..50 {
                let st = sh.step(&mut rng);
                assert_eq!(st.reads, st.writes, "{packer:?}");
                activated += st.activated;
            }
            assert!(activated > 0, "{packer:?}");
            assert!(sh.conflicts() > 0, "{packer:?}: dense graphs must drop candidates");
            assert_eq!(sh.runtime().activations(), activated as u64, "{packer:?}");
        }
    }

    #[test]
    fn build_produces_working_solvers() {
        let g = generators::er_threshold(15, 0.5, 31);
        let x_star = exact_pagerank(&g, 0.85);
        for spec in SolverSpec::all() {
            let mut solver = spec.build(&g, 0.85, 9);
            assert_eq!(solver.n(), 15, "{}", spec.key());
            let before = solver.error_sq_vs(&x_star);
            let mut rng = Rng::seeded(10);
            for _ in 0..400 {
                solver.step(&mut rng);
            }
            let after = solver.error_sq_vs(&x_star);
            assert!(
                after < before,
                "{} made no progress: {before} -> {after}",
                spec.key()
            );
        }
    }

    #[test]
    fn coordinator_adapter_counts_communication() {
        let g = generators::er_threshold(12, 0.5, 32);
        let spec = SolverSpec::sequential_coordinator();
        let mut solver = spec.build(&g, 0.85, 5);
        let mut rng = Rng::seeded(6);
        let stats = solver.step(&mut rng);
        assert_eq!(stats.activated, 1);
        assert!(stats.reads > 0, "an ER-threshold activation touches neighbours");
        // No self-loops in the ER-threshold model, so every read pairs
        // with a wire write (§II-D).
        assert_eq!(stats.reads, stats.writes);
    }

    #[test]
    fn from_spec_rejects_non_coordinator() {
        let g = generators::ring(5);
        assert!(CoordinatorSolver::from_spec(&g, 0.85, 1, &SolverSpec::Mp).is_err());
        assert!(CoordinatorSolver::from_spec(
            &g,
            0.85,
            1,
            &SolverSpec::sequential_coordinator()
        )
        .is_ok());
    }
}
