//! Scenario results: per-solver averaged trajectories, fitted decay
//! rates, communication totals and wall time — renderable for terminals,
//! CSV for plotting, and machine-readable JSON for the perf trajectory
//! (`BENCH_scenario.json`).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::algo::common::StepStats;
use crate::harness::experiment::AveragedTrajectory;
use crate::harness::{plot, report as harness_report};
use crate::util::json::Json;

use super::scenario::Scenario;
use super::solver_spec::SolverSpec;

/// One solver's result inside a scenario run.
#[derive(Debug, Clone)]
pub struct SolverReport {
    pub spec: SolverSpec,
    /// Cross-round averaged error trajectory (Fig.-1 axis).
    pub trajectory: AveragedTrajectory,
    /// Communication totals summed over all rounds.
    pub total_stats: StepStats,
    /// Fitted per-activation decay rate of the mean error (0 when the
    /// trajectory converged below the noise floor too fast to fit).
    pub decay_rate: f64,
    /// Final mean error `(1/N)‖x - x*‖²`.
    pub final_error: f64,
    /// Wall-clock time for all rounds of this solver.
    pub wall: Duration,
}

/// Everything a [`Scenario::run`] produces.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub scenario: Scenario,
    pub reports: Vec<SolverReport>,
}

impl ScenarioReport {
    /// Look up a solver's report by registry key.
    pub fn get(&self, key: &str) -> Option<&SolverReport> {
        self.reports.iter().find(|r| r.spec.key() == key)
    }

    /// Solver keys ordered by fitted decay rate, fastest (smallest rate)
    /// first — the Fig.-1 ordering check.
    pub fn rate_ordering(&self) -> Vec<(String, f64)> {
        let mut rates: Vec<(String, f64)> = self
            .reports
            .iter()
            .map(|r| (r.spec.key(), r.decay_rate))
            .collect();
        rates.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("rates are finite"));
        rates
    }

    /// Terminal rendering: semilogy plot of every trajectory plus a
    /// per-solver summary table.
    pub fn render(&self) -> String {
        let glyphs = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
        let series: Vec<plot::Series> = self
            .reports
            .iter()
            .enumerate()
            .map(|(i, r)| plot::Series {
                label: r.trajectory.name.clone(),
                xs: r.trajectory.ts.iter().map(|&t| t as f64).collect(),
                ys: r.trajectory.mean.clone(),
                glyph: glyphs[i % glyphs.len()],
            })
            .collect();
        let title = format!(
            "{} — (1/N)‖x_t - x*‖² on {}, α={}, {} rounds",
            self.scenario.name,
            self.scenario.graph.key(),
            self.scenario.alpha,
            self.scenario.rounds
        );
        let plot = plot::semilogy(&series, 72, 20, &title);
        let rows: Vec<Vec<String>> = self
            .reports
            .iter()
            .map(|r| {
                vec![
                    r.spec.key(),
                    format!("{:.3e}", r.final_error),
                    format!("{:.6}", r.decay_rate),
                    r.total_stats.reads.to_string(),
                    r.total_stats.writes.to_string(),
                    format!("{:.0}", r.wall.as_secs_f64() * 1e3),
                ]
            })
            .collect();
        let table = harness_report::table(
            &["solver", "final (1/N)|x-x*|²", "rate/step", "reads", "writes", "wall ms"],
            &rows,
        );
        format!("{plot}\n{table}")
    }

    /// CSV of every averaged trajectory (same shape as the Fig.-1 CSV).
    pub fn to_csv(&self) -> String {
        let trajectories: Vec<AveragedTrajectory> =
            self.reports.iter().map(|r| r.trajectory.clone()).collect();
        harness_report::trajectories_csv(&trajectories)
    }

    /// Machine-readable summary: scenario config plus per-solver final
    /// error, decay rate, communication totals and wall time.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("scenario".to_string(), self.scenario.to_json());
        m.insert(
            "solvers".to_string(),
            Json::Array(
                self.reports
                    .iter()
                    .map(|r| {
                        let mut s = BTreeMap::new();
                        s.insert("name".to_string(), Json::String(r.spec.key()));
                        s.insert("final_error".to_string(), Json::Number(r.final_error));
                        s.insert("decay_rate".to_string(), Json::Number(r.decay_rate));
                        s.insert(
                            "reads".to_string(),
                            Json::Number(r.total_stats.reads as f64),
                        );
                        s.insert(
                            "writes".to_string(),
                            Json::Number(r.total_stats.writes as f64),
                        );
                        s.insert(
                            "activated".to_string(),
                            Json::Number(r.total_stats.activated as f64),
                        );
                        s.insert(
                            "wall_ms".to_string(),
                            Json::Number(r.wall.as_secs_f64() * 1e3),
                        );
                        Json::Object(s)
                    })
                    .collect(),
            ),
        );
        Json::Object(m)
    }

    /// Dump [`ScenarioReport::to_json`] to disk — the perf-trajectory
    /// artifact (`BENCH_scenario.json` at the repo root by convention).
    pub fn write_bench_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        harness_report::write_file(path, &self.to_json().render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{GraphSpec, Scenario};

    fn small_report() -> ScenarioReport {
        Scenario::new("report-test", GraphSpec::paper(12))
            .with_solvers(vec![SolverSpec::Mp, SolverSpec::IshiiTempo])
            .with_steps(400)
            .with_stride(100)
            .with_rounds(2)
            .with_threads(1)
            .with_seed(3)
            .run()
            .expect("small scenario runs")
    }

    #[test]
    fn lookup_render_and_csv() {
        let rep = small_report();
        assert!(rep.get("mp").is_some());
        assert!(rep.get("nope").is_none());
        let txt = rep.render();
        assert!(txt.contains("report-test"));
        assert!(txt.contains("rate/step"));
        let csv = rep.to_csv();
        assert!(csv.starts_with("t,mp_mean,mp_var,ishii-tempo_mean"));
    }

    #[test]
    fn rate_ordering_sorted() {
        let rep = small_report();
        let rates = rep.rate_ordering();
        assert_eq!(rates.len(), 2);
        assert!(rates[0].1 <= rates[1].1);
        // MP is exponential, the averaging baseline is not: MP leads.
        assert_eq!(rates[0].0, "mp");
    }

    #[test]
    fn bench_json_shape() {
        let rep = small_report();
        let v = rep.to_json();
        let text = v.render();
        let parsed = Json::parse(&text).expect("valid json");
        let solvers = parsed.get("solvers").and_then(Json::as_array).expect("solvers");
        assert_eq!(solvers.len(), 2);
        assert_eq!(solvers[0].get("name").and_then(Json::as_str), Some("mp"));
        assert!(solvers[0].get("final_error").and_then(Json::as_f64).is_some());
        assert!(solvers[0].get("reads").and_then(Json::as_usize).expect("reads") > 0);
        assert!(parsed.get("scenario").and_then(|s| s.get("graph")).is_some());
    }

    #[test]
    fn bench_json_written_to_disk() {
        let rep = small_report();
        let dir = std::env::temp_dir().join("pagerank_mp_engine_test");
        let path = dir.join("BENCH_scenario.json");
        rep.write_bench_json(&path).expect("writes");
        let text = std::fs::read_to_string(&path).expect("readable");
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
