//! Scenario results: per-run averaged trajectories, fitted decay rates,
//! communication totals and wall time — renderable for terminals, CSV
//! for plotting, and machine-readable JSON for the perf trajectory
//! (`BENCH_scenario.json`).
//!
//! The report is polymorphic over the experiment kind: a PageRank
//! scenario yields [`SolverReport`]s (error vs `x*`, conflicts), a
//! size-estimation scenario yields [`EstimatorReport`]s (error vs
//! `𝟙/N` plus the relative-size-error trajectory); both share the
//! graph/seed/shape metadata, wall clocks and the rendering surfaces.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::algo::common::StepStats;
use crate::harness::experiment::AveragedTrajectory;
use crate::harness::{plot, report as harness_report};
use crate::util::json::Json;
use crate::util::stats;

use super::experiment_spec::EstimatorSpec;
use super::scenario::Scenario;
use super::solver_spec::SolverSpec;

/// One solver's result inside a scenario run.
#[derive(Debug, Clone)]
pub struct SolverReport {
    pub spec: SolverSpec,
    /// Cross-round averaged error trajectory (Fig.-1 axis).
    pub trajectory: AveragedTrajectory,
    /// Communication totals summed over all rounds.
    pub total_stats: StepStats,
    /// Fitted per-activation decay rate of the mean error (see
    /// [`fitted_decay`]): `NaN` when the trajectory has no fittable
    /// samples — it diverged to non-finite error or sits exactly at
    /// zero. NaN sorts last in [`ScenarioReport::rate_ordering`] and
    /// renders as `null` in the bench JSON.
    pub decay_rate: f64,
    /// Final mean error `(1/N)‖x - x*‖²`.
    pub final_error: f64,
    /// Candidates dropped by conflict-free packing, summed over rounds —
    /// nonzero only for the sharded backend (its effective-parallelism
    /// cost; 0 for every other solver).
    pub conflicts: u64,
    /// Fault-injection ledger absorbed over rounds — counters sum, the
    /// crash-divergence gauge maxes; all-zero for every solver that ran
    /// on an ideal network (and omitted from the bench JSON then).
    pub faults: crate::network::FaultCounters,
    /// Shard-locality ledger absorbed over rounds — the intra/cross
    /// conflict split (sharded worker packing), cross-shard wire counts
    /// (msgpass) and the resolved map's static cross-edge fraction;
    /// all-zero for single-shard and non-sharded solvers (and omitted
    /// from the bench JSON then).
    pub locality: crate::coordinator::LocalityCounters,
    /// Wall-clock time for all rounds of this solver.
    pub wall: Duration,
}

/// Fit a per-activation decay rate on the tail of an averaged
/// trajectory, cutting both the initial transient and the
/// floating-point noise floor (a converged trajectory flattens near
/// ~1e-30 and would bias the fit toward 1).
///
/// NaN-safe by construction (the fit itself is the shared
/// [`stats::decay_rate_above`]): non-finite and zero samples never
/// reach `ln`, and any trajectory with non-finite samples — a diverged
/// solver — yields `f64::NAN` outright, never a rate that would rank it
/// "fastest". For fully-finite trajectories whose tail converged below
/// the floor too fast to leave two fittable points (the dense backend
/// at small N), the transient from t=0 is fitted instead — that is
/// where a fast solver's rate lives. Callers sort NaN last and
/// serialize it as `null`.
pub fn fitted_decay(mean: &[f64], stride: usize) -> f64 {
    assert!(stride > 0);
    if !mean.iter().all(|v| v.is_finite()) {
        return f64::NAN; // diverged: a finite prefix must not rank it
    }
    let tail_fit = fit_above_floor(&mean[mean.len() / 5..], stride);
    if !tail_fit.is_nan() {
        return tail_fit;
    }
    // Converged-too-fast fallback: fit the transient from t=0.
    fit_above_floor(mean, stride)
}

fn fit_above_floor(samples: &[f64], stride: usize) -> f64 {
    const NOISE_FLOOR: f64 = 1e-26;
    // NaN.powf(_) stays NaN, so degenerate fits propagate unchanged.
    stats::decay_rate_above(samples, NOISE_FLOOR).powf(1.0 / stride as f64)
}

/// Table spelling of a fitted decay rate; NaN (unfittable, see
/// [`fitted_decay`]) renders as "n/a". Shared by the scenario and sweep
/// summary tables so the convention cannot drift between them.
pub(crate) fn render_rate(rate: f64) -> String {
    if rate.is_nan() {
        "n/a".to_string()
    } else {
        format!("{rate:.6}")
    }
}

/// The summary fields every run kind shares in the BENCH JSON.
fn summary_common(
    key: &str,
    final_error: f64,
    decay_rate: f64,
    total_stats: StepStats,
    wall: Duration,
) -> BTreeMap<String, Json> {
    let mut s = BTreeMap::new();
    s.insert("name".to_string(), Json::String(key.to_string()));
    s.insert("final_error".to_string(), Json::Number(final_error));
    // NaN renders as null (JSON has no NaN).
    s.insert("decay_rate".to_string(), Json::Number(decay_rate));
    s.insert("reads".to_string(), Json::Number(total_stats.reads as f64));
    s.insert("writes".to_string(), Json::Number(total_stats.writes as f64));
    s.insert(
        "activated".to_string(),
        Json::Number(total_stats.activated as f64),
    );
    s.insert("wall_ms".to_string(), Json::Number(wall.as_secs_f64() * 1e3));
    s
}

/// One estimator's result inside a size-estimation scenario run.
#[derive(Debug, Clone)]
pub struct EstimatorReport {
    pub spec: EstimatorSpec,
    /// Cross-round averaged `‖s_t - 𝟙/N‖²` trajectory (Fig.-2 axis).
    pub trajectory: AveragedTrajectory,
    /// Cross-round averaged mean relative size error `|N̂_i - N|/N`,
    /// sampled on the same stride — the metric estimators race on.
    pub size_rel_err: AveragedTrajectory,
    /// Communication totals summed over all rounds.
    pub total_stats: StepStats,
    /// Fitted per-activation decay rate of the mean squared error (same
    /// semantics as [`SolverReport::decay_rate`]).
    pub decay_rate: f64,
    /// Final mean `‖s - 𝟙/N‖²`.
    pub final_error: f64,
    /// Final mean relative size error — the headline Fig.-2 number and
    /// the metric `bench_diff` tracks for estimation runs.
    pub final_size_rel_err: f64,
    /// Wall-clock time for all rounds of this estimator.
    pub wall: Duration,
}

/// The kind-specific half of a [`ScenarioReport`].
#[derive(Debug, Clone)]
pub enum ExperimentReports {
    PageRank(Vec<SolverReport>),
    SizeEstimation(Vec<EstimatorReport>),
}

/// Everything a [`Scenario::run`] produces.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub scenario: Scenario,
    pub runs: ExperimentReports,
}

impl ScenarioReport {
    /// Number of runs (solvers or estimators) in the report.
    pub fn len(&self) -> usize {
        match &self.runs {
            ExperimentReports::PageRank(v) => v.len(),
            ExperimentReports::SizeEstimation(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The PageRank solver reports (empty slice for other kinds).
    pub fn solver_reports(&self) -> &[SolverReport] {
        match &self.runs {
            ExperimentReports::PageRank(v) => v,
            ExperimentReports::SizeEstimation(_) => &[],
        }
    }

    /// The size-estimator reports (empty slice for other kinds).
    pub fn estimator_reports(&self) -> &[EstimatorReport] {
        match &self.runs {
            ExperimentReports::SizeEstimation(v) => v,
            ExperimentReports::PageRank(_) => &[],
        }
    }

    /// Look up a solver's report by registry key.
    pub fn get(&self, key: &str) -> Option<&SolverReport> {
        self.solver_reports().iter().find(|r| r.spec.key() == key)
    }

    /// Look up an estimator's report by registry key.
    pub fn get_estimator(&self, key: &str) -> Option<&EstimatorReport> {
        self.estimator_reports().iter().find(|r| r.spec.key() == key)
    }

    /// Run keys ordered by fitted decay rate, fastest (smallest rate)
    /// first — the Fig.-1 ordering check, equally meaningful for the
    /// Fig.-2 estimator race. `NaN` rates (diverged or zero-error
    /// trajectories, see [`fitted_decay`]) sort last instead of
    /// panicking, so one diverged run cannot spoil the ranking.
    pub fn rate_ordering(&self) -> Vec<(String, f64)> {
        let mut rates: Vec<(String, f64)> = match &self.runs {
            ExperimentReports::PageRank(v) => {
                v.iter().map(|r| (r.spec.key(), r.decay_rate)).collect()
            }
            ExperimentReports::SizeEstimation(v) => {
                v.iter().map(|r| (r.spec.key(), r.decay_rate)).collect()
            }
        };
        // total_cmp orders every NaN after +inf, i.e. last.
        rates.sort_by(|a, b| a.1.total_cmp(&b.1));
        rates
    }

    /// Terminal rendering: semilogy plot of every trajectory plus a
    /// per-run summary table with kind-specific columns.
    pub fn render(&self) -> String {
        let glyphs = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
        let mk_series = |i: usize, tr: &AveragedTrajectory| plot::Series {
            label: tr.name.clone(),
            xs: tr.ts.iter().map(|&t| t as f64).collect(),
            ys: tr.mean.clone(),
            glyph: glyphs[i % glyphs.len()],
        };
        match &self.runs {
            ExperimentReports::PageRank(reports) => {
                let series: Vec<plot::Series> = reports
                    .iter()
                    .enumerate()
                    .map(|(i, r)| mk_series(i, &r.trajectory))
                    .collect();
                let title = format!(
                    "{} — (1/N)‖x_t - x*‖² on {}, α={}, {} rounds",
                    self.scenario.name,
                    self.scenario.graph.key(),
                    self.scenario.alpha,
                    self.scenario.rounds
                );
                let plot = plot::semilogy(&series, 72, 20, &title);
                let rows: Vec<Vec<String>> = reports
                    .iter()
                    .map(|r| {
                        vec![
                            r.spec.key(),
                            format!("{:.3e}", r.final_error),
                            render_rate(r.decay_rate),
                            r.total_stats.reads.to_string(),
                            r.total_stats.writes.to_string(),
                            r.total_stats.activated.to_string(),
                            r.conflicts.to_string(),
                            format!("{:.0}", r.wall.as_secs_f64() * 1e3),
                        ]
                    })
                    .collect();
                let table = harness_report::table(
                    &[
                        "solver",
                        "final (1/N)|x-x*|²",
                        "rate/step",
                        "reads",
                        "writes",
                        "activated",
                        "conflicts",
                        "wall ms",
                    ],
                    &rows,
                );
                format!("{plot}\n{table}")
            }
            ExperimentReports::SizeEstimation(reports) => {
                let series: Vec<plot::Series> = reports
                    .iter()
                    .enumerate()
                    .map(|(i, r)| mk_series(i, &r.trajectory))
                    .collect();
                let title = format!(
                    "{} — ‖s_t - 𝟙/N‖² on {}, {} rounds",
                    self.scenario.name,
                    self.scenario.graph.key(),
                    self.scenario.rounds
                );
                let plot = plot::semilogy(&series, 72, 20, &title);
                let rows: Vec<Vec<String>> = reports
                    .iter()
                    .map(|r| {
                        vec![
                            r.spec.key(),
                            format!("{:.3e}", r.final_error),
                            render_rate(r.decay_rate),
                            format!("{:.3e}", r.final_size_rel_err),
                            r.total_stats.reads.to_string(),
                            r.total_stats.writes.to_string(),
                            r.total_stats.activated.to_string(),
                            format!("{:.0}", r.wall.as_secs_f64() * 1e3),
                        ]
                    })
                    .collect();
                let table = harness_report::table(
                    &[
                        "estimator",
                        "final |s-1/N|²",
                        "rate/step",
                        "rel size err",
                        "reads",
                        "writes",
                        "activated",
                        "wall ms",
                    ],
                    &rows,
                );
                format!("{plot}\n{table}")
            }
        }
    }

    /// CSV of every averaged trajectory (same shape as the Fig.-1 CSV;
    /// size-estimation scenarios append the relative-size-error
    /// trajectories after the error trajectories).
    pub fn to_csv(&self) -> String {
        let trajectories: Vec<AveragedTrajectory> = match &self.runs {
            ExperimentReports::PageRank(v) => v.iter().map(|r| r.trajectory.clone()).collect(),
            ExperimentReports::SizeEstimation(v) => v
                .iter()
                .map(|r| r.trajectory.clone())
                .chain(v.iter().map(|r| r.size_rel_err.clone()))
                .collect(),
        };
        harness_report::trajectories_csv(&trajectories)
    }

    /// The per-run summary array shared by `BENCH_scenario.json` and the
    /// merged `BENCH_sweep.json` cells, with the JSON field it belongs
    /// under (`"solvers"` or `"estimators"`).
    pub fn run_summaries(&self) -> (&'static str, Json) {
        match &self.runs {
            ExperimentReports::PageRank(reports) => {
                let arr = reports
                    .iter()
                    .map(|r| {
                        let mut s = summary_common(
                            &r.spec.key(),
                            r.final_error,
                            r.decay_rate,
                            r.total_stats,
                            r.wall,
                        );
                        s.insert("conflicts".to_string(), Json::Number(r.conflicts as f64));
                        // Fault fields appear only for runs that saw (or
                        // could have seen) faults, so ideal-network BENCH
                        // documents keep their exact historical shape.
                        if r.faults.any() {
                            let f = &r.faults;
                            s.insert(
                                "messages_dropped".to_string(),
                                Json::Number(f.messages_dropped as f64),
                            );
                            s.insert(
                                "duplicates_suppressed".to_string(),
                                Json::Number(f.duplicates_suppressed as f64),
                            );
                            s.insert(
                                "retransmits".to_string(),
                                Json::Number(f.retransmits as f64),
                            );
                            s.insert(
                                "recoveries".to_string(),
                                Json::Number(f.recoveries as f64),
                            );
                            s.insert(
                                "residual_divergence_at_crash".to_string(),
                                Json::Number(f.residual_divergence_at_crash),
                            );
                            s.insert(
                                "link_downs".to_string(),
                                Json::Number(f.link_downs as f64),
                            );
                            s.insert(
                                "partitions_healed".to_string(),
                                Json::Number(f.partitions_healed as f64),
                            );
                            s.insert(
                                "rtt_estimate".to_string(),
                                Json::Number(f.rtt_estimate),
                            );
                        }
                        // Locality fields likewise appear only for runs
                        // with a shard boundary to measure, keeping
                        // single-shard and non-sharded summaries in
                        // their historical shape.
                        if r.locality.any() {
                            let l = &r.locality;
                            s.insert(
                                "intra_conflicts".to_string(),
                                Json::Number(l.intra_conflicts as f64),
                            );
                            s.insert(
                                "cross_conflicts".to_string(),
                                Json::Number(l.cross_conflicts as f64),
                            );
                            s.insert(
                                "cross_edge_fraction".to_string(),
                                Json::Number(l.cross_edge_fraction),
                            );
                            s.insert(
                                "cross_messages".to_string(),
                                Json::Number(l.cross_messages as f64),
                            );
                            s.insert(
                                "cross_bytes".to_string(),
                                Json::Number(l.cross_bytes as f64),
                            );
                            s.insert(
                                "subscriber_shard_sum".to_string(),
                                Json::Number(l.subscriber_shard_sum as f64),
                            );
                        }
                        Json::Object(s)
                    })
                    .collect();
                ("solvers", Json::Array(arr))
            }
            ExperimentReports::SizeEstimation(reports) => {
                let arr = reports
                    .iter()
                    .map(|r| {
                        let mut s = summary_common(
                            &r.spec.key(),
                            r.final_error,
                            r.decay_rate,
                            r.total_stats,
                            r.wall,
                        );
                        s.insert(
                            "final_size_rel_err".to_string(),
                            Json::Number(r.final_size_rel_err),
                        );
                        Json::Object(s)
                    })
                    .collect();
                ("estimators", Json::Array(arr))
            }
        }
    }

    /// Machine-readable summary: scenario config plus per-run final
    /// error, decay rate, communication totals, kind-specific metrics
    /// and wall time.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("scenario".to_string(), self.scenario.to_json());
        let (field, summaries) = self.run_summaries();
        m.insert(field.to_string(), summaries);
        Json::Object(m)
    }

    /// Dump [`ScenarioReport::to_json`] to disk — the perf-trajectory
    /// artifact (`BENCH_scenario.json` at the repo root by convention).
    pub fn write_bench_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        harness_report::write_file(path, &self.to_json().render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{GraphSpec, Scenario};

    fn small_report() -> ScenarioReport {
        Scenario::new("report-test", GraphSpec::paper(12))
            .with_solvers(vec![SolverSpec::Mp, SolverSpec::IshiiTempo])
            .with_steps(400)
            .with_stride(100)
            .with_rounds(2)
            .with_threads(1)
            .with_seed(3)
            .run()
            .expect("small scenario runs")
    }

    #[test]
    fn lookup_render_and_csv() {
        let rep = small_report();
        assert!(rep.get("mp").is_some());
        assert!(rep.get("nope").is_none());
        let txt = rep.render();
        assert!(txt.contains("report-test"));
        assert!(txt.contains("rate/step"));
        let csv = rep.to_csv();
        assert!(csv.starts_with("t,mp_mean,mp_var,ishii-tempo_mean"));
    }

    #[test]
    fn rate_ordering_sorted() {
        let rep = small_report();
        let rates = rep.rate_ordering();
        assert_eq!(rates.len(), 2);
        assert!(rates[0].1 <= rates[1].1);
        // MP is exponential, the averaging baseline is not: MP leads.
        assert_eq!(rates[0].0, "mp");
    }

    #[test]
    fn bench_json_shape() {
        let rep = small_report();
        let v = rep.to_json();
        let text = v.render();
        let parsed = Json::parse(&text).expect("valid json");
        let solvers = parsed.get("solvers").and_then(Json::as_array).expect("solvers");
        assert_eq!(solvers.len(), 2);
        assert_eq!(solvers[0].get("name").and_then(Json::as_str), Some("mp"));
        assert!(solvers[0].get("final_error").and_then(Json::as_f64).is_some());
        assert!(solvers[0].get("reads").and_then(Json::as_usize).expect("reads") > 0);
        assert!(solvers[0].get("conflicts").is_some(), "conflicts column missing");
        assert!(parsed.get("scenario").and_then(|s| s.get("graph")).is_some());
    }

    #[test]
    fn bench_json_gains_fault_fields_only_for_faulted_runs() {
        let rep = Scenario::new("fault-report", GraphSpec::paper(12))
            .with_solvers(vec![
                SolverSpec::parse("msgpass:2:4").expect("plain msgpass"),
                SolverSpec::parse("msgpass:2:4:mod:drop0.3:rel").expect("faulted msgpass"),
            ])
            .with_steps(200)
            .with_stride(100)
            .with_rounds(1)
            .with_threads(1)
            .with_seed(9)
            .run()
            .expect("fault scenario runs");
        let parsed = Json::parse(&rep.to_json().render()).expect("valid json");
        let solvers = parsed.get("solvers").and_then(Json::as_array).expect("solvers");
        assert_eq!(solvers.len(), 2);
        let plain = &solvers[0];
        let faulted = &solvers[1];
        assert_eq!(plain.get("name").and_then(Json::as_str), Some("msgpass:2:4"));
        assert!(
            plain.get("messages_dropped").is_none(),
            "ideal-network runs keep the historical summary shape"
        );
        assert_eq!(
            faulted.get("name").and_then(Json::as_str),
            Some("msgpass:2:4:mod:drop0.3:rel")
        );
        for field in [
            "messages_dropped",
            "duplicates_suppressed",
            "retransmits",
            "recoveries",
            "residual_divergence_at_crash",
            "link_downs",
            "partitions_healed",
            "rtt_estimate",
        ] {
            assert!(
                faulted.get(field).and_then(Json::as_f64).is_some(),
                "faulted run missing {field}"
            );
        }
        assert!(
            faulted.get("messages_dropped").and_then(Json::as_usize).expect("dropped") > 0,
            "a 30% drop plan must drop something"
        );
    }

    #[test]
    fn bench_json_gains_locality_fields_only_for_sharded_runs() {
        let rep = Scenario::new("locality-report", GraphSpec::paper(12))
            .with_solvers(vec![
                SolverSpec::Mp,
                SolverSpec::parse("sharded:2:8:mod:worker").expect("sharded"),
                SolverSpec::parse("msgpass:2:4:cluster").expect("msgpass"),
            ])
            .with_steps(200)
            .with_stride(100)
            .with_rounds(1)
            .with_threads(1)
            .with_seed(11)
            .run()
            .expect("locality scenario runs");
        let parsed = Json::parse(&rep.to_json().render()).expect("valid json");
        let solvers = parsed.get("solvers").and_then(Json::as_array).expect("solvers");
        assert_eq!(solvers.len(), 3);
        assert!(
            solvers[0].get("cross_conflicts").is_none(),
            "mp keeps the historical summary shape"
        );
        for (i, fields) in [
            (1, &["intra_conflicts", "cross_conflicts", "cross_edge_fraction"][..]),
            (2, &["cross_messages", "cross_bytes", "subscriber_shard_sum"][..]),
        ] {
            for field in fields {
                assert!(
                    solvers[i].get(field).and_then(Json::as_f64).is_some(),
                    "solver {i} missing {field}"
                );
            }
        }
        assert!(
            solvers[2].get("cross_messages").and_then(Json::as_usize).expect("msgs") > 0,
            "a 2-shard msgpass run must cross the wire"
        );
    }

    #[test]
    fn fitted_decay_recovers_geometric_rate_and_skips_zeros() {
        let geometric: Vec<f64> = (0..20).map(|i| 0.5f64.powi(i)).collect();
        assert!((fitted_decay(&geometric, 1) - 0.5).abs() < 1e-9);
        // A zero sample inside the tail (exactly-converged entry) is
        // skipped, not fed to ln().
        let mut with_zero = geometric.clone();
        with_zero[9] = 0.0;
        assert!((fitted_decay(&with_zero, 1) - 0.5).abs() < 1e-6);
        // Stride accounting: stride-th root of the per-record rate.
        let per_step = fitted_decay(&geometric, 10);
        assert!((per_step - 0.5f64.powf(0.1)).abs() < 1e-9);
    }

    #[test]
    fn fitted_decay_is_nan_safe_on_degenerate_trajectories() {
        // All-zero (instant convergence) and all-non-finite (divergence)
        // must both yield NaN — never 0, which would rank as "fastest".
        assert!(fitted_decay(&[0.0; 6], 10).is_nan());
        assert!(fitted_decay(&[f64::INFINITY; 6], 1).is_nan());
        assert!(fitted_decay(&[f64::NAN; 6], 1).is_nan());
        // Diverged mid-run: the healthy-looking finite prefix must NOT
        // ride the transient fallback to a finite rate — a solver that
        // blew up can never outrank one that converged.
        let mut diverged = vec![1.0, 0.5, 0.25];
        diverged.extend(std::iter::repeat(f64::INFINITY).take(12));
        assert!(fitted_decay(&diverged, 1).is_nan());
    }

    #[test]
    fn fitted_decay_fast_convergence_falls_back_to_transient() {
        // A solver that crosses the noise floor within two records (the
        // dense backend at small N): the tail holds < 2 fittable points,
        // but the transient still encodes the rate — 1e-10 per record.
        let traj = [1.0, 1e-10, 1e-30, 0.0, 0.0];
        let rate = fitted_decay(&traj, 1);
        assert!(
            (rate.log10() + 10.0).abs() < 1e-6,
            "transient fallback should see rate 1e-10, got {rate}"
        );
    }

    #[test]
    fn rate_ordering_puts_nan_last() {
        let mut rep = small_report();
        if let ExperimentReports::PageRank(reports) = &mut rep.runs {
            reports[0].decay_rate = f64::NAN; // pretend mp diverged
        }
        let rates = rep.rate_ordering();
        assert_eq!(rates.len(), 2);
        assert!(rates[0].1.is_finite(), "finite rate must lead");
        assert!(rates[1].1.is_nan(), "NaN must sort last");
        // And the render degrades gracefully instead of panicking.
        assert!(rep.render().contains("n/a"));
    }

    #[test]
    fn size_estimation_report_renders_and_serializes() {
        let rep = Scenario::new("se-report", GraphSpec::paper(15))
            .with_estimators(vec![EstimatorSpec::Kaczmarz, EstimatorSpec::RandomWalk])
            .with_steps(600)
            .with_stride(200)
            .with_rounds(2)
            .with_threads(1)
            .with_seed(4)
            .run()
            .expect("size-estimation scenario runs");
        assert!(rep.get_estimator("kaczmarz").is_some());
        assert!(rep.get_estimator("degree").is_none());
        assert!(rep.get("mp").is_none(), "no solver reports in a Fig.-2 run");
        let txt = rep.render();
        assert!(txt.contains("se-report"));
        assert!(txt.contains("rel size err"));
        let csv = rep.to_csv();
        assert!(csv.starts_with("t,kaczmarz_mean"), "{csv}");
        assert!(csv.contains("kaczmarz_relerr_mean"), "rel-err trajectory in the CSV");

        let parsed = Json::parse(&rep.to_json().render()).expect("valid json");
        let ests = parsed.get("estimators").and_then(Json::as_array).expect("estimators");
        assert_eq!(ests.len(), 2);
        assert_eq!(ests[0].get("name").and_then(Json::as_str), Some("kaczmarz"));
        assert!(ests[0].get("final_size_rel_err").and_then(Json::as_f64).is_some());
        assert!(parsed.get("solvers").is_none(), "no solvers key in estimation BENCH");
    }

    #[test]
    fn bench_json_written_to_disk() {
        let rep = small_report();
        let dir = std::env::temp_dir().join("pagerank_mp_engine_test");
        let path = dir.join("BENCH_scenario.json");
        rep.write_bench_json(&path).expect("writes");
        let text = std::fs::read_to_string(&path).expect("readable");
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
