//! **Algorithm 2** (Appendix) — distributed network-size estimation.
//!
//! Randomized row-projection (Kaczmarz) iteration on `C = (I - A)ᵀ`:
//! `s_{t+1} = s_t - (C(k,:) s_t / ‖C(k,:)‖²) C(k,:)ᵀ` (eq. 14), started at
//! `s_0 = e_1`. Because `C(k,:) = (e_k - A(:,k))ᵀ`, each update touches
//! only page `k` and its out-neighbours — the same communication pattern
//! as Algorithm 1. The iterate converges to the uniform stationary vector
//! `s = 𝟙/N`, and each page then estimates `N ≈ 1/s_i`.
//!
//! Requires strong connectivity (nullspace of C must be 1-dimensional);
//! construction fails loudly otherwise via [`SizeEstimationError`].

use crate::graph::scc::is_strongly_connected;
use crate::graph::Graph;
use crate::util::rng::Rng;

use super::common::StepStats;

/// Error cases for the estimator.
#[derive(Debug, PartialEq, Eq)]
pub enum SizeEstimationError {
    /// The graph is not strongly connected, so `s` is not unique.
    NotStronglyConnected,
    /// Empty graph.
    Empty,
}

impl std::fmt::Display for SizeEstimationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SizeEstimationError::NotStronglyConnected => {
                write!(f, "Algorithm 2 requires a strongly connected graph (Appendix assumption)")
            }
            SizeEstimationError::Empty => write!(f, "cannot size-estimate an empty graph"),
        }
    }
}

impl std::error::Error for SizeEstimationError {}

/// Row geometry of `C = (I-A)ᵀ`: per-row squared norms (`‖C(k,:)‖² =
/// 1 - 2A_kk + 1/N_k`, the α=1 analogue of Remark 3).
#[derive(Debug, Clone)]
struct CRows {
    norms_sq: Vec<f64>,
    inv_out_deg: Vec<f64>,
}

impl CRows {
    fn new(g: &Graph) -> CRows {
        let n = g.n();
        let mut norms_sq = Vec::with_capacity(n);
        let mut inv_out_deg = Vec::with_capacity(n);
        for k in 0..n {
            let deg = g.out_degree(k);
            assert!(deg > 0, "dangling page {k}");
            let nk = deg as f64;
            let akk = if g.has_self_loop(k) { 1.0 / nk } else { 0.0 };
            // ‖e_k - A(:,k)‖² = 1 - 2 A_kk + Σ (1/N_k)² over out(k) = 1 - 2A_kk + 1/N_k
            norms_sq.push(1.0 - 2.0 * akk + 1.0 / nk);
            inv_out_deg.push(1.0 / nk);
        }
        CRows { norms_sq, inv_out_deg }
    }
}

/// Algorithm 2 runner.
#[derive(Debug, Clone)]
pub struct SizeEstimator<'g> {
    graph: &'g Graph,
    rows: CRows,
    s: Vec<f64>,
    t: u64,
}

impl<'g> SizeEstimator<'g> {
    /// Create with the paper's initialization `s_0 = [1, 0, …, 0]`.
    pub fn new(graph: &'g Graph) -> Result<Self, SizeEstimationError> {
        if graph.n() == 0 {
            return Err(SizeEstimationError::Empty);
        }
        if !is_strongly_connected(graph) {
            return Err(SizeEstimationError::NotStronglyConnected);
        }
        let mut s = vec![0.0; graph.n()];
        s[0] = 1.0;
        Ok(SizeEstimator {
            rows: CRows::new(graph),
            graph,
            s,
            t: 0,
        })
    }

    /// One eq. 14 update at a given page `k`; touches `{k} ∪ out(k)` only.
    pub fn step_at(&mut self, k: usize) -> f64 {
        let g = self.graph;
        // C(k,:) s = s_k - (1/N_k) Σ_{j∈out(k)} s_j
        let mut acc = 0.0;
        for &j in g.out(k) {
            acc += self.s[j as usize];
        }
        let dot = self.s[k] - self.rows.inv_out_deg[k] * acc;
        let coef = dot / self.rows.norms_sq[k];
        // s -= coef * C(k,:)^T: entry k gets -coef·1, out-neighbours get
        // +coef/N_k (the self-loop position receives both, handled by
        // doing the neighbour pass first).
        let w = coef * self.rows.inv_out_deg[k];
        for &j in g.out(k) {
            self.s[j as usize] += w;
        }
        self.s[k] -= coef;
        self.t += 1;
        coef
    }

    /// One uniformly-sampled update (the algorithm's iteration).
    pub fn step(&mut self, rng: &mut Rng) -> StepStats {
        let k = rng.below(self.graph.n());
        let deg = self.graph.out_degree(k);
        self.step_at(k);
        StepStats { reads: deg, writes: deg, activated: 1 }
    }

    /// One update at a site drawn by `sampler`. With
    /// [`SiteSelection::Uniform`] this consumes the rng stream exactly
    /// like [`SizeEstimator::step`] (one `below(n)` draw), so the two
    /// are interchangeable bit-for-bit — the engine's `kaczmarz`
    /// estimator relies on that.
    pub fn step_with(&mut self, sampler: &mut SiteSampler, rng: &mut Rng) -> StepStats {
        let k = sampler.next(self.graph, rng);
        let deg = self.graph.out_degree(k);
        self.step_at(k);
        StepStats { reads: deg, writes: deg, activated: 1 }
    }

    /// Current iterate `s_t`.
    pub fn s(&self) -> &[f64] {
        &self.s
    }

    /// Squared error `‖s_t - 𝟙/N‖²` — Fig. 2's y-axis.
    pub fn error_sq(&self) -> f64 {
        let target = 1.0 / self.graph.n() as f64;
        self.s.iter().map(|v| (v - target) * (v - target)).sum()
    }

    /// Page `i`'s network-size estimate `1/s_i` (Appendix). Returns
    /// `None` while the local value is non-positive (early iterations).
    pub fn estimate_at(&self, i: usize) -> Option<f64> {
        let v = self.s[i];
        if v > 0.0 {
            Some(1.0 / v)
        } else {
            None
        }
    }

    /// Mean relative size error `|N̂_i - N| / N` over the pages whose
    /// local estimate is currently positive (early iterations leave some
    /// pages undefined). `NaN` while no page has a positive estimate —
    /// serialized as `null` in bench JSON, like degenerate decay rates.
    pub fn mean_rel_size_error(&self) -> f64 {
        let n = self.graph.n() as f64;
        let mut sum = 0.0;
        let mut count = 0usize;
        for i in 0..self.graph.n() {
            if let Some(nd) = self.estimate_at(i) {
                sum += (nd - n).abs() / n;
                count += 1;
            }
        }
        if count == 0 {
            f64::NAN
        } else {
            sum / count as f64
        }
    }

    pub fn steps(&self) -> u64 {
        self.t
    }
}

/// How the eq.-14 update site `k` is chosen each step.
///
/// `Uniform` is the paper's iteration (every page holds an equal-rate
/// activation clock). The other two are the engine's racing baselines:
/// the same row projection, driven by site streams a deployment might
/// actually have on hand — a uniformly random *edge* (degree-biased) or
/// a token walking the graph (no global sampling primitive at all). All
/// three visit every row infinitely often on a strongly connected graph,
/// so all three converge to `s = 𝟙/N`; the rates differ.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteSelection {
    /// `k ~ Uniform{0..N}` — Algorithm 2 as published.
    Uniform,
    /// `k ∝ out-degree(k)`: the source of a uniformly random edge.
    DegreeWeighted,
    /// `k` follows a random walk along out-links, starting at page 0.
    RandomWalk,
}

/// Stateful site chooser for [`SizeEstimator::step_with`].
#[derive(Debug, Clone)]
pub struct SiteSampler {
    selection: SiteSelection,
    /// Cumulative out-degrees (`cum[k]` = first edge index owned by page
    /// `k`); built only for degree-weighted selection.
    cum: Vec<usize>,
    /// Current walker position (random-walk selection).
    at: usize,
}

impl SiteSampler {
    pub fn new(g: &Graph, selection: SiteSelection) -> SiteSampler {
        let cum = match selection {
            SiteSelection::DegreeWeighted => {
                let mut cum = Vec::with_capacity(g.n() + 1);
                let mut acc = 0usize;
                cum.push(0);
                for k in 0..g.n() {
                    acc += g.out_degree(k);
                    cum.push(acc);
                }
                assert!(acc > 0, "degree-weighted site selection needs edges");
                cum
            }
            _ => Vec::new(),
        };
        SiteSampler { selection, cum, at: 0 }
    }

    /// Draw the next update site, advancing internal state.
    pub fn next(&mut self, g: &Graph, rng: &mut Rng) -> usize {
        match self.selection {
            SiteSelection::Uniform => rng.below(g.n()),
            SiteSelection::DegreeWeighted => {
                let e = rng.below(*self.cum.last().expect("built for degree selection"));
                // First page whose edge range ends past `e`; skips
                // zero-degree pages (their cum entries repeat).
                self.cum.partition_point(|&c| c <= e) - 1
            }
            SiteSelection::RandomWalk => {
                let k = self.at;
                let out = g.out(k);
                assert!(!out.is_empty(), "random walk stuck at dangling page {k}");
                self.at = out[rng.below(out.len())] as usize;
                k
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::vector;

    #[test]
    fn rejects_disconnected() {
        let mut b = crate::graph::GraphBuilder::new(4)
            .dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 1).add_edge(1, 0).add_edge(2, 3).add_edge(3, 2);
        let g = b.build().expect("builds");
        assert_eq!(
            SizeEstimator::new(&g).err(),
            Some(SizeEstimationError::NotStronglyConnected)
        );
    }

    #[test]
    fn rejects_empty() {
        let g = crate::graph::GraphBuilder::new(0).build().expect("builds");
        assert_eq!(SizeEstimator::new(&g).err(), Some(SizeEstimationError::Empty));
    }

    #[test]
    fn sum_of_entries_conserved() {
        // 𝟙ᵀ C(k,:)ᵀ = 0 (columns of C sum to zero), so Σ s_t ≡ 1.
        let g = generators::er_threshold(40, 0.5, 31);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let mut rng = Rng::seeded(32);
        for _ in 0..500 {
            est.step(&mut rng);
            let s = vector::sum(est.s());
            assert!((s - 1.0).abs() < 1e-10, "sum drifted to {s}");
        }
    }

    #[test]
    fn converges_to_uniform() {
        let g = generators::er_threshold(40, 0.5, 33);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let mut rng = Rng::seeded(34);
        let e0 = est.error_sq();
        for _ in 0..20_000 {
            est.step(&mut rng);
        }
        let e1 = est.error_sq();
        assert!(e1 < 1e-12 * e0.max(1.0), "error {e1} from {e0}");
        // every page's estimate of N is accurate
        for i in 0..g.n() {
            let nd = est.estimate_at(i).expect("positive");
            assert!((nd - 40.0).abs() < 1e-3, "page {i} estimates {nd}");
        }
    }

    #[test]
    fn error_decays_exponentially_in_mean() {
        let g = generators::er_threshold(30, 0.5, 35);
        let base = Rng::seeded(36);
        let mut rounds = Vec::new();
        for round in 0..30 {
            let mut est = SizeEstimator::new(&g).expect("connected");
            let mut rng = base.fork(round);
            let mut traj = vec![est.error_sq()];
            for t in 1..=3000usize {
                est.step(&mut rng);
                if t % 100 == 0 {
                    traj.push(est.error_sq());
                }
            }
            rounds.push(traj);
        }
        let avg = crate::util::stats::average_trajectories(&rounds);
        let per_record = crate::util::stats::decay_rate(&avg);
        assert!(per_record < 0.9, "not exponential: {per_record}");
        // Appendix bound: per-step rate <= 1 - sigma2(Chat)/N.
        let bound = crate::linalg::spectral::size_est_contraction_rate(&g);
        let per_step = per_record.powf(1.0 / 100.0);
        assert!(per_step <= bound + 5e-3, "measured {per_step} vs bound {bound}");
    }

    #[test]
    fn step_touches_only_out_neighbourhood() {
        let g = generators::ring(10);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let before = est.s().to_vec();
        est.step_at(4); // ring: out(4) = {5}
        let after = est.s();
        for i in 0..10 {
            if i == 4 || i == 5 {
                continue;
            }
            assert_eq!(before[i], after[i], "page {i} must be untouched");
        }
    }

    #[test]
    fn ring_converges() {
        let g = generators::ring(12);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let mut rng = Rng::seeded(37);
        for _ in 0..20_000 {
            est.step(&mut rng);
        }
        assert!(est.error_sq() < 1e-10);
    }

    #[test]
    fn uniform_sampler_is_bit_identical_to_plain_step() {
        let g = generators::er_threshold(25, 0.5, 40);
        let mut a = SizeEstimator::new(&g).expect("connected");
        let mut b = SizeEstimator::new(&g).expect("connected");
        let mut sampler = SiteSampler::new(&g, SiteSelection::Uniform);
        let mut rng_a = Rng::seeded(41);
        let mut rng_b = Rng::seeded(41);
        for _ in 0..300 {
            let sa = a.step(&mut rng_a);
            let sb = b.step_with(&mut sampler, &mut rng_b);
            assert_eq!(sa, sb);
        }
        assert_eq!(a.s(), b.s(), "same rng consumption, same iterate");
    }

    #[test]
    fn degree_and_walk_selections_also_converge_to_uniform() {
        // Non-uniform site streams visit the least-likely row less often,
        // so the rate is below Algorithm 2's — give them a generous step
        // budget and a bound several decades under e0 ≈ 1.
        let g = generators::er_threshold(30, 0.5, 42);
        for sel in [SiteSelection::DegreeWeighted, SiteSelection::RandomWalk] {
            let mut est = SizeEstimator::new(&g).expect("connected");
            let mut sampler = SiteSampler::new(&g, sel);
            let mut rng = Rng::seeded(43);
            for _ in 0..40_000 {
                est.step_with(&mut sampler, &mut rng);
            }
            assert!(est.error_sq() < 1e-6, "{sel:?}: error {}", est.error_sq());
            assert!(
                est.mean_rel_size_error() < 1e-2,
                "{sel:?}: rel err {}",
                est.mean_rel_size_error()
            );
        }
    }

    #[test]
    fn degree_weighted_sampler_respects_edge_measure() {
        // star: page 0 owns n-1 out-edges, each leaf owns 1 — page 0
        // must be drawn roughly half the time.
        let g = generators::star(9);
        let mut sampler = SiteSampler::new(&g, SiteSelection::DegreeWeighted);
        let mut rng = Rng::seeded(44);
        let mut hub = 0usize;
        let draws = 4_000;
        for _ in 0..draws {
            if sampler.next(&g, &mut rng) == 0 {
                hub += 1;
            }
        }
        let frac = hub as f64 / draws as f64;
        assert!((frac - 0.5).abs() < 0.05, "hub drawn {frac} of the time");
    }

    #[test]
    fn walk_sampler_visits_only_out_neighbours() {
        let g = generators::ring(8);
        let mut sampler = SiteSampler::new(&g, SiteSelection::RandomWalk);
        let mut rng = Rng::seeded(45);
        let mut prev = sampler.next(&g, &mut rng); // starts at 0
        assert_eq!(prev, 0);
        for _ in 0..32 {
            let k = sampler.next(&g, &mut rng);
            assert_eq!(k, (prev + 1) % 8, "ring walk must follow the single out-link");
            prev = k;
        }
    }

    #[test]
    fn rel_size_error_shrinks_and_starts_defined() {
        let g = generators::er_threshold(20, 0.5, 46);
        let mut est = SizeEstimator::new(&g).expect("connected");
        // s_0 = e_1: page 0 estimates N̂ = 1, everyone else undefined.
        let e0 = est.mean_rel_size_error();
        assert!((e0 - 19.0 / 20.0).abs() < 1e-12, "initial rel err {e0}");
        let mut rng = Rng::seeded(47);
        for _ in 0..10_000 {
            est.step(&mut rng);
        }
        assert!(est.mean_rel_size_error() < 1e-4);
    }
}
