//! **Algorithm 2** (Appendix) — distributed network-size estimation.
//!
//! Randomized row-projection (Kaczmarz) iteration on `C = (I - A)ᵀ`:
//! `s_{t+1} = s_t - (C(k,:) s_t / ‖C(k,:)‖²) C(k,:)ᵀ` (eq. 14), started at
//! `s_0 = e_1`. Because `C(k,:) = (e_k - A(:,k))ᵀ`, each update touches
//! only page `k` and its out-neighbours — the same communication pattern
//! as Algorithm 1. The iterate converges to the uniform stationary vector
//! `s = 𝟙/N`, and each page then estimates `N ≈ 1/s_i`.
//!
//! Requires strong connectivity (nullspace of C must be 1-dimensional);
//! construction fails loudly otherwise via [`SizeEstimationError`].

use crate::graph::scc::is_strongly_connected;
use crate::graph::Graph;
use crate::util::rng::Rng;

use super::common::StepStats;

/// Error cases for the estimator.
#[derive(Debug, PartialEq, Eq)]
pub enum SizeEstimationError {
    /// The graph is not strongly connected, so `s` is not unique.
    NotStronglyConnected,
    /// Empty graph.
    Empty,
}

impl std::fmt::Display for SizeEstimationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SizeEstimationError::NotStronglyConnected => {
                write!(f, "Algorithm 2 requires a strongly connected graph (Appendix assumption)")
            }
            SizeEstimationError::Empty => write!(f, "cannot size-estimate an empty graph"),
        }
    }
}

impl std::error::Error for SizeEstimationError {}

/// Row geometry of `C = (I-A)ᵀ`: per-row squared norms (`‖C(k,:)‖² =
/// 1 - 2A_kk + 1/N_k`, the α=1 analogue of Remark 3).
#[derive(Debug, Clone)]
struct CRows {
    norms_sq: Vec<f64>,
    inv_out_deg: Vec<f64>,
}

impl CRows {
    fn new(g: &Graph) -> CRows {
        let n = g.n();
        let mut norms_sq = Vec::with_capacity(n);
        let mut inv_out_deg = Vec::with_capacity(n);
        for k in 0..n {
            let deg = g.out_degree(k);
            assert!(deg > 0, "dangling page {k}");
            let nk = deg as f64;
            let akk = if g.has_self_loop(k) { 1.0 / nk } else { 0.0 };
            // ‖e_k - A(:,k)‖² = 1 - 2 A_kk + Σ (1/N_k)² over out(k) = 1 - 2A_kk + 1/N_k
            norms_sq.push(1.0 - 2.0 * akk + 1.0 / nk);
            inv_out_deg.push(1.0 / nk);
        }
        CRows { norms_sq, inv_out_deg }
    }
}

/// Algorithm 2 runner.
#[derive(Debug, Clone)]
pub struct SizeEstimator<'g> {
    graph: &'g Graph,
    rows: CRows,
    s: Vec<f64>,
    t: u64,
}

impl<'g> SizeEstimator<'g> {
    /// Create with the paper's initialization `s_0 = [1, 0, …, 0]`.
    pub fn new(graph: &'g Graph) -> Result<Self, SizeEstimationError> {
        if graph.n() == 0 {
            return Err(SizeEstimationError::Empty);
        }
        if !is_strongly_connected(graph) {
            return Err(SizeEstimationError::NotStronglyConnected);
        }
        let mut s = vec![0.0; graph.n()];
        s[0] = 1.0;
        Ok(SizeEstimator {
            rows: CRows::new(graph),
            graph,
            s,
            t: 0,
        })
    }

    /// One eq. 14 update at a given page `k`; touches `{k} ∪ out(k)` only.
    pub fn step_at(&mut self, k: usize) -> f64 {
        let g = self.graph;
        // C(k,:) s = s_k - (1/N_k) Σ_{j∈out(k)} s_j
        let mut acc = 0.0;
        for &j in g.out(k) {
            acc += self.s[j as usize];
        }
        let dot = self.s[k] - self.rows.inv_out_deg[k] * acc;
        let coef = dot / self.rows.norms_sq[k];
        // s -= coef * C(k,:)^T: entry k gets -coef·1, out-neighbours get
        // +coef/N_k (the self-loop position receives both, handled by
        // doing the neighbour pass first).
        let w = coef * self.rows.inv_out_deg[k];
        for &j in g.out(k) {
            self.s[j as usize] += w;
        }
        self.s[k] -= coef;
        self.t += 1;
        coef
    }

    /// One uniformly-sampled update (the algorithm's iteration).
    pub fn step(&mut self, rng: &mut Rng) -> StepStats {
        let k = rng.below(self.graph.n());
        let deg = self.graph.out_degree(k);
        self.step_at(k);
        StepStats { reads: deg, writes: deg, activated: 1 }
    }

    /// Current iterate `s_t`.
    pub fn s(&self) -> &[f64] {
        &self.s
    }

    /// Squared error `‖s_t - 𝟙/N‖²` — Fig. 2's y-axis.
    pub fn error_sq(&self) -> f64 {
        let target = 1.0 / self.graph.n() as f64;
        self.s.iter().map(|v| (v - target) * (v - target)).sum()
    }

    /// Page `i`'s network-size estimate `1/s_i` (Appendix). Returns
    /// `None` while the local value is non-positive (early iterations).
    pub fn estimate_at(&self, i: usize) -> Option<f64> {
        let v = self.s[i];
        if v > 0.0 {
            Some(1.0 / v)
        } else {
            None
        }
    }

    pub fn steps(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::vector;

    #[test]
    fn rejects_disconnected() {
        let mut b = crate::graph::GraphBuilder::new(4)
            .dangling_policy(crate::graph::DanglingPolicy::SelfLoop);
        b.add_edge(0, 1).add_edge(1, 0).add_edge(2, 3).add_edge(3, 2);
        let g = b.build().expect("builds");
        assert_eq!(
            SizeEstimator::new(&g).err(),
            Some(SizeEstimationError::NotStronglyConnected)
        );
    }

    #[test]
    fn rejects_empty() {
        let g = crate::graph::GraphBuilder::new(0).build().expect("builds");
        assert_eq!(SizeEstimator::new(&g).err(), Some(SizeEstimationError::Empty));
    }

    #[test]
    fn sum_of_entries_conserved() {
        // 𝟙ᵀ C(k,:)ᵀ = 0 (columns of C sum to zero), so Σ s_t ≡ 1.
        let g = generators::er_threshold(40, 0.5, 31);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let mut rng = Rng::seeded(32);
        for _ in 0..500 {
            est.step(&mut rng);
            let s = vector::sum(est.s());
            assert!((s - 1.0).abs() < 1e-10, "sum drifted to {s}");
        }
    }

    #[test]
    fn converges_to_uniform() {
        let g = generators::er_threshold(40, 0.5, 33);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let mut rng = Rng::seeded(34);
        let e0 = est.error_sq();
        for _ in 0..20_000 {
            est.step(&mut rng);
        }
        let e1 = est.error_sq();
        assert!(e1 < 1e-12 * e0.max(1.0), "error {e1} from {e0}");
        // every page's estimate of N is accurate
        for i in 0..g.n() {
            let nd = est.estimate_at(i).expect("positive");
            assert!((nd - 40.0).abs() < 1e-3, "page {i} estimates {nd}");
        }
    }

    #[test]
    fn error_decays_exponentially_in_mean() {
        let g = generators::er_threshold(30, 0.5, 35);
        let base = Rng::seeded(36);
        let mut rounds = Vec::new();
        for round in 0..30 {
            let mut est = SizeEstimator::new(&g).expect("connected");
            let mut rng = base.fork(round);
            let mut traj = vec![est.error_sq()];
            for t in 1..=3000usize {
                est.step(&mut rng);
                if t % 100 == 0 {
                    traj.push(est.error_sq());
                }
            }
            rounds.push(traj);
        }
        let avg = crate::util::stats::average_trajectories(&rounds);
        let per_record = crate::util::stats::decay_rate(&avg);
        assert!(per_record < 0.9, "not exponential: {per_record}");
        // Appendix bound: per-step rate <= 1 - sigma2(Chat)/N.
        let bound = crate::linalg::spectral::size_est_contraction_rate(&g);
        let per_step = per_record.powf(1.0 / 100.0);
        assert!(per_step <= bound + 5e-3, "measured {per_step} vs bound {bound}");
    }

    #[test]
    fn step_touches_only_out_neighbourhood() {
        let g = generators::ring(10);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let before = est.s().to_vec();
        est.step_at(4); // ring: out(4) = {5}
        let after = est.s();
        for i in 0..10 {
            if i == 4 || i == 5 {
                continue;
            }
            assert_eq!(before[i], after[i], "page {i} must be untouched");
        }
    }

    #[test]
    fn ring_converges() {
        let g = generators::ring(12);
        let mut est = SizeEstimator::new(&g).expect("connected");
        let mut rng = Rng::seeded(37);
        for _ in 0..20_000 {
            est.step(&mut rng);
        }
        assert!(est.error_sq() < 1e-10);
    }
}
