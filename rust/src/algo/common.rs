//! Shared solver interface and trajectory recording.
//!
//! Every algorithm (the paper's and the baselines') implements
//! [`PageRankSolver`], so the Figure-1 harness can run them uniformly:
//! one `step` = one page activation (the paper's iteration counter `t`),
//! and [`StepStats`] carries the communication cost of that activation —
//! the quantity the paper's §II-D analyzes ("the number of 'reads' and
//! 'writes' is exactly equal to the number of outgoing webpages").

use crate::util::rng::Rng;

/// Communication cost of one activation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepStats {
    /// Residual/value reads from other pages.
    pub reads: usize,
    /// Residual/value writes to other pages.
    pub writes: usize,
    /// Pages activated in this step (1 for sequential algorithms,
    /// batch size for the parallel extension).
    pub activated: usize,
}

impl StepStats {
    pub fn accumulate(&mut self, other: StepStats) {
        self.reads += other.reads;
        self.writes += other.writes;
        self.activated += other.activated;
    }
}

/// Uniform interface over all PageRank iterations.
pub trait PageRankSolver {
    /// Number of pages.
    fn n(&self) -> usize;

    /// Perform one activation/iteration, driven by `rng`.
    fn step(&mut self, rng: &mut Rng) -> StepStats;

    /// Current PageRank estimate in the paper's *scaled* normalization
    /// (entries summing to N at the fixed point).
    fn estimate(&self) -> Vec<f64>;

    /// Short identifier used in reports.
    fn name(&self) -> &'static str;

    /// Whether `step` needs in-neighbour information — the practical
    /// limitation (§I) the paper's algorithm avoids.
    fn requires_in_links(&self) -> bool {
        false
    }

    /// Candidates dropped by conflict-free packing so far — nonzero only
    /// for backends that thin a batched candidate stream (the sharded
    /// runtime overrides this); every other solver activates exactly
    /// what it samples.
    fn conflicts(&self) -> u64 {
        0
    }

    /// Fault-injection ledger — nonzero only for backends running over
    /// a faulted network (the msgpass runtime overrides this); every
    /// other solver computes on an ideal machine.
    fn fault_counters(&self) -> crate::network::FaultCounters {
        crate::network::FaultCounters::default()
    }

    /// Shard-locality ledger — nonzero only for the sharded/msgpass
    /// backends (which override this with their intra/cross conflict
    /// split and cross-shard wire counts); every other solver has no
    /// shard boundary to cross.
    fn locality(&self) -> crate::coordinator::LocalityCounters {
        crate::coordinator::LocalityCounters::default()
    }

    /// Squared l2 distance `‖x̂_t - x*‖²` of the current estimate from a
    /// reference vector — the quantity Fig. 1 plots (before its 1/N
    /// scaling). The default routes through [`PageRankSolver::estimate`]
    /// and therefore allocates a full vector per call; solvers that hold
    /// their estimate as plain state override it so the hot recording
    /// loop in [`Trajectory::record`] runs allocation-free.
    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.estimate(), x_star)
    }
}

/// A recorded error trajectory: `(1/N)‖x_t - x*‖²` sampled every `stride`
/// activations — exactly Fig. 1's y-axis.
#[derive(Debug, Clone)]
pub struct Trajectory {
    pub name: &'static str,
    pub stride: usize,
    pub errors: Vec<f64>,
    pub total_stats: StepStats,
}

impl Trajectory {
    /// Run `solver` for `steps` activations against reference `x_star`,
    /// recording the scaled squared error every `stride` steps (including
    /// t=0 before any step).
    pub fn record<S: PageRankSolver + ?Sized>(
        solver: &mut S,
        x_star: &[f64],
        steps: usize,
        stride: usize,
        rng: &mut Rng,
    ) -> Trajectory {
        assert_eq!(solver.n(), x_star.len());
        assert!(stride > 0);
        let n = solver.n() as f64;
        let mut errors = Vec::with_capacity(steps / stride + 1);
        let mut total = StepStats::default();
        errors.push(solver.error_sq_vs(x_star) / n);
        for t in 1..=steps {
            total.accumulate(solver.step(rng));
            if t % stride == 0 {
                errors.push(solver.error_sq_vs(x_star) / n);
            }
        }
        Trajectory {
            name: solver.name(),
            stride,
            errors,
            total_stats: total,
        }
    }

    /// Final recorded error.
    pub fn final_error(&self) -> f64 {
        *self.errors.last().expect("trajectory nonempty")
    }

    /// Fitted per-*record* decay rate (take the stride-th root for the
    /// per-activation rate).
    pub fn decay_rate(&self) -> f64 {
        crate::util::stats::decay_rate(&self.errors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fake solver that halves a scalar error each step: estimate is
    /// x* + e_0 * err.
    struct Halver {
        x_star: Vec<f64>,
        err: f64,
        in_links: bool,
    }

    impl PageRankSolver for Halver {
        fn n(&self) -> usize {
            self.x_star.len()
        }
        fn step(&mut self, _rng: &mut Rng) -> StepStats {
            self.err *= 0.5;
            StepStats { reads: 2, writes: 1, activated: 1 }
        }
        fn estimate(&self) -> Vec<f64> {
            let mut x = self.x_star.clone();
            x[0] += self.err;
            x
        }
        fn name(&self) -> &'static str {
            "halver"
        }
        fn requires_in_links(&self) -> bool {
            self.in_links
        }
    }

    #[test]
    fn trajectory_records_initial_and_strided() {
        let x_star = vec![1.0; 4];
        let mut s = Halver { x_star: x_star.clone(), err: 1.0, in_links: false };
        let mut rng = Rng::seeded(1);
        let tr = Trajectory::record(&mut s, &x_star, 10, 2, &mut rng);
        assert_eq!(tr.errors.len(), 6); // t = 0,2,4,6,8,10
        assert_eq!(tr.errors[0], 0.25); // err=1 -> ||e||²/N = 1/4
        assert!((tr.errors[1] - 0.25f64.powi(2) * 0.25).abs() < 1e-15); // err 0.25, squared, /N
        assert_eq!(tr.total_stats.reads, 20);
        assert_eq!(tr.total_stats.writes, 10);
        assert_eq!(tr.total_stats.activated, 10);
    }

    #[test]
    fn trajectory_decay_rate_matches() {
        let x_star = vec![0.0; 2];
        let mut s = Halver { x_star: x_star.clone(), err: 1.0, in_links: false };
        let mut rng = Rng::seeded(1);
        let tr = Trajectory::record(&mut s, &x_star, 20, 1, &mut rng);
        // err halves per step, squared error quarters
        assert!((tr.decay_rate() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn error_sq_vs_default_matches_estimate_distance() {
        let x_star = vec![1.0, 2.0, 3.0];
        let s = Halver { x_star: x_star.clone(), err: 0.5, in_links: false };
        let direct = crate::linalg::vector::dist_sq(&s.estimate(), &x_star);
        assert_eq!(s.error_sq_vs(&x_star), direct);
        assert!((direct - 0.25).abs() < 1e-15);
    }

    #[test]
    fn stats_accumulate() {
        let mut a = StepStats { reads: 1, writes: 2, activated: 1 };
        a.accumulate(StepStats { reads: 10, writes: 20, activated: 3 });
        assert_eq!(a, StepStats { reads: 11, writes: 22, activated: 4 });
    }
}
