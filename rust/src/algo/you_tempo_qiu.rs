//! Baseline \[15\]: You, Tempo & Qiu, *Randomized incremental algorithms
//! for the PageRank computation* (CDC 2015).
//!
//! The randomized-incremental-optimization view of the same linear system
//! `(I-αA)x = (1-α)𝟙`: minimize `Σ_i (B(i,:)x - y_i)²` by projecting onto
//! one random *row* constraint per step — randomized Kaczmarz:
//!
//! `x ← x + ((y_i - B(i,:)x) / ‖B(i,:)‖²) B(i,:)ᵀ`
//!
//! This converges exponentially in expectation (which is why the paper's
//! Fig. 1 shows \[15\] decaying at a rate similar to MP), **but** row `i`
//! of `B` is supported on `{i} ∪ in(i)` — the update must read the values
//! of the pages *linking to* `i` and write back to them, which is exactly
//! the in-neighbour dependence the paper's §I criticizes. Initialization:
//! zero vector (paper Fig. 1).

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Randomized row-projection (Kaczmarz) solver of \[15\].
#[derive(Debug, Clone)]
pub struct YouTempoQiu<'g> {
    graph: &'g Graph,
    alpha: f64,
    /// ‖B(i,:)‖² per row: 1 - 2αA_ii + α² Σ_{j∈in(i)} 1/N_j².
    row_norms_sq: Vec<f64>,
    x: Vec<f64>,
    t: u64,
}

impl<'g> YouTempoQiu<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        let n = graph.n();
        let mut row_norms_sq = Vec::with_capacity(n);
        for i in 0..n {
            // A dangling i carries the shared implicit self-loop (N_i =
            // 1, A_ii = 1); it is not in the CSR, so fold it in here.
            let aii = if graph.out_degree(i) == 0 {
                1.0
            } else if graph.has_self_loop(i) {
                1.0 / graph.out_degree(i) as f64
            } else {
                0.0
            };
            let mut s = if graph.out_degree(i) == 0 { 1.0 } else { 0.0 };
            for &j in graph.inc(i) {
                let nj = graph.out_degree(j as usize) as f64;
                s += 1.0 / (nj * nj);
            }
            row_norms_sq.push(1.0 - 2.0 * alpha * aii + alpha * alpha * s);
        }
        YouTempoQiu {
            graph,
            alpha,
            row_norms_sq,
            x: vec![0.0; n],
            t: 0,
        }
    }

    /// `B(i,:) x = x_i - α Σ_{j∈in(i)} x_j/N_j` — reads in-neighbours
    /// (plus `i` itself when the implicit dangling self-loop is live).
    fn row_dot(&self, i: usize) -> f64 {
        let mut s = 0.0;
        for &j in self.graph.inc(i) {
            s += self.x[j as usize] / self.graph.out_degree(j as usize) as f64;
        }
        if self.graph.out_degree(i) == 0 {
            s += self.x[i];
        }
        self.x[i] - self.alpha * s
    }

    /// One Kaczmarz projection at row `i`.
    pub fn step_at(&mut self, i: usize) -> f64 {
        let y_i = 1.0 - self.alpha;
        let resid = y_i - self.row_dot(i);
        let coef = resid / self.row_norms_sq[i];
        // x += coef * B(i,:)^T, supported on {i} ∪ in(i).
        for &j in self.graph.inc(i) {
            let nj = self.graph.out_degree(j as usize) as f64;
            self.x[j as usize] -= coef * self.alpha / nj;
        }
        self.x[i] += coef; // diagonal entry 1 (explicit self-loops are
                           // already folded in via in(i) containing i)
        if self.graph.out_degree(i) == 0 {
            // The implicit dangling self-loop's -α/N_i = -α share of the
            // row, absent from the CSR in-list.
            self.x[i] -= coef * self.alpha;
        }
        self.t += 1;
        coef
    }
}

impl<'g> PageRankSolver for YouTempoQiu<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        let i = rng.below(self.graph.n());
        let deg_in = self.graph.in_degree(i);
        self.step_at(i);
        StepStats {
            reads: deg_in,
            writes: deg_in,
            activated: 1,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "you-tempo-qiu [15]"
    }

    fn requires_in_links(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn row_norms_match_dense() {
        let g = generators::er_threshold(30, 0.5, 61);
        let alpha = 0.85;
        let ytq = YouTempoQiu::new(&g, alpha);
        let bt = DenseMatrix::b_matrix(&g, alpha).transpose();
        for i in 0..30 {
            let want = vector::norm2_sq(bt.col(i)); // row i of B
            assert!(
                (ytq.row_norms_sq[i] - want).abs() < 1e-12,
                "row {i}: {} vs {want}",
                ytq.row_norms_sq[i]
            );
        }
    }

    #[test]
    fn step_matches_dense_kaczmarz() {
        let g = generators::er_threshold(20, 0.5, 62);
        let alpha = 0.85;
        let mut ytq = YouTempoQiu::new(&g, alpha);
        // random-ish starting point
        let mut rng = Rng::seeded(63);
        for v in ytq.x.iter_mut() {
            *v = rng.normal();
        }
        let x0 = ytq.x.clone();
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bt = b.transpose();
        let i = 7;
        ytq.step_at(i);
        // dense reference
        let row = bt.col(i);
        let resid = (1.0 - alpha) - vector::dot(row, &x0);
        let coef = resid / vector::norm2_sq(row);
        let mut want = x0;
        vector::axpy(coef, row, &mut want);
        assert!(vector::dist_inf(&ytq.x, &want) < 1e-12);
    }

    #[test]
    fn converges_to_exact() {
        let g = generators::er_threshold(30, 0.5, 64);
        let x_star = exact_pagerank(&g, 0.85);
        let mut ytq = YouTempoQiu::new(&g, 0.85);
        let mut rng = Rng::seeded(65);
        for _ in 0..60_000 {
            ytq.step(&mut rng);
        }
        assert!(vector::dist_inf(&ytq.estimate(), &x_star) < 1e-8);
    }

    #[test]
    fn exponential_decay_like_mp() {
        // Fig. 1's observation: [15] decays exponentially at a similar
        // rate to MP.
        let g = generators::er_threshold(30, 0.5, 66);
        let x_star = exact_pagerank(&g, 0.85);
        let base = Rng::seeded(67);
        let mut rounds = Vec::new();
        for round in 0..20 {
            let mut ytq = YouTempoQiu::new(&g, 0.85);
            let mut rng = base.fork(round);
            let tr = crate::algo::common::Trajectory::record(
                &mut ytq, &x_star, 6000, 100, &mut rng,
            );
            rounds.push(tr.errors);
        }
        let avg = crate::util::stats::average_trajectories(&rounds);
        let rate = crate::util::stats::decay_rate(&avg);
        assert!(rate < 0.95, "should be exponential per record: {rate}");
    }

    #[test]
    fn dangling_chain_converges_to_the_repaired_fixed_point() {
        // chain(12)'s sink row folds the implicit self-loop into the
        // norm, the row dot and the projection; Kaczmarz then converges
        // to the same repaired-matrix solution as every other backend.
        let g = generators::chain(12);
        let x_star = exact_pagerank(&g, 0.85);
        let mut ytq = YouTempoQiu::new(&g, 0.85);
        let mut rng = Rng::seeded(69);
        for _ in 0..60_000 {
            ytq.step(&mut rng);
        }
        assert!(ytq.estimate().iter().all(|v| v.is_finite()));
        let err = vector::dist_inf(&ytq.estimate(), &x_star);
        assert!(err < 1e-8, "err={err}");
    }

    #[test]
    fn dangling_row_norms_match_dense() {
        // row_norms_match_dense, but on a graph with a genuine sink —
        // DenseMatrix::b_matrix applies the same implicit repair.
        let g = generators::chain(8);
        let alpha = 0.85;
        let ytq = YouTempoQiu::new(&g, alpha);
        let bt = DenseMatrix::b_matrix(&g, alpha).transpose();
        for i in 0..8 {
            let want = vector::norm2_sq(bt.col(i));
            assert!(
                (ytq.row_norms_sq[i] - want).abs() < 1e-12,
                "row {i}: {} vs {want}",
                ytq.row_norms_sq[i]
            );
        }
    }

    #[test]
    fn uses_in_links() {
        let g = generators::ring(5);
        assert!(YouTempoQiu::new(&g, 0.85).requires_in_links());
    }

    #[test]
    fn step_stats_count_in_degree() {
        let g = generators::star(6);
        let mut ytq = YouTempoQiu::new(&g, 0.85);
        let mut rng = Rng::seeded(68);
        let st = ytq.step(&mut rng);
        assert!(st.reads == 5 || st.reads == 1); // hub in-deg 5, leaf 1
        assert_eq!(st.reads, st.writes);
    }
}
