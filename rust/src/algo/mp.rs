//! **Algorithm 1** — Matching-Pursuit based PageRank (the paper's core
//! contribution), in its matrix-form (single address space) realization.
//!
//! State is exactly what the paper prescribes: two scalars per page
//! (`x_k`, `r_k`) plus the per-column constants of Remark 3. One `step`:
//!
//! 1. draw `k ~ U[0, N)`;
//! 2. `coef = B(:,k)ᵀ r / ‖B(:,k)‖²` — reads the residuals of `out(k)`;
//! 3. `x_k += coef` (eq. 7);
//! 4. `r -= coef · B(:,k)` — writes the residuals of `out(k)` and `k`
//!    (eq. 8).
//!
//! Cost per activation: `N_k` reads + `N_k` writes (§II-D). The squared
//! residual norm is maintained incrementally: a projection step satisfies
//! `‖r'‖² = ‖r‖² - coef² ‖B(:,k)‖²`, so no O(N) rescan is needed for
//! stopping criteria (periodically recomputed to cancel FP drift).
//!
//! The message-level (page-agent) realization of the same update lives in
//! [`crate::coordinator`]; both share this module's arithmetic through
//! [`crate::linalg::sparse::BColumns`].
//!
//! [`ResidualMatchingPursuit`] is the §IV future-work-3 variant: the
//! same `step_at` primitive driven by a residual-weighted sampler
//! (`k ∝ max(r_k², floor)`) over the shared Fenwick
//! [`crate::linalg::select::WeightTree`] — O(log N) per draw and per
//! touched-coordinate weight refresh.

use crate::graph::Graph;
use crate::linalg::select::{DEFAULT_WEIGHT_FLOOR, WeightTree};
use crate::linalg::sparse::BColumns;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Matrix-form Algorithm 1.
#[derive(Debug, Clone)]
pub struct MatchingPursuit<'g> {
    graph: &'g Graph,
    cols: BColumns,
    /// PageRank estimate x_t (eq. 7).
    x: Vec<f64>,
    /// Residual r_t (eq. 8); r_0 = y = (1-α)𝟙.
    r: Vec<f64>,
    /// Incrementally maintained ‖r_t‖².
    rnorm_sq: f64,
    /// Steps taken.
    t: u64,
    /// Recompute ‖r‖² exactly every this many steps (FP-drift control).
    refresh_every: u64,
}

impl<'g> MatchingPursuit<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        let n = graph.n();
        let cols = BColumns::new(graph, alpha);
        let y = 1.0 - alpha;
        MatchingPursuit {
            graph,
            cols,
            x: vec![0.0; n],
            r: vec![y; n],
            rnorm_sq: y * y * n as f64,
            t: 0,
            refresh_every: 1 << 20,
        }
    }

    /// Apply the eq. 7/8 update at a *given* page `k` — the primitive that
    /// uniform, exponential-clock and residual-weighted samplers all
    /// drive. Returns the projection coefficient.
    pub fn step_at(&mut self, k: usize) -> f64 {
        let num = self.cols.col_dot(self.graph, k, &self.r);
        let coef = num / self.cols.norm_sq(k);
        self.x[k] += coef;
        self.cols.sub_scaled_col(self.graph, k, coef, &mut self.r);
        // Orthogonal projection: ‖r'‖² = ‖r‖² - num²/‖B(:,k)‖².
        self.rnorm_sq -= coef * num;
        self.t += 1;
        if self.t % self.refresh_every == 0 {
            self.rnorm_sq = crate::linalg::vector::norm2_sq(&self.r);
        }
        coef
    }

    /// Current residual vector (the second scalar per page).
    pub fn residual(&self) -> &[f64] {
        &self.r
    }

    /// Incrementally tracked ‖r_t‖² — drives Prop. 2 style bounds and the
    /// stopping criterion of [`crate::algo::stopping`].
    pub fn residual_norm_sq(&self) -> f64 {
        self.rnorm_sq.max(0.0)
    }

    /// Number of activations so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    pub fn alpha(&self) -> f64 {
        self.cols.alpha()
    }

    pub fn graph(&self) -> &Graph {
        self.graph
    }

    /// Direct access to the column geometry (shared with the coordinator).
    pub fn columns(&self) -> &BColumns {
        &self.cols
    }

}

/// Matrix-form Algorithm 1 with residual-weighted activation
/// (§IV future-work 3): draw `k ∝ max(r_k², floor)` from a Fenwick
/// [`WeightTree`], apply the eq. 7/8 projection, and refresh the weights
/// of the touched coordinates `{k} ∪ out(k)` — O(log N) per draw and per
/// refresh, so the importance sampler costs the same asymptotics as the
/// uniform one.
///
/// `floor > 0` keeps every page's activation probability positive (the
/// chain stays irreducible), so the residual contracts in expectation
/// exactly as in Prop. 2 — the weighting only re-allocates activations
/// toward pages that currently carry residual mass. Registry key:
/// `mp:residual[:<floor>]`.
///
/// Weight refreshes walk the touched set in ascending page order; the
/// sharded runtime's residual policies do the same, which is what makes
/// `sharded:1:1:*:*:residual` replay this solver bit for bit (tested in
/// `tests/engine.rs`).
#[derive(Debug, Clone)]
pub struct ResidualMatchingPursuit<'g> {
    inner: MatchingPursuit<'g>,
    tree: WeightTree,
    floor: f64,
    /// Recycled touched-coordinate buffer (sorted before weight
    /// refresh — deterministic Fenwick arithmetic).
    touched: Vec<u32>,
}

impl<'g> ResidualMatchingPursuit<'g> {
    pub fn new(graph: &'g Graph, alpha: f64, floor: f64) -> Self {
        assert!(floor > 0.0, "floor must be > 0 to keep every page live");
        let y = 1.0 - alpha;
        let w0 = (y * y).max(floor);
        let tree = WeightTree::new(&vec![w0; graph.n()]);
        ResidualMatchingPursuit {
            inner: MatchingPursuit::new(graph, alpha),
            tree,
            floor,
            touched: Vec::new(),
        }
    }

    /// The default-floor variant (`mp:residual`).
    pub fn with_default_floor(graph: &'g Graph, alpha: f64) -> Self {
        ResidualMatchingPursuit::new(graph, alpha, DEFAULT_WEIGHT_FLOOR)
    }

    pub fn floor(&self) -> f64 {
        self.floor
    }

    /// The wrapped matrix-form solver (residual access, step counters).
    pub fn inner(&self) -> &MatchingPursuit<'g> {
        &self.inner
    }

    pub fn residual_norm_sq(&self) -> f64 {
        self.inner.residual_norm_sq()
    }
}

impl<'g> PageRankSolver for ResidualMatchingPursuit<'g> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        let k = self.tree.sample(rng);
        let graph = self.inner.graph;
        let deg = graph.out_degree(k);
        self.inner.step_at(k);
        // Residual support of the projection: {k} ∪ out(k) (a dangling
        // k's implicit self-loop touches only k). Sorted ascending so
        // the Fenwick update order — and with it every internal partial
        // sum — is a pure function of the activation sequence.
        self.touched.clear();
        self.touched.push(k as u32);
        self.touched.extend_from_slice(graph.out(k));
        self.touched.sort_unstable();
        self.touched.dedup();
        let r = self.inner.residual();
        for &j in &self.touched {
            let rj = r[j as usize];
            self.tree.update(j as usize, (rj * rj).max(self.floor));
        }
        StepStats {
            reads: deg,
            writes: deg,
            activated: 1,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        PageRankSolver::estimate(&self.inner)
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        self.inner.error_sq_vs(x_star)
    }

    fn name(&self) -> &'static str {
        "mp (residual-weighted, Fenwick-sampled)"
    }
}

impl<'g> PageRankSolver for MatchingPursuit<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        let k = rng.below(self.graph.n());
        let deg = self.graph.out_degree(k);
        self.step_at(k);
        StepStats {
            reads: deg,
            writes: deg,
            activated: 1,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "mp (Algorithm 1)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::common::Trajectory;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn conservation_b_x_plus_r_is_y() {
        // eq. 11: B x_t + r_t = y throughout the run.
        let g = generators::er_threshold(50, 0.5, 1);
        let alpha = 0.85;
        let mut mp = MatchingPursuit::new(&g, alpha);
        let mut rng = Rng::seeded(2);
        let b = DenseMatrix::b_matrix(&g, alpha);
        for _ in 0..500 {
            mp.step(&mut rng);
        }
        let bx = b.matvec(&mp.estimate());
        for (i, v) in bx.iter().enumerate() {
            let lhs = v + mp.residual()[i];
            assert!((lhs - (1.0 - alpha)).abs() < 1e-10, "page {i}: {lhs}");
        }
    }

    #[test]
    fn residual_norm_incremental_matches_exact() {
        let g = generators::er_threshold(40, 0.5, 3);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(4);
        for _ in 0..200 {
            mp.step(&mut rng);
        }
        let exact = vector::norm2_sq(mp.residual());
        assert!(
            (mp.residual_norm_sq() - exact).abs() < 1e-10,
            "incremental {} vs exact {}",
            mp.residual_norm_sq(),
            exact
        );
    }

    #[test]
    fn residual_never_increases() {
        let g = generators::er_threshold(30, 0.5, 5);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(6);
        let mut prev = mp.residual_norm_sq();
        for _ in 0..300 {
            mp.step(&mut rng);
            let cur = mp.residual_norm_sq();
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }

    #[test]
    fn converges_to_exact_pagerank() {
        let g = generators::er_threshold(30, 0.5, 7);
        let x_star = exact_pagerank(&g, 0.85);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(8);
        for _ in 0..60_000 {
            mp.step(&mut rng);
        }
        let err = vector::dist_inf(&mp.estimate(), &x_star);
        assert!(err < 1e-8, "err={err}");
    }

    #[test]
    fn trajectory_decays_exponentially_near_predicted_rate() {
        let g = generators::er_threshold(30, 0.5, 9);
        let x_star = exact_pagerank(&g, 0.85);
        let mut rng = Rng::seeded(10);
        // Average a few rounds for a stable fit.
        let mut rounds = Vec::new();
        for round in 0..20 {
            let mut mp = MatchingPursuit::new(&g, 0.85);
            let mut r = rng.fork(round);
            let tr = Trajectory::record(&mut mp, &x_star, 6000, 100, &mut r);
            rounds.push(tr.errors);
        }
        let avg = crate::util::stats::average_trajectories(&rounds);
        let per_record = crate::util::stats::decay_rate(&avg);
        let per_step = per_record.powf(1.0 / 100.0);
        let bound = crate::linalg::spectral::mp_contraction_rate(&g, 0.85);
        // Measured rate must decay at least as fast as the Prop. 2 bound
        // (the bound is conservative) and must be genuinely exponential.
        assert!(per_step < 1.0, "not decaying: {per_step}");
        assert!(
            per_step <= bound + 5e-4,
            "measured {per_step} slower than bound {bound}"
        );
    }

    #[test]
    fn step_stats_count_out_degree() {
        let g = generators::star(6); // hub degree 5, leaves 1
        let mut mp = MatchingPursuit::new(&g, 0.85);
        // Deterministically activate the hub then a leaf via step_at.
        mp.step_at(0);
        mp.step_at(3);
        // Now drive via the trait and check the stats match degrees.
        let mut rng = Rng::seeded(11);
        let stats = mp.step(&mut rng);
        assert_eq!(stats.reads, stats.writes);
        assert!(stats.reads == 1 || stats.reads == 5);
        assert_eq!(stats.activated, 1);
    }

    #[test]
    fn x_sums_toward_n() {
        // At the fixed point Σx* = N (Def. 2); partial sums approach it.
        let g = generators::er_threshold(25, 0.5, 12);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(13);
        for _ in 0..40_000 {
            mp.step(&mut rng);
        }
        let s = vector::sum(&mp.estimate());
        assert!((s - 25.0).abs() < 1e-6, "sum={s}");
    }

    #[test]
    fn zero_alpha_edge_not_allowed_but_small_alpha_works() {
        let g = generators::ring(8);
        let mut mp = MatchingPursuit::new(&g, 0.05);
        let mut rng = Rng::seeded(14);
        for _ in 0..2000 {
            mp.step(&mut rng);
        }
        let x_star = exact_pagerank(&g, 0.05);
        assert!(vector::dist_inf(&mp.estimate(), &x_star) < 1e-9);
    }

    #[test]
    fn does_not_require_in_links() {
        let g = generators::ring(4);
        let mp = MatchingPursuit::new(&g, 0.85);
        assert!(!mp.requires_in_links());
    }

    #[test]
    fn residual_weighted_converges_to_exact_pagerank() {
        // ER (dense paper graph), BA (hub-heavy) and chain (genuine
        // dangling sink): the floor keeps every page live, so the
        // importance sampler reaches the same fixed point as uniform.
        for (family, g, steps) in [
            ("er", generators::er_threshold(30, 0.5, 7), 60_000usize),
            ("ba", generators::barabasi_albert(40, 3, 7), 80_000),
            ("chain", generators::chain(20), 60_000),
        ] {
            let x_star = exact_pagerank(&g, 0.85);
            let mut rmp = ResidualMatchingPursuit::with_default_floor(&g, 0.85);
            let mut rng = Rng::seeded(8);
            for _ in 0..steps {
                rmp.step(&mut rng);
            }
            let err = vector::dist_inf(&PageRankSolver::estimate(&rmp), &x_star);
            assert!(err < 1e-8, "{family}: err={err}");
        }
    }

    #[test]
    fn residual_weighted_conserves_eq_11() {
        // B x_t + r_t = y must survive the non-uniform activation order.
        let g = generators::er_threshold(40, 0.5, 9);
        let alpha = 0.85;
        let mut rmp = ResidualMatchingPursuit::with_default_floor(&g, alpha);
        let mut rng = Rng::seeded(10);
        for _ in 0..500 {
            rmp.step(&mut rng);
        }
        let b = DenseMatrix::b_matrix(&g, alpha);
        let bx = b.matvec(&PageRankSolver::estimate(&rmp));
        for (i, v) in bx.iter().enumerate() {
            let lhs = v + rmp.inner().residual()[i];
            assert!((lhs - (1.0 - alpha)).abs() < 1e-10, "page {i}: {lhs}");
        }
    }

    #[test]
    fn residual_weighting_beats_uniform_in_activations_to_epsilon() {
        // §IV future-work 3: sampling ∝ r² allocates activations where
        // the residual mass sits, so at a fixed budget the weighted
        // error is smaller. Averaged over rounds for stability (the
        // coordinator's sampler ablation pins the same ordering).
        let g = generators::er_threshold(30, 0.5, 12);
        let x_star = exact_pagerank(&g, 0.85);
        let rounds = 5;
        let steps = 3_000;
        let (mut uni, mut wei) = (0.0, 0.0);
        for round in 0..rounds {
            let mut mp = MatchingPursuit::new(&g, 0.85);
            let mut rmp = ResidualMatchingPursuit::with_default_floor(&g, 0.85);
            let mut rng1 = Rng::seeded(40 + round);
            let mut rng2 = Rng::seeded(40 + round);
            for _ in 0..steps {
                mp.step(&mut rng1);
                rmp.step(&mut rng2);
            }
            uni += mp.error_sq_vs(&x_star);
            wei += rmp.error_sq_vs(&x_star);
        }
        assert!(
            wei < uni,
            "residual weighting must win on average: weighted {wei} vs uniform {uni}"
        );
    }

    #[test]
    fn residual_weights_track_the_residual() {
        let g = generators::er_threshold(20, 0.5, 13);
        let mut rmp = ResidualMatchingPursuit::with_default_floor(&g, 0.85);
        let mut rng = Rng::seeded(14);
        for _ in 0..2_000 {
            rmp.step(&mut rng);
        }
        let r = rmp.inner().residual().to_vec();
        for (j, &rj) in r.iter().enumerate() {
            let want = (rj * rj).max(rmp.floor());
            assert_eq!(rmp.tree.weight(j), want, "stale weight at {j}");
        }
    }

    #[test]
    #[should_panic]
    fn residual_weighted_rejects_zero_floor() {
        let g = generators::ring(4);
        ResidualMatchingPursuit::new(&g, 0.85, 0.0);
    }
}
