//! The dense backend: Jacobi sweeps `x ← αAx + (1-α)𝟙` on a
//! materialized hyperlink matrix — the host-side (f64) twin of the PJRT
//! `jacobi_chunk` artifact that [`crate::runtime::JacobiRunner`] executes
//! on-device.
//!
//! Role in the system (DESIGN.md §2): the dense engine cross-validates
//! the sparse production path on a completely different substrate —
//! dense linear algebra instead of CSR scatter. This module is what
//! [`crate::engine::SolverSpec::Dense`] builds, so the dense backend sits
//! on the same scenario axis as the sparse and sharded ones. It runs in
//! f64 and stays deterministic whether or not the PJRT client is linked;
//! the device path (f32, artifact-dependent) remains reachable through
//! `pagerank-mp rank --engine dense`, which keeps scenario results
//! reproducible across machines while the real `xla` crate is optional.
//!
//! Cost model: one `step` = one full dense sweep, O(N²) time and memory
//! — intentionally honest about what "dense" means, and the reason this
//! backend wins on small dense graphs and loses the moment N² stops
//! fitting in cache. Dangling pages take the shared implicit self-loop
//! repair via [`DenseMatrix::hyperlink`].

use crate::graph::Graph;
use crate::linalg::dense::DenseMatrix;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Dense-matrix Jacobi iteration (the engine registry's `"dense"`).
#[derive(Debug, Clone)]
pub struct DenseJacobi {
    /// Materialized hyperlink matrix `A` (column-major, like the padded
    /// artifact operand).
    a: DenseMatrix,
    alpha: f64,
    x: Vec<f64>,
    sweeps: u64,
}

impl DenseJacobi {
    pub fn new(graph: &Graph, alpha: f64) -> DenseJacobi {
        DenseJacobi {
            a: DenseMatrix::hyperlink(graph),
            alpha,
            x: vec![0.0; graph.n()],
            sweeps: 0,
        }
    }

    /// One dense sweep `x ← αAx + (1-α)𝟙`.
    pub fn sweep(&mut self) {
        let ax = self.a.matvec(&self.x);
        let c = 1.0 - self.alpha;
        for (xi, axi) in self.x.iter_mut().zip(ax) {
            *xi = self.alpha * axi + c;
        }
        self.sweeps += 1;
    }

    /// Sweeps executed so far.
    pub fn sweeps(&self) -> u64 {
        self.sweeps
    }

    /// Run until `‖x_{k+1} - x_k‖_∞ < tol` or `max_sweeps`.
    pub fn run_to_tolerance(&mut self, tol: f64, max_sweeps: usize) -> usize {
        for s in 0..max_sweeps {
            let prev = self.x.clone();
            self.sweep();
            if crate::linalg::vector::dist_inf(&prev, &self.x) < tol {
                return s + 1;
            }
        }
        max_sweeps
    }
}

impl PageRankSolver for DenseJacobi {
    fn n(&self) -> usize {
        self.x.len()
    }

    fn step(&mut self, _rng: &mut Rng) -> StepStats {
        self.sweep();
        let n = self.x.len();
        // A dense sweep touches every matrix entry: the honest cost.
        StepStats {
            reads: n * n,
            writes: n,
            activated: n,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "dense jacobi (materialized A)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::power_iteration::JacobiPowerIteration;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn dense_matches_sparse_jacobi_to_high_precision() {
        // Same iteration, two substrates (dense matvec vs CSR scatter):
        // after convergence they must agree far below 1e-10.
        let g = generators::er_threshold(40, 0.5, 301);
        let mut dense = DenseJacobi::new(&g, 0.85);
        let mut sparse = JacobiPowerIteration::new(&g, 0.85);
        dense.run_to_tolerance(1e-14, 1000);
        sparse.run_to_tolerance(1e-14, 1000);
        assert!(
            vector::dist_inf(&dense.estimate(), &sparse.estimate()) < 1e-12,
            "dense and sparse Jacobi diverged"
        );
    }

    #[test]
    fn converges_to_exact_reference() {
        let g = generators::er_threshold(30, 0.5, 302);
        let x_star = exact_pagerank(&g, 0.85);
        let mut dense = DenseJacobi::new(&g, 0.85);
        let sweeps = dense.run_to_tolerance(1e-13, 1000);
        assert!(sweeps < 1000);
        assert!(vector::dist_inf(&dense.estimate(), &x_star) < 1e-10);
    }

    #[test]
    fn dangling_page_stays_finite() {
        let g = generators::chain(12); // sink tail
        let x_star = exact_pagerank(&g, 0.85);
        let mut dense = DenseJacobi::new(&g, 0.85);
        dense.run_to_tolerance(1e-13, 2000);
        let est = dense.estimate();
        assert!(est.iter().all(|v| v.is_finite()));
        assert!(vector::dist_inf(&est, &x_star) < 1e-9);
    }

    #[test]
    fn step_stats_report_dense_cost() {
        let g = generators::ring(7);
        let mut dense = DenseJacobi::new(&g, 0.85);
        let mut rng = Rng::seeded(1);
        let st = dense.step(&mut rng);
        assert_eq!(st.reads, 49);
        assert_eq!(st.writes, 7);
        assert_eq!(st.activated, 7);
        assert_eq!(dense.sweeps(), 1);
    }
}
