//! Baseline \[12\]: Lei & Chen, *Distributed Randomized PageRank
//! Algorithm Based on Stochastic Approximation* (IEEE TAC 2015).
//!
//! SA form: when page `i` is activated at global time `t`, it moves its
//! value toward the local fixed-point target with a diminishing
//! Robbins–Monro gain:
//!
//! `x_i ← x_i + γ_t ( α Σ_{j∈in(i)} x_j/N_j + (1-α) - x_i )`
//!
//! with `γ_t = N / (N + t)` (unit initial gain, O(1/t) tail — satisfies
//! `Σγ = ∞`, `Σγ² < ∞` per page). The gain schedule is what makes SA
//! robust to update noise but also caps the convergence rate at
//! sub-exponential O(1/t) (cf. \[14\]) — the behaviour the paper under
//! reproduction contrasts against. In-neighbour reads are required, as
//! the paper's §I notes.

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// \[12\]-style stochastic-approximation iterate.
#[derive(Debug, Clone)]
pub struct LeiChen<'g> {
    graph: &'g Graph,
    alpha: f64,
    x: Vec<f64>,
    t: u64,
}

impl<'g> LeiChen<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        LeiChen {
            graph,
            alpha,
            x: vec![1.0; graph.n()], // start at the scaled uniform vector
            t: 0,
        }
    }

    /// Robbins–Monro gain at global step t.
    pub fn gain(&self) -> f64 {
        let n = self.graph.n() as f64;
        n / (n + self.t as f64)
    }

    /// Local fixed-point target for page i: `(Mx)_i` in scaled form.
    fn local_target(&self, i: usize) -> f64 {
        let mut s = 0.0;
        for &j in self.graph.inc(i) {
            s += self.x[j as usize] / self.graph.out_degree(j as usize) as f64;
        }
        if self.graph.out_degree(i) == 0 {
            // The shared implicit self-loop of a dangling page (N_i = 1):
            // its own value feeds the target, absent from the CSR
            // in-list.
            s += self.x[i];
        }
        self.alpha * s + (1.0 - self.alpha)
    }

    pub fn step_at(&mut self, i: usize) {
        let g = self.gain();
        let target = self.local_target(i);
        self.x[i] += g * (target - self.x[i]);
        self.t += 1;
    }
}

impl<'g> PageRankSolver for LeiChen<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        let i = rng.below(self.graph.n());
        let deg_in = self.graph.in_degree(i);
        self.step_at(i);
        StepStats {
            reads: deg_in,
            writes: 1,
            activated: 1,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "lei-chen SA [12]"
    }

    fn requires_in_links(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn gain_schedule() {
        let g = generators::ring(10);
        let mut lc = LeiChen::new(&g, 0.85);
        assert_eq!(lc.gain(), 1.0);
        for _ in 0..10 {
            lc.step_at(0);
        }
        assert!((lc.gain() - 0.5).abs() < 1e-12); // N/(N+t) = 10/20
    }

    #[test]
    fn fixed_point_is_stationary() {
        let g = generators::er_threshold(20, 0.5, 71);
        let x_star = exact_pagerank(&g, 0.85);
        let mut lc = LeiChen::new(&g, 0.85);
        lc.x = x_star.clone();
        for i in 0..20 {
            lc.step_at(i);
        }
        assert!(vector::dist_inf(&lc.x, &x_star) < 1e-10);
    }

    #[test]
    fn makes_progress_but_subexponential() {
        let g = generators::er_threshold(30, 0.5, 72);
        let x_star = exact_pagerank(&g, 0.85);
        let mut lc = LeiChen::new(&g, 0.85);
        let mut rng = Rng::seeded(73);
        let e0 = vector::dist_sq(&lc.estimate(), &x_star) / 30.0;
        for _ in 0..30_000 {
            lc.step(&mut rng);
        }
        let e1 = vector::dist_sq(&lc.estimate(), &x_star) / 30.0;
        assert!(e1 < 0.1 * e0, "no progress {e0} -> {e1}");
        // but far from the exponential floor MP reaches in the same budget
        assert!(e1 > 1e-10, "SA should not be at machine precision");
    }

    #[test]
    fn dangling_chain_progresses_toward_the_repaired_fixed_point() {
        // chain(12)'s sink target folds the implicit self-loop in, so
        // the repaired x* is stationary and SA contracts toward it.
        let g = generators::chain(12);
        let x_star = exact_pagerank(&g, 0.85);
        let mut stationary = LeiChen::new(&g, 0.85);
        stationary.x = x_star.clone();
        for i in 0..12 {
            stationary.step_at(i);
        }
        assert!(vector::dist_inf(&stationary.x, &x_star) < 1e-10);
        let mut lc = LeiChen::new(&g, 0.85);
        let mut rng = Rng::seeded(75);
        let e0 = vector::dist_sq(&lc.estimate(), &x_star) / 12.0;
        for _ in 0..30_000 {
            lc.step(&mut rng);
        }
        let e1 = vector::dist_sq(&lc.estimate(), &x_star) / 12.0;
        assert!(lc.estimate().iter().all(|v| v.is_finite()));
        assert!(e1 < 0.1 * e0, "no progress on the sink chain: {e0} -> {e1}");
    }

    #[test]
    fn step_stats() {
        let g = generators::star(5);
        let mut lc = LeiChen::new(&g, 0.85);
        let mut rng = Rng::seeded(74);
        let st = lc.step(&mut rng);
        assert_eq!(st.writes, 1);
        assert_eq!(st.activated, 1);
    }

    #[test]
    fn requires_in_links_flag() {
        let g = generators::ring(3);
        assert!(LeiChen::new(&g, 0.85).requires_in_links());
    }
}
