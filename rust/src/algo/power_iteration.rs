//! Centralized baselines: the Jacobi fixed-point iteration on the linear
//! system (eq. 6) and the classical Google power iteration on `M`.
//!
//! These are what the paper positions itself against ("performed by
//! Google on a regular basis using the centralized power iteration [3]
//! which requires large storage and computational power"): each sweep
//! costs O(m) and needs the full graph in one place, but converges at
//! rate α per sweep.

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// Jacobi iteration `x ← αAx + (1-α)𝟙` for the scaled system
/// `(I-αA)x = (1-α)𝟙`. One [`PageRankSolver::step`] = one full sweep.
#[derive(Debug, Clone)]
pub struct JacobiPowerIteration<'g> {
    graph: &'g Graph,
    alpha: f64,
    x: Vec<f64>,
    scratch: Vec<f64>,
    sweeps: u64,
}

impl<'g> JacobiPowerIteration<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        let n = graph.n();
        JacobiPowerIteration {
            graph,
            alpha,
            x: vec![0.0; n],
            scratch: vec![0.0; n],
            sweeps: 0,
        }
    }

    /// One full sweep; O(m). `A x` is computed by out-link scatter
    /// (`y_i += x_j / N_j` for each edge j→i) so only out-adjacency is
    /// used, matching how a crawler stores the graph. Dangling pages take
    /// the implicit self-loop repair (`A_jj = 1`), the shared convention
    /// of [`crate::linalg::sparse::BColumns`].
    pub fn sweep(&mut self) {
        let g = self.graph;
        let n = g.n();
        self.scratch.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..n {
            let deg = g.out_degree(j);
            if deg == 0 {
                self.scratch[j] += self.x[j];
                continue;
            }
            let w = self.x[j] / deg as f64;
            for &i in g.out(j) {
                self.scratch[i as usize] += w;
            }
        }
        let c = 1.0 - self.alpha;
        for i in 0..n {
            self.x[i] = self.alpha * self.scratch[i] + c;
        }
        self.sweeps += 1;
    }

    pub fn sweeps(&self) -> u64 {
        self.sweeps
    }

    /// Run until `‖x_{k+1} - x_k‖_∞ < tol` or `max_sweeps`.
    pub fn run_to_tolerance(&mut self, tol: f64, max_sweeps: usize) -> usize {
        for s in 0..max_sweeps {
            let prev = self.x.clone();
            self.sweep();
            if crate::linalg::vector::dist_inf(&prev, &self.x) < tol {
                return s + 1;
            }
        }
        max_sweeps
    }
}

impl<'g> PageRankSolver for JacobiPowerIteration<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, _rng: &mut Rng) -> StepStats {
        self.sweep();
        let m = self.graph.m();
        StepStats {
            reads: m,
            writes: self.graph.n(),
            activated: self.graph.n(),
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "jacobi power iteration (centralized)"
    }
}

/// Classical power iteration `x ← Mx` on the Google matrix
/// `M = αA + (1-α)𝟙𝟙ᵀ/N`, kept in the scaled normalization `Σx = N`.
/// Mathematically identical trajectory to Jacobi when started from
/// `x_0 = 𝟙` (since `Σx = N` is invariant under M); kept separate to
/// document and test that equivalence.
#[derive(Debug, Clone)]
pub struct GooglePowerIteration<'g> {
    graph: &'g Graph,
    alpha: f64,
    x: Vec<f64>,
    scratch: Vec<f64>,
}

impl<'g> GooglePowerIteration<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        let n = graph.n();
        GooglePowerIteration {
            graph,
            alpha,
            x: vec![1.0; n], // scaled: sums to N
            scratch: vec![0.0; n],
        }
    }

    pub fn sweep(&mut self) {
        let g = self.graph;
        let n = g.n();
        self.scratch.iter_mut().for_each(|v| *v = 0.0);
        for j in 0..n {
            let deg = g.out_degree(j);
            if deg == 0 {
                // dangling: implicit self-loop (shared BColumns convention)
                self.scratch[j] += self.x[j];
                continue;
            }
            let w = self.x[j] / deg as f64;
            for &i in g.out(j) {
                self.scratch[i as usize] += w;
            }
        }
        let total: f64 = crate::linalg::vector::sum(&self.x);
        let tele = (1.0 - self.alpha) * total / n as f64;
        for i in 0..n {
            self.x[i] = self.alpha * self.scratch[i] + tele;
        }
    }
}

impl<'g> PageRankSolver for GooglePowerIteration<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, _rng: &mut Rng) -> StepStats {
        self.sweep();
        StepStats {
            reads: self.graph.m(),
            writes: self.graph.n(),
            activated: self.graph.n(),
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.x.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.x, x_star)
    }

    fn name(&self) -> &'static str {
        "google power iteration (centralized)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    #[test]
    fn jacobi_converges_at_rate_alpha() {
        let g = generators::er_threshold(50, 0.5, 41);
        let alpha = 0.85;
        let x_star = exact_pagerank(&g, alpha);
        let mut pi = JacobiPowerIteration::new(&g, alpha);
        let mut errs = Vec::new();
        for _ in 0..100 {
            pi.sweep();
            errs.push(vector::dist_sq(&pi.estimate(), &x_star));
        }
        assert!(errs[99] < 1e-11, "err={}", errs[99]);
        // squared error contracts ~ alpha² per sweep
        let rate = crate::util::stats::decay_rate(&errs[5..80].to_vec());
        assert!(
            (rate - alpha * alpha).abs() < 0.05,
            "rate {rate} vs alpha² {}",
            alpha * alpha
        );
    }

    #[test]
    fn run_to_tolerance_stops_early() {
        let g = generators::er_threshold(30, 0.5, 42);
        let mut pi = JacobiPowerIteration::new(&g, 0.85);
        let sweeps = pi.run_to_tolerance(1e-10, 1000);
        assert!(sweeps < 200, "took {sweeps}");
        let x_star = exact_pagerank(&g, 0.85);
        assert!(vector::dist_inf(&pi.estimate(), &x_star) < 1e-8);
    }

    #[test]
    fn google_and_jacobi_agree_from_ones() {
        let g = generators::er_threshold(25, 0.5, 43);
        let mut jac = JacobiPowerIteration::new(&g, 0.85);
        // Align initial states: Jacobi starts at 0; after one sweep it is
        // (1-α)𝟙 — instead set both to 𝟙 for the comparison.
        jac.x = vec![1.0; 25];
        let mut goo = GooglePowerIteration::new(&g, 0.85);
        for _ in 0..10 {
            jac.sweep();
            goo.sweep();
        }
        // Same fixed point and, from Σx=N start, identical trajectories.
        assert!(vector::dist_inf(&jac.estimate(), &goo.estimate()) < 1e-12);
    }

    #[test]
    fn step_stats_reflect_centralized_cost() {
        let g = generators::er_threshold(20, 0.5, 44);
        let mut pi = JacobiPowerIteration::new(&g, 0.85);
        let mut rng = Rng::seeded(45);
        let st = pi.step(&mut rng);
        assert_eq!(st.reads, g.m());
        assert_eq!(st.activated, 20);
    }

    #[test]
    fn jacobi_handles_dangling_pages() {
        // sink at page 2: sweep must stay finite and converge to the
        // self-loop-repaired exact reference.
        let g = crate::graph::Graph::from_sorted_edges(3, &[(0, 1), (0, 2), (1, 0)]);
        let x_star = exact_pagerank(&g, 0.85);
        let mut pi = JacobiPowerIteration::new(&g, 0.85);
        pi.run_to_tolerance(1e-13, 2000);
        let est = pi.estimate();
        assert!(est.iter().all(|v| v.is_finite()));
        assert!(vector::dist_inf(&est, &x_star) < 1e-9);
    }

    #[test]
    fn solver_name_and_size() {
        let g = generators::ring(5);
        let pi = JacobiPowerIteration::new(&g, 0.85);
        assert_eq!(pi.n(), 5);
        assert!(pi.name().contains("centralized"));
    }
}
