//! §IV future-work 2 — **dynamic networks**: edge churn with warm restart.
//!
//! The paper notes that a centralized recomputation "typically entails
//! re-computation of the PageRank vector from scratch" when the web
//! changes. The MP formulation repairs *locally*: a change to page `p`'s
//! out-links alters only column `p` of `B` and the right-hand side not at
//! all, and the conservation law `r = y - Bx` (eq. 11) gives the exact new
//! residual with an O(N_p_old + N_p_new) fix:
//!
//! `r' = r + (B_old(:,p) - B_new(:,p)) · x_p`
//!
//! after which Algorithm 1 simply resumes from the still-nearly-converged
//! `(x, r)` pair — a *warm restart* whose advantage over cold recompute
//! the `dynamic_network` example and the ablation bench quantify.

use crate::graph::builder::{DanglingPolicy, GraphBuilder};
use crate::graph::Graph;
use crate::linalg::sparse::BColumns;
use crate::util::rng::Rng;

use super::common::StepStats;

/// A topology mutation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeEvent {
    /// Add `src -> dst`.
    Add { src: usize, dst: usize },
    /// Remove `src -> dst`.
    Remove { src: usize, dst: usize },
}

/// Matching-Pursuit PageRank over a mutable graph (owns its graph).
#[derive(Debug, Clone)]
pub struct DynamicMatchingPursuit {
    graph: Graph,
    cols: BColumns,
    alpha: f64,
    x: Vec<f64>,
    r: Vec<f64>,
    events_applied: u64,
}

impl DynamicMatchingPursuit {
    pub fn new(graph: Graph, alpha: f64) -> Self {
        let n = graph.n();
        let cols = BColumns::new(&graph, alpha);
        let y = 1.0 - alpha;
        DynamicMatchingPursuit {
            graph,
            cols,
            alpha,
            x: vec![0.0; n],
            r: vec![y; n],
            events_applied: 0,
        }
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// One Algorithm-1 activation (uniform page).
    pub fn step(&mut self, rng: &mut Rng) -> StepStats {
        let k = rng.below(self.graph.n());
        let deg = self.graph.out_degree(k);
        let num = self.cols.col_dot(&self.graph, k, &self.r);
        let coef = num / self.cols.norm_sq(k);
        self.x[k] += coef;
        self.cols.sub_scaled_col(&self.graph, k, coef, &mut self.r);
        StepStats { reads: deg, writes: deg, activated: 1 }
    }

    /// Apply a topology event with the local warm-restart repair.
    ///
    /// Returns the number of residual coordinates touched by the repair
    /// (the paper-style locality measure). The event must keep the page
    /// non-dangling — removing the last out-link is rejected.
    pub fn apply_event(&mut self, ev: EdgeEvent) -> Result<usize, String> {
        let (p, edges_after) = match ev {
            EdgeEvent::Add { src, dst } => {
                if src >= self.graph.n() || dst >= self.graph.n() {
                    return Err(format!("event endpoint out of range: {ev:?}"));
                }
                if self.graph.has_edge(src, dst) {
                    return Err(format!("edge already present: {ev:?}"));
                }
                let mut e = self.graph.edges();
                e.push((src as u32, dst as u32));
                (src, e)
            }
            EdgeEvent::Remove { src, dst } => {
                if !self.graph.has_edge(src, dst) {
                    return Err(format!("edge not present: {ev:?}"));
                }
                if self.graph.out_degree(src) == 1 {
                    return Err(format!(
                        "removing ({src},{dst}) would dangle page {src}"
                    ));
                }
                let e: Vec<(u32, u32)> = self
                    .graph
                    .edges()
                    .into_iter()
                    .filter(|&(s, d)| !(s as usize == src && d as usize == dst))
                    .collect();
                (src, e)
            }
        };

        // Old column contribution to r (scaled by x_p): r' = r + (B_old - B_new)(:,p) x_p.
        let xp = self.x[p];
        let old_col = self.cols.dense_col(&self.graph, p);

        // Rebuild graph + column geometry (only column p changed in B, but
        // the CSR is immutable — rebuild is O(m); the *algorithmic* repair
        // to the residual below is O(N_p), which is the paper-relevant
        // locality).
        let mut b = GraphBuilder::new(self.graph.n()).dangling_policy(DanglingPolicy::Error);
        b.extend(edges_after.into_iter().map(|(s, d)| (s as usize, d as usize)));
        let new_graph = b.build().map_err(|e| e.to_string())?;
        let new_cols = BColumns::new(&new_graph, self.alpha);
        let new_col = new_cols.dense_col(&new_graph, p);

        let mut touched = 0usize;
        if xp != 0.0 {
            for i in 0..self.graph.n() {
                let delta = old_col[i] - new_col[i];
                if delta != 0.0 {
                    self.r[i] += delta * xp;
                    touched += 1;
                }
            }
        }
        self.graph = new_graph;
        self.cols = new_cols;
        self.events_applied += 1;
        Ok(touched)
    }

    /// Verify eq. 11 (`Bx + r = y`) against the current topology — test
    /// and debugging hook; O(n²).
    pub fn conservation_error(&self) -> f64 {
        let b = crate::linalg::dense::DenseMatrix::b_matrix(&self.graph, self.alpha);
        let bx = b.matvec(&self.x);
        let y = 1.0 - self.alpha;
        bx.iter()
            .zip(&self.r)
            .map(|(a, r)| (a + r - y).abs())
            .fold(0.0, f64::max)
    }

    pub fn estimate(&self) -> &[f64] {
        &self.x
    }

    pub fn residual_norm_sq(&self) -> f64 {
        crate::linalg::vector::norm2_sq(&self.r)
    }

    pub fn events_applied(&self) -> u64 {
        self.events_applied
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    fn converge(dmp: &mut DynamicMatchingPursuit, steps: usize, seed: u64) {
        let mut rng = Rng::seeded(seed);
        for _ in 0..steps {
            dmp.step(&mut rng);
        }
    }

    #[test]
    fn conservation_holds_across_events() {
        let g = generators::er_threshold(25, 0.5, 121);
        let mut dmp = DynamicMatchingPursuit::new(g, 0.85);
        converge(&mut dmp, 2000, 122);
        assert!(dmp.conservation_error() < 1e-10);
        // add an edge
        let (s, d) = {
            let g = dmp.graph();
            let mut found = (0, 0);
            'outer: for s in 0..g.n() {
                for d in 0..g.n() {
                    if s != d && !g.has_edge(s, d) {
                        found = (s, d);
                        break 'outer;
                    }
                }
            }
            found
        };
        dmp.apply_event(EdgeEvent::Add { src: s, dst: d }).expect("add ok");
        assert!(
            dmp.conservation_error() < 1e-10,
            "warm-restart repair broke eq. 11: {}",
            dmp.conservation_error()
        );
        // remove it again
        dmp.apply_event(EdgeEvent::Remove { src: s, dst: d }).expect("remove ok");
        assert!(dmp.conservation_error() < 1e-10);
    }

    #[test]
    fn warm_restart_beats_cold_start() {
        let g = generators::er_threshold(30, 0.5, 123);
        let mut dmp = DynamicMatchingPursuit::new(g.clone(), 0.85);
        converge(&mut dmp, 30_000, 124);
        // mutate one edge
        let (s, d) = (0, {
            let mut d = 1;
            while dmp.graph().has_edge(0, d) {
                d += 1;
            }
            d
        });
        dmp.apply_event(EdgeEvent::Add { src: s, dst: d }).expect("add ok");
        let warm_r = dmp.residual_norm_sq();
        // cold solver on the same new topology
        let cold = DynamicMatchingPursuit::new(dmp.graph().clone(), 0.85);
        let cold_r = cold.residual_norm_sq();
        assert!(
            warm_r < 0.01 * cold_r,
            "warm {warm_r} should be far below cold {cold_r}"
        );
    }

    #[test]
    fn converges_to_new_exact_after_event() {
        let g = generators::er_threshold(20, 0.5, 125);
        let mut dmp = DynamicMatchingPursuit::new(g, 0.85);
        converge(&mut dmp, 5000, 126);
        let (s, d) = (3, {
            let mut d = 0;
            while d == 3 || dmp.graph().has_edge(3, d) {
                d += 1;
            }
            d
        });
        dmp.apply_event(EdgeEvent::Add { src: s, dst: d }).expect("add ok");
        converge(&mut dmp, 40_000, 127);
        let x_star = exact_pagerank(dmp.graph(), 0.85);
        assert!(vector::dist_inf(dmp.estimate(), &x_star) < 1e-7);
    }

    #[test]
    fn repair_touches_only_column_support() {
        let g = generators::er_threshold(30, 0.5, 128);
        let mut dmp = DynamicMatchingPursuit::new(g, 0.85);
        converge(&mut dmp, 1000, 129);
        let p = 5;
        let deg = dmp.graph().out_degree(p);
        let mut dst = 0;
        while dst == p || dmp.graph().has_edge(p, dst) {
            dst += 1;
        }
        let touched = dmp.apply_event(EdgeEvent::Add { src: p, dst }).expect("add ok");
        // Support of old+new column: at most old deg + new deg + diagonal.
        assert!(touched <= 2 * (deg + 1) + 1, "touched={touched} deg={deg}");
    }

    #[test]
    fn rejects_bad_events() {
        let g = generators::ring(5);
        let mut dmp = DynamicMatchingPursuit::new(g, 0.85);
        // duplicate add
        assert!(dmp.apply_event(EdgeEvent::Add { src: 0, dst: 1 }).is_err());
        // missing remove
        assert!(dmp.apply_event(EdgeEvent::Remove { src: 0, dst: 3 }).is_err());
        // dangling remove (ring has out-degree 1)
        assert!(dmp.apply_event(EdgeEvent::Remove { src: 0, dst: 1 }).is_err());
        // out of range
        assert!(dmp.apply_event(EdgeEvent::Add { src: 0, dst: 99 }).is_err());
        assert_eq!(dmp.events_applied(), 0);
    }
}
