//! §IV future-work 4 — **stopping criterion**: when can the iteration be
//! terminated with a *certified* ranking?
//!
//! From the Prop. 2 proof, `B(x_t - x*) = r_t`, hence
//!
//! `‖x_t - x*‖_∞ ≤ ‖x_t - x*‖₂ ≤ ‖r_t‖₂ / σ_min(B)`
//!
//! where `σ_min(B)` is the smallest singular value of the *un-normalized*
//! `B` (computed once per graph by [`crate::linalg::spectral`]). Every
//! page's true score then lies in `[x_i - ε, x_i + ε]` with
//! `ε = ‖r_t‖/σ_min(B)`; a pairwise order `x_i > x_j` is **certified**
//! when `x_i - x_j > 2ε`. Because Algorithm 1 tracks `‖r_t‖²`
//! incrementally, the test is O(1) per pair and O(N log N) for a full
//! certified prefix.

use crate::linalg::dense::DenseMatrix;
use crate::linalg::spectral::singular_values;
use crate::graph::Graph;

/// Precomputed certification context for a graph.
#[derive(Debug, Clone)]
pub struct RankingCertifier {
    sigma_min_b: f64,
}

/// Result of a certification query.
#[derive(Debug, Clone, PartialEq)]
pub struct Certification {
    /// Uniform error radius ε = ‖r‖/σ_min(B).
    pub epsilon: f64,
    /// Length of the certified top prefix of the ranking: the first `k`
    /// pages in descending score order whose pairwise gaps to the next
    /// rank all exceed 2ε.
    pub certified_prefix: usize,
    /// Ranking by descending estimate (ties by index).
    pub ranking: Vec<usize>,
}

impl RankingCertifier {
    /// O(n³) one-time spectral setup (reference scales).
    pub fn new(graph: &Graph, alpha: f64) -> Self {
        let b = DenseMatrix::b_matrix(graph, alpha);
        let sv = singular_values(&b);
        RankingCertifier { sigma_min_b: sv[0] }
    }

    /// Construct from a known σ_min(B) (e.g. cached across runs).
    pub fn from_sigma(sigma_min_b: f64) -> Self {
        assert!(sigma_min_b > 0.0);
        RankingCertifier { sigma_min_b }
    }

    pub fn sigma_min_b(&self) -> f64 {
        self.sigma_min_b
    }

    /// Error radius from the current residual norm (squared).
    pub fn epsilon(&self, residual_norm_sq: f64) -> f64 {
        residual_norm_sq.max(0.0).sqrt() / self.sigma_min_b
    }

    /// Certify the ranking of `x` given `‖r‖²`.
    pub fn certify(&self, x: &[f64], residual_norm_sq: f64) -> Certification {
        let eps = self.epsilon(residual_norm_sq);
        let ranking = crate::util::stats::ranking(x);
        let mut prefix = 0usize;
        for w in ranking.windows(2) {
            let gap = x[w[0]] - x[w[1]];
            if gap > 2.0 * eps {
                prefix += 1;
            } else {
                break;
            }
        }
        // If every consecutive gap certifies, the whole order is certified.
        if prefix + 1 == ranking.len() {
            prefix = ranking.len();
        }
        Certification {
            epsilon: eps,
            certified_prefix: prefix,
            ranking,
        }
    }

    /// Whether the top-`k` set (as a *set*, the usual search use case) is
    /// certified: gap between rank k and rank k+1 exceeds 2ε.
    pub fn top_k_certified(&self, x: &[f64], residual_norm_sq: f64, k: usize) -> bool {
        assert!(k >= 1 && k <= x.len());
        if k == x.len() {
            return true;
        }
        let eps = self.epsilon(residual_norm_sq);
        let ranking = crate::util::stats::ranking(x);
        x[ranking[k - 1]] - x[ranking[k]] > 2.0 * eps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::common::PageRankSolver;
    use crate::algo::mp::MatchingPursuit;
    use crate::graph::generators;
    use crate::linalg::solve::exact_pagerank;
    use crate::util::rng::Rng;

    #[test]
    fn epsilon_bound_is_sound() {
        // ‖x_t - x*‖∞ must actually be ≤ ε along an MP run.
        let g = generators::er_threshold(25, 0.5, 131);
        let x_star = exact_pagerank(&g, 0.85);
        let cert = RankingCertifier::new(&g, 0.85);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(132);
        for _ in 0..200 {
            for _ in 0..50 {
                mp.step(&mut rng);
            }
            let eps = cert.epsilon(mp.residual_norm_sq());
            let true_err = crate::linalg::vector::dist_inf(&mp.estimate(), &x_star);
            assert!(true_err <= eps + 1e-12, "bound violated: {true_err} > {eps}");
        }
    }

    #[test]
    fn certification_appears_as_residual_shrinks() {
        let g = generators::er_threshold(25, 0.5, 133);
        let cert = RankingCertifier::new(&g, 0.85);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(134);
        let c0 = cert.certify(&mp.estimate(), mp.residual_norm_sq());
        assert_eq!(c0.certified_prefix, 0, "nothing certifiable at t=0");
        for _ in 0..80_000 {
            mp.step(&mut rng);
        }
        let c1 = cert.certify(&mp.estimate(), mp.residual_norm_sq());
        assert!(
            c1.certified_prefix > 0,
            "after convergence some prefix must certify (eps={})",
            c1.epsilon
        );
    }

    #[test]
    fn certified_prefix_is_correct_ranking() {
        let g = generators::er_threshold(30, 0.5, 135);
        let x_star = exact_pagerank(&g, 0.85);
        let cert = RankingCertifier::new(&g, 0.85);
        let mut mp = MatchingPursuit::new(&g, 0.85);
        let mut rng = Rng::seeded(136);
        for _ in 0..60_000 {
            mp.step(&mut rng);
        }
        let c = cert.certify(&mp.estimate(), mp.residual_norm_sq());
        let true_ranking = crate::util::stats::ranking(&x_star);
        for i in 0..c.certified_prefix.min(c.ranking.len()) {
            assert_eq!(
                c.ranking[i], true_ranking[i],
                "certified rank {i} disagrees with ground truth"
            );
        }
    }

    #[test]
    fn top_k_certification() {
        let cert = RankingCertifier::from_sigma(1.0);
        let x = vec![10.0, 5.0, 4.9, 1.0];
        // ‖r‖ = 0.01 -> eps = 0.01: gap(1st,2nd)=5 > 0.02 certified;
        // gap(2nd,3rd)=0.1 > 0.02 too; gap(3rd,4th)=3.9 certified.
        assert!(cert.top_k_certified(&x, 1e-4, 1));
        assert!(cert.top_k_certified(&x, 1e-4, 2));
        // ‖r‖ = 1 -> eps = 1: gap(2nd,3rd)=0.1 < 2 not certified.
        assert!(!cert.top_k_certified(&x, 1.0, 2));
        // k = n is trivially certified.
        assert!(cert.top_k_certified(&x, 1.0, 4));
    }

    #[test]
    fn full_ranking_certified_at_tiny_residual() {
        let cert = RankingCertifier::from_sigma(0.5);
        let x = vec![3.0, 2.0, 1.0];
        let c = cert.certify(&x, 1e-20);
        assert_eq!(c.certified_prefix, 3);
        assert_eq!(c.ranking, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic]
    fn from_sigma_rejects_nonpositive() {
        RankingCertifier::from_sigma(0.0);
    }
}
