//! Baseline \[6\]: Ishii & Tempo, *Distributed Randomized Algorithms for
//! the PageRank Computation* (IEEE TAC 2010).
//!
//! Structure (as characterized by the paper under reproduction): a
//! stochastic power iteration `x(t+1) = M_{θ(t)} x(t)` over random
//! *distributed link matrices*, combined with Polyak (time) averaging —
//! the average, not the iterate, converges, and only sub-exponentially
//! (O(1/t) in mean square, cf. \[14\]).
//!
//! Our realization re-derives the construction in the *scaled* PageRank
//! normalization used throughout this repo (entries summing to N rather
//! than 1), so the trajectories are directly comparable on Fig. 1's axis:
//!
//! * when page `i` fires, the link matrix `A_i` moves `x_i` to its
//!   out-neighbours (`x_j += x_i/N_i`, then `x_i = 0`) and leaves all
//!   other pages untouched — column-stochastic, realizable with
//!   out-neighbour writes;
//! * damping mixes toward the (scaled) teleport `S x = (Σx/N)𝟙`:
//!   `x ← (1-α̂) A_i x + α̂ (Σx/N) 𝟙`, with
//!
//!   `α̂ = (1-α) / (αN + 1 - α)`
//!
//!   chosen so that `E[M_θ] x* = x*` for the paper's scaled PageRank
//!   vector — the derivation: `E[A_θ] = ((N-1)I + A)/N`, then requiring
//!   the fixed point gives the value above (coefficients verified in
//!   `expected_update_fixes_x_star`).
//!
//! The estimate returned is the running Polyak average
//! `x̄_t = (1/(t+1)) Σ_{l≤t} x(l)`, initialized (per the paper's Fig. 1)
//! at the all-one vector.

use crate::graph::Graph;
use crate::util::rng::Rng;

use super::common::{PageRankSolver, StepStats};

/// \[6\]-style distributed randomized power iteration with averaging.
#[derive(Debug, Clone)]
pub struct IshiiTempo<'g> {
    graph: &'g Graph,
    alpha_hat: f64,
    /// Raw iterate x(t) (oscillates, does not converge pointwise).
    x: Vec<f64>,
    /// Running Polyak average x̄_t (the estimator).
    avg: Vec<f64>,
    t: u64,
}

impl<'g> IshiiTempo<'g> {
    pub fn new(graph: &'g Graph, alpha: f64) -> Self {
        let n = graph.n();
        let nf = n as f64;
        let alpha_hat = (1.0 - alpha) / (alpha * nf + 1.0 - alpha);
        IshiiTempo {
            graph,
            alpha_hat,
            x: vec![1.0; n],   // paper Fig. 1: initialized with all-one vector
            avg: vec![1.0; n], // average includes x(0)
            t: 0,
        }
    }

    /// The derived damping weight α̂.
    pub fn alpha_hat(&self) -> f64 {
        self.alpha_hat
    }

    /// Raw (non-averaged) iterate — exposed for variance studies.
    pub fn raw_iterate(&self) -> &[f64] {
        &self.x
    }

    /// Apply one update with page `i` firing.
    pub fn step_at(&mut self, i: usize) {
        let g = self.graph;
        let n = g.n();
        // A_i x: page i distributes its mass to its out-neighbours. A
        // dangling i carries the shared implicit self-loop (the repaired
        // hyperlink matrix has A_ii = 1, N_i = 1), so its mass stays put
        // — the link-matrix part is the identity and only damping acts.
        if g.out_degree(i) > 0 {
            let deg = g.out_degree(i) as f64;
            let share = self.x[i] / deg;
            self.x[i] = 0.0;
            for &j in g.out(i) {
                self.x[j as usize] += share;
            }
        }
        // Damping toward the scaled teleport direction. Σx is invariant
        // under A_i (column stochastic), and under the full update too.
        let total: f64 = crate::linalg::vector::sum(&self.x);
        let tele = self.alpha_hat * total / n as f64;
        let keep = 1.0 - self.alpha_hat;
        for v in self.x.iter_mut() {
            *v = keep * *v + tele;
        }
        // Polyak average over x(0..=t+1).
        self.t += 1;
        let w = 1.0 / (self.t + 1) as f64;
        for (a, &v) in self.avg.iter_mut().zip(&self.x) {
            *a += (v - *a) * w;
        }
    }
}

impl<'g> PageRankSolver for IshiiTempo<'g> {
    fn n(&self) -> usize {
        self.graph.n()
    }

    fn step(&mut self, rng: &mut Rng) -> StepStats {
        let i = rng.below(self.graph.n());
        let deg = self.graph.out_degree(i);
        self.step_at(i);
        // Communication: the firing page pushes to its out-neighbours;
        // the teleport component is handled by [6] via a broadcast
        // primitive, which we count as one write per page.
        StepStats {
            reads: deg,
            writes: deg + self.graph.n(),
            activated: 1,
        }
    }

    fn estimate(&self) -> Vec<f64> {
        self.avg.clone()
    }

    fn error_sq_vs(&self, x_star: &[f64]) -> f64 {
        crate::linalg::vector::dist_sq(&self.avg, x_star)
    }

    fn name(&self) -> &'static str {
        "ishii-tempo [6]"
    }

    fn requires_in_links(&self) -> bool {
        // The TAC'10 scheme needs pages to combine incoming values (the
        // paper under reproduction cites this as its practical drawback).
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::solve::exact_pagerank;
    use crate::linalg::vector;

    /// E[M_θ] x* = x*: the α̂ derivation is correct.
    #[test]
    fn expected_update_fixes_x_star() {
        let g = generators::er_threshold(20, 0.5, 51);
        let n = g.n();
        let alpha = 0.85;
        let x_star = exact_pagerank(&g, alpha);
        // Average the one-step update applied deterministically at every
        // page (that's N · E[update]).
        let mut acc = vec![0.0; n];
        for i in 0..n {
            let mut it = IshiiTempo::new(&g, alpha);
            it.x = x_star.clone();
            it.step_at(i);
            vector::axpy(1.0, &it.x, &mut acc);
        }
        vector::scale(1.0 / n as f64, &mut acc);
        assert!(
            vector::dist_inf(&acc, &x_star) < 1e-10,
            "E[M]x* != x*: {}",
            vector::dist_inf(&acc, &x_star)
        );
    }

    #[test]
    fn sum_invariant() {
        let g = generators::er_threshold(30, 0.5, 52);
        let mut it = IshiiTempo::new(&g, 0.85);
        let mut rng = Rng::seeded(53);
        let s0 = vector::sum(it.raw_iterate());
        for _ in 0..200 {
            it.step(&mut rng);
            assert!((vector::sum(it.raw_iterate()) - s0).abs() < 1e-9);
        }
    }

    #[test]
    fn average_converges_slowly_toward_x_star() {
        let g = generators::er_threshold(30, 0.5, 54);
        let x_star = exact_pagerank(&g, 0.85);
        let mut it = IshiiTempo::new(&g, 0.85);
        let mut rng = Rng::seeded(55);
        let e0 = vector::dist_sq(&it.estimate(), &x_star) / 30.0;
        for _ in 0..30_000 {
            it.step(&mut rng);
        }
        let e1 = vector::dist_sq(&it.estimate(), &x_star) / 30.0;
        assert!(e1 < 0.5 * e0, "no progress: {e0} -> {e1}");
        // Sub-exponential: after 30k steps MP would be at ~1e-12·e0; [6]
        // must still be far from that (this is the paper's whole point).
        assert!(e1 > 1e-8 * e0, "suspiciously fast for an averaging scheme");
    }

    #[test]
    fn raw_iterate_does_not_converge_but_average_does() {
        let g = generators::er_threshold(25, 0.5, 56);
        let x_star = exact_pagerank(&g, 0.85);
        let mut it = IshiiTempo::new(&g, 0.85);
        let mut rng = Rng::seeded(57);
        for _ in 0..20_000 {
            it.step(&mut rng);
        }
        let raw_err = vector::dist_sq(it.raw_iterate(), &x_star);
        let avg_err = vector::dist_sq(&it.estimate(), &x_star);
        assert!(
            avg_err < 0.2 * raw_err,
            "averaging must dominate: avg {avg_err} raw {raw_err}"
        );
    }

    #[test]
    fn update_is_affine_as_documented() {
        // x' = (1-α̂)A_i x + α̂ (Σx/N) 𝟙 — check against a dense
        // materialization of A_i for one page.
        let g = generators::star(5);
        let alpha = 0.85;
        let mut it = IshiiTempo::new(&g, alpha);
        let x0: Vec<f64> = (0..5).map(|i| (i + 1) as f64).collect();
        it.x = x0.clone();
        it.step_at(0); // hub fires: distributes to 4 leaves
        let mut ai_x = x0.clone();
        let share = x0[0] / 4.0;
        ai_x[0] = 0.0;
        for j in 1..5 {
            ai_x[j] += share;
        }
        let total: f64 = ai_x.iter().sum();
        let ah = it.alpha_hat();
        let want: Vec<f64> = ai_x.iter().map(|&v| (1.0 - ah) * v + ah * total / 5.0).collect();
        assert!(vector::dist_inf(it.raw_iterate(), &want) < 1e-12);
    }

    #[test]
    fn alpha_hat_formula() {
        let g = generators::ring(10);
        let it = IshiiTempo::new(&g, 0.85);
        let want = 0.15 / (0.85 * 10.0 + 0.15);
        assert!((it.alpha_hat() - want).abs() < 1e-15);
    }

    #[test]
    fn dangling_chain_stays_finite_and_contracts() {
        // chain(12) ends in a genuine sink. The implicit self-loop keeps
        // the link matrix column-stochastic (mass parks at the sink), so
        // the iterate stays finite and the average still contracts
        // toward the repaired-matrix fixed point.
        let g = generators::chain(12);
        let x_star = exact_pagerank(&g, 0.85);
        let mut it = IshiiTempo::new(&g, 0.85);
        let mut rng = Rng::seeded(58);
        let e0 = vector::dist_sq(&it.estimate(), &x_star);
        for _ in 0..20_000 {
            it.step(&mut rng);
        }
        assert!(it.estimate().iter().all(|v| v.is_finite()), "sink poisoned the iterate");
        let e1 = vector::dist_sq(&it.estimate(), &x_star);
        assert!(e1 < 0.5 * e0, "no progress on the sink chain: {e0} -> {e1}");
    }

    #[test]
    fn declares_in_link_requirement() {
        let g = generators::ring(4);
        assert!(IshiiTempo::new(&g, 0.85).requires_in_links());
    }

    #[allow(dead_code)]
    fn dense_check_helper(_m: &DenseMatrix) {}
}
